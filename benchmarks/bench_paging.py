"""spring-pages benchmark: concurrent capacity of the paged COW KV pool
vs the slot-monolithic pool at equal physical page bytes.

A heavy-tailed prompt trace (mostly short prompts, a few long, half
sharing a common prefix) is replayed through both engines:

  * monolithic: ``MONO_SLOTS`` slots x ``MAX_LEN`` rows of packed
    storage — the physical byte budget;
  * paged: the *same* physical budget expressed as
    ``MONO_SLOTS * ceil(MAX_LEN / PAGE_TOKENS)`` pages, density-aware
    admission overcommitting logical frames against it, prefix blocks
    shared copy-on-write.

The capacity metric is ``peak_active`` — the most requests concurrently
resident — which the monolithic pool caps at its slot count while the
paged pool admits by measured packed bits (a page costs
``20*density + 1`` bits/elem, so sub-dense traffic packs >1 logical
page per physical page) and by page-granular allocation (a short prompt
holds 2 pages, not max_len rows).

Rows (name, us_per_call, derived[, impl]):

  paging.engine.<arch>.peak_active_paged   derived = paged peak residents
  paging.engine.<arch>.peak_active_mono    derived = monolithic peak
  paging.engine.<arch>.capacity_x          derived = paged / mono peak —
                                           the --smoke gate (>= 1.5x)
  paging.engine.<arch>.tok_s               derived = paged decode tokens/s
  paging.engine.<arch>.prefix_hits         derived = blocks adopted shared
  paging.engine.<arch>.cow_copies          derived = COW page forks
  paging.engine.<arch>.spills              derived = spill/resume round trips
  paging.engine.<arch>.page_utilization    derived = peak live bits /
                                           physical budget

``--smoke`` (the CI paging job) additionally asserts the paged tokens
are bit-identical to the monolithic pool's, everything finite, and no
page leaked at drain.
"""

from __future__ import annotations

import sys

import jax

ARCH = "llama3.2-1b"
MODE = "quant_sparse"
PAGE_TOKENS = 8
MONO_SLOTS = 2
MAX_LEN = 48
GEN = 5
#: equal physical bytes: the monolithic pool's dense-equivalent page count
NUM_PAGES = MONO_SLOTS * (MAX_LEN // PAGE_TOKENS)
PAGED_SLOTS = 8
OVERCOMMIT = 2.0
#: heavy-tailed prompt lengths; even indices share an 8-token prefix
TRACE_LENS = (6, 7, 6, 9, 30, 6, 8, 7, 6, 22)

#: Canonical RunSpec surface for benchmarks/run.py --json.
SPEC_RUN = "serve"
SPEC_OVERRIDES = {
    "arch.id": ARCH,
    "numerics.mode": MODE,
    "shape.gen": GEN,
    "serving.slots": PAGED_SLOTS,
    "serving.queue": len(TRACE_LENS),
    "serving.pages": True,
    "serving.page_tokens": PAGE_TOKENS,
    "serving.num_pages": NUM_PAGES,
    "serving.overcommit": OVERCOMMIT,
}

_SETUP = None


def _setup():
    """Model + trace, built once per process (both engines replay it)."""
    global _SETUP
    if _SETUP is not None:
        return _SETUP
    from repro.configs import get_arch
    from repro.launch.serve import serving_config
    from repro.models.lm import lm_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.runtime.train import StepConfig

    view = get_arch(ARCH).view(reduced=True)
    step_cfg = StepConfig(spring=serving_config(MODE),
                          optimizer=OptimizerConfig())
    params = lm_init(jax.random.PRNGKey(0), view.config)
    key = jax.random.PRNGKey(11)
    vocab = view.config.vocab
    prefix = [int(t) for t in
              jax.random.randint(jax.random.fold_in(key, 999), (8,), 0, vocab)]
    prompts = []
    for i, n in enumerate(TRACE_LENS):
        toks = [int(t) for t in
                jax.random.randint(jax.random.fold_in(key, i), (n,), 0, vocab)]
        if i % 2 == 0:  # the shared-prefix mix
            toks = (prefix + toks)[:max(n, len(prefix) + 1)]
        prompts.append(toks)
    _SETUP = (view, step_cfg, params, prompts)
    return _SETUP


def _replay(paged: bool) -> dict:
    from repro.serving.engine import ServingEngine
    from repro.serving.paging import PagedServingEngine

    view, step_cfg, params, prompts = _setup()
    if paged:
        eng = PagedServingEngine(
            view, step_cfg, params=params, n_slots=PAGED_SLOTS,
            max_len=MAX_LEN, page_tokens=PAGE_TOKENS, num_pages=NUM_PAGES,
            overcommit=OVERCOMMIT)
    else:
        eng = ServingEngine(view, step_cfg, params=params,
                            n_slots=MONO_SLOTS, max_len=MAX_LEN)
    for i, p in enumerate(prompts):
        eng.submit_prompt(p, GEN, seed=100 + i)
    out = eng.run()
    out["_engine"] = eng
    return out


def _measure() -> tuple[list[tuple], dict, dict]:
    from repro.kernels import registry

    mono = _replay(paged=False)
    paged = _replay(paged=True)
    impl = registry.resolve("kv_pack", _count=False).name
    pg = paged["paging"]
    step_us = paged["decode_s"] / max(paged["decode_steps"], 1) * 1e6
    mono_us = mono["decode_s"] / max(mono["decode_steps"], 1) * 1e6
    ratio = pg["peak_active"] / max(mono["peak_active"], 1)
    rows = [
        (f"paging.engine.{ARCH}.peak_active_paged", step_us,
         pg["peak_active"], impl),
        (f"paging.engine.{ARCH}.peak_active_mono", mono_us,
         mono["peak_active"], impl),
        (f"paging.engine.{ARCH}.capacity_x", step_us, ratio, impl),
        (f"paging.engine.{ARCH}.tok_s", step_us, paged["tokens_per_s"], impl),
        (f"paging.engine.{ARCH}.prefix_hits", step_us, pg["prefix_hits"], impl),
        (f"paging.engine.{ARCH}.cow_copies", step_us, pg["cow_copies"], impl),
        (f"paging.engine.{ARCH}.spills", step_us, pg["spills"], impl),
        (f"paging.engine.{ARCH}.page_utilization", step_us,
         pg["peak_page_utilization"], impl),
    ]
    return rows, mono, paged


def rows() -> list[tuple]:
    return _measure()[0]


def smoke() -> int:
    """CI gate: at equal physical page bytes the paged pool must hold
    >= 1.5x the monolithic pool's concurrent requests, bit-identically."""
    import numpy as np

    bench_rows, mono, paged = _measure()
    pg = paged["paging"]
    failures = []
    if not (mono["finite"] and paged["finite"]):
        failures.append("non-finite decode logits")
    ratio = pg["peak_active"] / max(mono["peak_active"], 1)
    if ratio < 1.5:
        failures.append(
            f"paged concurrency {pg['peak_active']} vs monolithic "
            f"{mono['peak_active']} = {ratio:.2f}x < 1.5x at equal "
            f"physical bytes ({NUM_PAGES} pages x {PAGE_TOKENS} tokens)")
    mono_toks = {r["rid"]: r["tokens"] for r in mono["per_request"]}
    paged_toks = {r["rid"]: r["tokens"] for r in paged["per_request"]}
    if mono_toks != paged_toks:
        bad = [rid for rid in mono_toks if mono_toks[rid] != paged_toks.get(rid)]
        failures.append(f"paged tokens diverged from monolithic: rids {bad}")
    eng = paged["_engine"]
    if eng.alloc.n_allocated != 0:
        failures.append(f"page leak: {eng.alloc.n_allocated} frames live "
                        f"after drain")
    if pg["resumes"] != pg["spills"]:
        failures.append(f"{pg['spills']} spills but {pg['resumes']} resumes")
    if pg["prefix_hits"] < 1:
        failures.append("shared-prefix trace produced no prefix-cache hits")
    if not np.isfinite(pg["peak_page_utilization"]):
        failures.append("non-finite page utilization")
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in bench_rows:
        print(f"{name},{us:.2f},{derived:.6g},{impl}")
    for f in failures:
        print(f"PAGING SMOKE FAILURE: {f}", file=sys.stderr)
    return 1 if failures else 0


def main() -> None:
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in rows():
        print(f"{name},{us:.2f},{derived:.6g},{impl}")


if __name__ == "__main__":
    main()
