"""Paper Fig. 5: binary-mask compression.  Reproduces the worked example
(16 elems, 6 nnz, 16-bit values -> 2.29x) exactly, then measures
compression ratio and encode wall-time across sparsity levels at the
paper's Q4.16 (21 bits incl. mask).

Rows: us_per_call = mask_encode wall time; derived = compression ratio.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.masking import compression_ratio, mask_encode


def _time(fn, *args, iters: int = 20) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rows() -> list[tuple[str, float, float]]:
    out = []
    # the paper's worked example: 16 elements, 6 non-zeros, 16-bit values
    example = jnp.zeros((16,)).at[jnp.array([1, 3, 6, 9, 12, 15])].set(1.0)
    mv = mask_encode(example)
    out.append(("fig5_example_16elem_6nnz_16bit", 0.0, float(compression_ratio(mv, 16))))

    enc = jax.jit(mask_encode)
    key = jax.random.PRNGKey(0)
    for sparsity in (0.3, 0.5, 0.7, 0.9):
        x = jax.random.normal(key, (1 << 20,))
        x = x * (jax.random.uniform(jax.random.fold_in(key, 1), x.shape) > sparsity)
        mv = enc(x)
        us = _time(enc, x)
        out.append((f"fig5_ratio_s{int(sparsity*100)}_q4.16", us,
                    float(compression_ratio(mv, 21))))
    return out
