"""Paper Figs. 11-16: SPRING vs GTX 1080 Ti across the seven CNNs —
performance (11/12), reciprocal power (13/14), energy efficiency (15/16)
for training and inference, from the analytical model (perfmodel/).

Rows: name, us_per_call = modeled SPRING batch latency (us),
derived = the figure's ratio (speedup | power reduction | energy eff).
"""

from __future__ import annotations

from repro.models.cnn import PAPER_CNNS
from repro.perfmodel.spring_model import evaluate_cnn, geomean

PAPER_GEOMEANS = {
    ("train", "speedup"): 15.6,
    ("train", "power_reduction"): 4.2,
    ("train", "energy_eff"): 66.0,
    ("inference", "speedup"): 15.5,
    ("inference", "power_reduction"): 4.5,
    ("inference", "energy_eff"): 69.1,
}

_FIG = {
    ("train", "speedup"): "fig11_perf_train",
    ("inference", "speedup"): "fig12_perf_infer",
    ("train", "power_reduction"): "fig13_power_train",
    ("inference", "power_reduction"): "fig14_power_infer",
    ("train", "energy_eff"): "fig15_energy_train",
    ("inference", "energy_eff"): "fig16_energy_infer",
}


def rows() -> list[tuple[str, float, float]]:
    out = []
    for training in (True, False):
        phase = "train" if training else "inference"
        results = [evaluate_cnn(d, training=training) for d in PAPER_CNNS.values()]
        for metric in ("speedup", "power_reduction", "energy_eff"):
            fig = _FIG[(phase, metric)]
            for r in results:
                out.append((f"{fig}.{r['cnn']}", r["spring_time_s"] * 1e6, r[metric]))
            gm = geomean(r[metric] for r in results)
            out.append((f"{fig}.GEOMEAN", 0.0, gm))
            out.append((f"{fig}.PAPER_GEOMEAN", 0.0, PAPER_GEOMEANS[(phase, metric)]))
    return out
