"""The paper's §6 batch-level assumption (after Gupta et al. 2015):
fixed-point Q4.16 training with stochastic rounding converges like fp32,
while round-to-nearest fixed-point degrades.  Trained end-to-end on the
synthetic LM task (reduced llama3.2-1b, same data/steps/seed across arms).

Rows: us_per_call = mean step wall time; derived = final loss.
"""

from __future__ import annotations

import time

from repro.launch.train import train_loop

STEPS = 120

# No SPEC_RUN/SPEC_OVERRIDES here: the two arms run *different* numerics
# (dense vs quant+SR), so one suite-level spec_hash would misattribute
# whichever arm it doesn't describe.  benchmarks/run.py only stamps rows
# of suites that declare a spec they actually run (bench_serving does).


def rows() -> list[tuple[str, float, float]]:
    out = []
    arms = [
        ("sr_train.fp32_baseline", dict(mode="dense")),
        ("sr_train.q4.16_stochastic", dict(mode="quant", fixed_point_weights=True)),
    ]
    for name, kw in arms:
        t0 = time.perf_counter()
        res = train_loop("llama3.2-1b", reduced=True, steps=STEPS, batch=8,
                         seq=64, lr=3e-3, **kw)
        us = (time.perf_counter() - t0) / STEPS * 1e6
        out.append((name, us, res["last_loss"]))
    return out
