"""Kernel microbenchmarks (CPU wall time of the registry-resolved paths +
interpret-mode functional checks; TPU perf comes from the §Roofline
dry-run, not here).

Rows: us_per_call = wall time; derived = a kernel-specific figure of merit
(tile-skip fraction, GFLOP count, rel-err vs oracle); impl = the impl the
kernel registry resolved for the call, so BENCH trajectories are
attributable to a backend.

``--smoke`` sweeps every registered (op, impl) pair runnable on the
current backend through the registry's example inputs and cross-checks
each against the op's oracle — the CI kernel-parity job runs this, so a
kernel cannot ship without registering.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.masked_matmul.backward import (
    masked_matmul_dw,
    masked_matmul_dx,
)
from repro.kernels.masked_matmul.ops import masked_matmul, tile_skip_fraction
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.stochastic_round.ops import stochastic_round


def _time(fn, *args, iters: int = 10, **kw) -> float:
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def _resolved(op: str) -> str:
    # planning lookup for row attribution; the timed call itself counts
    return registry.resolve(op, _count=False).name


def rows() -> list[tuple]:
    out = []
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (512, 1024))
    us = _time(stochastic_round, x, jnp.uint32(1))
    out.append(("kernel.stochastic_round.512x1024", us, x.size / 1e6,
                _resolved("stochastic_round")))

    # block-sparse fixed-point matmul: 50% of 128-tiles pruned
    m = k = n = 512
    a = jnp.round(jax.random.normal(key, (m, k)) * 64) / 256
    w = jnp.round(jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 64) / 256
    a = a.at[:256, :256].set(0.0)
    w = w.at[256:, 256:].set(0.0)
    us = _time(masked_matmul, a, w, jnp.uint32(3))
    skip = float(tile_skip_fraction(a, w))
    out.append(("kernel.masked_matmul.512cube", us, skip,
                _resolved("masked_matmul")))

    # the backward GEMMs of the same layer: a ReLU-masked cotangent (top
    # half of the 128-tiles zeroed) against the sparse weights/activation —
    # derived = measured backward tile-skip fraction
    g = jnp.round(jax.random.normal(jax.random.fold_in(key, 8), (m, n)) * 64) / 256
    g = g.at[:256, :].set(0.0)
    us = _time(masked_matmul_dx, g, w)
    out.append(("kernel.masked_matmul_dx.512cube", us,
                float(tile_skip_fraction(g, w.T)),
                _resolved("masked_matmul_dx")))
    us = _time(masked_matmul_dw, a, g)
    out.append(("kernel.masked_matmul_dw.512cube", us,
                float(tile_skip_fraction(a.T, g)),
                _resolved("masked_matmul_dw")))

    q = jax.random.normal(key, (1, 4, 512, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 512, 64))
    us = _time(flash_attention, q, kk, v, causal=True)
    flops = 4 * 1 * 4 * 512 * 512 * 64 / 2  # causal half
    out.append(("kernel.flash_attention.b1h4s512", us, flops / 1e9,
                _resolved("flash_attention")))

    xs = jax.random.normal(key, (2, 512, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 4), (2, 512, 8)))
    aa = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 5), (8,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(key, 6), (2, 512, 2, 64)) / 8
    c = jax.random.normal(jax.random.fold_in(key, 7), (2, 512, 2, 64)) / 8
    us = _time(ssd_scan, xs, dt, aa, b, c)
    ref = ssd_scan(xs, dt, aa, b, c, impl="ref")
    got = ssd_scan(xs, dt, aa, b, c)
    rel = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    out.append(("kernel.ssd_scan.b2s512h8", us, rel, _resolved("ssd_scan")))
    return out


# ---------------------------------------------------------------------------
# Registry parity smoke: the CI sweep over every registered (op, impl).
# ---------------------------------------------------------------------------


def smoke_rows() -> tuple[list[tuple], list[str]]:
    """One row per registered (op, impl) pair runnable here, parity-checked
    against the op's oracle on its registered example inputs.  A failing
    pair does not abort the sweep: it is reported in the returned failure
    list (and its row carries derived=nan)."""
    out = []
    failures = []
    for op, impl in registry.parity_pairs():
        spec = registry.op_spec(op)
        if spec.examples is None:
            continue
        cases = spec.examples()
        worst = 0.0
        t0 = time.perf_counter()
        try:
            for case in cases:
                args, kwargs = case[0], case[1]
                case_cmp = case[2] if len(case) > 2 else None
                oracle_fn = registry.impls(op)[spec.oracle].fn
                impl_fn = registry.impls(op)[impl].fn
                want = oracle_fn(*args, **kwargs)
                got = impl_fn(*args, **kwargs)
                worst = max(worst, registry.compare_outputs(op, got, want, case_cmp))
        except Exception as e:  # parity violation or impl crash
            failures.append(f"{op}.{impl}: {e}")
            worst = float("nan")
        us = (time.perf_counter() - t0) / max(len(cases), 1) * 1e6
        out.append((f"kernel.parity.{op}.{impl}", us, worst, impl))
    return out, failures


def main() -> None:
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived,impl")
    failures = []
    if smoke:
        smoke_out, failures = smoke_rows()
        if not smoke_out:
            failures.append("registry reports no parity pairs — registration broken?")
        for name, us, derived, impl in smoke_out:
            print(f"{name},{us:.2f},{derived:.6g},{impl}")
    else:
        for name, us, derived, impl in rows():
            print(f"{name},{us:.2f},{derived:.6g},{impl}")
    for f in failures:
        print(f"PARITY FAILURE: {f}", file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
