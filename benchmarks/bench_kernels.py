"""Kernel microbenchmarks (CPU wall time of the jnp paths + interpret-mode
functional checks; TPU perf comes from the §Roofline dry-run, not here).

Rows: us_per_call = wall time; derived = a kernel-specific figure of merit
(tile-skip fraction, GFLOP count, rel-err vs oracle).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.masked_matmul.ops import masked_matmul, tile_skip_fraction
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.stochastic_round.ops import stochastic_round


def _time(fn, *args, iters: int = 10, **kw) -> float:
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def rows() -> list[tuple[str, float, float]]:
    out = []
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (512, 1024))
    us = _time(stochastic_round, x, jnp.uint32(1), impl="ref")
    out.append(("kernel.stochastic_round.512x1024", us, x.size / 1e6))

    # block-sparse fixed-point matmul: 50% of 128-tiles pruned
    m = k = n = 512
    a = jnp.round(jax.random.normal(key, (m, k)) * 64) / 256
    w = jnp.round(jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 64) / 256
    a = a.at[:256, :256].set(0.0)
    w = w.at[256:, 256:].set(0.0)
    us = _time(masked_matmul, a, w, jnp.uint32(3), impl="ref")
    skip = float(tile_skip_fraction(a, w))
    out.append(("kernel.masked_matmul.512cube", us, skip))

    q = jax.random.normal(key, (1, 4, 512, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 512, 64))
    us = _time(flash_attention, q, kk, v, causal=True, impl="ref")
    flops = 4 * 1 * 4 * 512 * 512 * 64 / 2  # causal half
    out.append(("kernel.flash_attention.b1h4s512", us, flops / 1e9))

    xs = jax.random.normal(key, (2, 512, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 4), (2, 512, 8)))
    aa = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 5), (8,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(key, 6), (2, 512, 2, 64)) / 8
    c = jax.random.normal(jax.random.fold_in(key, 7), (2, 512, 2, 64)) / 8
    us = _time(ssd_scan, xs, dt, aa, b, c, impl="jnp")
    ref = ssd_scan(xs, dt, aa, b, c, impl="ref")
    got = ssd_scan(xs, dt, aa, b, c, impl="jnp")
    rel = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    out.append(("kernel.ssd_scan.b2s512h8", us, rel))
    return out
