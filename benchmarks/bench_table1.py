"""Paper Table 1: the SPRING design point, echoed with derived peaks so
the analytical model's constants are auditable.

Rows: derived = the design parameter value.
"""

from __future__ import annotations

from repro.perfmodel.spring_model import GPU_1080TI, SPRING_DESIGN


def rows() -> list[tuple[str, float, float]]:
    d = SPRING_DESIGN
    return [
        ("table1.clock_mhz", 0.0, d.clock_hz / 1e6),
        ("table1.n_pe", 0.0, d.n_pe),
        ("table1.mac_lanes_per_pe", 0.0, d.mac_lanes_per_pe),
        ("table1.muls_per_lane", 0.0, d.muls_per_lane),
        ("table1.peak_tmacs", 0.0, d.peak_macs / 1e12),
        ("table1.weight_buffer_mb", 0.0, d.weight_buffer_bytes / 1e6),
        ("table1.act_buffer_mb", 0.0, d.act_buffer_bytes / 1e6),
        ("table1.mask_buffer_mb", 0.0, d.mask_buffer_bytes / 1e6),
        ("table1.il_bits", 0.0, d.il_bits),
        ("table1.fl_bits", 0.0, d.fl_bits),
        ("table1.rram_tb_per_s", 0.0, d.mem_bw / 1e12),
        ("table1.gpu_peak_tflops", 0.0, GPU_1080TI.peak_flops / 1e12),
        ("table1.gpu_mem_gb_per_s", 0.0, GPU_1080TI.mem_bw / 1e9),
    ]
