"""spring-survive benchmark: snapshot/restore/rescale cost + chaos seal.

A short continuous-batching run is interrupted mid-flight and the
elastic machinery is timed:

  * ``snapshot`` — build the versioned artifact (packed pool bits +
    scheduler/ledger/sampling state) and serialize it to one ``.npz``;
  * ``restore`` — rebuild a live engine from the loaded artifact;
  * ``rescale`` — shrink the pool below occupancy (spill path) and grow
    it back, requests surviving;
  * ``chaos`` — a fixed kill/roundtrip/rescale schedule replayed through
    :class:`repro.serving.elastic.ChaosHarness`, compared token-for-token
    against the uninterrupted oracle.

Rows (name, us_per_call, derived[, impl]):

  elastic.engine.<arch>.snapshot_us   derived = artifact bytes on disk
  elastic.engine.<arch>.restore_us    derived = 1.0 iff the restored
                                      engine finished with oracle tokens
  elastic.engine.<arch>.rescale_us    derived = spill/resume round trips
  elastic.engine.<arch>.chaos_match   derived = 1.0 iff every request
                                      matched the oracle bit-exactly

``--smoke`` (the CI elastic job) replays the chaos schedule on BOTH pool
backends and fails on any token divergence, lost request, or snapshot
that does not round-trip byte-exactly.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import jax

ARCH = "llama3.2-1b"
MODE = "quant_sparse"
SLOTS = 2
MAX_LEN = 48
GEN = 5
N_PROMPTS = 3

#: Canonical RunSpec surface for benchmarks/run.py --json.
SPEC_RUN = "serve"
SPEC_OVERRIDES = {
    "arch.id": ARCH,
    "numerics.mode": MODE,
    "shape.gen": GEN,
    "serving.slots": SLOTS,
    "serving.queue": N_PROMPTS,
    "serving.snapshot_every": 2,
}

_SETUP = None


def _setup():
    global _SETUP
    if _SETUP is not None:
        return _SETUP
    from repro.configs import get_arch
    from repro.launch.serve import serving_config
    from repro.models.lm import lm_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.runtime.train import StepConfig

    view = get_arch(ARCH).view(reduced=True)
    step_cfg = StepConfig(spring=serving_config(MODE),
                          optimizer=OptimizerConfig())
    params = lm_init(jax.random.PRNGKey(0), view.config)
    key = jax.random.PRNGKey(7)
    prompts = [[int(t) for t in
                jax.random.randint(jax.random.fold_in(key, i), (6 + i,), 0,
                                   view.config.vocab)]
               for i in range(N_PROMPTS)]
    _SETUP = (view, step_cfg, params, prompts)
    return _SETUP


def _engine(paged: bool):
    from repro.serving.engine import ServingEngine
    from repro.serving.paging import PagedServingEngine

    view, step_cfg, params, prompts = _setup()
    kw = dict(params=params, n_slots=SLOTS, max_len=MAX_LEN,
              spec_hash="bench-elastic")
    eng = (PagedServingEngine(view, step_cfg, page_tokens=8, **kw)
           if paged else ServingEngine(view, step_cfg, **kw))
    for i, p in enumerate(prompts):
        eng.submit_prompt(p, GEN, seed=100 + i)
    return eng


def _tokens(out):
    return [r["tokens"] for r in sorted(out["per_request"],
                                        key=lambda r: r["rid"])]


def _chaos_events():
    from repro.serving.elastic import ChaosEvent

    return [ChaosEvent(1, "snapshot"),
            ChaosEvent(2, "kill"),
            ChaosEvent(4, "roundtrip"),
            ChaosEvent(6, "rescale", slots=SLOTS + 1),
            ChaosEvent(8, "rewind")]


def _measure(paged: bool = False) -> tuple[list[tuple], dict]:
    from repro.kernels import registry
    from repro.serving.elastic import (ChaosHarness, load_snapshot,
                                       save_snapshot)

    impl = registry.resolve("kv_pack", _count=False).name
    tag = "paged" if paged else ARCH

    # oracle: the uninterrupted run
    eng = _engine(paged)
    snap0 = eng.snapshot()
    oracle = _tokens(eng.run())

    # snapshot cost mid-flight (warm jits: reuse the same engine)
    eng.restore(snap0)
    for _ in range(3):
        eng.step()
    t0 = time.perf_counter()
    snap = eng.snapshot()
    fd, path = tempfile.mkstemp(suffix=".npz")
    os.close(fd)
    save_snapshot(snap, path)
    snapshot_us = (time.perf_counter() - t0) * 1e6
    snapshot_bytes = os.path.getsize(path)

    # restore cost + exactness of the remaining tokens
    loaded = load_snapshot(path)
    os.unlink(path)
    t0 = time.perf_counter()
    eng.restore(loaded)
    restore_us = (time.perf_counter() - t0) * 1e6
    restore_ok = 1.0 if _tokens(eng.run()) == oracle else 0.0

    # rescale cost: shrink below occupancy (spills), grow back
    eng.restore(snap0)
    for _ in range(2):
        eng.step()
    t0 = time.perf_counter()
    eng.rescale(1)
    eng.rescale(SLOTS + 1)
    rescale_us = (time.perf_counter() - t0) * 1e6 / 2
    spills = eng.sched.n_spills
    rescale_ok = _tokens(eng.run()) == oracle

    # chaos: fixed failure schedule vs the oracle
    eng.restore(snap0)
    t0 = time.perf_counter()
    out = ChaosHarness(eng, _chaos_events(), max_steps=500).run()
    chaos_us = (time.perf_counter() - t0) * 1e6
    chaos_ok = 1.0 if (_tokens(out) == oracle and out["finite"]) else 0.0

    rows = [
        (f"elastic.engine.{tag}.snapshot_us", snapshot_us, snapshot_bytes,
         impl),
        (f"elastic.engine.{tag}.restore_us", restore_us, restore_ok, impl),
        (f"elastic.engine.{tag}.rescale_us", rescale_us, float(spills), impl),
        (f"elastic.engine.{tag}.chaos_match", chaos_us, chaos_ok, impl),
    ]
    detail = {"oracle": oracle, "restore_ok": bool(restore_ok),
              "rescale_ok": rescale_ok, "chaos_ok": bool(chaos_ok),
              "snapshot_bytes": snapshot_bytes, "elastic": out["elastic"]}
    return rows, detail


def rows() -> list[tuple]:
    return _measure(paged=False)[0]


def smoke() -> int:
    """CI gate: the chaos schedule (kill / on-disk round-trip / shrink-
    grow rescale / rewind) must leave every request bit-identical to the
    uninterrupted oracle on both pool backends."""
    failures = []
    all_rows = []
    for paged in (False, True):
        bench_rows, detail = _measure(paged=paged)
        all_rows += bench_rows
        tag = "paged" if paged else "monolithic"
        for check in ("restore_ok", "rescale_ok", "chaos_ok"):
            if not detail[check]:
                failures.append(f"{tag}: {check} diverged from the oracle")
        if detail["snapshot_bytes"] <= 0:
            failures.append(f"{tag}: empty snapshot artifact")
        el = detail["elastic"]
        if el["n_spills"] != el["n_resumes"]:
            failures.append(f"{tag}: {el['n_spills']} spills but "
                            f"{el['n_resumes']} resumes")
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in all_rows:
        print(f"{name},{us:.2f},{derived:.6g},{impl}")
    for f in failures:
        print(f"ELASTIC SMOKE FAILURE: {f}", file=sys.stderr)
    return 1 if failures else 0


def main() -> None:
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in rows():
        print(f"{name},{us:.2f},{derived:.6g},{impl}")


if __name__ == "__main__":
    main()
