"""Memstash microbenches: compression ratio + stash/restore throughput vs
activation sparsity, the wire-vs-formula cross-check, and the end-to-end
gradient overhead of the stash policy on a small conv stack.

Rows:
  memstash_compress_sNN   us = jitted compress() wall time (1M f32 elems at
                          NN% sparsity); derived = dense-fp32 / wire-bytes
                          compression ratio at value_bits=20.
  memstash_restore_sNN    us = jitted decompress() wall time; derived = max
                          |roundtrip error| (must be 0: bit-exact).
  memstash_formula_s50    derived = measured wire bytes / analytical
                          ``20*d + 1`` bits/elem formula (≈ 1.0).
  memstash_grad_stash     us = jitted grad step of a 2-conv stack under
                          policy "stash"; derived = time ratio vs "none"
                          (the recompute cost memstash pays for memory).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.memstash import (
    MemstashConfig,
    compress,
    decompress,
    formula_bits_per_elem,
    wire_bytes,
)
from repro.models.cnn import ParamStore, conv
from repro.models.layers import SpringContext


def _time(fn, *args, iters: int = 20) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _sparse(key, n: int, sparsity: float) -> jax.Array:
    x = jax.random.normal(key, (n,))
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > sparsity
    return x * keep


def _grad_time(policy: str) -> float:
    scfg = MemstashConfig(policy=policy) if policy != "none" else None
    ctx = SpringContext(memstash=scfg)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 32, 8))

    def stack(store, x_):
        h = conv(store, ctx, "c0", x_, 16, k=3)
        h = conv(store, ctx, "c1", h, 16, k=3)
        return jnp.sum(h * h)

    init_store = ParamStore(key)
    stack(init_store, x)  # init-on-first-touch materializes params
    params = init_store.params

    def net(p, x_):
        return stack(ParamStore(key, p), x_)

    g = jax.jit(jax.grad(net))
    return _time(g, params, x, iters=10)


def rows() -> list[tuple[str, float, float]]:
    out = []
    n = 1 << 20
    key = jax.random.PRNGKey(0)
    comp = jax.jit(compress)
    deco = jax.jit(decompress)
    for sparsity in (0.3, 0.5, 0.7, 0.9):
        x = _sparse(jax.random.fold_in(key, int(sparsity * 100)), n, sparsity)
        sv = comp(x)
        ratio = float(n * 4 / wire_bytes(sv))
        out.append((f"memstash_compress_s{int(sparsity*100)}", _time(comp, x), ratio))
        err = float(jnp.max(jnp.abs(deco(sv) - x)))
        out.append((f"memstash_restore_s{int(sparsity*100)}", _time(deco, sv), err))

    x = _sparse(jax.random.fold_in(key, 50), n, 0.5)
    sv = comp(x)
    d = float(sv.nnz) / n
    formula = n * formula_bits_per_elem(d, 20) / 8.0
    out.append(("memstash_formula_s50", 0.0, float(wire_bytes(sv)) / formula))

    t_none = _grad_time("none")
    t_stash = _grad_time("stash")
    out.append(("memstash_grad_stash", t_stash, t_stash / t_none))
    return out
