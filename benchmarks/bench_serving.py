"""Serving benchmarks: continuous-batching engine throughput + the
sparsity-compressed KV cache's measured wire traffic.

Rows (name, us_per_call, derived[, impl]):

  serving.engine.<arch>.tok_s          us = mean decode-step wall time;
                                       derived = decode tokens/s
  serving.engine.<arch>.occupancy      derived = mean slot occupancy
  serving.engine.<arch>.kv_wire_bytes  derived = mean per-step KV wire
                                       bytes of the packed pool
  serving.engine.<arch>.kv_traffic_x   derived = dense-fp32-pool bytes /
                                       measured wire bytes per step
  serving.kv_pack.d{25,50,100}         kv_pack on a synthetic block at
                                       that density; derived = fp32-bits /
                                       measured wire bits (the 20d+1
                                       format ratio: 2.9x at the natural
                                       ReLU density 0.5, 1.52x dense)

``--smoke`` (the CI serving job) runs the quant_sparse engine case and
asserts >= 2x KV wire-byte reduction vs a dense fp32 pool plus finite
outputs; failures exit non-zero.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.kv_cache.ops import KV_VALUE_BITS, kv_pack, kv_wire_bits

ARCH = "llama3.2-1b"
#: engine case: queue > slots so requests genuinely join mid-flight and
#: the pool sees the natural occupancy profile of rolling admissions
ENGINE_CASE = dict(batch=3, slots=2, queue=6, prompt_len=10, gen=8,
                   mode="quant_sparse")

#: Canonical RunSpec surface for benchmarks/run.py --json: the engine
#: bench below runs from exactly this spec, so its rows' spec_hash is the
#: configuration that produced them.
SPEC_RUN = "serve"
SPEC_OVERRIDES = {
    "arch.id": ARCH,
    "shape.batch": ENGINE_CASE["batch"],
    "shape.prompt_len": ENGINE_CASE["prompt_len"],
    "shape.gen": ENGINE_CASE["gen"],
    "serving.slots": ENGINE_CASE["slots"],
    "serving.queue": ENGINE_CASE["queue"],
    "numerics.mode": ENGINE_CASE["mode"],
}


def _engine_rows() -> tuple[list[tuple], dict]:
    from repro.api.sessions import ServeSession
    from repro.api.spec import build_spec

    # use_env=False: the bench measures its declared configuration (the
    # ambient SPRING_KERNEL_IMPL still steers dispatch through the
    # registry and is recorded per row as ``impl``)
    spec = build_spec(SPEC_RUN, overrides=[
        (path, value, "bench:bench_serving")
        for path, value in SPEC_OVERRIDES.items()], use_env=False)
    out = ServeSession(spec).run()
    impl = registry.resolve("kv_pack", _count=False).name
    step_us = out["decode_s"] / max(out["decode_steps"], 1) * 1e6
    rows = [
        (f"serving.engine.{ARCH}.tok_s", step_us, out["tokens_per_s"], impl),
        (f"serving.engine.{ARCH}.occupancy", step_us, out["mean_occupancy"], impl),
        (f"serving.engine.{ARCH}.kv_wire_bytes", step_us,
         out["kv_mean_wire_bytes"], impl),
        (f"serving.engine.{ARCH}.kv_traffic_x", step_us,
         out["kv_traffic_reduction_vs_fp32"], impl),
    ]
    return rows, out


def _format_rows() -> list[tuple]:
    from benchmarks.bench_kernels import _time  # warmup + mean timing

    rows = []
    n = 1 << 16
    key = jax.random.PRNGKey(0)
    for pct in (25, 50, 100):
        density = pct / 100.0
        x = jax.random.normal(key, (n,))
        keep = jax.random.uniform(jax.random.fold_in(key, pct), (n,)) < density
        x = jnp.where(keep, x, 0.0)
        us = _time(kv_pack, x)
        packed = kv_pack(x)
        ratio = (n * 32.0) / float(kv_wire_bits(int(packed["nnz"]), n,
                                                KV_VALUE_BITS))
        rows.append((f"serving.kv_pack.d{pct}", us, ratio,
                     registry.resolve("kv_pack", _count=False).name))
    return rows


def rows() -> list[tuple]:
    engine_rows, _ = _engine_rows()
    return engine_rows + _format_rows()


#: spans the engine opens on one decode tick (tick + schedule + decode +
#: sample + repack, plus prefill/install on admit ticks) — the multiplier
#: for the disabled-path overhead gate below
SPANS_PER_TICK = 8


def _disabled_span_overhead_us(iters: int = 20000) -> float:
    """Measured cost of one disabled ``telemetry.span`` enter/exit (the
    no-op path: a thread-local load + None test + shared null context)."""
    import time as _time

    from repro import telemetry

    assert telemetry.tracer() is None, "overhead probe needs telemetry off"
    with telemetry.span("warmup"):
        pass
    t0 = _time.perf_counter()
    for _ in range(iters):
        with telemetry.span("overhead.probe"):
            pass
    return (_time.perf_counter() - t0) / iters * 1e6


def smoke() -> int:
    """CI gate: the quant_sparse engine must beat a dense fp32 KV pool by
    >= 2x on measured per-step wire bytes, decode must stay finite, and
    the disabled spring-trace path must cost < 5% of a decode step."""
    engine_rows, out = _engine_rows()
    failures = []
    if not out["finite"]:
        failures.append("non-finite decode logits")
    red = out["kv_traffic_reduction_vs_fp32"]
    if red < 2.0:
        failures.append(f"KV wire reduction {red:.2f}x < 2x vs dense fp32")
    if out["kv_mean_wire_bytes"] <= 0:
        failures.append("no KV wire bytes measured")
    done = [r["n_tokens"] for r in out["per_request"]]
    if done != [ENGINE_CASE["gen"]] * ENGINE_CASE["queue"]:
        failures.append(f"request completion mismatch: {done}")
    fmt = _format_rows()
    relu_ratio = [r[2] for r in fmt if r[0] == "serving.kv_pack.d50"][0]
    if relu_ratio < 2.0:
        failures.append(f"kv_pack ratio at ReLU density {relu_ratio:.2f}x < 2x")
    # overhead gate: per-call no-op span cost x spans/tick vs the measured
    # decode step (a direct estimate — comparing two full engine runs
    # would drown the signal in CI timing noise)
    step_us = out["decode_s"] / max(out["decode_steps"], 1) * 1e6
    span_us = _disabled_span_overhead_us()
    overhead = span_us * SPANS_PER_TICK / step_us if step_us else 0.0
    tel_rows = [("serving.telemetry.disabled_span", span_us, overhead, "-")]
    if overhead >= 0.05:
        failures.append(
            f"disabled-telemetry overhead {overhead:.2%} of a decode step "
            f"({span_us:.3f}us/span x {SPANS_PER_TICK}) >= 5%")
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in engine_rows + fmt + tel_rows:
        print(f"{name},{us:.2f},{derived:.6g},{impl}")
    for f in failures:
        print(f"SERVING SMOKE FAILURE: {f}", file=sys.stderr)
    return 1 if failures else 0


def main() -> None:
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in rows():
        print(f"{name},{us:.2f},{derived:.6g},{impl}")


if __name__ == "__main__":
    main()
