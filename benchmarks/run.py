# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_table1        Table 1 design parameters (echo + derived peaks)
  bench_paper_figs    Figs 11-16 perf / power / energy, train + inference
  bench_compression   Fig 5 binary-mask compression (exact worked example)
  bench_memstash      compressed activation stash: ratio/throughput vs
                      sparsity + formula cross-check + grad overhead
  bench_kernels       kernel-registry-dispatched microbenches
  bench_collectives   spring-mesh packed collectives: wire compression
                      + packed-vs-dense bit-identity
  bench_serving       continuous-batching engine throughput + KV wire
  bench_paging        spring-pages concurrent capacity vs the monolithic
                      pool at equal physical page bytes
  bench_elastic       spring-survive snapshot/restore/rescale cost and
                      the chaos-schedule-vs-oracle seal
  bench_sr_training   §6 / Gupta'15 SR-vs-fp32 convergence claim

Run: PYTHONPATH=src python -m benchmarks.run [--skip-slow] [--json PATH]

Suites may emit 3-tuples (name, us, derived) or 4-tuples with a trailing
resolved kernel-impl name.  The CSV keeps the stable 3-column schema; the
``--json`` payload carries the impl per row plus the registry's full
resolution table, so BENCH_*.json trajectories are attributable to a
backend (and to the SPRING_KERNEL_IMPL / --kernel-impl policy in force).

Each suite also resolves a canonical RunSpec (its ``SPEC_RUN`` /
``SPEC_OVERRIDES`` attributes layered over the spec defaults + SPRING_*
env), embedded per suite in the ``--json`` payload with its hash; every
row carries its suite's ``spec_hash`` so BENCH trajectories are tied to
the exact configuration that produced them.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skip-slow", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + kernel-impl attribution as JSON")
    args = ap.parse_args()
    skip_slow = args.skip_slow
    json_path = args.json
    from benchmarks import (
        bench_collectives,
        bench_compression,
        bench_elastic,
        bench_kernels,
        bench_memstash,
        bench_paging,
        bench_paper_figs,
        bench_serving,
        bench_sr_training,
        bench_table1,
    )

    suites = [bench_table1, bench_paper_figs, bench_compression, bench_memstash,
              bench_kernels, bench_collectives, bench_serving, bench_paging,
              bench_elastic]
    if not skip_slow:
        suites.append(bench_sr_training)

    import jax

    from benchmarks.bench_serving import ARCH as ARCH_SERVE
    from repro.api.spec import SpecError, build_spec
    from repro.kernels import registry

    def suite_spec(suite):
        """Canonical RunSpec for a suite that declares one (SPEC_RUN +
        SPEC_OVERRIDES module attributes over the spec defaults), or None
        for suites whose benches are not spec-shaped (micro-kernel sweeps)
        — those rows carry no spec_hash rather than a fabricated one.  No
        env layer: a declaring suite runs its declared configuration
        regardless of SPRING_* (the ambient kernel policy is recorded
        separately as ``kernel_policy``)."""
        if not hasattr(suite, "SPEC_RUN"):
            return None
        name = suite.__name__.rsplit(".", 1)[-1]
        overrides = [(path, value, f"bench:{name}") for path, value in
                     getattr(suite, "SPEC_OVERRIDES", {}).items()]
        return build_spec(suite.SPEC_RUN, overrides=overrides, use_env=False)

    print("name,us_per_call,derived")
    failures = 0
    records = []
    suite_specs = {}
    for suite in suites:
        name = suite.__name__.rsplit(".", 1)[-1]
        spec = None
        try:
            spec = suite_spec(suite)
            if spec is not None:
                suite_specs[name] = spec
        except SpecError:  # a broken SPEC_OVERRIDES must not kill the rows
            failures += 1
            traceback.print_exc(file=sys.stderr)
        try:
            for row in suite.rows():
                row_name, us, derived = row[0], row[1], row[2]
                impl = row[3] if len(row) > 3 else None
                print(f"{row_name},{us:.2f},{derived:.6g}")
                rec = {"name": row_name, "us_per_call": us, "derived": derived}
                if spec is not None:
                    rec["spec_hash"] = spec.spec_hash()
                if impl is not None:
                    rec["impl"] = impl
                records.append(rec)
        except Exception:  # keep the harness alive; report at exit
            failures += 1
            traceback.print_exc(file=sys.stderr)
    if json_path:
        # backward tile-skip attribution: the derived column of every
        # masked_matmul_dx/dw bench row, keyed by bench name, so BENCH
        # trajectories track training-direction sparsity separately
        backward_skip = {
            r["name"]: r["derived"] for r in records
            if "masked_matmul_dx" in r["name"] or "masked_matmul_dw" in r["name"]
        }
        # serving attribution: engine throughput + the compressed KV
        # pool's measured wire bytes, keyed off the bench_serving rows
        by_name = {r["name"]: r["derived"] for r in records}
        serving = {
            "tokens_per_s": by_name.get(f"serving.engine.{ARCH_SERVE}.tok_s"),
            "kv_wire_bytes": by_name.get(
                f"serving.engine.{ARCH_SERVE}.kv_wire_bytes"),
            "kv_traffic_reduction_vs_fp32": by_name.get(
                f"serving.engine.{ARCH_SERVE}.kv_traffic_x"),
            "mean_occupancy": by_name.get(
                f"serving.engine.{ARCH_SERVE}.occupancy"),
        }
        # spring-pages attribution: concurrent-capacity ratio of the
        # paged COW pool vs the monolithic pool at equal physical bytes
        from benchmarks.bench_paging import ARCH as ARCH_PAGE

        paging = {
            "peak_active_paged": by_name.get(
                f"paging.engine.{ARCH_PAGE}.peak_active_paged"),
            "peak_active_monolithic": by_name.get(
                f"paging.engine.{ARCH_PAGE}.peak_active_mono"),
            "capacity_x": by_name.get(f"paging.engine.{ARCH_PAGE}.capacity_x"),
            "prefix_hits": by_name.get(
                f"paging.engine.{ARCH_PAGE}.prefix_hits"),
            "cow_copies": by_name.get(
                f"paging.engine.{ARCH_PAGE}.cow_copies"),
            "spills": by_name.get(f"paging.engine.{ARCH_PAGE}.spills"),
            "peak_page_utilization": by_name.get(
                f"paging.engine.{ARCH_PAGE}.page_utilization"),
        }
        # spring-survive attribution: snapshot artifact size, restore
        # latency and the chaos-vs-oracle seal from the bench_elastic rows
        from benchmarks.bench_elastic import ARCH as ARCH_EL

        by_us = {r["name"]: r["us_per_call"] for r in records}
        elastic = {
            "snapshot_bytes": by_name.get(
                f"elastic.engine.{ARCH_EL}.snapshot_us"),
            "snapshot_us": by_us.get(f"elastic.engine.{ARCH_EL}.snapshot_us"),
            "restore_us": by_us.get(f"elastic.engine.{ARCH_EL}.restore_us"),
            "rescale_us": by_us.get(f"elastic.engine.{ARCH_EL}.rescale_us"),
            "chaos_match": by_name.get(
                f"elastic.engine.{ARCH_EL}.chaos_match"),
        }
        payload = {
            "backend": jax.default_backend(),
            "kernel_policy": registry.current_policy().describe(),
            "kernel_impls": registry.resolution_table(),
            "backward_tile_skip": backward_skip,
            "serving": serving,
            "paging": paging,
            "elastic": elastic,
            # per-suite canonical RunSpec + hash: ties every BENCH row
            # (via its spec_hash) to the exact configuration it measured
            "suites": {
                name: {"spec": spec.to_dict(),
                       "spec_hash": spec.spec_hash()}
                for name, spec in suite_specs.items()
            },
            "rows": records,
            "failures": failures,
        }
        # spring-trace snapshot: whatever the suites drove through the
        # one metrics registry (kernel dispatch counts, eager hook
        # histograms, engine gauges) rides along in the artifact
        from repro import telemetry

        payload["telemetry"] = {"metrics": telemetry.metrics().snapshot()}
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
