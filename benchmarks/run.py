# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_table1        Table 1 design parameters (echo + derived peaks)
  bench_paper_figs    Figs 11-16 perf / power / energy, train + inference
  bench_compression   Fig 5 binary-mask compression (exact worked example)
  bench_memstash      compressed activation stash: ratio/throughput vs
                      sparsity + formula cross-check + grad overhead
  bench_kernels       Pallas-kernel jnp-path microbenches
  bench_sr_training   §6 / Gupta'15 SR-vs-fp32 convergence claim

Run: PYTHONPATH=src python -m benchmarks.run [--skip-slow]
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    skip_slow = "--skip-slow" in sys.argv
    from benchmarks import (
        bench_compression,
        bench_kernels,
        bench_memstash,
        bench_paper_figs,
        bench_sr_training,
        bench_table1,
    )

    suites = [bench_table1, bench_paper_figs, bench_compression, bench_memstash,
              bench_kernels]
    if not skip_slow:
        suites.append(bench_sr_training)

    print("name,us_per_call,derived")
    failures = 0
    for suite in suites:
        try:
            for name, us, derived in suite.rows():
                print(f"{name},{us:.2f},{derived:.6g}")
        except Exception:  # keep the harness alive; report at exit
            failures += 1
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
