"""Packed-collective microbenches: wire compression + bit-identity of the
spring-mesh ``packed_all_gather`` / ``packed_reduce_scatter`` op families
(simulation mode — the registry lowering the sharded sessions jit, minus
the device wire hop).

Rows:
  collective_ag_dNN   us = jitted sim-mode packed_all_gather (world 4,
                      64K elems/device at NN% density); derived = dense
                      fp32 bytes / packed wire bytes at the ``20·d + 1``
                      accounting.
  collective_rs_dNN   us = jitted sim-mode packed_reduce_scatter;
                      derived = max |packed - dense reference| over the
                      scattered shards (must be 0: bit-exact).
  collective_formula_d50  derived = measured wire bytes / analytical
                      formula (= 1.0 at word alignment).

``--smoke`` (the CI mesh job) gates the packed wire bytes at >= 2x under
dense fp32 at ReLU density (0.5) and re-asserts per-shard bit-identity
of packed vs dense collectives for every selectable impl.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp

from repro.dist.collectives import (
    collective_probe,
    dense_all_gather,
    dense_reduce_scatter,
    packed_all_gather,
    packed_reduce_scatter,
    _shard_block,
)
from repro.kernels import registry

WORLD = 4
LENGTH = 1 << 16


def _time(fn, *args, iters: int = 20) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rows() -> list[tuple]:
    registry.ensure_registered()
    impl = registry.resolve("packed_all_gather", None, _count=False).name
    ag = jax.jit(lambda x: packed_all_gather(x))
    rs = jax.jit(lambda x: packed_reduce_scatter(x))
    out = []
    for density in (0.1, 0.5, 0.9):
        x = _shard_block(int(density * 100), WORLD, LENGTH, density)
        probe = collective_probe(density, world=WORLD, length=LENGTH)
        out.append((f"collective_ag_d{int(density*100)}", _time(ag, x),
                    probe["compression_vs_fp32"], impl))
        err = float(jnp.max(jnp.abs(rs(x) - dense_reduce_scatter(x))))
        out.append((f"collective_rs_d{int(density*100)}", _time(rs, x),
                    err, impl))
    p50 = collective_probe(0.5, world=WORLD, length=LENGTH)
    out.append(("collective_formula_d50", 0.0, p50["wire_vs_formula"], impl))
    return out


def smoke() -> int:
    """CI gate: >= 2x packed-vs-dense-fp32 wire bytes at ReLU density,
    per-shard bit-identity of packed vs dense collectives on every
    selectable impl, and the 20·d+1 formula cross-check."""
    registry.ensure_registered()
    failures = []
    probe = collective_probe(0.5, world=WORLD, length=LENGTH)
    if probe["compression_vs_fp32"] < 2.0:
        failures.append(
            f"packed wire bytes only {probe['compression_vs_fp32']:.2f}x "
            f"under dense fp32 at density {probe['density']:.2f} (< 2x)")
    if abs(probe["wire_vs_formula"] - 1.0) > 1e-6:
        failures.append(
            f"wire/formula ratio {probe['wire_vs_formula']:.6f} != 1.0 "
            "(payload not word-aligned or accounting drifted)")
    if not probe["exact"]:
        failures.append("packed all-gather round trip not bit-exact")
    for impl in ("ref", "jnp"):
        for density in (1.0, 0.5, 0.1):
            x = _shard_block(7, WORLD, LENGTH, density)
            if not jnp.array_equal(packed_all_gather(x, impl=impl),
                                   dense_all_gather(x)):
                failures.append(f"all_gather[{impl}] d={density} != dense")
            if not jnp.array_equal(packed_reduce_scatter(x, impl=impl),
                                   dense_reduce_scatter(x)):
                failures.append(f"reduce_scatter[{impl}] d={density} != dense")
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in rows():
        print(f"{name},{us:.2f},{derived:.6g},{impl}")
    for f in failures:
        print(f"COLLECTIVES SMOKE FAILURE: {f}", file=sys.stderr)
    return 1 if failures else 0


def main() -> None:
    if "--smoke" in sys.argv:
        sys.exit(smoke())
    print("name,us_per_call,derived,impl")
    for name, us, derived, impl in rows():
        print(f"{name},{us:.2f},{derived:.6g},{impl}")


if __name__ == "__main__":
    main()
