"""Quickstart: SPRING's three pillars in ~70 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    QUANT_SPARSE,
    KeyGen,
    SPRING_FORMAT,
    compression_ratio,
    mask_decode,
    mask_encode,
    quantize_stochastic,
    sparse_dot,
    spring_matmul,
)


def main(steps: int = 25) -> None:
    key = jax.random.PRNGKey(0)

    # --- P1: binary-mask sparsity encoding (paper Fig. 5) -------------------
    x = jax.random.normal(key, (1024,))
    x = x * (jax.random.uniform(jax.random.fold_in(key, 1), x.shape) > 0.5)
    mv = mask_encode(x)
    print(f"[P1] {int(mv.nnz)}/{x.size} non-zeros kept; "
          f"compression at Q4.16+mask: {float(compression_ratio(mv, 21)):.2f}x; "
          f"decode exact: {bool(jnp.all(mask_decode(mv) == x))}")

    w = jax.random.normal(jax.random.fold_in(key, 2), (1024,))
    w = w * (jax.random.uniform(jax.random.fold_in(key, 3), w.shape) > 0.5)
    print(f"[P1] zero-free dot == dense dot: "
          f"{abs(float(sparse_dot(mv, mask_encode(w)) - jnp.dot(x, w))) < 1e-4}")

    # --- P2: stochastic rounding (paper Eq. 4) ------------------------------
    v = jnp.full((100_000,), 0.5 + 0.3 * SPRING_FORMAT.eps)
    q = quantize_stochastic(jax.random.fold_in(key, 4), v)
    print(f"[P2] SR bias: {float(q.mean() - v[0]) / SPRING_FORMAT.eps:+.4f} eps "
          f"(unbiased => fixed-point training converges)")

    # --- P1+P2 together: the sparsity-aware quantized matmul ----------------
    a = jax.random.normal(key, (64, 256))
    b = jax.random.normal(jax.random.fold_in(key, 5), (256, 32)) / 256**0.5
    y_dense = a @ b
    y_spring = spring_matmul(a, b, QUANT_SPARSE, KeyGen(jax.random.fold_in(key, 6)))
    rel = float(jnp.max(jnp.abs(y_spring - y_dense)) / jnp.max(jnp.abs(y_dense)))
    print(f"[P1+P2] spring_matmul rel deviation vs fp32: {rel:.2e} "
          f"(quantization noise, gradient-safe via STE)")

    # --- sparsity in training: the backward pass is masked too --------------
    # backward_sparsity="auto" (the QUANT_SPARSE default) routes dL/dX and
    # dL/dW through the tile-skipping masked_matmul_dx/dw kernels.
    from repro.kernels.masked_matmul.backward import sparsity_probe

    probe = sparsity_probe(density=0.5, size=256)
    print(f"[train] tile-skip at 50% block density — fwd "
          f"{probe['forward_tile_skip']:.2f}, bwd dX "
          f"{probe['backward_tile_skip_dx']:.2f}, bwd dW "
          f"{probe['backward_tile_skip_dw']:.2f} "
          f"(sparsity pays in both directions)")

    # --- a taste of the training stack: one declarative RunSpec -------------
    # The whole run — arch, shape, numerics, sparsity, kernels, seeds — is
    # one frozen spec; its canonical JSON (embedded in every run artifact)
    # reproduces the run bit-for-bit.  See DESIGN.md §10.
    from repro.api import TrainSession, build_spec

    spec = build_spec("train", sets=[
        "arch.id=llama3.2-1b", f"train.steps={steps}", "shape.batch=8",
        "shape.seq=64", "numerics.mode=quant",
        "numerics.fixed_point_weights=true", "train.log_every=100",
    ])
    print(f"[spec] canonical hash {spec.spec_hash()} "
          f"(numerics.mode={spec.numerics.mode!r} from "
          f"{spec.provenance['numerics.mode']})")
    res = TrainSession(spec).run()
    print(f"[train] Q4.16+SR end-to-end: loss {res['first_loss']:.3f} -> "
          f"{res['last_loss']:.3f}")


if __name__ == "__main__":
    main()
