"""End-to-end training driver: data pipeline -> SPRING train step ->
checkpoint/resume -> straggler watchdog — a thin adapter over RunSpec.

Presets:
  cpu-small (default) — a reduced llama-family model, a few hundred steps
    on this CPU container (minutes).
  pod-100m — a ~100M-param llama-family config for a few hundred steps on
    real hardware; same code path, bigger dims + production mesh.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --preset pod-100m --steps 300
  PYTHONPATH=src python examples/train_lm.py \
      --spec examples/specs/train_quant_sparse.json
"""

import argparse
import dataclasses
import logging

from repro.api.cli import flag, legacy_overrides
from repro.api.sessions import TrainSession
from repro.api.spec import build_spec
from repro.configs import get_arch
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig

FLAGS = (
    flag("--steps", "train.steps", type=int),
    flag("--mode", "numerics.mode",
         choices=["dense", "quant", "quant_sparse"]),
    flag("--backward-sparsity", "sparsity.backward",
         choices=["none", "auto", "ref", "jnp", "interpret", "pallas"]),
    flag("--ckpt-dir", "train.ckpt_dir"),
)


def config_100m() -> LMConfig:
    """~100M params: 12L, d768, 12 heads, d_ff 3072, 32k vocab."""
    return LMConfig(
        name="llama-100m", d_model=768, vocab=32768, n_layers=12,
        pattern_unit=(("attn", "swiglu"),), n_units=12,
        attn=AttnSpec(n_heads=12, n_kv_heads=4, head_dim=64),
        d_ff=3072, tie_embeddings=True,
    )


def main(steps: int | None = None, argv: list[str] | None = None):
    """CLI entry point.  ``main(steps=1)`` runs the cpu-small preset for
    one step with default flags (the smoke-test path)."""
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None, metavar="PATH",
                    help="RunSpec file (JSON or TOML)")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    metavar="KEY=VALUE", help="dotted RunSpec override")
    ap.add_argument("--preset", default="cpu-small",
                    choices=["cpu-small", "pod-100m"])
    for f in FLAGS:
        f.add_to(ap)
    if steps is not None and argv is None:
        argv = []  # programmatic call: don't read the host process argv
    args = ap.parse_args(argv)

    if args.preset == "pod-100m":
        # register the 100M config under the llama arch machinery
        arch = get_arch("llama3.2-1b")
        cfg = config_100m()
        arch = dataclasses.replace(arch, config=cfg, reduced=lambda: cfg)
        import repro.configs.registry as reg

        reg.ARCHS["llama-100m"] = arch
        base = {"arch": {"id": "llama-100m"},
                "shape": {"batch": 32, "seq": 512}}
    else:
        base = {"arch": {"id": "llama3.2-1b"},
                "shape": {"batch": 8, "seq": 128}}
    base["train"] = {"steps": 300, "ckpt_dir": "/tmp/repro_train_lm",
                     "ckpt_every": 100, "log_every": 20}

    over = legacy_overrides(args, FLAGS, warn=False)
    if steps is not None:
        over.append(("train.steps", steps, "call:steps"))
    spec = build_spec("train", data=base, data_label=f"preset:{args.preset}",
                      spec_file=args.spec, overrides=over, sets=args.sets)
    # SR fixed-point master weights whenever the mode is quantized (the
    # pre-RunSpec behavior of this example), unless the spec said otherwise
    if (spec.numerics.mode != "dense"
            and spec.provenance.get("numerics.fixed_point_weights") == "default"):
        spec = dataclasses.replace(
            spec, numerics=dataclasses.replace(
                spec.numerics, fixed_point_weights=True),
            provenance={**spec.provenance,
                        "numerics.fixed_point_weights": f"preset:{args.preset}"})
    res = TrainSession(spec).run()
    print(f"final: loss {res['first_loss']:.4f} -> {res['last_loss']:.4f} "
          f"over {spec.train.steps} steps; {res['slow_steps']} slow steps; "
          f"checkpoints in {spec.train.ckpt_dir} [spec {res['spec_hash']}]")
    return res


if __name__ == "__main__":
    main()
