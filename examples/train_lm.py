"""End-to-end training driver: data pipeline -> SPRING train step ->
checkpoint/resume -> straggler watchdog.

Presets:
  cpu-small (default) — a reduced llama-family model, a few hundred steps
    on this CPU container (minutes).
  pod-100m — a ~100M-param llama-family config for a few hundred steps on
    real hardware; same code path, bigger dims + production mesh.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --preset pod-100m --steps 300
"""

import argparse
import dataclasses
import logging

from repro.configs import get_arch
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig


def config_100m() -> LMConfig:
    """~100M params: 12L, d768, 12 heads, d_ff 3072, 32k vocab."""
    return LMConfig(
        name="llama-100m", d_model=768, vocab=32768, n_layers=12,
        pattern_unit=(("attn", "swiglu"),), n_units=12,
        attn=AttnSpec(n_heads=12, n_kv_heads=4, head_dim=64),
        d_ff=3072, tie_embeddings=True,
    )


def main(steps: int | None = None, argv: list[str] | None = None):
    """CLI entry point.  ``main(steps=1)`` runs the cpu-small preset for
    one step with default flags (the smoke-test path)."""
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small", choices=["cpu-small", "pod-100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mode", default="dense", choices=["dense", "quant", "quant_sparse"])
    ap.add_argument("--backward-sparsity", default="auto",
                    choices=["none", "auto", "ref", "jnp", "interpret", "pallas"],
                    help="sparsity-aware backward pass (quant_sparse mode)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    if steps is not None and argv is None:
        argv = []  # programmatic call: don't read the host process argv
    args = ap.parse_args(argv)
    if steps is not None:
        args.steps = steps

    from repro.launch import train as train_mod

    if args.preset == "pod-100m":
        # register the 100M config under the llama arch machinery
        arch = get_arch("llama3.2-1b")
        cfg = config_100m()
        arch = dataclasses.replace(arch, config=cfg, reduced=lambda: cfg)
        import repro.configs.registry as reg

        reg.ARCHS["llama-100m"] = arch
        arch_id, batch, seq = "llama-100m", 32, 512
    else:
        arch_id, batch, seq = "llama3.2-1b", 8, 128

    res = train_mod.train_loop(
        arch_id, reduced=True, steps=args.steps, batch=batch, seq=seq,
        mode=args.mode, fixed_point_weights=(args.mode != "dense"),
        backward_sparsity=args.backward_sparsity,
        ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=20,
    )
    print(f"final: loss {res['first_loss']:.4f} -> {res['last_loss']:.4f} "
          f"over {args.steps} steps; {res['slow_steps']} slow steps; "
          f"checkpoints in {args.ckpt_dir}")
    return res


if __name__ == "__main__":
    main()
