"""The paper's core training claim, measured end-to-end: Q4.16 fixed-point
training with STOCHASTIC rounding converges like fp32, while NEAREST
rounding at the same precision is visibly worse (Gupta et al. 2015;
paper §3.2/§6).

Three arms, identical data/seed/steps, small CNN on the synthetic image
task:  fp32  |  Q4.16 + stochastic rounding  |  Q4.16 + nearest rounding.

  PYTHONPATH=src python examples/sr_accuracy_parity.py --steps 150
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import SPRING_FORMAT
from repro.core.spring_ops import DENSE, QUANT, KeyGen, SpringConfig
from repro.data.pipeline import DataConfig, SyntheticImageTask
from repro.models.cnn import ParamStore, conv, fc, gap
from repro.models.layers import SpringContext
from repro.optim.optimizers import OptimizerConfig, make_optimizer


def tiny_cnn(store, ctx, x):
    x = conv(store, ctx, "c1", x, 16, k=3, stride=2)
    x = conv(store, ctx, "c2", x, 32, k=3, stride=2)
    x = conv(store, ctx, "c3", x, 32, k=3)
    return fc(store, ctx, "head", gap(x), 10)


def run_arm(name: str, spring: SpringConfig, stochastic: bool, steps: int, seed=0):
    data = SyntheticImageTask(DataConfig(seed=seed, global_batch=32), hw=16)
    key = jax.random.PRNGKey(seed)
    store = ParamStore(key)
    tiny_cnn(store, SpringContext(), jnp.zeros((1, 16, 16, 3)))  # init params
    params = store.params
    spring = dataclasses.replace(spring, stochastic=stochastic)
    wf = SPRING_FORMAT if spring.is_quantized else None
    opt_cfg = OptimizerConfig(kind="sgdm", lr=0.05, momentum=0.9, weight_format=wf)
    opt_init, opt_update = make_optimizer(opt_cfg)
    opt_state = opt_init(params)

    @jax.jit
    def step(params, opt_state, x, y, key):
        def loss_fn(p):
            ctx = SpringContext(cfg=spring,
                                keys=KeyGen(key) if spring.is_quantized else None)
            logits = tiny_cnn(ParamStore(key, p), ctx, x)
            lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), -1)
            gold = jnp.take_along_axis(logits.astype(jnp.float32), y[:, None], 1)[:, 0]
            return (lse - gold).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt_update(grads, opt_state, params, key)
        return params, opt_state, loss

    losses = []
    for i in range(steps):
        x, y = data.batch(i)
        params, opt_state, loss = step(params, opt_state, x, y, jax.random.fold_in(key, i))
        losses.append(float(loss))
    tail = sum(losses[-10:]) / 10
    print(f"{name:28s} loss {losses[0]:.4f} -> {tail:.4f} (tail-10 mean)")
    return tail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    fp32 = run_arm("fp32 baseline", DENSE, True, args.steps)
    sr = run_arm("Q4.16 stochastic (SPRING)", QUANT, True, args.steps)
    rn = run_arm("Q4.16 round-to-nearest", QUANT, False, args.steps)
    print(f"\nSR gap vs fp32:      {sr - fp32:+.4f}  (paper claim: ~0)")
    print(f"nearest gap vs fp32: {rn - fp32:+.4f}  (worse -> SR is the enabler)")


if __name__ == "__main__":
    main()
