"""Batched serving demo: prefill a batch of prompts, decode with the
quantized KV-serving path, report latency/throughput.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m --gen 24
"""

import argparse

from repro.launch.serve import serve_session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="dense", choices=["dense", "quant", "quant_sparse"])
    args = ap.parse_args()

    out = serve_session(args.arch, reduced=True, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen, mode=args.mode)
    print(f"arch={args.arch} mode={args.mode}")
    print(f"  prefill: {out['prefill_s']*1e3:8.1f} ms  ({args.batch} x {args.prompt_len} tokens)")
    print(f"  decode:  {out['decode_s']*1e3:8.1f} ms  ({out['tokens_per_s']:.1f} tok/s)")
    print(f"  sample:  {out['generated'][0][:10].tolist()}")
    assert out["finite"]


if __name__ == "__main__":
    main()
