"""Continuous-batching serving demo: submit a queue of prompts over a
fixed slot pool, decode with the sparsity-compressed KV cache, report
latency/throughput/compression.

  PYTHONPATH=src python examples/serve_batched.py --arch llama3.2-1b \
      --batch 4 --slots 2 --queue 6 --gen 24 --mode quant_sparse \
      --kernel-impl ref --seed 7
"""

import argparse

from repro.launch.serve import serve_session


def main(argv: list | None = None):
    """CLI entry point; ``main(argv=[...])`` is the smoke-test path."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="dense", choices=["dense", "quant", "quant_sparse"])
    ap.add_argument("--kernel-impl", default=None,
                    help="kernel-dispatch policy, e.g. 'ref' (default: auto)")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine slot-pool size (default: --batch)")
    ap.add_argument("--queue", type=int, default=None,
                    help="total requests (default: --batch); surplus joins mid-flight")
    ap.add_argument("--greedy", dest="greedy", action="store_true", default=True)
    ap.add_argument("--sample", dest="greedy", action="store_false",
                    help="sample with per-request PRNG keys")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    out = serve_session(args.arch, reduced=True, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen,
                        mode=args.mode, kernel_impl=args.kernel_impl,
                        greedy=args.greedy, seed=args.seed,
                        slots=args.slots, queue=args.queue)
    print(f"arch={args.arch} mode={args.mode} slots={out.get('slots', args.batch)}")
    print(f"  prefill: {out['prefill_s']*1e3:8.1f} ms")
    print(f"  decode:  {out['decode_s']*1e3:8.1f} ms  ({out['tokens_per_s']:.1f} tok/s)")
    if out.get("engine"):
        lat = sorted(r["latency_s"] for r in out["per_request"])
        print(f"  latency: p50 {lat[len(lat)//2]*1e3:.0f} ms  "
              f"p100 {lat[-1]*1e3:.0f} ms  occupancy {out['mean_occupancy']:.2f}")
        print(f"  kv:      {out['kv_mean_wire_bytes']/1e3:.1f} KB/step wire, "
              f"{out['kv_traffic_reduction_vs_fp32']:.2f}x less than dense fp32")
    print(f"  sample:  {out['generated'][0][:10].tolist()}")
    assert out["finite"]
    return out


if __name__ == "__main__":
    main()
