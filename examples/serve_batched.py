"""Continuous-batching serving demo: submit a queue of prompts over a
fixed slot pool, decode with the sparsity-compressed KV cache, report
latency/throughput/compression.

The example is a thin adapter over the RunSpec API — the whole run is
one declarative spec:

  PYTHONPATH=src python examples/serve_batched.py \
      --spec examples/specs/serve_quant_sparse.json
  PYTHONPATH=src python examples/serve_batched.py --arch llama3.2-1b \
      --batch 4 --slots 2 --queue 6 --gen 24 --mode quant_sparse \
      --kernel-impl ref --seed 7
"""

import argparse

from repro.api.cli import flag, legacy_overrides
from repro.api.sessions import ServeSession
from repro.api.spec import build_spec

# The short flags are this example's convenience surface; each one is an
# alias for the --set spelling of the same RunSpec field (no deprecation
# here — the example documents both).
FLAGS = (
    flag("--arch", "arch.id"),
    flag("--batch", "shape.batch", type=int),
    flag("--prompt-len", "shape.prompt_len", type=int),
    flag("--gen", "shape.gen", type=int),
    flag("--mode", "numerics.mode",
         choices=["dense", "quant", "quant_sparse"]),
    flag("--kernel-impl", "kernels.policy"),
    flag("--slots", "serving.slots", type=int),
    flag("--queue", "serving.queue", type=int),
    flag("--greedy", "serving.greedy", const=True, dest="legacy_greedy"),
    flag("--sample", "serving.greedy", const=False, dest="legacy_greedy"),
    flag("--seed", "seeds.seed", type=int),
    flag("--pages", "serving.pages", const=True),
    flag("--page-tokens", "serving.page_tokens", type=int),
    flag("--prefix-cache", "serving.prefix_cache", type=lambda s: s.lower()
         not in ("0", "false", "no", "off")),
)


def main(argv: list | None = None):
    """CLI entry point; ``main(argv=[...])`` is the smoke-test path."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=None, metavar="PATH",
                    help="RunSpec file (JSON or TOML)")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    metavar="KEY=VALUE", help="dotted RunSpec override")
    for f in FLAGS:
        f.add_to(ap)
    args = ap.parse_args(argv)

    # base layer = this example's historical defaults (batch 4)
    spec = build_spec("serve", data={"shape": {"batch": 4}},
                      data_label="example-default",
                      spec_file=args.spec, sets=args.sets,
                      overrides=legacy_overrides(args, FLAGS, warn=False))
    out = ServeSession(spec).run()
    print(f"arch={spec.arch.id} mode={spec.numerics.mode} "
          f"slots={out.get('slots', spec.shape.batch)} "
          f"spec={out['spec_hash']}")
    print(f"  prefill: {out['prefill_s']*1e3:8.1f} ms")
    print(f"  decode:  {out['decode_s']*1e3:8.1f} ms  ({out['tokens_per_s']:.1f} tok/s)")
    if out.get("engine"):
        lat = sorted(r["latency_s"] for r in out["per_request"])
        print(f"  latency: p50 {lat[len(lat)//2]*1e3:.0f} ms  "
              f"p100 {lat[-1]*1e3:.0f} ms  occupancy {out['mean_occupancy']:.2f}")
        print(f"  kv:      {out['kv_mean_wire_bytes']/1e3:.1f} KB/step wire, "
              f"{out['kv_traffic_reduction_vs_fp32']:.2f}x less than dense fp32")
        if out.get("paging"):
            p = out["paging"]
            print(f"  pages:   {p['num_pages']} x {p['page_tokens']} tok "
                  f"(x{p['overcommit']:.1f} logical)  "
                  f"prefix hits {p['prefix_hits']}  cow {p['cow_copies']}  "
                  f"spills {p['spills']}")
        for r in out["per_request"]:
            print(f"  req {r['rid']:>3}: queue {r['queue_s']*1e3:6.1f} ms  "
                  f"ttft {r['ttft_s']*1e3:6.1f} ms  "
                  f"total {r['latency_s']*1e3:6.1f} ms  "
                  f"{r['n_tokens']} tok  ticks {r['enqueue_tick']}->"
                  f"{r['first_token_tick']}->{r['finish_tick']} "
                  f"({r['finished_by']})")
    print(f"  sample:  {out['generated'][0][:10].tolist()}")
    assert out["finite"]
    return out


if __name__ == "__main__":
    main()
