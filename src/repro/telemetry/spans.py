"""Span tracer with Chrome trace-event (Perfetto-loadable) JSON export.

Spans wrap the phases worth attributing wall-clock to: TrainSession step
phases (data / dispatch / device / host), ServingEngine tick phases
(schedule / prefill / install / decode / sample / repack), and memstash
pack/unpack.  Each completed span becomes one Chrome ``"ph": "X"``
(complete) event — ``chrome://tracing`` and https://ui.perfetto.dev load
the exported file directly.

Overhead contract (DESIGN.md §11): when tracing is disabled — the
default — ``span()`` is one attribute load, one truthiness test, and the
return of a shared no-op context manager.  No object allocation, no
timestamp read, no lock.  The enabled path takes two ``monotonic_ns``
reads and one list append per span (plus one lock-guarded sampling
accumulator update per root span); there is deliberately no jax work
and no device sync inside the tracer, so enabling it cannot perturb
numerics (the on/off parity seal in tests/test_telemetry.py).

Sampling is deterministic (no PRNG — workflows replay): a fractional
accumulator records ``ceil(k * rate)`` of the first ``k`` top-level
spans, evenly spread.  Nested spans follow their root's decision so a
sampled trace always shows complete ticks, never orphaned children.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

__all__ = ["SpanTracer", "Span", "validate_chrome_trace"]

#: Required keys of a Chrome complete event (the schema CI validates).
CHROME_EVENT_KEYS = ("name", "ph", "ts", "dur", "pid", "tid")


class Span:
    """One open span; append-only record closed by ``__exit__``."""

    __slots__ = ("tracer", "name", "args", "_t0", "recorded")

    def __init__(self, tracer: "SpanTracer", name: str, args: dict,
                 recorded: bool):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.recorded = recorded
        self._t0 = 0

    def __enter__(self) -> "Span":
        self.tracer._depth.value += 1
        self._t0 = time.monotonic_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.monotonic_ns()
        self.tracer._depth.value -= 1
        if self.recorded:
            self.tracer._record(self.name, self._t0, t1, self.args)


class _NullSpan:
    """Shared no-op context manager: the whole disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None


_NULL = _NullSpan()


class _Depth(threading.local):
    def __init__(self):
        self.value = 0
        self.root_sampled = True


class SpanTracer:
    """Collects spans; exports the Chrome trace-event JSON object."""

    def __init__(self, enabled: bool = True, sample_rate: float = 1.0):
        if not 0.0 < sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate must be in (0, 1], got {sample_rate}")
        self.enabled = enabled
        self.sample_rate = sample_rate
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._depth = _Depth()
        self._acc = 0.0  # deterministic sampling accumulator
        self._epoch_ns = time.monotonic_ns()
        self._pid = os.getpid()

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **args):
        """Context manager timing one phase.  Disabled tracers hand back
        the shared no-op; nested spans inherit the root sampling call."""
        if not self.enabled:
            return _NULL
        if self._depth.value == 0:  # root: one sampling decision per tree
            with self._lock:  # _acc is shared across threads' root spans
                self._acc += self.sample_rate
                sampled = self._acc >= 1.0
                if sampled:
                    self._acc -= 1.0
            self._depth.root_sampled = sampled
        # unsampled spans still track depth (a _NULL here would make the
        # dropped root's children look like fresh roots and re-roll the
        # sampling decision mid-tree)
        return Span(self, name, args, recorded=self._depth.root_sampled)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker (Chrome ``"ph": "i"`` instant event)."""
        if not self.enabled or not self._depth.root_sampled:
            return
        ev = {
            "name": name, "ph": "i", "s": "t",
            "ts": (time.monotonic_ns() - self._epoch_ns) / 1e3,
            "pid": self._pid, "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def _record(self, name: str, t0_ns: int, t1_ns: int, args: dict) -> None:
        ev = {
            "name": name, "ph": "X",
            "ts": (t0_ns - self._epoch_ns) / 1e3,  # microseconds
            "dur": (t1_ns - t0_ns) / 1e3,
            "pid": self._pid, "tid": threading.get_ident(),
        }
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # -- export --------------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._acc = 0.0

    def to_chrome_trace(self, extra_metadata: Optional[dict] = None) -> dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        meta = {"tracer": "spring-trace"}
        if extra_metadata:
            meta.update(extra_metadata)
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": meta,
        }

    def write(self, path: str, extra_metadata: Optional[dict] = None) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(extra_metadata), f)
        return path


def validate_chrome_trace(data) -> list[dict]:
    """Validate a loaded trace object (or JSON text) against the Chrome
    trace-event schema this tracer emits; returns the events.

    Raises ``ValueError`` naming the first violation — the CI
    trace-schema step feeds exported files through this.
    """
    if isinstance(data, (str, bytes)):
        data = json.loads(data)
    if not isinstance(data, dict) or "traceEvents" not in data:
        raise ValueError("trace must be an object with a 'traceEvents' key")
    events = data["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            raise ValueError(f"event {i}: unexpected phase {ph!r}")
        keys = CHROME_EVENT_KEYS if ph == "X" else tuple(
            k for k in CHROME_EVENT_KEYS if k != "dur")
        for k in keys:
            if k not in ev:
                raise ValueError(f"event {i} ({ev.get('name')!r}): "
                                 f"missing key {k!r}")
        if not isinstance(ev["name"], str) or not ev["name"]:
            raise ValueError(f"event {i}: name must be a non-empty string")
        if ph == "X" and ev["dur"] < 0:
            raise ValueError(f"event {i}: negative duration")
    return events
