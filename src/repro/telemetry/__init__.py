"""spring-trace: unified telemetry — metrics registry, span tracing, and
serving latency attribution (DESIGN.md §11).

One subsystem owns all runtime measurement:

  * :mod:`repro.telemetry.metrics` — the labeled
    :class:`MetricsRegistry` (counters / gauges / quantile-sketch
    histograms) every other subsystem writes into, with
    ``snapshot()`` / ``reset()`` isolation and Prometheus exposition;
  * :mod:`repro.telemetry.spans` — the Chrome-trace span tracer;
  * :mod:`repro.telemetry.sketch` — the mergeable quantile sketch;
  * :mod:`repro.telemetry.report` — the CLI rendering artifacts.

Ambient surface (this module): instrumented code calls
``telemetry.span("serve.tick.decode")`` / ``telemetry.enabled()``
unconditionally; both are near-zero-overhead no-ops until a
:class:`TelemetryConfig` scope activates a tracer.  Sessions activate it
from the RunSpec ``telemetry`` section (``--set telemetry.enabled=true``)
via :func:`scope`, which also writes the trace file on exit.  Enabling
telemetry never changes computed values — the tracer does no jax work
(sealed by the on/off parity test).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

from repro.telemetry.metrics import MetricsRegistry, default_registry
from repro.telemetry.sketch import QuantileSketch
from repro.telemetry.spans import SpanTracer, validate_chrome_trace

__all__ = [
    "TelemetryConfig", "MetricsRegistry", "QuantileSketch", "SpanTracer",
    "default_registry", "validate_chrome_trace",
    "span", "instant", "enabled", "tracer", "scope", "metrics",
]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Resolved telemetry settings (mirrors the RunSpec section)."""

    enabled: bool = False
    trace_path: str = ""  # "" = collect in memory only
    sample_rate: float = 1.0  # fraction of tick/step span trees recorded


class _Ambient(threading.local):
    """Per-thread active tracer (None = disabled fast path)."""

    def __init__(self):
        self.tracer: Optional[SpanTracer] = None


_AMBIENT = _Ambient()


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL = _NullSpan()


def tracer() -> Optional[SpanTracer]:
    """The active tracer, or None when telemetry is disabled."""
    return _AMBIENT.tracer


def enabled() -> bool:
    return _AMBIENT.tracer is not None


def span(name: str, **args):
    """Time one phase: ``with telemetry.span("serve.tick.decode"): ...``.

    Disabled path = one attribute load + one None test + returning a
    shared no-op context manager (the overhead gate budget in
    ``benchmarks/bench_serving.py`` measures exactly this call).
    """
    t = _AMBIENT.tracer
    if t is None:
        return _NULL
    return t.span(name, **args)


def instant(name: str, **args) -> None:
    """Zero-duration trace marker (no-op when disabled)."""
    t = _AMBIENT.tracer
    if t is not None:
        t.instant(name, **args)


def metrics() -> MetricsRegistry:
    """Alias for :func:`default_registry` (the one metrics home)."""
    return default_registry()


@contextlib.contextmanager
def scope(cfg: Optional[TelemetryConfig], metadata: Optional[dict] = None):
    """Activate telemetry for a session body.

    Yields the active :class:`SpanTracer` (None when ``cfg`` is None or
    disabled — callers need no branching; ambient ``span()`` handles it).
    On exit the trace is written to ``cfg.trace_path`` when set, and the
    previous ambient tracer is restored (scopes nest).
    """
    if cfg is None or not cfg.enabled:
        yield None
        return
    t = SpanTracer(enabled=True, sample_rate=cfg.sample_rate)
    prev = _AMBIENT.tracer
    _AMBIENT.tracer = t
    try:
        yield t
    finally:
        _AMBIENT.tracer = prev
        if cfg.trace_path:
            t.write(cfg.trace_path, extra_metadata=metadata)
