"""MetricsRegistry: one labeled home for every runtime measurement.

Three instrument kinds, deliberately Prometheus-shaped so the exposition
is a straight rendering rather than a translation layer:

  * **counter** — monotonically increasing total (kernel dispatches,
    tokens emitted, wire bytes moved);
  * **gauge** — last-written value (KV-pool density, slot occupancy);
  * **histogram** — a :class:`~repro.telemetry.sketch.QuantileSketch`
    per label set (token latency, TTFT, queue wait, tile-skip fraction).

One process-wide default registry replaces the module-level dicts that
used to hold kernel dispatch counts (``kernels/registry.py``) — every
subsystem writes here, and tests isolate through the explicit
``snapshot()`` / ``reset()`` API (an autouse conftest fixture resets the
default registry per test, so counts no longer leak between tests and
benchmarks sharing a process).

``snapshot()`` is the JSON artifact embedded in ``serve --json``, train
results and ``benchmarks/run.py --json``; ``to_prometheus()`` (also
available on a saved snapshot via :func:`prometheus_from_snapshot`)
renders the text exposition format for scrape-style consumption, and
``render_table()`` the human view ``repro.telemetry.report`` prints.
"""

from __future__ import annotations

import threading
from typing import Optional

from repro.telemetry.sketch import QuantileSketch

__all__ = ["MetricsRegistry", "default_registry", "prometheus_from_snapshot",
           "render_snapshot_table"]

KINDS = ("counter", "gauge", "histogram")

#: Histogram percentiles reported in snapshots / tables.
PERCENTILES = (50, 95, 99)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Family:
    """One metric name: its kind, help text, and per-label-set cells."""

    __slots__ = ("name", "kind", "help", "cells")

    def __init__(self, name: str, kind: str, help: str):
        self.name = name
        self.kind = kind
        self.help = help
        self.cells: dict[tuple, object] = {}


class MetricsRegistry:
    """Thread-safe labeled metrics store with snapshot/reset isolation."""

    def __init__(self, *, alpha: float = 0.01, max_exact: int = 128):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}
        self._alpha = alpha
        self._max_exact = max_exact

    # -- registration / write path ------------------------------------------

    def _family(self, name: str, kind: str, help: str) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = _Family(name, kind, help)
        elif fam.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as a {fam.kind}, "
                f"cannot re-register as a {kind}")
        return fam

    def inc(self, name: str, value: float = 1.0, *, help: str = "",
            **labels) -> None:
        """Increment a counter cell (creates the family on first use)."""
        if value < 0:
            raise ValueError(f"counter {name!r}: negative increment {value}")
        key = _label_key(labels)
        with self._lock:
            cells = self._family(name, "counter", help).cells
            cells[key] = cells.get(key, 0.0) + value

    def set(self, name: str, value: float, *, help: str = "",
            **labels) -> None:
        """Write a gauge cell (last value wins)."""
        with self._lock:
            self._family(name, "gauge", help).cells[_label_key(labels)] = \
                float(value)

    def observe(self, name: str, value: float, *, help: str = "",
                **labels) -> None:
        """Feed one sample into a histogram cell's quantile sketch."""
        key = _label_key(labels)
        with self._lock:
            cells = self._family(name, "histogram", help).cells
            sk = cells.get(key)
            if sk is None:
                sk = cells[key] = QuantileSketch(alpha=self._alpha,
                                                max_exact=self._max_exact)
            sk.add(value)

    # -- read path -----------------------------------------------------------

    def get(self, name: str, **labels):
        """Current value of one cell: float for counter/gauge, the live
        QuantileSketch for a histogram; None if never written."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return None
            return fam.cells.get(_label_key(labels))

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._families)

    def snapshot(self) -> dict:
        """JSON-safe view of every cell.

        ``{name: {"kind", "help", "cells": [{"labels": {...}, ...}]}}``;
        histogram cells carry count/sum/min/max/mean + the reporting
        percentiles and the full serialized sketch (so snapshots merge).
        """
        with self._lock:
            out = {}
            for name in sorted(self._families):
                fam = self._families[name]
                cells = []
                for key in sorted(fam.cells):
                    cell: dict = {"labels": dict(key)}
                    v = fam.cells[key]
                    if fam.kind == "histogram":
                        cell.update(
                            count=v.count, sum=v.sum, mean=v.mean,
                            min=v.min if v.count else None,
                            max=v.max if v.count else None,
                            **v.percentiles(PERCENTILES),
                            sketch=v.to_dict())
                    else:
                        cell["value"] = v
                    cells.append(cell)
                out[name] = {"kind": fam.kind, "help": fam.help,
                             "cells": cells}
            return out

    def reset(self, name: Optional[str] = None) -> None:
        """Clear one family (``name``) or everything (the per-test
        isolation hook; registrations are recreated on next write)."""
        with self._lock:
            if name is None:
                self._families.clear()
            else:
                self._families.pop(name, None)

    def restore(self, snap: dict) -> None:
        """Load a ``snapshot()`` payload back into the live registry.

        Cells present in the snapshot *overwrite* live cells of the same
        name/labels (counters are assigned, not added; histogram sketches
        are replaced wholesale) — this is not a merge.  Intended to follow
        ``reset()``, as the conftest isolation fixture does, to put the
        registry back exactly as a prior snapshot saw it."""
        with self._lock:
            for name in snap:
                fam_snap = snap[name]
                fam = self._family(name, fam_snap["kind"],
                                   fam_snap.get("help", ""))
                for cell in fam_snap["cells"]:
                    key = _label_key(cell.get("labels", {}))
                    if fam.kind == "histogram":
                        fam.cells[key] = QuantileSketch.from_dict(
                            cell["sketch"])
                    else:
                        fam.cells[key] = float(cell["value"])

    # -- renderings ----------------------------------------------------------

    def to_prometheus(self) -> str:
        return prometheus_from_snapshot(self.snapshot())

    def render_table(self) -> str:
        return render_snapshot_table(self.snapshot())


# -- snapshot renderings (shared by the live registry and saved artifacts) --


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + body + "}"


def _prom_value(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def prometheus_from_snapshot(snap: dict) -> str:
    """Prometheus text exposition (v0.0.4) of a ``snapshot()`` payload.

    Histograms expose ``_count`` / ``_sum`` plus quantile samples in the
    summary style (``{quantile="0.5"}``) — the sketch stores quantiles,
    not cumulative le-buckets, so summary is the faithful rendering.
    """
    lines = []
    for name in sorted(snap):
        fam = snap[name]
        kind = {"histogram": "summary"}.get(fam["kind"], fam["kind"])
        if fam.get("help"):
            lines.append(f"# HELP {name} {fam['help']}")
        lines.append(f"# TYPE {name} {kind}")
        for cell in fam["cells"]:
            labels = cell.get("labels", {})
            if fam["kind"] == "histogram":
                for p in PERCENTILES:
                    q = dict(labels, quantile=str(p / 100.0))
                    lines.append(
                        f"{name}{_prom_labels(q)} "
                        f"{_prom_value(cell[f'p{p:g}'])}")
                lines.append(f"{name}_count{_prom_labels(labels)} "
                             f"{_prom_value(cell['count'])}")
                lines.append(f"{name}_sum{_prom_labels(labels)} "
                             f"{_prom_value(cell['sum'])}")
            else:
                lines.append(f"{name}{_prom_labels(labels)} "
                             f"{_prom_value(cell['value'])}")
    return "\n".join(lines) + ("\n" if lines else "")


def render_snapshot_table(snap: dict) -> str:
    """Human table of a snapshot (the ``repro.telemetry.report`` view)."""
    rows = [("metric", "kind", "labels", "value")]
    for name in sorted(snap):
        fam = snap[name]
        for cell in fam["cells"]:
            labels = ",".join(f"{k}={v}"
                              for k, v in sorted(cell.get("labels", {}).items()))
            if fam["kind"] == "histogram":
                val = (f"n={cell['count']} mean={cell['mean']:.6g} "
                       + " ".join(f"p{p:g}={cell[f'p{p:g}']:.6g}"
                                  for p in PERCENTILES))
            else:
                val = f"{cell['value']:.6g}"
            rows.append((name, fam["kind"], labels or "-", val))
    if len(rows) == 1:
        return "(no metrics recorded)"
    widths = [max(len(r[i]) for r in rows) for i in range(3)]
    out = []
    for i, r in enumerate(rows):
        out.append("  ".join([r[0].ljust(widths[0]), r[1].ljust(widths[1]),
                              r[2].ljust(widths[2]), r[3]]))
        if i == 0:
            out.append("  ".join("-" * w for w in widths + [8]))
    return "\n".join(out)


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem writes to (kernels,
    serving, sessions).  Tests isolate via ``default_registry().reset()``
    — conftest installs that as an autouse fixture."""
    return _DEFAULT
