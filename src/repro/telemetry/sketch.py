"""Mergeable streaming quantile sketch for latency histograms.

The serving engine observes one latency sample per emitted token; a run
can emit millions, and per-request / per-engine sketches must combine
into one fleet view, so the estimator has to be *mergeable* with a
deterministic result.  The sketch is a two-phase hybrid:

  * **exact phase** — up to ``max_exact`` samples are kept verbatim, so
    small runs (every test, every smoke bench) report exact quantiles;
  * **bucketed phase** — past that, samples collapse into DDSketch-style
    logarithmic buckets: magnitude index ``ceil(log_gamma |x|)`` with
    ``gamma = (1 + alpha) / (1 - alpha)``, held in separate stores per
    sign (the magnitude index is itself negative for ``|x| < 1``, so
    sign must be carried by the store, not the index).  This bounds the
    *relative* error of any quantile estimate by ``alpha`` (the bucket
    midpoint is within ``alpha`` of every value the bucket holds).

Merging is associative and commutative by construction: bucket
assignment is a pure per-value function (independent of arrival or merge
order) and bucket counts add; two exact-phase sketches whose union still
fits stay exact.  ``tests/test_telemetry.py`` seals all three contracts
(associativity, rank/relative-error bound, small-n exactness) with
hypothesis properties.

No numpy/jax imports: the sketch is pure python so the scheduler-side
hot path (one ``add`` per token) stays allocation-light and the module
is importable anywhere (report CLIs, conftest) without pulling in jax.
"""

from __future__ import annotations

import math
from typing import Iterable, Optional

__all__ = ["QuantileSketch"]

#: Default exact-phase capacity: plenty for tests/smokes, tiny in memory.
DEFAULT_MAX_EXACT = 128
#: Default relative-error bound for the bucketed phase (1%).
DEFAULT_ALPHA = 0.01


class QuantileSketch:
    """Mergeable quantile sketch: exact under small n, ``alpha``-relative
    error beyond.  Tracks count/sum/min/max exactly in both phases."""

    __slots__ = ("alpha", "max_exact", "_gamma", "_log_gamma", "_exact",
                 "_pos", "_neg", "_zero", "count", "sum", "min", "max")

    def __init__(self, alpha: float = DEFAULT_ALPHA,
                 max_exact: int = DEFAULT_MAX_EXACT):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        if max_exact < 0:
            raise ValueError(f"max_exact must be >= 0, got {max_exact}")
        self.alpha = float(alpha)
        self.max_exact = int(max_exact)
        self._gamma = (1.0 + alpha) / (1.0 - alpha)
        self._log_gamma = math.log(self._gamma)
        self._exact: Optional[list] = []  # None once bucketed
        #: Separate per-sign stores keyed by the *magnitude* index
        #: ``ceil(log_gamma |x|)`` (standard DDSketch layout).  A single
        #: sign-mirrored dict would collide: ``|x| < 1`` has a negative
        #: magnitude index, which a mirror scheme confuses with the
        #: opposite sign.
        self._pos: dict[int, int] = {}
        self._neg: dict[int, int] = {}
        self._zero = 0  # exact zeros (log-bucket index is undefined at 0)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- ingestion -----------------------------------------------------------

    def add(self, x: float) -> None:
        x = float(x)
        if math.isnan(x):
            raise ValueError("QuantileSketch cannot ingest NaN")
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if self._exact is not None:
            self._exact.append(x)
            if self.count > self.max_exact:
                self._collapse()
        else:
            self._bucket_add(x, 1)

    def _index(self, mag: float) -> int:
        """Deterministic bucket index for a *magnitude* ``mag > 0``.
        Negative for ``mag < 1`` — which is why the two signs live in
        separate stores rather than a mirrored index space."""
        return math.ceil(math.log(mag) / self._log_gamma)

    def _bucket_add(self, x: float, n: int) -> None:
        if x == 0.0:
            self._zero += n
        elif x > 0.0:
            i = self._index(x)
            self._pos[i] = self._pos.get(i, 0) + n
        else:
            i = self._index(-x)
            self._neg[i] = self._neg.get(i, 0) + n

    def _collapse(self) -> None:
        """Exact -> bucketed; per-value and order-independent, so any
        merge order that ends past ``max_exact`` lands on the same state."""
        assert self._exact is not None
        for v in self._exact:
            self._bucket_add(v, 1)
        self._exact = None

    # -- merge ---------------------------------------------------------------

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Pure merged copy (``self`` and ``other`` are untouched).

        Associative/commutative: the result depends only on the multiset
        of ingested values, never on merge order (the seal property).
        """
        if (self.alpha, self.max_exact) != (other.alpha, other.max_exact):
            raise ValueError(
                f"cannot merge sketches with different parameters: "
                f"(alpha={self.alpha}, max_exact={self.max_exact}) vs "
                f"(alpha={other.alpha}, max_exact={other.max_exact})")
        out = QuantileSketch(self.alpha, self.max_exact)
        out.count = self.count + other.count
        out.sum = self.sum + other.sum
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        if (self._exact is not None and other._exact is not None
                and out.count <= out.max_exact):
            out._exact = self._exact + other._exact
            return out
        out._exact = None
        for src in (self, other):
            if src._exact is not None:
                for v in src._exact:
                    out._bucket_add(v, 1)
            else:
                out._zero += src._zero
                for store, src_store in ((out._pos, src._pos),
                                         (out._neg, src._neg)):
                    for i, n in src_store.items():
                        store[i] = store.get(i, 0) + n
        return out

    def update(self, values: Iterable[float]) -> "QuantileSketch":
        for v in values:
            self.add(v)
        return self

    # -- queries -------------------------------------------------------------

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def is_exact(self) -> bool:
        return self._exact is not None

    def _representative(self, i: int) -> float:
        """Positive bucket midpoint for magnitude index ``i``: within
        ``alpha`` relative error of every magnitude the bucket holds
        (2*g^i/(g+1) for the (g^(i-1), g^i] bucket).  Callers apply the
        sign of the store the bucket came from."""
        return 2.0 * self._gamma ** i / (self._gamma + 1.0)

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1] (nearest-rank definition:
        the smallest ingested value whose rank >= ceil(q * n))."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))  # 1-based target rank
        if self._exact is not None:
            return sorted(self._exact)[rank - 1]
        # ordered sweep: negative buckets (largest magnitude = most
        # negative first), zeros, then positive buckets (smallest first)
        seen = 0
        for i in sorted(self._neg, reverse=True):
            seen += self._neg[i]
            if seen >= rank:
                return self._clamp(-self._representative(i))
        seen += self._zero
        if seen >= rank:
            return 0.0
        for i in sorted(self._pos):
            seen += self._pos[i]
            if seen >= rank:
                return self._clamp(self._representative(i))
        return self.max  # numeric-edge fallback; unreachable in practice

    def _clamp(self, v: float) -> float:
        """Keep representatives inside the observed range, so q=0/q=1
        degrade gracefully to the exact extrema."""
        return min(max(v, self.min), self.max)

    def percentiles(self, ps=(50, 95, 99)) -> dict[str, float]:
        return {f"p{p:g}": self.quantile(p / 100.0) for p in ps}

    # -- (de)serialization ---------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-safe state; ``from_dict`` round-trips it bit-exactly."""
        d = {
            "alpha": self.alpha,
            "max_exact": self.max_exact,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }
        if self._exact is not None:
            d["exact"] = list(self._exact)
        else:
            d["zero"] = self._zero
            d["pos"] = {str(i): n for i, n in sorted(self._pos.items())}
            d["neg"] = {str(i): n for i, n in sorted(self._neg.items())}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "QuantileSketch":
        out = cls(alpha=d["alpha"], max_exact=d["max_exact"])
        out.count = int(d["count"])
        out.sum = float(d["sum"])
        out.min = math.inf if d["min"] is None else float(d["min"])
        out.max = -math.inf if d["max"] is None else float(d["max"])
        if "exact" in d:
            out._exact = [float(v) for v in d["exact"]]
        else:
            out._exact = None
            out._zero = int(d.get("zero", 0))
            out._pos = {int(i): int(n) for i, n in d.get("pos", {}).items()}
            out._neg = {int(i): int(n) for i, n in d.get("neg", {}).items()}
        return out

    # -- canonical equality (the associativity seal compares these) ---------

    def _canonical(self) -> tuple:
        if self._exact is not None:
            return ("exact", tuple(sorted(self._exact)))
        return ("buckets", self._zero, tuple(sorted(self._pos.items())),
                tuple(sorted(self._neg.items())))

    def __eq__(self, other) -> bool:
        if not isinstance(other, QuantileSketch):
            return NotImplemented
        return ((self.alpha, self.max_exact, self.count)
                == (other.alpha, other.max_exact, other.count)
                and self._canonical() == other._canonical())

    __hash__ = None  # mutable

    def __repr__(self) -> str:
        phase = "exact" if self._exact is not None else "buckets"
        return (f"QuantileSketch(n={self.count}, {phase}, "
                f"alpha={self.alpha})")
