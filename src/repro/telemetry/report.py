"""Render telemetry artifacts: human tables + Prometheus exposition.

Consumes the ``telemetry`` block embedded in run artifacts (``serve
--json``, ``train --json``, ``benchmarks/run.py --json``) or a raw
``MetricsRegistry.snapshot()`` JSON, and validates exported Chrome
traces (the CI trace-schema step).

  PYTHONPATH=src python -m repro.telemetry.report results/serving/run.json
  PYTHONPATH=src python -m repro.telemetry.report run.json --prom
  PYTHONPATH=src python -m repro.telemetry.report --validate-trace trace.json
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.telemetry.metrics import (
    prometheus_from_snapshot,
    render_snapshot_table,
)
from repro.telemetry.spans import validate_chrome_trace


def extract_snapshot(artifact: dict) -> dict:
    """Metrics snapshot from a run artifact or a bare snapshot dump.

    Accepts: ``{"telemetry": {"metrics": {...}}}`` (session artifacts),
    ``{"metrics": {...}}``, or a raw ``snapshot()`` mapping.
    """
    if "telemetry" in artifact and isinstance(artifact["telemetry"], dict):
        inner = artifact["telemetry"]
        if "metrics" in inner:
            return inner["metrics"]
        return inner
    if "metrics" in artifact and isinstance(artifact["metrics"], dict):
        return artifact["metrics"]
    # bare snapshot: every value is a {"kind", "cells"} family
    if all(isinstance(v, dict) and "kind" in v and "cells" in v
           for v in artifact.values()):
        return artifact
    raise SystemExit(
        "error: no telemetry block found — run with "
        "--set telemetry.enabled=true to record one")


def latency_lines(artifact: dict) -> list[str]:
    """Per-request latency attribution lines from a serve artifact."""
    reqs = artifact.get("per_request")
    if not reqs or not isinstance(reqs, list):
        return []
    out = ["rid  queue_ms  ttft_ms  total_ms  tokens  ticks(enq->first->fin)"]
    for r in reqs:
        if "ttft_s" not in r:
            return []
        ticks = (f"{r.get('enqueue_tick', -1)}->"
                 f"{r.get('first_token_tick', -1)}->"
                 f"{r.get('finish_tick', -1)}")
        out.append(
            f"{r['rid']:>3}  {r.get('queue_s', 0.0)*1e3:8.1f}  "
            f"{r['ttft_s']*1e3:7.1f}  {r.get('latency_s', 0.0)*1e3:8.1f}  "
            f"{r.get('n_tokens', len(r.get('tokens', []))):>6}  {ticks}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact", nargs="?", default=None,
                    help="run artifact or metrics snapshot JSON")
    ap.add_argument("--prom", action="store_true",
                    help="print the Prometheus text exposition instead of "
                         "the human table")
    ap.add_argument("--validate-trace", default=None, metavar="PATH",
                    help="validate a Chrome trace-event file and print its "
                         "span census, then exit")
    args = ap.parse_args(argv)

    if args.validate_trace:
        with open(args.validate_trace) as f:
            events = validate_chrome_trace(f.read())
        census: dict[str, int] = {}
        for ev in events:
            census[ev["name"]] = census.get(ev["name"], 0) + 1
        print(f"{args.validate_trace}: {len(events)} events OK")
        for name in sorted(census):
            print(f"  {name}: {census[name]}")
        if args.artifact is None:
            return

    if args.artifact is None:
        ap.error("an artifact path (or --validate-trace) is required")
    with open(args.artifact) as f:
        artifact = json.load(f)
    snap = extract_snapshot(artifact)
    if args.prom:
        sys.stdout.write(prometheus_from_snapshot(snap))
        return
    print(render_snapshot_table(snap))
    lat = latency_lines(artifact)
    if lat:
        print("\nper-request latency attribution")
        for line in lat:
            print(f"  {line}")


if __name__ == "__main__":
    main()
