"""Sharded serving steps: rows over the data axis, logits packed on wire.

The spring-mesh serving program (DESIGN.md §14) genuinely shards request
rows: each device prefills/decodes its ``batch/world`` rows (per-row
compute is batch-composition-invariant — the engine's alone-vs-strangers
seal), then the per-shard logits cross the wire through
``packed_all_gather`` so every device reassembles the full ``(B, V)``
logit block bit-identically to the single-device oracle.  KV-cache
leaves stay sharded on their batch dim between steps; specs come from
the same ``logical_axes_for_path`` table jit boundary shardings use,
restricted to the ``data`` axis.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import packed_all_gather
from repro.dist.mesh import data_axis_size
from repro.runtime.compat import shard_map
from repro.runtime.sharding import logical_to_spec, sharding_context
from repro.runtime.tree_sharding import logical_axes_for_path
from repro.serving.steps import make_decode_step, make_prefill_step

#: shard_map rules for serving: only the data axis participates (pod and
#: model axes stay replicated here); unknown logical axes replicate.
DATA_ONLY_RULES: dict[str, tuple] = {
    "batch": (("data",),),
    "cache_batch": (("data",),),
}


def _cache_specs(cache, mesh):
    """PartitionSpec tree for a cache pytree: batch dim over 'data',
    everything else replicated (leading scanned-layer dims handled by
    the path table's None padding)."""
    with sharding_context(mesh, DATA_ONLY_RULES):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: logical_to_spec(
                logical_axes_for_path(p, l.shape), l.shape, mesh),
            cache)


def _row_specs(batch, mesh):
    """PartitionSpec tree sharding dim 0 of every leaf over 'data'."""
    with sharding_context(mesh, DATA_ONLY_RULES):
        return jax.tree_util.tree_map(
            lambda l: logical_to_spec(
                ("batch",) + (None,) * (l.ndim - 1), l.shape, mesh),
            batch)


def _gather_logits(logits, world, impl):
    b_local, vocab = logits.shape
    flat = packed_all_gather(logits.reshape(-1), axis_name="data", impl=impl)
    return flat.reshape(world * b_local, vocab)


def make_sharded_prefill_step(arch, step_cfg, mesh, reduced: bool = False,
                              impl: Optional[str] = None):
    base = make_prefill_step(arch, step_cfg, mesh=None, reduced=reduced)
    world = data_axis_size(mesh)

    def body(params, batch, key):
        logits, cache = base(params, batch, key)
        return _gather_logits(logits, world, impl), cache

    def prefill(params, batch, key):
        _, cache_shape = jax.eval_shape(base, params, batch, key)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(), _row_specs(batch, mesh), P()),
            out_specs=(P(), _cache_specs(cache_shape, mesh)),
            axis_names={"data"}, check_vma=False,
        )
        return fn(params, batch, key)

    return prefill


def make_sharded_decode_step(arch, step_cfg, mesh, reduced: bool = False,
                             impl: Optional[str] = None):
    base = make_decode_step(arch, step_cfg, mesh=None, reduced=reduced)
    world = data_axis_size(mesh)

    def body(params, tokens, cache, key):
        logits, new_cache = base(params, tokens, cache, key)
        return _gather_logits(logits, world, impl), new_cache

    def decode(params, tokens, cache, key):
        _, cache_shape = jax.eval_shape(base, params, tokens, cache, key)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(), _row_specs(tokens, mesh), _cache_specs(cache, mesh),
                      P()),
            out_specs=(P(), _cache_specs(cache_shape, mesh)),
            axis_names={"data"}, check_vma=False,
        )
        return fn(params, tokens, cache, key)

    return decode
