"""spring-mesh: sharded training & serving with packed collectives.

SPRING's binary-mask format (20·density + 1 bits/elem) governs memory
and the KV pool; this package puts it on the *wire*.  Inter-device
traffic — parameter/gradient exchange in training, logits in serving —
crosses the mesh as packed values + occupancy-mask words through the
``packed_all_gather`` / ``packed_reduce_scatter`` registry op families
(``repro.dist.collectives``), with the same exact byte accounting the
rest of the attribution spine uses.  ``repro.dist.train`` and
``repro.dist.serve`` build the ``shard_map``'d session programs;
``repro.dist.mesh`` builds explicit ``(pod, data, model)`` meshes from a
``MeshSpec``.  Semantics, wire format, and the bit-exactness guarantees
are documented in DESIGN.md §14.

Import submodules directly (``from repro.dist import collectives``);
this package root stays import-light so the kernel registry can load
``repro.dist.collectives`` without cycles.
"""
