"""Explicit ``(pod, data, model)`` mesh construction for spring-mesh.

``MeshSpec`` kinds ("single", "debug", ...) keep resolving through
``api.sessions.build_mesh``; this module handles the explicit-axes form
(``--set shape.mesh.data=4``), where the spec names the extents directly
and the device pool must be large enough to honor them.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_explicit_mesh(pod: int, data: int, model: int) -> Mesh:
    """Build a ``(pod, data, model)`` mesh over the first pod*data*model
    visible devices (``jax.make_mesh`` device order, same as the debug
    mesh).  On a CPU host the pool is grown with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the CI mesh
    job and the tests/conftest.py ``debug_mesh`` fixture both do."""
    need = pod * data * model
    have = len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"shape.mesh pod{pod}.data{data}.model{model} needs {need} "
            f"devices but only {have} are visible; on a CPU host export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "before jax initializes")
    return jax.make_mesh((pod, data, model), ("pod", "data", "model"))


def data_axis_size(mesh: Mesh) -> int:
    """Extent of the ``data`` axis (1 when the mesh doesn't have one)."""
    return int(dict(mesh.shape).get("data", 1))
