"""Sharded train step: replicated compute + packed gradient exchange.

The spring-mesh training program (DESIGN.md §14) keeps params, optimizer
state and the batch replicated across the ``data`` axis — every device
runs the identical forward/backward — and splices a *real* packed
reduce-scatter / all-gather round trip into the gradient path via the
``grad_sync`` seam of ``make_train_step``.  Because the per-device
addends are identical and the world is a power of two (RunSpec
validates), the tree sum is exactly ``world·g`` and the ``/world``
rescale is an exponent shift, so the synced gradients — and therefore
the losses — are bit-identical to the single-device oracle while the
gradients genuinely cross the wire binary-mask compressed.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import packed_all_reduce_mean
from repro.dist.mesh import data_axis_size
from repro.runtime.compat import shard_map
from repro.runtime.train import make_train_step


def make_sharded_train_step(arch, step_cfg, mesh, reduced: bool = False,
                            impl: Optional[str] = None):
    """Build the shard_map'd train step for an explicit data mesh."""
    if step_cfg.compress_pod_grads:
        raise ValueError(
            "compress_pod_grads drives the int8+EF pod link; the packed "
            "data-axis exchange is a separate link — use shape.mesh.pod "
            "for pods or drop shape.mesh.data")
    world = data_axis_size(mesh)

    def grad_sync(grads):
        # one fused wire transaction: every gradient leaf rides a single
        # packed reduce-scatter -> /world -> all-gather round trip
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        flat = jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])
        synced = packed_all_reduce_mean(flat, axis_name="data", world=world,
                                        impl=impl)
        out, off = [], 0
        for l in leaves:
            out.append(synced[off:off + l.size].reshape(l.shape).astype(l.dtype))
            off += l.size
        return jax.tree_util.tree_unflatten(treedef, out)

    base = make_train_step(arch, step_cfg, mesh=None, reduced=reduced,
                           grad_sync=grad_sync)

    def step(state, batch):
        in_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            jax.tree_util.tree_map(lambda _: P(), batch),
        )
        out_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            P(),
        )
        fn = shard_map(
            base, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names={"data"}, check_vma=False,
        )
        return fn(state, batch)

    return step
