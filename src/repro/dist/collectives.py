"""Packed collectives: ``packed_all_gather`` / ``packed_reduce_scatter``.

SPRING's binary-mask format on the *wire* (DESIGN.md §14): a collective
payload travels as its non-zeros collapsed to the front at the 20-bit
SPRING value width plus 1-bit-per-element packed occupancy words, so the
link moves ``20·density + 1`` bits/elem at word alignment instead of a
dense fp32's 32.  Protocol per device: pack local shard -> all-gather the
canonical (values, mask-words) pair -> unpack every row -> concatenate
(all-gather) or pairwise-tree-sum and slice own shard (reduce-scatter).

Both ops have two modes:

  simulation   ``axis_name=None``; input is the stacked per-device
               payload ``(D, n)`` and the collective is replayed locally.
               This is what the registry parity examples (and the tier-1
               bit-identity suite) exercise on one device.
  collective   ``axis_name="data"`` under ``shard_map``; input is the
               local ``(n,)`` shard and the wire hop is a real
               ``jax.lax.all_gather`` wrapped in a ``jax.named_scope``
               so HLO op_name metadata lands in the collective
               attribution buckets.

Bit-exactness: the reduction is a fixed pairwise tree (power-of-two
worlds only — RunSpec validates ``shape.mesh.data``), so summing D
identical addends yields exactly ``D·x`` and the later ``/D`` rescale is
an exact exponent shift.  The only value canonicalization is
``-0.0 -> +0.0`` (occupancy bit 0) — the ``kv_pack`` precedent, invisible
to downstream math.

Implementation ladder (through ``registry.resolve``): ref = cumsum-scatter
collapse + reshape word pack; jnp = stable-argsort collapse + gather word
pack; interpret = mask words from the Pallas ``mask_pack`` kernel in
interpret mode (collapse via ref).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masking import (
    MASK_WORD_BITS,
    collapse_to_front,
    expand_from_mask,
    pack_mask_bits,
    unpack_mask_bits,
)
from repro.kernels import registry

#: SPRING wire width of one live value (IL4 + FL16 fixed point) — the
#: same interface width the KV pool and memstash account with.
COLLECTIVE_VALUE_BITS = 20


def _n_words(n: int) -> int:
    return (n + MASK_WORD_BITS - 1) // MASK_WORD_BITS


# -- per-impl pack/unpack pairs ----------------------------------------------


def _pack_ref(flat):
    bits = flat != 0
    return collapse_to_front(flat, bits, flat.shape[0]), pack_mask_bits(bits)


def _unpack_ref(values, words, length):
    return expand_from_mask(values, unpack_mask_bits(words, length))


def _pack_jnp(flat):
    # independent exact lowering: stable-argsort collapse + gather word pack
    n = flat.shape[0]
    bits = flat != 0
    order = jnp.argsort(jnp.logical_not(bits), stable=True)
    nnz = bits.sum().astype(jnp.int32)
    values = jnp.where(jnp.arange(n) < nnz, flat[order],
                       jnp.zeros((), flat.dtype))
    word = jnp.arange(n) // MASK_WORD_BITS
    shift = (jnp.arange(n) % MASK_WORD_BITS).astype(jnp.uint32)
    contrib = jnp.where(bits, jnp.uint32(1) << shift, jnp.uint32(0))
    words = jnp.zeros((_n_words(n),), jnp.uint32).at[word].add(contrib)
    return values, words


def _unpack_jnp(values, words, length):
    idx = jnp.arange(length)
    shift = (idx % MASK_WORD_BITS).astype(jnp.uint32)
    bits = (words[idx // MASK_WORD_BITS] >> shift) & jnp.uint32(1)
    src = jnp.cumsum(bits.astype(jnp.int32)) - 1
    cap = values.shape[0]
    live = (bits == 1) & (src < cap)
    gathered = values[jnp.clip(src, 0, cap - 1)]
    return jnp.where(live, gathered, jnp.zeros((), values.dtype))


def _pack_kernel(flat, *, interpret):
    from repro.kernels.mask_compress.mc_kernel import mask_pack_pallas
    from repro.kernels.mask_compress.ops import _pad2d

    n = flat.shape[0]
    bits = flat != 0
    x2d, _, _ = _pad2d(flat)
    words = mask_pack_pallas(x2d, interpret=interpret)
    words = words.reshape(-1)[:_n_words(n)]
    return collapse_to_front(flat, bits, n), words


def _pack_interpret(flat):
    return _pack_kernel(flat, interpret=True)


def _pack_pallas(flat):
    return _pack_kernel(flat, interpret=False)


def _tree_sum(rows):
    """Fixed pairwise reduction over axis 0 — the §14 bit-exactness seal.
    Requires a power-of-two row count (RunSpec validates mesh.data)."""
    d = rows.shape[0]
    if d & (d - 1):
        raise ValueError(
            f"packed reduce: world size must be a power of two, got {d}")
    while rows.shape[0] > 1:
        rows = rows[0::2] + rows[1::2]
    return rows[0]


# -- op factories -------------------------------------------------------------


def _make_all_gather(pack, unpack):
    def fn(x, *, axis_name: Optional[str] = None):
        if axis_name is None:
            d, n = x.shape
            return jnp.concatenate(
                [unpack(*pack(x[i]), n) for i in range(d)], axis=0)
        (n,) = x.shape
        v, w = pack(x)
        with jax.named_scope("packed_all_gather"):
            vg = jax.lax.all_gather(v, axis_name)
            wg = jax.lax.all_gather(w, axis_name)
        d = vg.shape[0]
        return jnp.concatenate(
            [unpack(vg[i], wg[i], n) for i in range(d)], axis=0)

    return fn


def _make_reduce_scatter(pack, unpack):
    def fn(x, *, axis_name: Optional[str] = None):
        if axis_name is None:
            d, n = x.shape
            if n % d:
                raise ValueError(f"payload length {n} not divisible by world {d}")
            rows = jnp.stack([unpack(*pack(x[i]), n) for i in range(d)])
            return _tree_sum(rows).reshape(d, n // d)
        (n,) = x.shape
        v, w = pack(x)
        with jax.named_scope("packed_reduce_scatter"):
            vg = jax.lax.all_gather(v, axis_name)
            wg = jax.lax.all_gather(w, axis_name)
        d = vg.shape[0]
        if n % d:
            raise ValueError(f"payload length {n} not divisible by world {d}")
        rows = jnp.stack([unpack(vg[i], wg[i], n) for i in range(d)])
        total = _tree_sum(rows)
        shard = n // d
        return jax.lax.dynamic_slice_in_dim(
            total, jax.lax.axis_index(axis_name) * shard, shard)

    return fn


# -- dense references (same tree order => per-shard bit-identity) ------------


def dense_all_gather(x, *, axis_name: Optional[str] = None):
    """Uncompressed reference with the packed op's exact semantics."""
    if axis_name is None:
        return x.reshape(-1)
    with jax.named_scope("dense_all_gather"):
        return jax.lax.all_gather(x, axis_name).reshape(-1)


def dense_reduce_scatter(x, *, axis_name: Optional[str] = None):
    """Uncompressed reference using the same pairwise tree reduction."""
    if axis_name is None:
        d, n = x.shape
        return _tree_sum(x).reshape(d, n // d)
    (n,) = x.shape
    with jax.named_scope("dense_reduce_scatter"):
        rows = jax.lax.all_gather(x, axis_name)
    d = rows.shape[0]
    total = _tree_sum(rows)
    shard = n // d
    return jax.lax.dynamic_slice_in_dim(
        total, jax.lax.axis_index(axis_name) * shard, shard)


# -- registry examples --------------------------------------------------------


def _shard_block(seed: int, d: int, n: int, density: float,
                 dtype=jnp.float32) -> jax.Array:
    """Stacked per-device payload with elementwise density (sim mode)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (d, n), jnp.float32)
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (d, n)) < density
    return jnp.where(keep, x, 0.0).astype(dtype)


def _collective_examples() -> list:
    # payload shapes mirror the session modes: dense fp32, quant (bf16 at
    # ReLU-ish density), quant_sparse (pruned, word-unaligned), empty
    return [
        ((_shard_block(0, 2, 1024, 1.0),), {}),
        ((_shard_block(1, 4, 512, 0.5, jnp.bfloat16),), {}),
        ((_shard_block(2, 4, 500, 0.1),), {}),
        ((jnp.zeros((2, 64), jnp.float32),), {}),
    ]


registry.register_op("packed_all_gather", oracle="ref",
                     examples=_collective_examples,
                     compare={"kind": "exact"})
registry.register_impl("packed_all_gather", "ref", priority=10)(
    _make_all_gather(_pack_ref, _unpack_ref))
registry.register_impl("packed_all_gather", "jnp", priority=20)(
    _make_all_gather(_pack_jnp, _unpack_jnp))
registry.register_impl("packed_all_gather", "interpret", selectable=False)(
    _make_all_gather(_pack_interpret, _unpack_jnp))
registry.register_impl("packed_all_gather", "pallas", priority=30,
                       available=registry.on_tpu)(
    _make_all_gather(_pack_pallas, _unpack_jnp))

registry.register_op("packed_reduce_scatter", oracle="ref",
                     examples=_collective_examples,
                     compare={"kind": "exact"})
registry.register_impl("packed_reduce_scatter", "ref", priority=10)(
    _make_reduce_scatter(_pack_ref, _unpack_ref))
registry.register_impl("packed_reduce_scatter", "jnp", priority=20)(
    _make_reduce_scatter(_pack_jnp, _unpack_jnp))
registry.register_impl("packed_reduce_scatter", "interpret", selectable=False)(
    _make_reduce_scatter(_pack_interpret, _unpack_jnp))
registry.register_impl("packed_reduce_scatter", "pallas", priority=30,
                       available=registry.on_tpu)(
    _make_reduce_scatter(_pack_pallas, _unpack_jnp))


# -- public wrappers ----------------------------------------------------------


def collective_wire_bits(nnz, length: int, world: int,
                         value_bits: int = COLLECTIVE_VALUE_BITS):
    """Bits the link moves for one collective: every device contributes
    its live values at the SPRING width plus its packed mask words.  At
    word alignment this is ``world * length * (value_bits*density + 1)``
    — the ``formula_bits_per_elem`` accounting."""
    return nnz * value_bits + world * _n_words(length) * MASK_WORD_BITS


def _note(op: str, x, axis_name: Optional[str]) -> None:
    # host-side wire accounting: simulation mode only (in collective mode
    # x is a tracer inside shard_map; dryrun measures via collective_probe)
    if axis_name is not None or isinstance(x, jax.core.Tracer):
        return
    d, n = x.shape
    nnz = float(jnp.count_nonzero(x))
    wire = float(collective_wire_bits(nnz, n, d)) / 8.0
    density = nnz / float(d * n) if d * n else 0.0
    from repro.telemetry.metrics import default_registry

    reg = default_registry()
    reg.inc("spring_mesh_collective_bytes_total", wire, kind=op,
            help="packed-collective wire bytes (formula accounting)")
    reg.observe("spring_mesh_collective_density", density, kind=op,
                help="elementwise density of collective payloads")
    if registry.metrics_active():
        registry.note_metric(op, wire_bytes=wire, density=density)


def packed_all_gather(x: jax.Array, *, axis_name: Optional[str] = None,
                      impl: Optional[str] = None) -> jax.Array:
    """All-gather through the packed wire format.

    Simulation mode (``axis_name=None``): ``x`` is ``(D, n)`` stacked
    payloads; returns the ``(D*n,)`` device-order concatenation every
    device would hold.  Collective mode: ``x`` is the local ``(n,)``
    shard inside ``shard_map``; returns ``(D*n,)`` per device.
    """
    kimpl = registry.resolve("packed_all_gather", impl)
    out = kimpl.fn(x, axis_name=axis_name)
    _note("packed_all_gather", x, axis_name)
    return out


def packed_reduce_scatter(x: jax.Array, *, axis_name: Optional[str] = None,
                          impl: Optional[str] = None) -> jax.Array:
    """Reduce-scatter (pairwise-tree sum) through the packed wire format.

    Simulation mode: ``x`` is ``(D, n)``; returns the ``(D, n//D)``
    stacked shards.  Collective mode: local ``(n,)`` in, own ``(n//D,)``
    shard out.
    """
    kimpl = registry.resolve("packed_reduce_scatter", impl)
    out = kimpl.fn(x, axis_name=axis_name)
    _note("packed_reduce_scatter", x, axis_name)
    return out


def packed_all_reduce_mean(flat: jax.Array, *, axis_name: str, world: int,
                           impl: Optional[str] = None) -> jax.Array:
    """Mean-all-reduce as RS -> /world -> AG (both hops packed).

    Exact when the per-device inputs are identical and ``world`` is a
    power of two: the tree sum yields exactly ``world*x`` and the rescale
    is an exponent shift — the train-parity seal (DESIGN.md §14).
    """
    n = flat.shape[0]
    pad = (-n) % world
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = packed_reduce_scatter(flat, axis_name=axis_name, impl=impl)
    shard = shard / world
    full = packed_all_gather(shard, axis_name=axis_name, impl=impl)
    return full[:n]


def collective_probe(density: float = 0.5, world: int = 2,
                     length: int = 1 << 14,
                     impl: Optional[str] = None) -> dict:
    """Eager packed-collective probe for dry-run attribution.

    A lowered multi-chip cell never executes collectives on the host, so
    this replays one all-gather in simulation mode at the given payload
    density and reports the wire accounting: bytes moved, the reduction
    vs a dense fp32 collective, the measured-over-formula ratio (1.0 at
    word alignment — the ``20·density + 1`` cross-check), and whether the
    round trip reproduced the payload bit-exactly.
    """
    x = _shard_block(0, world, length, density)
    out = packed_all_gather(x, impl=impl)
    nnz = int(jnp.count_nonzero(x))
    wire = float(collective_wire_bits(nnz, length, world)) / 8.0
    dense_bytes = world * length * 4.0
    from repro.memstash.format import formula_bits_per_elem

    formula = world * length * formula_bits_per_elem(
        nnz / (world * length), COLLECTIVE_VALUE_BITS) / 8.0
    return {
        "world": world,
        "density": nnz / (world * length),
        "wire_bytes": wire,
        "dense_bytes": dense_bytes,
        "compression_vs_fp32": dense_bytes / wire,
        "wire_vs_formula": wire / formula,
        "exact": bool(jnp.array_equal(out, x.reshape(-1))),
        "impl": registry.resolve("packed_all_gather", impl, _count=False).name,
    }
