"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE FIRST TWO LINES must run before any other import (jax locks the
device count on first init): they give this process 512 placeholder host
devices so ``jax.make_mesh`` can build the production meshes.

Per cell this emits: memory_analysis (fits-on-chip proof), cost_analysis
(FLOPs/bytes for §Roofline), and the parsed collective-bytes table, as
JSON consumed by EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k --mesh multi --mode dense --out results/q.json
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_arch  # noqa: E402
from repro.core.spring_ops import DENSE, QUANT, QUANT_SPARSE  # noqa: E402
from repro.kernels import registry as kernel_registry  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    collective_bytes,
    fusion_adjusted_bytes,
    memory_summary,
    roofline_terms,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh  # noqa: E402
from repro.optim.optimizers import OptimizerConfig  # noqa: E402
from repro.runtime.compat import cost_analysis_dict  # noqa: E402
from repro.runtime.train import (  # noqa: E402
    StepConfig,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.runtime.tree_sharding import batch_shardings, tree_shardings  # noqa: E402

MODES = {"dense": DENSE, "quant": QUANT, "quant_sparse": QUANT_SPARSE}


def _param_counts(arch) -> tuple[float, float]:
    """(total, active) parameter counts from init shapes (no allocation)."""
    from repro.models import encdec as ed_mod
    from repro.models import lm as lm_mod

    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), arch.config))
    total = emb = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if names[-1] == "embedding":
            emb += n
        if names[-1] in ("w_gate", "w_up", "w_down"):
            expert += n
    # tied embeddings serve as the lm_head -> their matmul IS model compute
    tied = bool(getattr(arch.config, "tie_embeddings", False)) or arch.is_encdec
    active = total - (0 if tied else emb)
    cfg = arch.config
    moe = getattr(cfg, "moe", None)
    if moe is not None and expert:
        active -= expert * (1.0 - moe.top_k / moe.n_experts)
    return float(total), float(active)


def model_flops(arch, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    total, active = _param_counts(arch)
    d_tokens = sh.global_batch * sh.seq_len
    if arch.is_encdec and sh.kind != "decode":
        d_tokens = sh.global_batch * (sh.seq_len + arch.config.enc_seq)
    if sh.kind == "train":
        return 6.0 * active * d_tokens
    if sh.kind == "prefill":
        return 2.0 * active * d_tokens
    return 2.0 * active * sh.global_batch  # decode: per emitted token


def build_mesh(kind: str):
    if kind == "single":
        return make_production_mesh(multi_pod=False)
    if kind == "multi":
        return make_production_mesh(multi_pod=True)
    if kind == "debug":
        return make_debug_mesh()
    if kind == "debug_multi":
        return make_debug_mesh(multi_pod=True)
    raise ValueError(kind)


def run_lower(arch, shape_name, mesh, step_cfg, serve_dtype):
    """Lower one cell (train | prefill | decode) with explicit shardings."""
    sh = SHAPES[shape_name]
    mode_quant = step_cfg.spring.is_quantized
    if sh.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), arch, step_cfg)
        )
        batch_shapes = {
            k: v for k, v in arch.input_specs(shape_name, arch.config).items()
        }
        step = make_train_step(arch, step_cfg, mesh=mesh)
        state_sh = tree_shardings(state_shapes, mesh)
        batch_sh = batch_shardings(batch_shapes, mesh)
        return jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_shapes, batch_shapes)

    from repro.models import encdec as ed_mod
    from repro.models import lm as lm_mod

    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    param_shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), arch.config))
    param_shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype)
        if s.dtype == jnp.float32 else s, param_shapes)
    param_sh = tree_shardings(param_shapes, mesh)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if sh.kind == "prefill":
        batch_shapes = dict(arch.input_specs(shape_name, arch.config))
        batch_sh = batch_shardings(batch_shapes, mesh)
        fn = make_prefill_step(arch, step_cfg, mesh=mesh)
        out_shapes = jax.eval_shape(fn, param_shapes, batch_shapes, key_spec)
        out_sh = (None, tree_shardings(out_shapes[1], mesh))
        return jax.jit(
            fn, in_shardings=(param_sh, batch_sh, None), out_shardings=out_sh
        ).lower(param_shapes, batch_shapes, key_spec)

    # decode
    cache_shapes = arch.cache_specs(
        shape_name, arch.config,
        cache_dtype="int8" if step_cfg.int8_cache else None)
    cache_shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype)
        if s.dtype == jnp.bfloat16 and mode_quant else s, cache_shapes)
    cache_sh = tree_shardings(cache_shapes, mesh)
    tok_shapes = dict(arch.input_specs(shape_name, arch.config))
    tok_sh = batch_shardings(tok_shapes, mesh)
    fn = make_decode_step(arch, step_cfg, mesh=mesh)
    return jax.jit(
        fn,
        in_shardings=(param_sh, tok_sh["tokens"], cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    ).lower(param_shapes, tok_shapes["tokens"], cache_shapes, key_spec)


def _unrolled(arch):
    """Cost-shadow variant: fully unrolled layer scan so cost_analysis and
    the collective parse see every layer (XLA counts while bodies once)."""
    import dataclasses

    return dataclasses.replace(
        arch, config=dataclasses.replace(arch.config, scan_unroll=True)
    )


DEFAULT_TRAIN_MICROBATCH = 8  # grad accumulation: activation memory / 8
# MoE dispatch buffers replicate tokens x top_k; VLM carries 26B params:
# these archs need deeper accumulation to fit 16 GB/chip
# NB: global_batch/microbatch must stay divisible by the DP extent (16),
# else activations replicate: 256/16 = 16 rows/micro = 1 row per DP shard.
TRAIN_MICROBATCH_OVERRIDES = {
    "olmoe-1b-7b": 16, "deepseek-v2-lite-16b": 16, "internvl2-26b": 16,
}


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, mode: str,
             microbatch=None, verbose: bool = True, cost_unrolled: bool = True,
             seq_parallel: bool = False, bf16_logits: bool = False,
             layout: str = "tp", remat_policy: str = "full",
             cache_int8: bool = False, quant_opt: bool = False,
             variant: str = "baseline", kernel_impl: str | None = None,
             backward_sparsity: str = "auto",
             probe_density: float = 0.5) -> dict:
    import dataclasses as _dc

    arch = get_arch(arch_id)
    sh = SHAPES[shape_name]
    if microbatch is None and sh.kind == "train":
        microbatch = TRAIN_MICROBATCH_OVERRIDES.get(arch_id, DEFAULT_TRAIN_MICROBATCH)
    if bf16_logits and hasattr(arch.config, "bf16_logits"):
        arch = _dc.replace(arch, config=_dc.replace(arch.config, bf16_logits=True))
    if remat_policy != "full" and hasattr(arch.config, "remat_policy"):
        arch = _dc.replace(arch, config=_dc.replace(arch.config, remat_policy=remat_policy))
    if shape_name in arch.skipped_shapes():
        return {
            "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
            "mode": mode, "status": "skipped",
            "reason": arch.skipped_shapes()[shape_name],
        }
    mesh = build_mesh(mesh_kind)
    n_chips = mesh.devices.size
    rules_override = ()
    if seq_parallel:
        rules_override = (("seq", (("model",), None)),)
    if layout == "fsdp":
        # pure DP x FSDP: batch over all mesh axes, no tensor parallelism.
        # Wins when the model is small relative to the per-step token count
        # (TP activation all-reduces >> FSDP weight all-gathers).
        rules_override = rules_override + (
            ("batch", (("pod", "data", "model"), ("data", "model"))),
            ("heads", (None,)), ("kv_heads", (None,)),
            ("mlp_act", (None,)), ("vocab_act", (None,)),
            ("w_qkv", (None,)), ("w_mlp", (None,)), ("w_vocab", (None,)),
            ("w_embed", (("data", "model"), ("data",))),
            ("cache_batch", (("pod", "data", "model"), ("data", "model"), ("data",))),
            ("cache_seq", (None,)),
        )
    spring_cfg = MODES[mode]
    if quant_opt and spring_cfg.is_quantized:
        spring_cfg = _dc.replace(spring_cfg, weights_pre_quantized=True,
                                 operand_rounding="nearest")
    kpolicy = kernel_registry.KernelPolicy.parse(kernel_impl or "")
    spring_cfg = _dc.replace(spring_cfg, kernels=kpolicy)
    step_cfg = StepConfig(
        spring=spring_cfg,
        backward_sparsity=backward_sparsity,
        optimizer=OptimizerConfig(kind="adamw"),
        microbatch=microbatch,
        rules_override=rules_override,
        int8_cache=cache_int8,
    )
    serve_dtype = jnp.bfloat16 if mode == "dense" else jnp.float32

    kernel_registry.reset_dispatch_counts()
    t0 = time.time()
    lowered = run_lower(arch, shape_name, mesh, step_cfg, serve_dtype)
    t_lower = time.time() - t0
    # what the program actually dispatched at trace time, plus what the
    # policy resolves for every registered op on this host (roofline_report
    # renders both so BENCH/dry-run trajectories are backend-attributable)
    kernel_dispatch = kernel_registry.dispatch_counts()
    kernel_impls = kernel_registry.resolution_table(kpolicy)

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    bf16c = (mode == "dense")  # TPU-native bf16 math; CPU legalized it to f32
    cost = cost_analysis_dict(compiled)
    mem = memory_summary(compiled.memory_analysis())
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text, bf16_correct=bf16c)
    adj = fusion_adjusted_bytes(hlo_text, bf16_correct=bf16c)["fusion_adjusted_bytes"]

    # Cost-shadow: recompile with the layer scan unrolled AND the
    # microbatch scan disabled so per-layer FLOPs/bytes/collectives are
    # all visible (XLA cost analysis counts while bodies once; per-step
    # totals are microbatch-invariant).  Memory comes from the real
    # compile above; cost/collectives come from this one.
    t_cost_compile = None
    if cost_unrolled:
        import dataclasses as _dc

        t0 = time.time()
        shadow_cfg = _dc.replace(step_cfg, microbatch=None)
        shadow = run_lower(_unrolled(arch), shape_name, mesh, shadow_cfg, serve_dtype)
        shadow_c = shadow.compile()
        t_cost_compile = time.time() - t0
        cost = cost_analysis_dict(shadow_c)
        shadow_text = shadow_c.as_text()
        coll = collective_bytes(shadow_text, bf16_correct=bf16c)
        adj = fusion_adjusted_bytes(shadow_text, bf16_correct=bf16c)["fusion_adjusted_bytes"]
        del shadow_c, shadow_text

    mf = model_flops(arch, shape_name)
    terms = roofline_terms(cost, coll["total"], n_chips, model_flops=mf,
                           adjusted_bytes=adj)

    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
        "variant": variant,
        "status": "ok", "n_chips": int(n_chips), "microbatch": microbatch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_compile_s": round(t_cost_compile, 1) if t_cost_compile else None,
        "kernel_policy": kpolicy.describe(),
        "kernel_impls": kernel_impls,
        "kernel_dispatch": kernel_dispatch,
        "backward_sparsity": backward_sparsity,
        "memory": mem, "collectives": coll, "roofline": terms,
    }
    if mode == "quant_sparse" and backward_sparsity != "none" \
            and sh.kind == "train":
        # Measured fwd/bwd tile-skip at the probe density: the lowered
        # program never executes in a dry run, so this small eager probe
        # is what attributes backward sparsity savings per cell.
        from repro.kernels.masked_matmul.backward import sparsity_probe

        result["sparsity_probe"] = sparsity_probe(probe_density, size=256)
    if mode == "quant_sparse" and sh.kind == "decode":
        # Serving twin of the sparsity probe: measured KV wire bytes of
        # one packed block at the probe density, with the 20d+1 formula
        # cross-check (roofline_report renders the table).
        from repro.kernels.kv_cache.ops import kv_probe

        result["kv_probe"] = kv_probe(probe_density)
    if verbose:
        print(json.dumps(result, indent=2))
        print(f"peak bytes/chip (arg+out+temp-alias): {mem['peak_bytes_per_chip_est']/1e9:.3f} GB", file=sys.stderr)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "debug", "debug_multi"])
    ap.add_argument("--mode", default="dense", choices=list(MODES))
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-unrolled-cost", action="store_true",
                    help="skip the unrolled cost-shadow compile")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--bf16-logits", action="store_true")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--remat-policy", default="full", choices=["full", "block_io"])
    ap.add_argument("--cache-int8", action="store_true")
    ap.add_argument("--quant-opt", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--kernel-impl", default=None,
                    help="kernel policy spec, e.g. 'ref' or 'ssd_scan=jnp' "
                         "(see repro.kernels.registry.KernelPolicy.parse)")
    ap.add_argument("--backward-sparsity", default="auto",
                    choices=["none", "auto", "ref", "jnp", "interpret", "pallas"],
                    help="sparsity-aware backward pass for quant_sparse cells")
    ap.add_argument("--probe-density", type=float, default=0.5,
                    help="tile-granular density for the backward-skip probe")
    args = ap.parse_args()
    result = run_cell(args.arch, args.shape, args.mesh, args.mode, args.microbatch,
                      cost_unrolled=not args.no_unrolled_cost,
                      seq_parallel=args.seq_parallel, bf16_logits=args.bf16_logits,
                      layout=args.layout, remat_policy=args.remat_policy,
                      cache_int8=args.cache_int8, quant_opt=args.quant_opt,
                      variant=args.variant, kernel_impl=args.kernel_impl,
                      backward_sparsity=args.backward_sparsity,
                      probe_density=args.probe_density)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
