"""Multi-pod dry-run launcher: a thin adapter over the RunSpec API.

THE FIRST TWO LINES must run before any other import (jax locks the
device count on first init): they give this process 512 placeholder host
devices so ``jax.make_mesh`` can build the production meshes.

Per cell this emits: memory_analysis (fits-on-chip proof), cost_analysis
(FLOPs/bytes for §Roofline), the parsed collective-bytes table, and the
canonical resolved RunSpec (+hash/provenance), as JSON consumed by
EXPERIMENTS.md and ``roofline_report``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --spec examples/specs/dryrun_decode_debug.json
  PYTHONPATH=src python -m repro.launch.dryrun --set arch.id=qwen2-7b \
      --set shape.cell=train_4k --set shape.mesh=multi --out results/q.json

Legacy flag spellings (``--arch``, ``--shape``, ``--kernel-impl``, ...)
shim to the same RunSpec fields with a DeprecationWarning; ``run_cell``
keeps its keyword signature for programmatic callers.
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

import argparse  # noqa: E402,F401  (re-export site for older callers)
import json  # noqa: E402
import sys  # noqa: E402

from repro.api.cli import _SKIP, flag, make_parser, spec_from_args  # noqa: E402
from repro.api.sessions import (  # noqa: E402
    DryrunSession,
    build_mesh,
    dryrun_spec,
    model_flops,
    run_lower,
)
from repro.api.spec import (  # noqa: E402
    DEFAULT_TRAIN_MICROBATCH,
    TRAIN_MICROBATCH_OVERRIDES,
)
from repro.configs import SHAPES  # noqa: E402
from repro.core.spring_ops import MODES  # noqa: E402

LEGACY_FLAGS = (
    flag("--arch", "arch.id"),
    flag("--shape", "shape.cell", choices=list(SHAPES)),
    flag("--mesh", "shape.mesh",
         choices=["single", "multi", "debug", "debug_multi"]),
    flag("--mode", "numerics.mode", choices=list(MODES)),
    flag("--microbatch", "shape.microbatch", type=int),
    flag("--no-unrolled-cost", "dryrun.cost_unrolled", const=False),
    flag("--seq-parallel", "shape.seq_parallel", const=True),
    flag("--bf16-logits", "arch.bf16_logits", const=True),
    flag("--layout", "shape.layout", choices=["tp", "fsdp"]),
    # legacy quirk preserved: --remat-policy full was a no-op
    flag("--remat-policy", "arch.remat_policy",
         choices=["full", "block_io"],
         transform=lambda v: _SKIP if v == "full" else v),
    flag("--cache-int8", "serving.int8_cache", const=True),
    flag("--quant-opt", "dryrun.quant_opt", const=True),
    flag("--variant", "dryrun.variant"),
    flag("--kernel-impl", "kernels.policy"),
    flag("--backward-sparsity", "sparsity.backward",
         choices=["none", "auto", "ref", "jnp", "interpret", "pallas"]),
    flag("--probe-density", "sparsity.probe_density", type=float),
)


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, mode: str,
             microbatch=None, verbose: bool = True, cost_unrolled: bool = True,
             seq_parallel: bool = False, bf16_logits: bool = False,
             layout: str = "tp", remat_policy: str = "full",
             cache_int8: bool = False, quant_opt: bool = False,
             variant: str = "baseline", kernel_impl: str | None = None,
             backward_sparsity: str = "auto",
             probe_density: float = 0.5) -> dict:
    """Legacy keyword surface: builds the equivalent RunSpec and runs a
    :class:`repro.api.DryrunSession` (full configs, like the old path)."""
    spec = dryrun_spec(
        arch_id, shape_name, mesh_kind, mode, microbatch=microbatch,
        cost_unrolled=cost_unrolled, seq_parallel=seq_parallel,
        bf16_logits=bf16_logits, layout=layout, remat_policy=remat_policy,
        cache_int8=cache_int8, quant_opt=quant_opt, variant=variant,
        kernel_impl=kernel_impl, backward_sparsity=backward_sparsity,
        probe_density=probe_density)
    return DryrunSession(spec).run(verbose=verbose)


def build_parser():
    ap = make_parser(__doc__, LEGACY_FLAGS, out=True)
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    try:
        spec = spec_from_args("dryrun", args, LEGACY_FLAGS)
    except Exception as e:  # SpecError -> argparse-style exit
        raise SystemExit(f"error: {e}") from None
    # the pre-RunSpec CLI required --arch/--shape; keep a bare invocation
    # from silently compiling the default cell on the production mesh
    # (arch.reduced=null resolves run-conditionally: dryrun = full config)
    for path, old_flag in (("arch.id", "--arch"), ("shape.cell", "--shape")):
        if spec.provenance.get(path, "default") == "default":
            ap.error(f"{path} must be set (--spec file, --set {path}=..., "
                     f"or the deprecated {old_flag})")
    if args.explain:
        print(spec.describe())
        return 0
    result = DryrunSession(spec).run(verbose=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
