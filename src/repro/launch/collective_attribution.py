"""Attribute collective traffic to model components via HLO metadata —
the 'profiler' of the dry-run methodology (no wall-clock on CPU; the
lowered IR is the profile).

  PYTHONPATH=src python -m repro.launch.collective_attribution /tmp/x.hlo
"""

from __future__ import annotations

import collections
import re
import sys

from repro.launch.hlo_analysis import _COLLECTIVES, _shape_bytes

# one head regex for every line-oriented pass: result shape (tuple or
# scalar), op mnemonic, and — when present — the op_name metadata path
# (group 3 is None on unattributed lines, e.g. top-level parameters)
_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)"
    r'(?:.*metadata=\{[^}]*op_name="([^"]*)")?'
)


def _bucket(op_name: str) -> str:
    """Collapse op_name paths into human buckets."""
    if not op_name:
        return "(unattributed)"
    for key, label in [
        # spring-mesh packed collectives announce themselves via
        # jax.named_scope before any generic rule can claim the line
        ("packed_all_gather", "mesh-packed-gather"),
        ("packed_reduce_scatter", "mesh-packed-reduce"),
        ("dense_all_gather", "mesh-dense-gather"),
        ("dense_reduce_scatter", "mesh-dense-reduce"),
        ("transpose[", "backward"),
        ("chunked_softmax_xent", "loss/vocab"),
        ("checkpoint", "layer-remat"),
        ("bkgqs", "attention-scores"),
        ("bkgs", "attention-decode"),
        ("dot_general", "matmul"),
        ("while", "layer-scan"),
    ]:
        if key in op_name:
            return label
    parts = [p for p in op_name.split("/") if p and not p.startswith("jit(")]
    return parts[0] if parts else "(root)"


def attribute(hlo_text: str) -> dict[str, dict[str, float]]:
    out: dict[str, collections.Counter] = collections.defaultdict(collections.Counter)
    for line in hlo_text.splitlines():
        m = _LINE.match(line.strip())
        if not m:
            continue
        shape_str, op, op_name = m.groups()
        kind = next((c for c in _COLLECTIVES if op == c or op.startswith(c + "-start")), None)
        if kind is None or op.endswith("-done"):
            continue
        dt = re.search(r"(f32|bf16|f16|s8|u8|u32|s32)\[", shape_str)
        bucket = f"{_bucket(op_name or '')}:{dt.group(1) if dt else '?'}"
        out[kind][bucket] += _shape_bytes(shape_str)
    return {k: dict(v) for k, v in out.items()}


def main():
    text = open(sys.argv[1]).read()
    for kind, buckets in attribute(text).items():
        print(f"\n== {kind} ==")
        for b, by in sorted(buckets.items(), key=lambda kv: -kv[1])[:12]:
            print(f"  {by/1e9:8.2f} GB  {b}")


if __name__ == "__main__":
    main()
