"""Production mesh construction (spec'd by the assignment).

A FUNCTION, not a module constant — importing this module never touches
jax device state, so tests/benches see 1 CPU device unless the dry-run
entrypoint has set ``xla_force_host_platform_device_count`` first.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh for CI-scale dry-run smoke tests (e.g. 8 host devices)."""
    n = n_devices or len(jax.devices())
    if multi_pod:
        assert n % 2 == 0 and n >= 8
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    return jax.make_mesh((2, n // 2), ("data", "model"))
