"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Dry-run / §Roofline
tables.

  PYTHONPATH=src python -m repro.launch.roofline_report results/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load_all(d: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        try:
            out.append(json.load(open(f)))
        except Exception:
            pass
    return out


def fmt_bytes(b: float) -> str:
    return f"{b/1e9:.2f}"


def dryrun_table(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | chips | peak GB/chip | HLO GFLOP/chip | coll GB/chip (AG/AR/RS/A2A/CP) | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | skipped | - | {r['reason'][:50]} | - |")
            continue
        c = r["collectives"]
        coll = "/".join(
            f"{c.get(k,0)/1e9:.1f}" for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"))
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {r['memory']['peak_bytes_per_chip_est']/1e9:.2f} "
            f"| {rl['hlo_flops_per_chip']/1e9:.0f} "
            f"| {coll} "
            f"| {r['compile_s']}+{r.get('cost_compile_s') or 0} |")
    return "\n".join(lines)


def roofline_table(rows: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | frac-of-peak | MODEL_FLOPS | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| {t['dominant'].replace('_s','')} | {t['roofline_fraction_of_peak']:.3f} "
            f"| {t.get('model_flops',0):.3e} | {t.get('useful_flops_ratio',0):.2f} |")
    return "\n".join(lines)


def memstash_table(results: list[dict]) -> str:
    """Render ``repro.memstash.report`` JSONs: measured stash traffic per
    model vs the analytical binary-mask formula (bits/elem = 20*d + 1)."""
    lines = [
        "| model | stash points | mean density | dense fp32 MB | wire MB | ratio | wire/formula |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in results:
        s = r.get("summary", {})
        if not s.get("stash_points"):
            continue
        lines.append(
            f"| {r['model']} | {s['stash_points']} | {s['mean_density']:.3f} "
            f"| {s['dense_fp32_bytes']/1e6:.2f} | {s['wire_bytes']/1e6:.2f} "
            f"| {s['compression_vs_fp32']:.2f}x | {s['wire_vs_formula']:.4f} |")
    return "\n".join(lines)


def kernel_table(rows: list[dict]) -> str:
    """Render the per-cell kernel backend attribution (dry-run
    ``kernel_impls`` / ``kernel_dispatch``, emitted since the dispatch
    registry landed; older JSONs without the fields are skipped)."""
    lines = [
        "| arch | shape | policy | resolved (op=impl) | dispatches |",
        "|---|---|---|---|---|",
    ]
    any_row = False
    for r in rows:
        impls = r.get("kernel_impls")
        if r.get("status") != "ok" or not impls:
            continue
        any_row = True
        resolved = " ".join(f"{op}={name}" for op, name in sorted(impls.items())
                            if not str(name).startswith("error"))
        disp = r.get("kernel_dispatch") or {}
        dispatched = " ".join(
            f"{op}:{name}x{n}" for op, by in sorted(disp.items())
            for name, n in sorted(by.items())) or "-"
        lines.append(f"| {r['arch']} | {r['shape']} "
                     f"| {r.get('kernel_policy', 'auto')} | {resolved} | {dispatched} |")
    return "\n".join(lines) if any_row else ""


def backward_sparsity_table(rows: list[dict]) -> str:
    """Render per-cell backward tile-skip probes (dry-run ``sparsity_probe``
    emitted for quant_sparse train cells since the sparsity-aware backward
    landed; older JSONs without the field are skipped).  Forward and
    backward skip fractions are attributed separately — the backward
    columns are what the custom_vjp dx/dw kernels measured."""
    lines = [
        "| arch | shape | bwd policy | probe density | fwd skip | dX skip | dW skip |",
        "|---|---|---|---|---|---|---|",
    ]
    any_row = False
    for r in rows:
        p = r.get("sparsity_probe")
        if r.get("status") != "ok" or not p:
            continue
        any_row = True

        def f(v):
            return "-" if v is None else f"{v:.3f}"

        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('backward_sparsity', 'auto')} "
            f"| {p['density']:.2f} | {f(p['forward_tile_skip'])} "
            f"| {f(p['backward_tile_skip_dx'])} | {f(p['backward_tile_skip_dw'])} |")
    return "\n".join(lines) if any_row else ""


def kv_cache_table(rows: list[dict]) -> str:
    """Render per-cell serving KV-compression probes (dry-run ``kv_probe``
    emitted for quant_sparse decode cells since spring-serve landed;
    older JSONs without the field are skipped)."""
    lines = [
        "| arch | shape | impl | density | wire KB | vs fp32 | wire/formula |",
        "|---|---|---|---|---|---|---|",
    ]
    any_row = False
    for r in rows:
        p = r.get("kv_probe")
        if r.get("status") != "ok" or not p:
            continue
        any_row = True
        lines.append(
            f"| {r['arch']} | {r['shape']} | {p.get('impl', '-')} "
            f"| {p['density']:.2f} | {p['wire_bytes']/1e3:.1f} "
            f"| {p['compression_vs_fp32']:.2f}x | {p['wire_vs_formula']:.4f} |")
    return "\n".join(lines) if any_row else ""


def collectives_table(rows: list[dict]) -> str:
    """spring-mesh packed-collective accounting per dry-run cell: the
    simulated wire bytes of one packed all-gather at the cell's probe
    density, the reduction vs a dense fp32 collective, the ``20·d + 1``
    formula cross-check, and any divisibility fallbacks the sharding
    rules hit (``collective_probe`` / ``mesh_fallbacks`` fields, emitted
    since spring-mesh landed; older JSONs are skipped)."""
    lines = [
        "| arch | shape | mesh | world | density | wire KB | vs fp32 | wire/formula | exact | fallbacks |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    any_row = False
    for r in rows:
        p = r.get("collective_probe")
        fb = r.get("mesh_fallbacks") or {}
        if r.get("status") != "ok" or (not p and not fb):
            continue
        any_row = True
        fbs = " ".join(f"{k}x{int(v)}" for k, v in sorted(fb.items())) or "-"
        if p:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {p['world']} "
                f"| {p['density']:.2f} | {p['wire_bytes']/1e3:.1f} "
                f"| {p['compression_vs_fp32']:.2f}x | {p['wire_vs_formula']:.4f} "
                f"| {'yes' if p.get('exact') else 'NO'} | {fbs} |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| - | - | - | - | - | - | {fbs} |")
    return "\n".join(lines) if any_row else ""


def serving_table(results: list[dict]) -> str:
    """Render ``repro.launch.serve --json`` engine sessions: per-request
    latency percentiles, throughput, slot occupancy and measured KV
    wire traffic of the compressed pool."""
    lines = [
        "| mode | slots | requests | tok/s | occupancy | p50 ms | p100 ms | KV wire/step | vs fp32 | spec |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    any_row = False
    for r in results:
        reqs = r.get("per_request")
        if not r.get("engine") or not reqs:
            continue
        any_row = True
        lat = sorted(q["latency_s"] for q in reqs)
        lines.append(
            f"| {r.get('mode', '-')} | {r.get('slots', '-')} | {len(reqs)} "
            f"| {r['tokens_per_s']:.1f} | {r['mean_occupancy']:.2f} "
            f"| {lat[len(lat)//2]*1e3:.0f} | {lat[-1]*1e3:.0f} "
            f"| {r['kv_mean_wire_bytes']/1e3:.1f}KB "
            f"| {r['kv_traffic_reduction_vs_fp32']:.2f}x "
            f"| {r.get('spec_hash', '-')[:10]} |")
    if not any_row:
        return ""
    out = "\n".join(lines)
    at = latency_attribution_table(results)
    if at:
        out += f"\n\n{at}"
    pt = paging_table(results)
    if pt:
        out += f"\n\n{pt}"
    return out


def paging_table(results: list[dict]) -> str:
    """spring-pages sessions per ``serve --json``: the paged COW pool's
    physical budget, peak residency, prefix sharing and spill traffic
    (``summary()["paging"]``; non-paged sessions are skipped)."""
    lines = [
        "| mode | pages | overcommit | peak resident | prefix hits | cow | spills/resumes | peak util | spec |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    any_row = False
    for r in results:
        p = r.get("paging")
        if not r.get("engine") or not p:
            continue
        any_row = True
        lines.append(
            f"| {r.get('mode', '-')} "
            f"| {p['num_pages']}x{p['page_tokens']}tok "
            f"| x{p['overcommit']:.1f} ({p['logical_frames']} logical) "
            f"| {p['peak_active']} | {p['prefix_hits']} | {p['cow_copies']} "
            f"| {p['spills']}/{p['resumes']} "
            f"| {p['peak_page_utilization']:.2f} "
            f"| {r.get('spec_hash', '-')[:10]} |")
    return "\n".join(lines) if any_row else ""


def latency_attribution_table(results: list[dict]) -> str:
    """spring-trace latency attribution per engine session: where a
    request's wall-clock went (queue-wait vs TTFT vs steady-state token
    cadence) plus scheduler tick utilization — from the engine's
    streaming quantile sketches (``summary()["latency"]``)."""
    lines = [
        "| mode | queue p50/p95 ms | ttft p50/p95 ms | token p50/p95/p99 ms | ticks | tick util | spec |",
        "|---|---|---|---|---|---|---|",
    ]
    any_row = False
    for r in results:
        la = r.get("latency")
        if not r.get("engine") or not la:
            continue
        any_row = True
        q, t, tok = la["queue_s"], la["ttft_s"], la["token_s"]
        lines.append(
            f"| {r.get('mode', '-')} "
            f"| {q['p50']*1e3:.0f}/{q['p95']*1e3:.0f} "
            f"| {t['p50']*1e3:.0f}/{t['p95']*1e3:.0f} "
            f"| {tok['p50']*1e3:.1f}/{tok['p95']*1e3:.1f}/{tok['p99']*1e3:.1f} "
            f"| {la['ticks']} | {la['tick_utilization']:.2f} "
            f"| {r.get('spec_hash', '-')[:10]} |")
    return "\n".join(lines) if any_row else ""


def pick_hillclimb(rows: list[dict]) -> list[str]:
    ok = [r for r in rows if r["status"] == "ok" and r["mesh"] == "single"]
    notes = []
    if not ok:
        return notes
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction_of_peak"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    notes.append(f"worst-fraction: {worst['arch']} x {worst['shape']} "
                 f"(frac {worst['roofline']['roofline_fraction_of_peak']:.3f})")
    notes.append(f"most-collective-bound: {coll['arch']} x {coll['shape']} "
                 f"(coll {coll['roofline']['collective_s']:.3f}s)")
    return notes


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load_all(d)
    print("## Dry-run table\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(rows, "multi"))
    kt = kernel_table(rows)
    if kt:
        print("\n## Kernel dispatch (registry-resolved backends)\n")
        print(kt)
    bt = backward_sparsity_table(rows)
    if bt:
        print("\n## Backward sparsity (measured tile-skip, fwd vs dX/dW)\n")
        print(bt)
    kv = kv_cache_table(rows)
    if kv:
        print("\n## Serving KV cache (measured compression probes)\n")
        print(kv)
    ct = collectives_table(rows)
    if ct:
        print("\n## Packed collectives (spring-mesh wire accounting)\n")
        print(ct)
    print("\n## Hillclimb candidates\n")
    for n in pick_hillclimb(rows):
        print("-", n)
    # memstash accounting lives next to the dry-run dir (results/memstash)
    ms_dir = os.path.join(os.path.dirname(os.path.normpath(d)) or ".", "memstash")
    ms_rows = load_all(ms_dir)
    if ms_rows:
        print("\n## Memstash (compressed activation stash)\n")
        print(memstash_table(ms_rows))
    # engine sessions live next to the dry-run dir (results/serving),
    # written by `repro.launch.serve --json`
    sv_dir = os.path.join(os.path.dirname(os.path.normpath(d)) or ".", "serving")
    sv_rows = load_all(sv_dir)
    st = serving_table(sv_rows)
    if st:
        print("\n## Serving engine sessions\n")
        print(st)


if __name__ == "__main__":
    main()
