"""Roofline-term extraction from compiled dry-run artifacts.

``cost_analysis()`` supplies HLO FLOPs and bytes; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (assignment §Roofline).  Hardware
constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every 'dtype[dims]' group in a (possibly tuple) shape."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, bf16_correct: bool = False) -> dict[str, int]:
    """Per-collective-kind result bytes summed over the module.

    Convention: the *result* shape approximates payload per chip (for
    all-gather that is the received bytes; for reduce-scatter the operand
    is larger but the wire traffic matches the scattered result x (P-1)).
    fusion-internal collectives don't exist post-SPMD, so line scanning
    is sound.

    ``bf16_correct``: the CPU backend legalizes bf16 dots to f32 (convert-
    wrapped operands), so activation-path collectives carry f32 payloads
    that are bf16 on the TPU target — count f32 payloads at 2 bytes/elem.
    Raw totals are reported alongside as ``*_raw``.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    raw_total = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        b = _shape_bytes(shape_str)
        raw_total += b
        if bf16_correct:
            b = _shape_bytes(shape_str.replace("f32[", "bf16["))
        out[kind] += b
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["total_raw_f32"] = raw_total
    return out


_TRAFFIC_OPS = ("dot", "convolution", "gather", "scatter", "dynamic-update-slice",
                "dynamic-slice", "copy", "reduce-window", "sort")


def fusion_adjusted_bytes(hlo_text: str, bf16_correct: bool = False) -> dict[str, float]:
    """TPU-realistic HBM traffic estimate from CPU-compiled HLO.

    The CPU pipeline leaves elementwise chains unfused, so cost_analysis
    "bytes accessed" counts every intermediate (observed ~10x inflation:
    convert/add/broadcast dominate).  On the TPU target those chains fuse
    into their producers/consumers; the HBM traffic that remains is
    (a) matmul/conv operands + results, (b) data-movement ops
    (gather/scatter/slice-update/copy/sort), (c) collective payloads,
    (d) entry parameters/outputs.  We reconstruct (a)-(b) with a
    symbol-table walk so *operand* shapes resolve, and report this as the
    memory-roofline numerator next to the raw number.
    """
    symbols: dict[str, str] = {}
    traffic = 0.0
    params_bytes = 0.0
    line_re = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([\w\-]+)")
    for raw in hlo_text.splitlines():
        m = line_re.match(raw)
        if not m:
            continue
        name, shape_str, op = m.groups()
        symbols[name.lstrip("%")] = shape_str
        if op == "parameter":
            continue
        if op in _TRAFFIC_OPS:
            eff = shape_str.replace("f32[", "bf16[") if bf16_correct else shape_str
            b = _shape_bytes(eff)
            # operand bytes via the symbol table (CPU HLO uses bare %refs;
            # only the op's own parens, not attribute/metadata parens)
            op_call = raw.find("(")
            args = raw[op_call + 1 : raw.find(")", op_call)]
            for ref in re.findall(r"%([\w.\-]+)", args):
                if ref in symbols:
                    sh = symbols[ref]
                    b += _shape_bytes(sh.replace("f32[", "bf16[") if bf16_correct else sh)
            traffic += b
    return {"fusion_adjusted_bytes": traffic}


def roofline_terms(
    cost: dict[str, Any],
    coll_bytes: int,
    n_chips: int,
    model_flops: float | None = None,
    adjusted_bytes: float | None = None,
) -> dict[str, float]:
    """The three roofline terms, in seconds.

    XLA's cost_analysis and post-SPMD HLO shapes are PER-CHIP, so the
    assignment formulas `global / (chips x rate)` reduce to
    `per_chip / rate`; global totals are recorded alongside
    (= per-chip x chips, exact for the homogeneous SPMD programs here).
    """
    flops_pc = float(cost.get("flops", 0.0))
    bytes_pc = float(cost.get("bytes accessed", 0.0))
    compute_s = flops_pc / PEAK_FLOPS
    memory_s_raw = bytes_pc / HBM_BW
    # dominant-term decisions use the fusion-adjusted traffic when given
    # (raw CPU-backend bytes overcount unfused elementwise chains ~10x)
    mem_bytes = adjusted_bytes if adjusted_bytes is not None else bytes_pc
    memory_s = mem_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {
        "hlo_flops_per_chip": flops_pc,
        "hlo_flops_global": flops_pc * n_chips,
        "hlo_bytes_per_chip_raw": bytes_pc,
        "hlo_bytes_per_chip_fusion_adjusted": float(mem_bytes),
        "collective_bytes_per_chip": float(coll_bytes),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_s_raw": memory_s_raw,
        "collective_s": collective_s,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dom
    bound = max(compute_s, memory_s, collective_s)
    terms["roofline_fraction_of_peak"] = (compute_s / bound) if bound > 0 else 0.0
    if model_flops is not None:
        terms["model_flops"] = float(model_flops)
        g = flops_pc * n_chips
        terms["useful_flops_ratio"] = (model_flops / g) if g else 0.0
    return terms


def memory_summary(mem_analysis) -> dict[str, float]:
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    out = {}
    for k in keys:
        v = getattr(mem_analysis, k, None)
        if v is not None:
            out[k] = float(v)
    out["peak_bytes_per_chip_est"] = (
        out.get("argument_size_in_bytes", 0.0)
        + out.get("output_size_in_bytes", 0.0)
        + out.get("temp_size_in_bytes", 0.0)
        - out.get("alias_size_in_bytes", 0.0)
    )
    return out
