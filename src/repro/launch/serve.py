"""Serving launcher: batched prefill + decode loop with the SPRING
numerics modes, runnable on CPU with reduced configs.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.spring_ops import DENSE, QUANT, QUANT_SPARSE
from repro.kernels.registry import KernelPolicy
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import StepConfig, make_decode_step, make_prefill_step

MODES = {"dense": DENSE, "quant": QUANT, "quant_sparse": QUANT_SPARSE}


def serve_session(
    arch_id: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    mode: str = "dense",
    kernel_impl: str | None = None,  # KernelPolicy spec, e.g. "ref"
    greedy: bool = True,
    seed: int = 0,
    mesh=None,
) -> dict:
    arch = get_arch(arch_id)
    cfg = arch.reduced() if reduced else arch.config

    class _A:
        is_encdec = arch.is_encdec
        config = cfg

        @staticmethod
        def reduced():
            return cfg

    spring_cfg = dataclasses.replace(
        MODES[mode], kernels=KernelPolicy.parse(kernel_impl or ""))
    step_cfg = StepConfig(spring=spring_cfg, optimizer=OptimizerConfig())
    key = jax.random.PRNGKey(seed)

    from repro.models import encdec as ed_mod
    from repro.models import lm as lm_mod

    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    params = init(key, cfg)

    if arch.is_encdec:
        batch_inputs = {
            "frames": jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab),
        }
    else:
        batch_inputs = {"tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)}
        if cfg.vlm_prefix_len:
            batch_inputs["img_embeds"] = jax.random.normal(
                key, (batch, cfg.vlm_prefix_len, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(_A, step_cfg, mesh=mesh, reduced=True))
    decode = jax.jit(make_decode_step(_A, step_cfg, mesh=mesh, reduced=True))

    t0 = time.monotonic()
    if arch.is_encdec:
        from repro.models.layers import SpringContext

        cache = ed_mod.encdec_init_cache(params, cfg, batch_inputs["frames"],
                                         SpringContext(), max_len=prompt_len + gen)
        logits = jnp.zeros((batch, cfg.vocab))
        next_tok = batch_inputs["tokens"][:, 0]
    else:
        # decode continues past the prompt: extend the cache buffers
        from repro.models.lm import pad_cache

        logits, cache = prefill(params, batch_inputs, key)
        cache = pad_cache(cache, gen)
        next_tok = jnp.argmax(logits, -1)
    t_prefill = time.monotonic() - t0

    tokens_out = []
    t0 = time.monotonic()
    for i in range(gen):
        logits, cache = decode(params, next_tok, cache, jax.random.fold_in(key, i))
        next_tok = (jnp.argmax(logits, -1) if greedy
                    else jax.random.categorical(jax.random.fold_in(key, 1000 + i), logits))
        tokens_out.append(next_tok)
    jax.block_until_ready(logits)
    t_decode = time.monotonic() - t0

    seqs = jnp.stack(tokens_out, axis=1)
    return {
        "generated": seqs,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * gen / t_decode if t_decode else 0.0,
        "finite": bool(jnp.all(jnp.isfinite(logits))),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="dense", choices=list(MODES))
    ap.add_argument("--kernel-impl", default=None,
                    help="kernel-dispatch policy, e.g. 'ref', 'interpret', "
                         "'ssd_scan=jnp' (default: auto)")
    args = ap.parse_args()
    out = serve_session(args.arch, reduced=args.reduced, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen, mode=args.mode,
                        kernel_impl=args.kernel_impl)
    print(f"prefill {out['prefill_s']*1e3:.1f}ms, decode {out['decode_s']*1e3:.1f}ms "
          f"({out['tokens_per_s']:.1f} tok/s), finite={out['finite']}")
    print("sample tokens:", out["generated"][0][:12])


if __name__ == "__main__":
    main()
