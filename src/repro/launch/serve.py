"""Serving launcher: the spring-serve continuous-batching engine with the
SPRING numerics modes, runnable on CPU with reduced configs.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --reduced --batch 4 --slots 2 --prompt-len 32 --gen 16 \
      --mode quant_sparse --kernel-impl ref

``serve_session`` is a one-shot wrapper over :class:`ServingEngine`: it
submits a synthetic batch of requests and drains the queue.  The
pre-refactor static batch loop survives as
:func:`static_reference_session` — the oracle the parity suite
(tests/test_serving.py) seals the engine against, and the fallback for
encoder-decoder archs (the engine serves decoder-only LMs).

Serving numerics: quantized modes round to nearest (DESIGN.md §9) so a
request's tokens are a function of the request alone, not of its batch
co-tenants.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.spring_ops import DENSE, QUANT, QUANT_SPARSE, SpringConfig
from repro.kernels.registry import KernelPolicy
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import StepConfig
from repro.serving.engine import ServingEngine
from repro.serving.steps import make_decode_step, make_prefill_step

MODES = {"dense": DENSE, "quant": QUANT, "quant_sparse": QUANT_SPARSE}


def serving_config(mode: str, kernel_impl: str | None = None) -> SpringConfig:
    """SpringConfig for serving: the chosen mode with deterministic
    (nearest) rounding — SR is training's convergence device; at serving
    time it would couple a request's tokens to its batch co-tenants."""
    return dataclasses.replace(
        MODES[mode], stochastic=False,
        kernels=KernelPolicy.parse(kernel_impl or ""))


def _synthetic_batch(arch, cfg, batch: int, prompt_len: int, key) -> dict:
    """The launcher's stand-in traffic (same construction the static path
    always used, so engine/static parity runs on identical prompts)."""
    if arch.is_encdec:
        return {
            "frames": jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab),
        }
    out = {"tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)}
    if cfg.vlm_prefix_len:
        out["img_embeds"] = jax.random.normal(
            key, (batch, cfg.vlm_prefix_len, cfg.d_model), jnp.bfloat16)
    return out


def static_reference_session(
    arch_id: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    mode: str = "dense",
    kernel_impl: str | None = None,
    greedy: bool = True,
    seed: int = 0,
    mesh=None,
) -> dict:
    """The pre-engine static path: one fixed batch, prefill once, decode
    ``gen`` steps, throw the cache away.  Kept verbatim as (a) the parity
    oracle the engine is sealed against and (b) the encdec fallback."""
    arch = get_arch(arch_id)
    view = arch.view(reduced=reduced)
    cfg = view.config
    step_cfg = StepConfig(spring=serving_config(mode, kernel_impl),
                          optimizer=OptimizerConfig())
    key = jax.random.PRNGKey(seed)

    from repro.models import encdec as ed_mod
    from repro.models import lm as lm_mod

    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    params = init(key, cfg)
    batch_inputs = _synthetic_batch(arch, cfg, batch, prompt_len, key)

    prefill = jax.jit(make_prefill_step(view, step_cfg, mesh=mesh, reduced=True))
    decode = jax.jit(make_decode_step(view, step_cfg, mesh=mesh, reduced=True))

    t0 = time.monotonic()
    if arch.is_encdec:
        from repro.models.layers import SpringContext

        cache = ed_mod.encdec_init_cache(params, cfg, batch_inputs["frames"],
                                         SpringContext(), max_len=prompt_len + gen)
        logits = jnp.zeros((batch, cfg.vocab))
        next_tok = batch_inputs["tokens"][:, 0]
    else:
        # decode continues past the prompt: extend the cache buffers
        from repro.models.lm import pad_cache

        logits, cache = prefill(params, batch_inputs, key)
        cache = pad_cache(cache, gen)
        next_tok = jnp.argmax(logits, -1)
    t_prefill = time.monotonic() - t0

    tokens_out = []
    t0 = time.monotonic()
    for i in range(gen):
        logits, cache = decode(params, next_tok, cache, jax.random.fold_in(key, i))
        next_tok = (jnp.argmax(logits, -1) if greedy
                    else jax.random.categorical(jax.random.fold_in(key, 1000 + i), logits))
        tokens_out.append(next_tok)
    jax.block_until_ready(logits)
    t_decode = time.monotonic() - t0

    seqs = jnp.stack(tokens_out, axis=1)
    return {
        "generated": seqs,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * gen / t_decode if t_decode else 0.0,
        "finite": bool(jnp.all(jnp.isfinite(logits))),
        "engine": False,
    }


def serve_session(
    arch_id: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    mode: str = "dense",
    kernel_impl: str | None = None,
    greedy: bool = True,
    seed: int = 0,
    slots: int | None = None,
    queue: int | None = None,
    mesh=None,
) -> dict:
    """One-shot engine session: submit ``queue`` synthetic requests (default
    ``batch``) over a pool of ``slots`` slots (default ``batch``) and drain.

    Returns the legacy result surface (``generated``/``prefill_s``/
    ``decode_s``/``tokens_per_s``/``finite``) plus the engine's metrics
    (per-request latency, occupancy, KV wire bytes & compression).
    """
    arch = get_arch(arch_id)
    if arch.is_encdec:
        # encoder-decoder archs keep the static loop (DESIGN.md §9 scope)
        return static_reference_session(
            arch_id, reduced=reduced, batch=batch, prompt_len=prompt_len,
            gen=gen, mode=mode, kernel_impl=kernel_impl, greedy=greedy,
            seed=seed, mesh=mesh)

    view = arch.view(reduced=reduced)
    cfg = view.config
    # None means "default to batch"; an explicit 0 must reach the engine's
    # own validation rather than being silently replaced
    n_requests = batch if queue is None else queue
    n_slots = batch if slots is None else slots
    step_cfg = StepConfig(spring=serving_config(mode, kernel_impl),
                          optimizer=OptimizerConfig())
    key = jax.random.PRNGKey(seed)

    from repro.models.lm import lm_init

    params = lm_init(key, cfg)
    # queued requests beyond the first batch reuse the synthetic
    # construction with a folded key (distinct prompts, reproducible)
    prompts = []
    img = []
    for chunk in range((n_requests + batch - 1) // batch):
        bi = _synthetic_batch(arch, cfg, batch, prompt_len,
                              jax.random.fold_in(key, chunk) if chunk else key)
        for b in range(batch):
            prompts.append([int(t) for t in bi["tokens"][b]])
            img.append(bi.get("img_embeds")[b] if "img_embeds" in bi else None)
    prompts, img = prompts[:n_requests], img[:n_requests]

    engine = ServingEngine(view, step_cfg, params=params, n_slots=n_slots,
                           max_len=prompt_len + gen + 1, greedy=greedy,
                           mesh=mesh, reduced=False, seed=seed)
    for i, p in enumerate(prompts):
        engine.submit_prompt(p, gen, seed=seed + i, img_embeds=img[i])
    out = engine.run()
    out["generated"] = jnp.asarray(
        [r["tokens"] for r in out["per_request"]], jnp.int32)
    out["engine"] = True
    out["slots"] = n_slots
    out["mode"] = mode
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mode", default="dense", choices=list(MODES))
    ap.add_argument("--kernel-impl", default=None,
                    help="kernel-dispatch policy, e.g. 'ref', 'interpret', "
                         "'ssd_scan=jnp' (default: auto)")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine slot-pool size (default: --batch)")
    ap.add_argument("--queue", type=int, default=None,
                    help="total requests to submit (default: --batch); the "
                         "surplus waits FCFS and joins mid-flight")
    ap.add_argument("--greedy", dest="greedy", action="store_true", default=True)
    ap.add_argument("--sample", dest="greedy", action="store_false",
                    help="sample with each request's own PRNG key")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static", action="store_true",
                    help="run the pre-engine static reference path")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full engine metrics as JSON (write into "
                         "results/serving/ for roofline_report to render "
                         "the 'Serving engine sessions' table)")
    args = ap.parse_args()
    fn = static_reference_session if args.static else serve_session
    kw = {} if args.static else {"slots": args.slots, "queue": args.queue}
    out = fn(args.arch, reduced=args.reduced, batch=args.batch,
             prompt_len=args.prompt_len, gen=args.gen, mode=args.mode,
             kernel_impl=args.kernel_impl, greedy=args.greedy,
             seed=args.seed, **kw)
    print(f"prefill {out['prefill_s']*1e3:.1f}ms, decode {out['decode_s']*1e3:.1f}ms "
          f"({out['tokens_per_s']:.1f} tok/s), finite={out['finite']}")
    if out.get("engine"):
        lat = [r["latency_s"] for r in out["per_request"]]
        print(f"requests {len(lat)} over {out['slots']} slots: "
              f"occupancy {out['mean_occupancy']:.2f}, "
              f"p50 latency {sorted(lat)[len(lat)//2]*1e3:.0f}ms, "
              f"KV wire {out['kv_mean_wire_bytes']/1e6:.2f}MB/step "
              f"({out['kv_traffic_reduction_vs_fp32']:.2f}x less traffic "
              f"than a dense fp32 pool)")
    print("sample tokens:", out["generated"][0][:12])
    if args.json:
        payload = {k: v for k, v in out.items() if k != "generated"}
        payload["generated_first"] = [int(t) for t in out["generated"][0]]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)


if __name__ == "__main__":
    main()
