"""Serving launcher: a thin adapter over the RunSpec API.

  PYTHONPATH=src python -m repro.launch.serve --spec examples/specs/serve_quant_sparse.json
  PYTHONPATH=src python -m repro.launch.serve --set arch.id=llama3.2-1b \
      --set serving.slots=2 --set serving.queue=6 --set numerics.mode=quant_sparse

The engine session lives in :class:`repro.api.ServeSession`; the
pre-refactor static batch loop survives behind ``serving.static=true``
(and as the encoder-decoder fallback) — the oracle the parity suite
(tests/test_serving.py) seals the engine against.  Legacy flag spellings
(``--slots``, ``--queue``, ``--kernel-impl``, ...) shim to the same
RunSpec fields with a DeprecationWarning.

Serving numerics: quantized modes round to nearest (DESIGN.md §9) so a
request's tokens are a function of the request alone, not of its batch
co-tenants.

``serve_session`` / ``static_reference_session`` / ``serving_config``
keep their historical signatures as wrappers for programmatic callers.
"""

from __future__ import annotations

import json

from repro.api.cli import flag, make_parser, run_main
from repro.api.sessions import ServeSession, serve_spec
from repro.api.spec import RunSpec, KernelsSection, NumericsSection
from repro.core.spring_ops import MODES, SpringConfig  # legacy import site

LEGACY_FLAGS = (
    flag("--arch", "arch.id"),
    flag("--reduced", "arch.reduced", const=True),
    flag("--batch", "shape.batch", type=int),
    flag("--prompt-len", "shape.prompt_len", type=int),
    flag("--gen", "shape.gen", type=int),
    flag("--mode", "numerics.mode", choices=list(MODES)),
    flag("--kernel-impl", "kernels.policy"),
    flag("--slots", "serving.slots", type=int),
    flag("--queue", "serving.queue", type=int),
    flag("--greedy", "serving.greedy", const=True, dest="legacy_greedy"),
    flag("--sample", "serving.greedy", const=False, dest="legacy_greedy"),
    flag("--seed", "seeds.seed", type=int),
    flag("--static", "serving.static", const=True),
    flag("--pages", "serving.pages", const=True),
    flag("--page-tokens", "serving.page_tokens", type=int),
    flag("--prefix-cache", "serving.prefix_cache", type=lambda s: s.lower()
         not in ("0", "false", "no", "off")),
    # spring-survive: periodic snapshots, restore-and-drain, load shedding
    flag("--snapshot-every", "serving.snapshot_every", type=int),
    flag("--snapshot-path", "serving.snapshot_path"),
    flag("--restore", "serving.restore_path"),
    flag("--max-queue-depth", "serving.max_queue_depth", type=int),
    flag("--deadline-ticks", "serving.deadline_ticks", type=int),
)


def serving_config(mode: str, kernel_impl: str | None = None) -> SpringConfig:
    """SpringConfig for serving: the chosen mode with deterministic
    (nearest) rounding — SR is training's convergence device; at serving
    time it would couple a request's tokens to its batch co-tenants.

    Delegates to the RunSpec resolver (run="serve") so there is exactly
    one place serving numerics are decided."""
    return RunSpec(
        run="serve", numerics=NumericsSection(mode=mode),
        kernels=KernelsSection(policy=kernel_impl or "auto"),
    ).resolve().spring


def static_reference_session(
    arch_id: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    mode: str = "dense",
    kernel_impl: str | None = None,
    greedy: bool = True,
    seed: int = 0,
    mesh=None,
) -> dict:
    """The pre-engine static path: one fixed batch, prefill once, decode
    ``gen`` steps, throw the cache away.  Kept as (a) the parity oracle
    the engine is sealed against and (b) the encdec fallback."""
    spec = serve_spec(arch_id, reduced=reduced, batch=batch,
                      prompt_len=prompt_len, gen=gen, mode=mode,
                      kernel_impl=kernel_impl, greedy=greedy, seed=seed,
                      static=True)
    return ServeSession(spec, mesh=mesh).run()


def serve_session(
    arch_id: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    mode: str = "dense",
    kernel_impl: str | None = None,
    greedy: bool = True,
    seed: int = 0,
    slots: int | None = None,
    queue: int | None = None,
    pages: bool = False,
    page_tokens: int | None = None,
    num_pages: int | None = None,
    overcommit: float | None = None,
    prefix_cache: bool | None = None,
    mesh=None,
) -> dict:
    """One-shot engine session: submit ``queue`` synthetic requests
    (default ``batch``) over a pool of ``slots`` slots (default ``batch``)
    and drain.  ``pages=True`` serves on the paged COW pool (spring-pages).
    Returns the legacy result surface plus the engine metrics and the
    canonical resolved spec."""
    spec = serve_spec(arch_id, reduced=reduced, batch=batch,
                      prompt_len=prompt_len, gen=gen, mode=mode,
                      kernel_impl=kernel_impl, greedy=greedy, seed=seed,
                      slots=slots, queue=queue, pages=pages,
                      page_tokens=page_tokens, num_pages=num_pages,
                      overcommit=overcommit, prefix_cache=prefix_cache)
    return ServeSession(spec, mesh=mesh).run()


#: This adapter's historical defaults (the old argparse had --batch
#: default=4), layered *below* file/env/CLI so bare invocations keep
#: their pre-RunSpec behavior; provenance labels them launcher-default.
CLI_BASE = {"shape": {"batch": 4}}


def build_parser():
    return make_parser(__doc__, LEGACY_FLAGS, json_out=True)


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec = run_main("serve", args, LEGACY_FLAGS, base=CLI_BASE)
    out = ServeSession(spec).run()
    print(f"prefill {out['prefill_s']*1e3:.1f}ms, decode {out['decode_s']*1e3:.1f}ms "
          f"({out['tokens_per_s']:.1f} tok/s), finite={out['finite']}")
    if out.get("engine"):
        lat = [r["latency_s"] for r in out["per_request"]]
        print(f"requests {len(lat)} over {out['slots']} slots: "
              f"occupancy {out['mean_occupancy']:.2f}, "
              f"p50 latency {sorted(lat)[len(lat)//2]*1e3:.0f}ms, "
              f"KV wire {out['kv_mean_wire_bytes']/1e6:.2f}MB/step "
              f"({out['kv_traffic_reduction_vs_fp32']:.2f}x less traffic "
              f"than a dense fp32 pool)")
        la = out["latency"]
        print(f"latency attribution: queue p50 {la['queue_s']['p50']*1e3:.0f}ms, "
              f"ttft p50 {la['ttft_s']['p50']*1e3:.0f}ms, "
              f"token p50/p95/p99 {la['token_s']['p50']*1e3:.1f}/"
              f"{la['token_s']['p95']*1e3:.1f}/{la['token_s']['p99']*1e3:.1f}ms, "
              f"tick utilization {la['tick_utilization']:.2f}")
        el = out.get("elastic") or {}
        if any(el.get(k) for k in ("n_rejected", "n_spills", "n_rescales",
                                   "n_snapshots", "n_restores")):
            print(f"elastic: shed {el['n_rejected']} ({el['rejected']}), "
                  f"spills {el['n_spills']}/{el['n_resumes']} resumed, "
                  f"rescales {el['n_rescales']}, "
                  f"snapshots {el['n_snapshots']}, "
                  f"restores {el['n_restores']}")
        if out.get("paging"):
            p = out["paging"]
            print(f"paging: {p['num_pages']} pages x {p['page_tokens']} tok "
                  f"(x{p['overcommit']:.1f} logical overcommit), "
                  f"peak {p['peak_active']} resident, "
                  f"prefix hits {p['prefix_hits']}, cow {p['cow_copies']}, "
                  f"spills {p['spills']}/{p['resumes']} resumed, "
                  f"peak budget utilization {p['peak_page_utilization']:.2f}")
    if "telemetry" in out:
        print(f"telemetry: {out['telemetry']['spans']} spans -> "
              f"{out['telemetry']['trace_path']} (load in Perfetto)")
    if len(out["generated"]):
        print("sample tokens:", list(out["generated"][0][:12]))
    print(f"spec {out['spec_hash']}")
    if args.json:
        payload = {k: v for k, v in out.items() if k != "generated"}
        payload["generated_first"] = ([int(t) for t in out["generated"][0]]
                                      if len(out["generated"]) else [])
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)


if __name__ == "__main__":
    main()
