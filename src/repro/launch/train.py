"""Training launcher: end-to-end driver (data -> train_step -> checkpoint
-> resume), runnable on CPU with reduced configs and on a pod with the
production mesh.

Example (CPU, reduced config, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --reduced --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.core.fixedpoint import SPRING_FORMAT
from repro.core.spring_ops import DENSE, QUANT, QUANT_SPARSE, SpringConfig
from repro.kernels.registry import KernelPolicy
from repro.memstash.config import MemstashConfig
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.resilience import StragglerWatchdog
from repro.runtime.train import StepConfig, TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.train")

MODES = {"dense": DENSE, "quant": QUANT, "quant_sparse": QUANT_SPARSE}


def train_loop(
    arch_id: str = "llama3.2-1b",
    *,
    reduced: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    mode: str = "dense",
    lr: float = 3e-3,
    fixed_point_weights: bool = False,
    kernel_impl: str | None = None,  # KernelPolicy spec, e.g. "ref" | "ssd_scan=jnp"
    backward_sparsity: str = "auto",  # none | auto | ref | jnp | interpret | pallas
    stash: str = "none",  # memstash policy: none | remat | stash
    ckpt_dir: str | None = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    mesh=None,
    seed: int = 0,
) -> dict:
    arch = get_arch(arch_id)
    cfg = arch.reduced() if reduced else arch.config
    cfg = dataclasses.replace(cfg)  # defensive copy
    if stash != "none":
        if hasattr(cfg, "remat_policy"):
            if stash == "stash":
                # route the residual-stream checkpoints through the memstash
                # subsystem (compressed activation store; DESIGN.md §4.3)
                cfg = dataclasses.replace(cfg, remat_policy="stash")
            else:  # "remat": force plain recompute even if the config
                # (e.g. a reduced variant) disabled remat
                cfg = dataclasses.replace(cfg, remat=True, remat_policy="full")
        else:
            log.warning("--stash %s has no effect for %s (config has no remat_policy)",
                        stash, arch_id)
    spring_cfg = dataclasses.replace(
        MODES[mode], kernels=KernelPolicy.parse(kernel_impl or ""))
    step_cfg = StepConfig(
        spring=spring_cfg,
        backward_sparsity=backward_sparsity,
        memstash=MemstashConfig(policy=stash),
        optimizer=OptimizerConfig(
            # warmup must not depend on ``steps``: a resumed run would
            # otherwise follow a different LR schedule than the original
            kind="adamw", lr=lr, warmup_steps=10,
            weight_format=SPRING_FORMAT if fixed_point_weights else None,
        ),
    )

    view = arch.view(config=cfg)  # arch view with the chosen config
    data = SyntheticLMStream(DataConfig(seed=seed, vocab=cfg.vocab, seq_len=seq, global_batch=batch))
    state = init_train_state(jax.random.PRNGKey(seed), view, step_cfg, reduced=True)
    start_step = 0

    manager = CheckpointManager(ckpt_dir, every_steps=ckpt_every) if ckpt_dir else None
    if manager is not None:
        restored = manager.restore_or_none()
        if restored is not None:
            start_step, tree = restored
            state = TrainState(*tree)
            log.info("resumed from step %d", start_step)

    step_fn = jax.jit(make_train_step(view, step_cfg, mesh=mesh), donate_argnums=(0,))
    watchdog = StragglerWatchdog()
    losses = []
    for step in range(start_step, steps):
        tokens = data.batch(step)
        watchdog.step_start()
        state, metrics = step_fn(state, {"tokens": tokens})
        loss = float(metrics["loss"])
        watchdog.step_end(step)
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            log.info("step %d loss %.4f grad_norm %.3f", step, loss, float(metrics["grad_norm"]))
        if manager is not None:
            manager.maybe_save(step + 1, tuple(state.tree_flatten()[0]),
                               {"arch": arch_id, "mode": mode})
    if manager is not None:
        manager.maybe_save(steps, tuple(state.tree_flatten()[0]),
                           {"arch": arch_id, "mode": mode}, force=True)
    return {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "losses": losses,
        "slow_steps": sum(1 for e in watchdog.events if e.slow),
        "state": state,
    }


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="dense", choices=list(MODES))
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fixed-point-weights", action="store_true")
    ap.add_argument("--kernel-impl", default=None,
                    help="kernel-dispatch policy, e.g. 'ref', 'interpret', "
                         "'ssd_scan=jnp,masked_matmul=ref' (default: auto)")
    ap.add_argument("--backward-sparsity", default="auto",
                    choices=["none", "auto", "ref", "jnp", "interpret", "pallas"],
                    help="sparsity-aware backward pass (quant_sparse mode): "
                         "route dL/dX / dL/dW through the masked_matmul_dx/dw "
                         "kernels; 'none' keeps dense autodiff")
    ap.add_argument("--stash", default="none", choices=["none", "remat", "stash"],
                    help="memstash activation-checkpoint policy")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()
    out = train_loop(
        args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
        seq=args.seq, mode=args.mode, lr=args.lr,
        fixed_point_weights=args.fixed_point_weights,
        kernel_impl=args.kernel_impl, backward_sparsity=args.backward_sparsity,
        stash=args.stash,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    print(f"loss {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"({args.steps} steps, slow={out['slow_steps']})")


if __name__ == "__main__":
    main()
