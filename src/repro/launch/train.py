"""Training launcher: a thin adapter over the RunSpec API.

The native surface is a spec file plus dotted overrides:

  PYTHONPATH=src python -m repro.launch.train --spec examples/specs/train_quant_sparse.json
  PYTHONPATH=src python -m repro.launch.train --set arch.id=llama3.2-1b \
      --set train.steps=300 --set shape.batch=8 --set shape.seq=128

Every pre-redesign flag (``--arch``, ``--steps``, ``--stash``,
``--kernel-impl``, ``--backward-sparsity``, ...) still works as a
deprecated shim that resolves to the same RunSpec field (see
``repro.api.cli``).  ``--explain`` prints each field with the layer that
set it; ``--json`` writes the result with the canonical resolved spec so
the run is reproducible from one artifact.

``train_loop`` keeps its historical keyword signature as a wrapper over
``TrainSession`` for programmatic callers (tests, examples, benches).
"""

from __future__ import annotations

import json
import logging

from repro.api.cli import flag, make_parser, run_main
from repro.api.sessions import TrainSession, train_spec
from repro.core.spring_ops import MODES  # re-export (legacy import site)

log = logging.getLogger("repro.train")

#: Legacy flag spellings -> RunSpec fields (all warn with the --set form).
LEGACY_FLAGS = (
    flag("--arch", "arch.id"),
    flag("--reduced", "arch.reduced", const=True),
    flag("--steps", "train.steps", type=int),
    flag("--batch", "shape.batch", type=int),
    flag("--seq", "shape.seq", type=int),
    flag("--mode", "numerics.mode", choices=list(MODES)),
    flag("--lr", "optimizer.lr", type=float),
    flag("--fixed-point-weights", "numerics.fixed_point_weights", const=True),
    flag("--kernel-impl", "kernels.policy"),
    flag("--backward-sparsity", "sparsity.backward",
         choices=["none", "auto", "ref", "jnp", "interpret", "pallas"]),
    flag("--stash", "memstash.policy", choices=["none", "remat", "stash"]),
    flag("--ckpt-dir", "train.ckpt_dir"),
    flag("--ckpt-every", "train.ckpt_every", type=int),
)


def train_loop(
    arch_id: str = "llama3.2-1b",
    *,
    reduced: bool = True,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    mode: str = "dense",
    lr: float = 3e-3,
    fixed_point_weights: bool = False,
    kernel_impl: str | None = None,  # KernelPolicy spec, e.g. "ref" | "ssd_scan=jnp"
    backward_sparsity: str = "auto",  # none | auto | ref | jnp | interpret | pallas
    stash: str = "none",  # memstash policy: none | remat | stash
    ckpt_dir: str | None = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    mesh=None,
    seed: int = 0,
) -> dict:
    """Legacy keyword surface: builds the equivalent RunSpec and runs a
    :class:`repro.api.TrainSession`."""
    spec = train_spec(
        arch_id, reduced=reduced, steps=steps, batch=batch, seq=seq,
        mode=mode, lr=lr, fixed_point_weights=fixed_point_weights,
        kernel_impl=kernel_impl, backward_sparsity=backward_sparsity,
        stash=stash, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        log_every=log_every, seed=seed)
    return TrainSession(spec, mesh=mesh).run()


def build_parser():
    return make_parser(__doc__, LEGACY_FLAGS, json_out=True)


def main(argv=None):
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    args = build_parser().parse_args(argv)
    spec = run_main("train", args, LEGACY_FLAGS)
    out = TrainSession(spec).run()
    print(f"loss {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"({spec.train.steps} steps, slow={out['slow_steps']}) "
          f"[spec {out['spec_hash']}]")
    if args.json:
        payload = {k: v for k, v in out.items() if k != "state"}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=float)


if __name__ == "__main__":
    main()
