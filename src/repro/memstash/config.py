"""Memstash policy configuration (see DESIGN.md §4.3).

A ``MemstashConfig`` decides, per stash point, what happens to the forward
activation that the backward pass will need:

  none   — leave it to XLA (dense residual, the fp32/bf16 baseline);
  remat  — ``jax.checkpoint``: store nothing, recompute in backward;
  stash  — store it in SPRING's binary-mask compressed form (packed
           occupancy bits + front-collapsed non-zeros) and decompress it in
           the backward pass; the block is then recomputed from the
           restored input (remat-from-compressed-input).

The config is a frozen dataclass so it can ride through jit closures and
``jax.custom_vjp`` non-differentiable arguments (both require hashability).
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional

STASH_POLICIES = ("none", "remat", "stash")


@dataclasses.dataclass(frozen=True)
class MemstashConfig:
    """Per-layer checkpoint policy + accounting parameters.

    policy:     default policy for every stash point.
    per_layer:  ``((fnmatch_pattern, policy), ...)`` overrides matched
                against the stash-point name; first match wins.
    value_bits: bits per stored non-zero in the wire accounting (the
                paper's Q4.16 value is 20; the traffic formula is
                ``bits/elem = value_bits * density + 1``).
    capacity:   fraction of the dense length allocated for the collapsed
                value buffer.  1.0 is always bit-exact; < 1.0 trades
                exactness above that density for a genuinely smaller
                buffer under jit's static shapes (overflow values decode
                as zero and are counted by ``StashedActivation.overflow``).
    min_elems:  stash points smaller than this fall back to "none" — the
                mask word + metadata overhead isn't worth it.
    """

    policy: str = "none"
    per_layer: tuple = ()
    value_bits: int = 20
    capacity: float = 1.0
    min_elems: int = 1024

    def __post_init__(self):
        if self.policy not in STASH_POLICIES:
            raise ValueError(f"policy {self.policy!r} not in {STASH_POLICIES}")
        for pat, pol in self.per_layer:
            if pol not in STASH_POLICIES:
                raise ValueError(f"per_layer[{pat!r}] policy {pol!r} not in {STASH_POLICIES}")
        if not 0.0 < self.capacity <= 1.0:
            raise ValueError(f"capacity must be in (0, 1], got {self.capacity}")

    def policy_for(self, name: str, elems: Optional[int] = None) -> str:
        pol = self.policy
        for pat, p in self.per_layer:
            if fnmatch.fnmatchcase(name, pat):
                pol = p
                break
        if pol != "none" and elems is not None and elems < self.min_elems:
            return "none"
        return pol


# Convenience presets: CNN ReLU activations are genuinely sparse (the
# paper's ~50% claim) so compressed stashing pays; LM residual streams are
# dense, where remat is the sane default and "stash" degrades gracefully
# to ~dense bytes + 1 mask bit/elem (measurable via the instrumentation).
STASH_ALL = MemstashConfig(policy="stash")
REMAT_ALL = MemstashConfig(policy="remat")
