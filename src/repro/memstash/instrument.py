"""Byte-accounting instrumentation for stash points.

A thread-local recorder collects one row per stash point when active.
Rows need *concrete* values (density is data-dependent), so recording only
happens for eagerly-executed forwards — under jit/grad tracing the
activation is a tracer and the hook is a no-op, keeping training free of
host syncs.  ``repro.memstash.report`` runs models eagerly under
``record_stash_traffic`` to produce the per-layer tables that feed
``launch/roofline_report.py`` and README.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax

from repro.memstash.config import MemstashConfig
from repro.memstash.format import (
    compress,
    dense_fp32_bytes,
    formula_bits_per_elem,
    logical_bytes,
    wire_bytes,
)


class _Recorder(threading.local):
    def __init__(self):
        self.rows: Optional[list] = None


_REC = _Recorder()


@contextlib.contextmanager
def record_stash_traffic():
    """Collect stash-point rows from eager forwards run inside the block."""
    prev = _REC.rows
    _REC.rows = []
    try:
        yield _REC.rows
    finally:
        _REC.rows = prev


def recording() -> bool:
    return _REC.rows is not None


def maybe_record(name: str, x: jax.Array, scfg: MemstashConfig) -> None:
    """Record measured compression stats for one stash point (eager only).

    Under jit/grad tracing the activation is a tracer, so only a
    lightweight trace-time marker is recorded (shape info, no data) —
    enough for tests to assert a stash point is actually wired into a
    compiled program without forcing a host sync."""
    if _REC.rows is None:
        return
    if isinstance(x, jax.core.Tracer):
        _REC.rows.append({"layer": name, "elems": int(x.size),
                          "dtype": str(x.dtype), "traced": True})
        return
    sv = compress(x, capacity=scfg.capacity)
    n = sv.n
    nnz = int(sv.nnz)
    density = nnz / n
    _REC.rows.append({
        "layer": name,
        "elems": n,
        "nnz": nnz,
        "density": density,
        "dtype": str(x.dtype),
        "logical_bytes": logical_bytes(sv),
        "dense_fp32_bytes": dense_fp32_bytes(sv),
        "wire_bytes": float(wire_bytes(sv, scfg.value_bits)),
        "formula_bytes": n * formula_bits_per_elem(density, scfg.value_bits) / 8.0,
        "overflow": int(sv.overflow),
    })


def summarize(rows: list) -> dict:
    """Aggregate per-layer rows into model-level totals (measured rows
    only; trace-time markers carry no data and are skipped)."""
    rows = [r for r in rows if not r.get("traced")]
    if not rows:
        return {"stash_points": 0}
    wire = sum(r["wire_bytes"] for r in rows)
    dense = sum(r["dense_fp32_bytes"] for r in rows)
    formula = sum(r["formula_bytes"] for r in rows)
    elems = sum(r["elems"] for r in rows)
    return {
        "stash_points": len(rows),
        "total_elems": elems,
        "mean_density": sum(r["nnz"] for r in rows) / elems,
        "dense_fp32_bytes": dense,
        "wire_bytes": wire,
        "formula_bytes": formula,
        "compression_vs_fp32": dense / wire if wire else float("inf"),
        "wire_vs_formula": wire / formula if formula else float("nan"),
    }
