"""Compressed activation stash: SPRING's RRAM training-memory interface as
a runnable subsystem (binary-mask compressed forward residuals, restored on
the backward pass).  See DESIGN.md §4.3."""

from repro.memstash.config import MemstashConfig, REMAT_ALL, STASH_ALL, STASH_POLICIES
from repro.memstash.format import (
    StashedActivation,
    compress,
    decompress,
    dense_fp32_bytes,
    formula_bits_per_elem,
    logical_bytes,
    wire_bits,
    wire_bytes,
)
from repro.memstash.instrument import record_stash_traffic, summarize
from repro.memstash.stash import checkpoint_apply, stash_apply

__all__ = [
    "MemstashConfig",
    "REMAT_ALL",
    "STASH_ALL",
    "STASH_POLICIES",
    "StashedActivation",
    "checkpoint_apply",
    "compress",
    "decompress",
    "dense_fp32_bytes",
    "formula_bits_per_elem",
    "logical_bytes",
    "record_stash_traffic",
    "stash_apply",
    "summarize",
    "wire_bits",
    "wire_bytes",
]
