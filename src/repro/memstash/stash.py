"""The stash/restore autodiff pair (DESIGN.md §4.3).

SPRING's training story is that forward activations are written to the
monolithic-3D RRAM in binary-mask compressed form and re-read in the
backward pass.  ``stash_apply`` is the executable counterpart: a
``jax.custom_vjp`` wrapper around a block ``f(x, aux)`` whose residual is
the *compressed* input instead of the block's dense intermediates —

  forward:  y = f(x, aux);   residual = (compress(x), aux)
  backward: x = decompress(residual); grads = vjp(f, x, aux)(g)

i.e. remat-from-compressed-input: the block recomputes like ``jax.checkpoint``
but reads its input back through the compressed stash.  The modeled wire
traffic of that residual is ``nnz * value_bits + 1 bit/elem`` — the
quantity SPRING's RRAM interface moves, which the instrumentation measures
and cross-checks against the perfmodel formula.  *Device* memory under
jit's static shapes only shrinks with ``capacity < 1.0`` (the value buffer
is allocated at ``ceil(n * capacity)``); at the default capacity 1.0 the
residual is dense-length values + mask words, and what you buy is the
bit-exact restore: gradients identical to the unstashed program (dense
mode; quantized modes re-draw SR keys on the backward re-trace, the same
caveat ``jax.checkpoint`` already has with ``KeyGen``).

``checkpoint_apply`` dispatches one stash point through the per-layer
policy: "none" (XLA keeps the dense residual), "remat" (``jax.checkpoint``),
or "stash" (this wrapper).
"""

from __future__ import annotations

from functools import partial

import jax

from repro import telemetry
from repro.memstash.config import MemstashConfig, STASH_POLICIES
from repro.memstash.format import compress, decompress
from repro.memstash.instrument import maybe_record


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _stashed_call(f, scfg: MemstashConfig, name: str, x, aux):
    return f(x, aux)


def _stashed_fwd(f, scfg: MemstashConfig, name: str, x, aux):
    y = f(x, aux)
    # NB: under jit these spans time *tracing* of the pack (staging it
    # into the program), eager calls time the pack itself — either way
    # they mark where each stash point's compression enters the step
    with telemetry.span("memstash.pack", layer=name, elems=int(x.size)):
        sv = compress(x, capacity=scfg.capacity)
    return y, (sv, aux)


def _stashed_bwd(f, scfg: MemstashConfig, name: str, res, g):
    sv, aux = res
    with telemetry.span("memstash.unpack", layer=name, elems=int(sv.n)):
        x = decompress(sv)
    _, vjp = jax.vjp(f, x, aux)
    return vjp(g)


_stashed_call.defvjp(_stashed_fwd, _stashed_bwd)


def stash_apply(f, scfg: MemstashConfig, name: str, x, aux=()):
    """Run ``f(x, aux)`` storing ``x`` compressed for the backward pass.

    ``x`` is the (sparse) activation worth compressing; ``aux`` is a pytree
    of other differentiable inputs (weights, biases, small carries) kept
    dense in the residual — parameters are live in memory anyway.
    """
    maybe_record(name, x, scfg)
    return _stashed_call(f, scfg, name, x, aux)


def checkpoint_apply(f, policy: str, scfg, name: str, x, aux=()):
    """Apply one stash point under the selected checkpoint policy."""
    if policy == "none":
        return f(x, aux)
    if policy == "remat":
        return jax.checkpoint(f)(x, aux)
    if policy == "stash":
        return stash_apply(f, scfg if scfg is not None else MemstashConfig(policy="stash"),
                           name, x, aux)
    raise ValueError(f"policy {policy!r} not in {STASH_POLICIES}")
