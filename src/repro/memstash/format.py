"""The stash storage format: SPRING binary-mask compression for whole
activation tensors (paper Fig. 5, extended to arbitrary shapes/dtypes).

A ``StashedActivation`` holds

  values — (capacity_len,) original dtype: non-zeros collapsed to the
           front (Fig. 7(c) zero-collapsing shifter as a cumsum-scatter),
           zero-padded tail;
  mask   — (ceil(n/32),) uint32 packed occupancy bits (1 bit/element);
  nnz    — () int32 live-value count;

plus static aux data (shape, dtype) so it round-trips through jit,
``jax.custom_vjp`` residuals and ``lax.scan`` carries.  With the default
capacity (= dense length) the round trip is bit-exact for any dtype:
values are stored verbatim, only positions are re-derived from the mask.
The single canonicalization is ``-0.0 -> +0.0`` (a signed zero compares
equal to zero so its mask bit is 0) — irrelevant for ReLU activations,
whose zeros are produced as +0.0.

Byte accounting distinguishes

  logical bytes — the dense tensor at its own dtype (what XLA would keep);
  wire bytes    — what SPRING's RRAM interface moves: ``nnz * value_bits``
                  for data + one mask bit per element, i.e. the perfmodel
                  traffic formula ``bits/elem = value_bits*density + 1``
                  evaluated at the *measured* density (DESIGN.md §4.3).

``formula_bits_per_elem`` is the single source of that formula; the
analytical perf model imports it from here.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.masking import (
    MASK_WORD_BITS,
    collapse_to_front,
    expand_from_mask,
    pack_mask_bits,
    unpack_mask_bits,
)


def formula_bits_per_elem(density: float, value_bits: int = 20):
    """Paper Fig. 5 traffic accounting: ``value_bits * density + 1``."""
    return value_bits * density + 1.0


@jax.tree_util.register_pytree_node_class
class StashedActivation:
    """Binary-mask compressed tensor; a pytree with static shape/dtype."""

    def __init__(self, values, mask, nnz, shape, dtype):
        self.values = values
        self.mask = mask
        self.nnz = nnz
        self.shape = tuple(shape)
        self.dtype = jnp.dtype(dtype)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.values, self.mask, self.nnz), (self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, mask, nnz = children
        shape, dtype = aux
        return cls(values, mask, nnz, shape, dtype)

    # -- derived quantities ------------------------------------------------
    @property
    def n(self) -> int:
        return int(math.prod(self.shape))

    @property
    def capacity_len(self) -> int:
        return int(self.values.shape[0])

    @property
    def density(self) -> jax.Array:
        return self.nnz.astype(jnp.float32) / self.n

    @property
    def overflow(self) -> jax.Array:
        """Live values dropped because nnz exceeded the capacity buffer."""
        return jnp.maximum(self.nnz - self.capacity_len, 0)


def _capacity_len(n: int, capacity: float) -> int:
    return n if capacity >= 1.0 else max(1, int(math.ceil(n * capacity)))


def compress(x: jax.Array, capacity: float = 1.0) -> StashedActivation:
    """Dense tensor -> binary-mask compressed stash record."""
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    n = flat.shape[0]
    assert n > 0, "cannot stash an empty tensor"
    cap = _capacity_len(n, capacity)
    bits = flat != 0
    return StashedActivation(
        values=collapse_to_front(flat, bits, cap),
        mask=pack_mask_bits(bits),
        nnz=bits.sum().astype(jnp.int32),
        shape=shape,
        dtype=dtype,
    )


def decompress(sv: StashedActivation) -> jax.Array:
    """Compressed stash record -> dense tensor (bit-exact at capacity 1.0)."""
    bits = unpack_mask_bits(sv.mask, sv.n)
    return expand_from_mask(sv.values, bits).reshape(sv.shape)


# -- byte accounting ---------------------------------------------------------


def logical_bytes(sv: StashedActivation) -> float:
    """Dense footprint at the tensor's own dtype."""
    return float(sv.n * sv.dtype.itemsize)


def dense_fp32_bytes(sv: StashedActivation) -> float:
    """Dense fp32 footprint — the paper's GPU-baseline comparison point."""
    return float(sv.n * 4)


def wire_bits(sv: StashedActivation, value_bits: int = 20) -> jax.Array:
    """Bits SPRING's memory interface moves: data + 1 mask bit/element.

    The mask contribution counts the packed words actually stored
    (``ceil(n/32)`` uint32s), so this is the measured size of the
    representation, not the formula — the two are cross-checked in tests.
    """
    mask_bits = sv.mask.shape[0] * MASK_WORD_BITS
    live = jnp.minimum(sv.nnz, sv.capacity_len).astype(jnp.float32)
    return live * value_bits + mask_bits


def wire_bytes(sv: StashedActivation, value_bits: int = 20) -> jax.Array:
    return wire_bits(sv, value_bits) / 8.0
