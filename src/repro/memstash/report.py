"""Measure per-layer stash traffic for the paper CNNs and an LM block.

Runs model forwards eagerly under the stash-traffic recorder (ReLU gives
the CNNs their natural activation sparsity) and writes one JSON per model
into ``results/memstash/``, which ``launch/roofline_report.py`` renders as
the memstash table.

  PYTHONPATH=src python -m repro.memstash.report --cnn mobilenet_v2 --hw 96
  PYTHONPATH=src python -m repro.memstash.report --all-cnns --out results/memstash
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.memstash.config import MemstashConfig
from repro.memstash.instrument import record_stash_traffic, summarize


def measure_cnn_stash(name: str = "mobilenet_v2", hw: int = 96, batch: int = 2,
                      scfg: MemstashConfig | None = None, seed: int = 0) -> dict:
    """Per-layer stash accounting for one paper CNN at reduced resolution."""
    from repro.models.cnn import PAPER_CNNS, cnn_apply, cnn_init
    from repro.models.layers import SpringContext

    if name not in PAPER_CNNS:
        raise SystemExit(f"unknown CNN {name!r}; choose from {sorted(PAPER_CNNS)}")
    cnn = PAPER_CNNS[name]
    if scfg is None:
        from repro.configs.base import default_memstash

        scfg = default_memstash("cnn")
    params = cnn_init(jax.random.PRNGKey(seed), cnn, input_hw=hw)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, hw, hw, 3))
    ctx = SpringContext(memstash=scfg)
    with record_stash_traffic() as rows:
        cnn_apply(params, cnn, x, ctx)
    return {"model": name, "kind": "cnn", "hw": hw, "batch": batch,
            "rows": rows, "summary": summarize(rows)}


def measure_lm_stash(arch_id: str = "llama3.2-1b", batch: int = 2, seq: int = 64,
                     scfg: MemstashConfig | None = None, seed: int = 0) -> dict:
    """Stash accounting for one reduced-LM residual block, run eagerly.

    LM residual streams are dense, so this measures the stash format's
    graceful-degradation point: ~logical bytes + 1 mask bit/elem.
    """
    from repro.configs import get_arch
    from repro.models import lm as lm_mod
    from repro.models.layers import SpringContext
    from repro.memstash.stash import stash_apply

    arch = get_arch(arch_id)
    cfg = arch.reduced()
    scfg = scfg or MemstashConfig(policy="stash")
    params = lm_mod.lm_init(jax.random.PRNGKey(seed), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (batch, seq), 0, cfg.vocab)
    ctx = SpringContext(memstash=scfg)
    x = lm_mod.embed_apply(params["embed"], tokens, ctx)
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    unit0 = jax.tree_util.tree_map(lambda a: a[0], params["unit_0"])
    kind = cfg.pattern_unit[0]

    def block(h, aux):
        out, _, _ = lm_mod.block_apply(aux[0], h, ctx, cfg, kind, positions)
        return out

    with record_stash_traffic() as rows:
        stash_apply(block, scfg, f"{arch_id}/unit0", x, (unit0,))
    return {"model": arch_id, "kind": "lm_block", "batch": batch, "seq": seq,
            "rows": rows, "summary": summarize(rows)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cnn", action="append", default=[])
    ap.add_argument("--all-cnns", action="store_true")
    ap.add_argument("--lm", action="append", default=[])
    ap.add_argument("--hw", type=int, default=96)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--out", default="results/memstash")
    args = ap.parse_args()

    jobs = list(args.cnn)
    if args.all_cnns:
        from repro.models.cnn import PAPER_CNNS

        jobs = sorted(PAPER_CNNS)
    if not jobs and not args.lm:
        jobs = ["mobilenet_v2"]

    os.makedirs(args.out, exist_ok=True)
    for name in jobs:
        hw = min(args.hw, 96)  # keep eager CPU forwards tractable
        if hw != args.hw:
            print(f"note: --hw {args.hw} clamped to {hw} (eager CPU forwards; "
                  f"JSONs record the measured resolution)")
        res = measure_cnn_stash(name, hw=hw, batch=args.batch)
        path = os.path.join(args.out, f"{name}.json")
        json.dump(res, open(path, "w"), indent=1)
        s = res["summary"]
        if not s.get("stash_points"):
            print(f"{name}: no stash points recorded (policy resolved everything to none)")
            continue
        print(f"{name}: {s['stash_points']} points, density {s['mean_density']:.3f}, "
              f"{s['dense_fp32_bytes']/1e6:.2f} MB fp32 -> {s['wire_bytes']/1e6:.2f} MB wire "
              f"({s['compression_vs_fp32']:.2f}x), wire/formula {s['wire_vs_formula']:.4f}")
    for arch_id in args.lm:
        res = measure_lm_stash(arch_id, batch=args.batch)
        path = os.path.join(args.out, f"{arch_id.replace('/', '_')}_block.json")
        json.dump(res, open(path, "w"), indent=1)
        s = res["summary"]
        if not s.get("stash_points"):
            print(f"{arch_id} block: no stash points recorded")
            continue
        print(f"{arch_id} block: density {s['mean_density']:.3f}, "
              f"{s['dense_fp32_bytes']/1e6:.2f} MB fp32 -> {s['wire_bytes']/1e6:.2f} MB wire")


if __name__ == "__main__":
    main()
