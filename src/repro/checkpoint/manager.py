"""Checkpoint manager: atomic, integrity-checked, keep-k, re-mesh restore.

Fault-tolerance contract (DESIGN.md §4):
  * writes go to ``<dir>/tmp.step_N`` and are renamed atomically — a
    preempted writer can never corrupt the latest valid checkpoint;
  * every array records a SHA-256 digest in the manifest; loads verify;
  * ``latest`` resolution scans valid manifests (not a symlink), so a
    torn write is skipped automatically on restart;
  * arrays are stored logically (full shapes) — restore reshards onto
    *whatever mesh is active* (elastic shrink/grow across restarts);
  * optimizer state / data step / rng all live in the same tree, so
    resume is exact.

On a real multi-host pod each process would write its owned shards
(process-local `.npz` + shared manifest); this container is single-host,
so arrays are written whole — the formats and the restore path are the
same (recorded as a scale note in DESIGN.md).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _tree_to_flat(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[name] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.step_{step:08d}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _tree_to_flat(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    digests = {k: hashlib.sha256(v.tobytes()).hexdigest() for k, v in flat.items()}
    ts = jax.tree_util.tree_structure(tree)
    try:  # proto is stable across versions but rejects user-defined nodes
        treedef_hex, treedef_kind = ts.serialize_using_proto().hex(), "proto"
    except ValueError:  # e.g. NamedTuple optimizer states -> pickle
        import pickle

        treedef_hex, treedef_kind = pickle.dumps(ts).hex(), "pickle"
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype), "sha256": digests[k]} for k, v in flat.items()},
        "treedef": treedef_hex,
        "treedef_kind": treedef_kind,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def _valid_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def load_checkpoint(
    ckpt_dir: str,
    step: Optional[int] = None,
    sharding_fn: Optional[Callable[[str, tuple], Any]] = None,
    verify: bool = True,
) -> tuple[int, Any]:
    """Load latest (or given) step.  ``sharding_fn(name, shape)`` may
    return a Sharding to place each array directly onto the active mesh
    (the elastic re-mesh path); None keeps host arrays."""
    steps = _valid_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no valid checkpoints in {ckpt_dir}")
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    names = list(manifest["arrays"].keys())
    try:
        data = np.load(os.path.join(path, "arrays.npz"))
        arrays = {name: data[name] for name in names}
    except Exception as e:  # torn/corrupt archive -> uniform IOError
        raise IOError(f"checkpoint corruption reading {path}: {e}") from e
    for name in names:
        arr = arrays[name]
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != manifest["arrays"][name]["sha256"]:
                raise IOError(f"checkpoint corruption: {name} digest mismatch")
        if sharding_fn is not None:
            sh = sharding_fn(name, arr.shape)
            leaves.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
        else:
            leaves.append(jnp.asarray(arr))
    if manifest.get("treedef_kind", "proto") == "pickle":
        import pickle

        treedef = pickle.loads(bytes.fromhex(manifest["treedef"]))
    else:
        from repro.runtime.compat import deserialize_treedef

        treedef = deserialize_treedef(bytes.fromhex(manifest["treedef"]))
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Keep-k rotation + auto-resume + preemption-safe cadence."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every_steps: int = 100):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.every_steps = every_steps

    def maybe_save(self, step: int, tree: Any, metadata: Optional[dict] = None, force: bool = False):
        if not force and (step % self.every_steps != 0):
            return None
        path = save_checkpoint(self.ckpt_dir, step, tree, metadata)
        for old in _valid_steps(self.ckpt_dir)[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{old:08d}"), ignore_errors=True)
        return path

    def restore_or_none(self, sharding_fn=None):
        try:
            return load_checkpoint(self.ckpt_dir, sharding_fn=sharding_fn)
        except FileNotFoundError:
            return None

    def latest_step(self) -> Optional[int]:
        steps = _valid_steps(self.ckpt_dir)
        return steps[-1] if steps else None
