"""Optimizers with SPRING reduced-precision weight updates."""

from repro.optim.optimizers import (
    OptState,
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    make_optimizer,
    sgdm_init,
    sgdm_update,
)

__all__ = [
    "OptState",
    "OptimizerConfig",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "make_optimizer",
    "sgdm_init",
    "sgdm_update",
]
