"""SGD-momentum and AdamW, built from scratch (no optax), with the
SPRING twist: optional fixed-point Q(IL,FL) master weights updated via
stochastic rounding (paper §3.2 — the mechanism that keeps reduced-
precision *training* convergent).  ``weight_format=None`` gives standard
fp32 training (the dense baseline).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import FixedPointFormat, quantize_stochastic


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"  # "adamw" | "sgdm"
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0
    # SPRING reduced-precision master weights (None -> fp32 baseline)
    weight_format: Optional[FixedPointFormat] = None
    warmup_steps: int = 0


class OptState(NamedTuple):
    step: jax.Array
    m: Any  # first moment / momentum
    v: Any  # second moment (adamw) or None-like zeros (sgdm)


def _zeros_like_tree(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def _schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.float32(cfg.lr)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return lr


def _finalize_weights(new_p, cfg: OptimizerConfig, key: Optional[jax.Array]):
    """SR-quantize updated weights onto the Q(IL,FL) grid when configured."""
    if cfg.weight_format is None:
        return new_p
    assert key is not None, "fixed-point weight update needs an rng key"
    leaves, treedef = jax.tree_util.tree_flatten(new_p)
    keys = jax.random.split(key, len(leaves))
    out = [quantize_stochastic(k, p, cfg.weight_format) for k, p in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


# -- AdamW -------------------------------------------------------------------


def adamw_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_tree(params), _zeros_like_tree(params))


def adamw_update(
    cfg: OptimizerConfig,
    grads,
    state: OptState,
    params,
    key: Optional[jax.Array] = None,
):
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.m, grads)
    new_v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads)

    def upd(p, m, v):
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        return (p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_p = jax.tree_util.tree_map(upd, params, new_m, new_v)
    new_p = _finalize_weights(new_p, cfg, key)
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}


# -- SGD momentum ------------------------------------------------------------


def sgdm_init(params) -> OptState:
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_tree(params),
                    jax.tree_util.tree_map(lambda p: jnp.zeros((), jnp.float32), params))


def sgdm_update(
    cfg: OptimizerConfig,
    grads,
    state: OptState,
    params,
    key: Optional[jax.Array] = None,
):
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    new_m = jax.tree_util.tree_map(
        lambda m, g: cfg.momentum * m + g.astype(jnp.float32), state.m, grads
    )
    new_p = jax.tree_util.tree_map(
        lambda p, m: (p.astype(jnp.float32) - lr * (m + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype),
        params, new_m,
    )
    new_p = _finalize_weights(new_p, cfg, key)
    return new_p, OptState(step, new_m, state.v), {"grad_norm": gn, "lr": lr}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.kind == "adamw":
        return adamw_init, lambda g, s, p, key=None: adamw_update(cfg, g, s, p, key)
    if cfg.kind == "sgdm":
        return sgdm_init, lambda g, s, p, key=None: sgdm_update(cfg, g, s, p, key)
    raise ValueError(cfg.kind)
