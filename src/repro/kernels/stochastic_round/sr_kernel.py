"""Pallas TPU kernel: elementwise stochastic rounding to Q(IL, FL).

The SPRING MAC-lane epilogue (paper Fig. 8): wide accumulator values are
rounded back to the storage fixed-point format with probability
proportional to fractional proximity (Eq. 4), driven by an in-kernel
counter-based xorshift PRNG (DESIGN.md deviation 3 — LFSR -> xorshift).

Tiling: the array is flattened and processed in (8, 1024) f32 VMEM blocks
(sublane x lane aligned); one grid step per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.prng import hash_uint32, uniform_from_bits

# (sublanes, lanes) per VMEM block — f32-aligned 8x128 multiples.
BLOCK_ROWS = 8
BLOCK_COLS = 1024
BLOCK = BLOCK_ROWS * BLOCK_COLS


def _sr_kernel(x_ref, seed_ref, out_ref, *, fl: int, min_v: float, max_v: float):
    i = pl.program_id(0)
    x = x_ref[...]
    scale = jnp.float32(2.0**fl)
    inv_scale = jnp.float32(2.0**-fl)
    xc = jnp.clip(x, min_v, max_v)
    scaled = xc * scale
    lo = jnp.floor(scaled)
    frac = scaled - lo

    # Per-element global counter: block offset + intra-block linear index.
    rows = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    counter = (
        jnp.uint32(i) * jnp.uint32(BLOCK)
        + rows * jnp.uint32(BLOCK_COLS)
        + cols
    )
    u = uniform_from_bits(hash_uint32(counter, seed_ref[0, 0]))
    rounded = lo + (u < frac).astype(jnp.float32)
    out_ref[...] = jnp.clip(rounded * inv_scale, min_v, max_v)


def sr_pallas(
    x: jax.Array,
    seed: jax.Array,
    *,
    il: int = 4,
    fl: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """Stochastically round flat-viewable ``x`` (float32) onto Q(il, fl)."""
    orig_shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = pl.cdiv(n, BLOCK) * BLOCK
    flat = jnp.pad(flat, (0, padded - n))
    x2d = flat.reshape(-1, BLOCK_COLS)
    grid = (x2d.shape[0] // BLOCK_ROWS,)

    eps = 2.0**-fl
    kernel = functools.partial(
        _sr_kernel, fl=fl, min_v=-(2.0**il), max_v=2.0**il - eps
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.float32),
        interpret=interpret,
    )(x2d, seed.astype(jnp.uint32).reshape(1, 1))
    return out.reshape(-1)[:n].reshape(orig_shape)
