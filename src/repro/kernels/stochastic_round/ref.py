"""Pure-jnp oracle for the stochastic-rounding kernel.

Bit-exact mirror of ``sr_kernel``: identical counter layout, identical
hash, identical clip/floor sequence — so tests can assert exact equality,
not just closeness.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.prng import hash_uint32, uniform_from_bits
from repro.kernels.stochastic_round.sr_kernel import BLOCK_COLS, BLOCK_ROWS

BLOCK = BLOCK_ROWS * BLOCK_COLS


def sr_reference(x: jax.Array, seed: jax.Array, *, il: int = 4, fl: int = 16) -> jax.Array:
    orig_shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    padded = (n + BLOCK - 1) // BLOCK * BLOCK
    flat = jnp.pad(flat, (0, padded - n))

    eps = 2.0**-fl
    min_v, max_v = -(2.0**il), 2.0**il - eps
    xc = jnp.clip(flat, min_v, max_v)
    scaled = xc * jnp.float32(2.0**fl)
    lo = jnp.floor(scaled)
    frac = scaled - lo

    # Same counter layout as the kernel: counters are contiguous in the
    # flattened (block, row, col) order, which equals the flat index.
    counter = jnp.arange(padded, dtype=jnp.uint32)
    u = uniform_from_bits(hash_uint32(counter, seed.astype(jnp.uint32)))
    rounded = lo + (u < frac).astype(jnp.float32)
    out = jnp.clip(rounded * jnp.float32(eps), min_v, max_v)
    return out[:n].reshape(orig_shape)
