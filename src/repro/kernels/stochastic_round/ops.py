"""Jitted public wrapper for the stochastic-rounding kernel.

Dispatch: Pallas kernel on TPU, interpret-mode kernel when explicitly
requested (tests), bit-identical jnp reference otherwise (CPU dry-run).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.stochastic_round.ref import sr_reference
from repro.kernels.stochastic_round.sr_kernel import sr_pallas


@partial(jax.jit, static_argnames=("il", "fl", "impl"))
def stochastic_round(
    x: jax.Array,
    seed: jax.Array,
    *,
    il: int = 4,
    fl: int = 16,
    impl: str = "auto",
) -> jax.Array:
    """SR onto Q(il, fl). impl: auto|pallas|interpret|ref."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return sr_pallas(x, seed, il=il, fl=fl, interpret=False)
    if impl == "interpret":
        return sr_pallas(x, seed, il=il, fl=fl, interpret=True)
    return sr_reference(x, seed, il=il, fl=fl)
