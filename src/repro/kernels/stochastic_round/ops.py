"""Public wrapper for the stochastic-rounding kernel.

Implementations (see ``repro.kernels.registry``): ``pallas`` on TPU,
``interpret`` when explicitly requested (tests), bit-identical ``ref``
jnp lowering elsewhere (the CPU production path).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.stochastic_round.ref import sr_reference
from repro.kernels.stochastic_round.sr_kernel import sr_pallas


@partial(jax.jit, static_argnames=("il", "fl"))
def _sr_ref(x, seed, *, il=4, fl=16):
    return sr_reference(x, seed, il=il, fl=fl)


@partial(jax.jit, static_argnames=("il", "fl", "interpret"))
def _sr_kernel(x, seed, *, il=4, fl=16, interpret=False):
    return sr_pallas(x, seed, il=il, fl=fl, interpret=interpret)


def _examples() -> list:
    cases = []
    for i, shape in enumerate([(128,), (333, 17), (8, 1024), (3, 5, 9)]):
        x = jax.random.normal(jax.random.PRNGKey(42 + i), shape) * 3
        cases.append(((x, jnp.uint32(9)), {}))
    x = jax.random.normal(jax.random.PRNGKey(4), (256, 64)) * 3
    cases.append(((x, jnp.uint32(9)), {"il": 2, "fl": 6}))
    return cases


registry.register_op("stochastic_round", oracle="ref", examples=_examples,
                     compare={"kind": "exact"})
registry.register_impl("stochastic_round", "ref", priority=10)(_sr_ref)
registry.register_impl("stochastic_round", "interpret", selectable=False)(
    partial(_sr_kernel, interpret=True))
registry.register_impl("stochastic_round", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_sr_kernel, interpret=False))


def stochastic_round(
    x: jax.Array,
    seed: jax.Array,
    *,
    il: int = 4,
    fl: int = 16,
    impl: str | None = None,
) -> jax.Array:
    """SR onto Q(il, fl); ``impl`` pins a registered implementation."""
    kimpl = registry.resolve("stochastic_round", impl)
    return kimpl.fn(x, seed, il=il, fl=fl)
