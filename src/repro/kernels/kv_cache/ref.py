"""Element-serial numpy oracles for the KV-cache block format — the same
binary-mask encoding the memstash subsystem uses for activations (paper
Fig. 5), applied to one flattened KV block: non-zeros collapsed to the
front of a dense-length value buffer + 1 packed occupancy bit per element.

The serving engine's compressed slot pool is tested against these, the
vectorized registry impls are tested against the ``ref`` registration
(which is itself cross-checked against these in
``tests/test_kv_cache_roundtrip.py``).
"""

from __future__ import annotations

import numpy as np


def kv_pack_reference(x: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Flattened block -> (values, mask_words, nnz), element-serial.

    values keeps the block's own dtype and dense length (capacity = n, so
    the round trip is bit-exact); mask_words is ``ceil(n/32)`` uint32 with
    bit i of word w = element ``32*w + i``.
    """
    flat = np.asarray(x).reshape(-1)
    n = flat.shape[0]
    values = np.zeros_like(flat)
    p = 0
    for v in flat:
        if v != 0:
            values[p] = v
            p += 1
    bits = (flat != 0).astype(np.uint32)
    words = np.zeros(((n + 31) // 32,), np.uint32)
    for i, b in enumerate(bits):
        if b:
            words[i // 32] |= np.uint32(1) << np.uint32(i % 32)
    return values, words, p


def kv_unpack_reference(values: np.ndarray, words: np.ndarray, length: int) -> np.ndarray:
    """Inverse of :func:`kv_pack_reference` (flat dense block)."""
    out = np.zeros((length,), values.dtype)
    p = 0
    for i in range(length):
        if (words[i // 32] >> np.uint32(i % 32)) & np.uint32(1):
            out[i] = values[p]
            p += 1
    return out


def kv_wire_bits_reference(nnz: int, length: int, value_bits: int = 20) -> int:
    """Bits the SPRING memory interface moves for one packed block: 20-bit
    values for the live entries + the packed mask words actually stored."""
    return nnz * value_bits + ((length + 31) // 32) * 32
