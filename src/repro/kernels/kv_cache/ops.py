"""KV-cache block compression ops: ``kv_pack`` / ``kv_unpack``.

The inference-side twin of the memstash activation format (DESIGN.md
§4.3): one flattened KV block is stored as its non-zeros collapsed to the
front of a dense-length value buffer (bit-exact round trip, values kept
verbatim in the block's own dtype) plus a 1-bit-per-element packed
occupancy mask.  The serving engine's slot pool stores every seq-bearing
cache leaf in this form and unpacks it on read inside the decode step
(``repro.serving.kvpool``); the wire accounting is the paper's
``bits/elem = 20*density + 1`` interface formula, single-sourced with
``memstash.format.formula_bits_per_elem``.

Implementation ladder:

  ref        cumsum-scatter collapse + reshape-based mask pack (the
             vectorized oracle, shared with core/masking.py);
  jnp        stable-argsort collapse + gather-based word pack — a second,
             independently-derived exact lowering (cross-checked in CI);
  interpret  mask words from the Pallas ``mask_pack`` kernel in interpret
             mode (lane-padded, trimmed to the canonical word count);
  pallas     the same kernel compiled on TPU.

``kv_unpack`` is a shift-and-test + gather on every backend; its
interpret/pallas registrations alias the vectorized lowering (the
mask_unpack precedent) and are excluded from the parity sweep.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.masking import (
    MASK_WORD_BITS,
    collapse_to_front,
    pack_mask_bits,
)
from repro.kernels import registry

#: SPRING storage width of one cached value on the RRAM interface
#: (IL4 + FL16 fixed point — SpringDesign.value_bits).
KV_VALUE_BITS = 20


def _n_words(n: int) -> int:
    return (n + MASK_WORD_BITS - 1) // MASK_WORD_BITS


@jax.jit
def _pack_ref(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    bits = flat != 0
    return {
        "values": collapse_to_front(flat, bits, n),
        "mask": pack_mask_bits(bits),
        "nnz": bits.sum().astype(jnp.int32),
    }


@jax.jit
def _pack_jnp(x):
    # independent exact lowering: live elements first via a stable argsort
    # on the occupancy bits, dead/overflow tail zeroed behind nnz
    flat = x.reshape(-1)
    n = flat.shape[0]
    bits = flat != 0
    order = jnp.argsort(jnp.logical_not(bits), stable=True)
    nnz = bits.sum().astype(jnp.int32)
    gathered = flat[order]
    values = jnp.where(jnp.arange(n) < nnz, gathered,
                       jnp.zeros((), flat.dtype))
    # gather-based word pack (vs the ref's reshape-based pack)
    word = jnp.arange(n) // MASK_WORD_BITS
    shift = (jnp.arange(n) % MASK_WORD_BITS).astype(jnp.uint32)
    contrib = jnp.where(bits, jnp.uint32(1) << shift, jnp.uint32(0))
    words = jnp.zeros((_n_words(n),), jnp.uint32).at[word].add(contrib)
    return {"values": values, "mask": words, "nnz": nnz}


@partial(jax.jit, static_argnames=("interpret",))
def _pack_kernel(x, *, interpret):
    from repro.kernels.mask_compress.ops import _pad2d
    from repro.kernels.mask_compress.mc_kernel import mask_pack_pallas

    flat = x.reshape(-1)
    n = flat.shape[0]
    bits = flat != 0
    x2d, _, _ = _pad2d(flat)
    # lane-padded kernel words are bit-compatible with the canonical
    # layout (word j covers elements 32j..32j+31); the pad tail is zero
    words = mask_pack_pallas(x2d, interpret=interpret).reshape(-1)[:_n_words(n)]
    return {
        "values": collapse_to_front(flat, bits, n),
        "mask": words,
        "nnz": bits.sum().astype(jnp.int32),
    }


@partial(jax.jit, static_argnames=("length",))
def _unpack_ref(values, mask, *, length):
    from repro.core.masking import expand_from_mask, unpack_mask_bits

    bits = unpack_mask_bits(mask, length)
    return expand_from_mask(values, bits)


@partial(jax.jit, static_argnames=("length",))
def _unpack_jnp(values, mask, *, length):
    # gather-based shift-and-test (vs the ref's reshape-based unpack)
    idx = jnp.arange(length)
    shift = (idx % MASK_WORD_BITS).astype(jnp.uint32)
    bits = (mask[idx // MASK_WORD_BITS] >> shift) & jnp.uint32(1)
    src = jnp.cumsum(bits.astype(jnp.int32)) - 1
    cap = values.shape[0]
    live = (bits == 1) & (src < cap)
    gathered = values[jnp.clip(src, 0, cap - 1)]
    return jnp.where(live, gathered, jnp.zeros((), values.dtype))


# -- registry examples --------------------------------------------------------


def _kv_block(seed: int, n: int, live_rows: int, total_rows: int,
              dtype=jnp.bfloat16) -> jax.Array:
    """A slot-pool-shaped block: the first ``live_rows`` of ``total_rows``
    carry dense KV values, the unfilled tail is zero (the natural sparsity
    pattern of a partially-decoded slot)."""
    key = jax.random.PRNGKey(seed)
    per_row = n // total_rows
    x = jax.random.normal(key, (total_rows, per_row), jnp.float32)
    live = jnp.arange(total_rows)[:, None] < live_rows
    return jnp.where(live, x, 0.0).astype(dtype).reshape(-1)[:n]


def _pack_examples() -> list:
    return [
        ((_kv_block(0, 4096, 9, 16),), {}),                  # bf16, word-aligned
        ((_kv_block(1, 4096, 16, 16, jnp.float32),), {}),    # fully dense
        ((_kv_block(2, 1000, 3, 10, jnp.float32),), {}),     # unaligned length
        ((jnp.zeros((640,), jnp.bfloat16),), {}),            # empty slot
    ]


def _unpack_examples() -> list:
    out = []
    for (x,), _ in _pack_examples():
        packed = _pack_ref(x)
        out.append(((packed["values"], packed["mask"]),
                    {"length": int(x.size)}))
    return out


registry.register_op("kv_pack", oracle="ref", examples=_pack_examples,
                     compare={"kind": "exact"})
registry.register_impl("kv_pack", "ref", priority=10)(_pack_ref)
registry.register_impl("kv_pack", "jnp", priority=20)(_pack_jnp)
registry.register_impl("kv_pack", "interpret", selectable=False)(
    partial(_pack_kernel, interpret=True))
registry.register_impl("kv_pack", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_pack_kernel, interpret=False))

registry.register_op("kv_unpack", oracle="ref", examples=_unpack_examples,
                     compare={"kind": "exact"})
registry.register_impl("kv_unpack", "ref", priority=10)(_unpack_ref)
registry.register_impl("kv_unpack", "jnp", priority=20)(_unpack_jnp)
registry.register_impl("kv_unpack", "interpret", selectable=False,
                       parity=False)(_unpack_jnp)
registry.register_impl("kv_unpack", "pallas", priority=30, parity=False,
                       available=registry.on_tpu)(_unpack_jnp)


# -- public wrappers ----------------------------------------------------------


def kv_wire_bits(nnz, length: int, value_bits: int = KV_VALUE_BITS):
    """Bits the memory interface moves for one packed block: live values
    at the SPRING 20-bit width + the packed mask words actually stored.
    At word alignment this is exactly ``length * (value_bits*density + 1)``
    — the ``formula_bits_per_elem`` accounting (cross-checked in tests)."""
    return nnz * value_bits + _n_words(length) * MASK_WORD_BITS


def kv_pack(x: jax.Array, impl: str | None = None) -> dict:
    """Flattened KV block -> {"values", "mask", "nnz"} (bit-exact format).

    ``values`` keeps ``x``'s dtype and dense length; the only
    canonicalization is ``-0.0 -> +0.0`` (its occupancy bit is 0), which
    is invisible to the attention math.
    """
    kimpl = registry.resolve("kv_pack", impl)
    packed = kimpl.fn(x)
    if registry.metrics_active() and not isinstance(
            packed["nnz"], jax.core.Tracer):
        nnz = float(packed["nnz"])
        registry.note_metric(
            "kv_pack",
            wire_bytes=float(kv_wire_bits(nnz, x.size)) / 8.0,
            density=nnz / float(x.size),
        )
    return packed


def kv_unpack(values: jax.Array, mask: jax.Array, length: int,
              impl: str | None = None) -> jax.Array:
    """Packed block -> flat dense ``(length,)`` (``kv_pack`` inverse)."""
    kimpl = registry.resolve("kv_unpack", impl)
    return kimpl.fn(values, mask, length=length)


def kv_probe(density: float = 0.5, size: int = 1 << 14,
             impl: str | None = None) -> dict:
    """Eager KV-compression probe for dry-run attribution.

    A lowered decode cell never executes, so this packs one synthetic KV
    block at the given element density and reports what the registry-
    resolved ``kv_pack`` measured: wire bytes, the reduction vs a dense
    fp32 block, and the measured-over-formula ratio (1.0 at word
    alignment — the ``20*density + 1`` cross-check).
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (size,))
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (size,)) < density
    x = jnp.where(keep, x, 0.0)
    packed = kv_pack(x, impl=impl)
    nnz = int(packed["nnz"])
    wire = float(kv_wire_bits(nnz, size)) / 8.0
    from repro.memstash.format import formula_bits_per_elem

    formula = size * formula_bits_per_elem(nnz / size, KV_VALUE_BITS) / 8.0
    return {
        "density": nnz / size,
        "wire_bytes": wire,
        "compression_vs_fp32": size * 4.0 / wire,
        "wire_vs_formula": wire / formula,
        "impl": registry.resolve("kv_pack", impl, _count=False).name,
    }
