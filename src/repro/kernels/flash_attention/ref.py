"""Pure-jnp oracle: dense softmax attention with causal/window masking."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, HKV, Skv, D). fp32 dense softmax."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    kr = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) * sm_scale
    q_idx = jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), jnp.bool_)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can't happen for causal q>=0) -> zeros
    p = jnp.where(mask.any(axis=-1)[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)
