"""Pallas TPU kernel: blockwise-softmax (flash) attention, causal + local.

Not a SPRING contribution per se — SPRING targets conv/FC compute — but
the assigned LM architectures (32k prefill, recurrentgemma's local
attention, 500k-token cells) need sub-quadratic-memory attention, and the
attention einsums are exactly the "MAC lane" hot spot SPRING accelerates,
so this is where the TPU build spends its FLOPs.

Design: grid (B, H, Sq/BQ, Skv/BK); the kv axis is sequential and carries
the online-softmax state (running max m, denominator l, accumulator acc)
in VMEM scratch.  Causal and sliding-window block-skips gate both the MXU
issue and the HBM->VMEM stream of never-attended kv blocks.  GQA is
handled in the k/v index maps (q head h reads kv head h // group), so kv
is never materialized per-q-head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 128
NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    kv_steps: int,
    causal: bool,
    window: int | None,
    sm_scale: float,
):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip: causal (kv block entirely in the future) and
    # window (kv block entirely before the attention window).
    live = True
    if causal:
        live = live & (j * BK <= i * BQ + BQ - 1)
    if window is not None:
        live = live & (j * BK + BK - 1 >= i * BQ - (window - 1))

    @pl.when(live)
    def _mac():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (BK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        q_idx = i * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
        k_idx = j * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
        mask = jnp.ones((BQ, BK), jnp.bool_)
        if causal:
            mask &= q_idx >= k_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        l_prev = l_scr[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Rows with no live key yet keep m == NEG_INF; exp(NEG_INF - NEG_INF)
        # would be NaN — guard the correction factor.
        alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[:, 0] = m_new
        l_scr[:, 0] = l_new

    @pl.when(j == kv_steps - 1)
    def _epilogue():
        l = l_scr[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, HKV, Skv, D); H % HKV == 0.

    Sq, Skv must be multiples of BQ/BK (wrapper pads).  Returns (B,H,Sq,D).
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0 and sq % BQ == 0 and skv % BK == 0
    group = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d**0.5)
    grid = (b, h, sq // BQ, skv // BK)
    kernel = functools.partial(
        _fa_kernel,
        kv_steps=grid[3],
        causal=causal,
        window=window,
        sm_scale=sm_scale,
    )
    from jax.experimental.pallas import tpu as pltpu

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    scratch = [
        pltpu.VMEM((BQ, 1), jnp.float32),
        pltpu.VMEM((BQ, 1), jnp.float32),
        pltpu.VMEM((BQ, d), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, BQ, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, BK, d), lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, BK, d), lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BQ, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(q, k, v)
