"""Public wrapper: padding + registry dispatch for flash attention.

Implementations: ``ref`` (dense fp32 softmax oracle, the vectorized CPU
lowering), ``interpret`` (the Pallas kernel in interpret mode, tests),
``pallas`` (TPU).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.flash_attention.fa_kernel import BK, BQ, flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference


@partial(jax.jit, static_argnames=("causal", "window"))
def _fa_ref(q, k, v, *, causal=True, window=None):
    return attention_reference(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def _fa_kernel(q, k, v, *, causal=True, window=None, interpret=False):
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    sq_pad = (sq + BQ - 1) // BQ * BQ
    skv_pad = (skv + BK - 1) // BK * BK
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 interpret=interpret)
    return out[:, :, :sq, :]


def _examples() -> list:
    def qkv(seed, b, h, hkv, s, d, dtype=jnp.float32):
        key = jax.random.PRNGKey(seed)
        q = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, d), dtype)
        k = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, s, d), dtype)
        v = jax.random.normal(jax.random.fold_in(key, 3), (b, hkv, s, d), dtype)
        return q, k, v

    return [
        (qkv(0, 2, 4, 2, 256, 64), {"causal": True}),
        (qkv(1, 1, 4, 1, 300, 64), {"causal": True}),     # ragged seq pad
        (qkv(2, 2, 2, 2, 256, 64), {"causal": True, "window": 128}),
        (qkv(3, 1, 8, 4, 384, 128), {"causal": False}),
        (qkv(4, 1, 2, 2, 128, 64, jnp.bfloat16), {},
         {"kind": "allclose", "atol": 2e-2, "rtol": 0.0}),
    ]


registry.register_op("flash_attention", oracle="ref", examples=_examples,
                     compare={"kind": "allclose", "atol": 2e-5, "rtol": 0.0})
registry.register_impl("flash_attention", "ref", priority=10)(_fa_ref)
registry.register_impl("flash_attention", "interpret", selectable=False)(
    partial(_fa_kernel, interpret=True))
registry.register_impl("flash_attention", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_fa_kernel, interpret=False))


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    impl: str | None = None,
) -> jax.Array:
    """Multi-head attention; q (B,H,Sq,D), k/v (B,HKV,Skv,D) -> (B,H,Sq,D).

    Padded keys land at indices >= Skv and are causally masked for all
    real queries; padded query rows are sliced away.  ``impl`` pins a
    registered implementation; None defers to the active KernelPolicy.
    """
    kimpl = registry.resolve("flash_attention", impl)
    return kimpl.fn(q, k, v, causal=causal, window=window)
