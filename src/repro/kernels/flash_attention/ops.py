"""Jitted public wrapper: padding + backend dispatch for flash attention."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.fa_kernel import BK, BQ, flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference


@partial(jax.jit, static_argnames=("causal", "window", "impl"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    impl: str = "auto",
) -> jax.Array:
    """Multi-head attention; q (B,H,Sq,D), k/v (B,HKV,Skv,D) -> (B,H,Sq,D).

    Padded keys land at indices >= Skv and are causally masked for all
    real queries; padded query rows are sliced away.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return attention_reference(q, k, v, causal=causal, window=window)

    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    sq_pad = (sq + BQ - 1) // BQ * BQ
    skv_pad = (skv + BK - 1) // BK * BK
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_pad - skv), (0, 0)))
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window, interpret=(impl == "interpret")
    )
    return out[:, :, :sq, :]
