"""SPRING compute kernels.

Each op family lives in its own package (``<name>_kernel.py`` Pallas
body, ``ops.py`` public wrapper, ``ref.py`` oracle) and registers its
implementations with :mod:`repro.kernels.registry` — the single
dispatch/backend-policy/instrumentation layer every wrapper resolves
through.  New kernels MUST register (the kernel-parity CI job and the
registration-completeness test enforce it).
"""
