"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

Assigned architecture ``mamba2-780m`` [arXiv:2405.21060].  The SSD
recurrence per head (state N, head dim P):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T        (N x P)
    y_t = C_t @ h_t

is evaluated chunkwise: within a length-L chunk the lower-triangular
decay-weighted score matrix turns the recurrence into two MXU matmuls
(the "duality"); across chunks a single (N, P) state carries in VMEM
scratch along the sequential grid axis.

Grid: (B, H, S/L) with the chunk axis sequential.  B/C are grouped
(G state-groups, GQA-style): head h reads group h // (H/G) via the
index map, so grouped B/C are never materialized per head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, nchunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    a = a_ref[0, 0].astype(jnp.float32)  # scalar, negative
    bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (L, N)

    da = dt * a  # (L,) per-step log decay
    cum = jnp.cumsum(da)  # inclusive
    l = x.shape[0]

    # Intra-chunk (the dual quadratic form): S[t, j] = (C_t . B_j)
    #   * exp(cum[t] - cum[j]) * dt[j], masked to j <= t.
    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # (L, L)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    # Mask the exponent: the upper triangle has positive diffs that would
    # overflow exp to inf (exp(-inf) = 0 is the safe form).
    diff = jnp.where(t_idx >= j_idx, cum[:, None] - cum[None, :], -jnp.inf)
    w = jnp.exp(diff)
    y_intra = jnp.dot(scores * w * dt[None, :], x, preferred_element_type=jnp.float32)

    # Inter-chunk: contribution of the carried state.
    h0 = state_scr[...]  # (N, P)
    y_inter = jnp.exp(cum)[:, None] * jnp.dot(cm, h0, preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # State for the next chunk.
    decay_to_end = jnp.exp(cum[-1] - cum)  # (L,)
    state_scr[...] = jnp.exp(cum[-1]) * h0 + jnp.dot(
        (bm * (decay_to_end * dt)[:, None]).T, x, preferred_element_type=jnp.float32
    )


def ssd_scan_pallas(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """x: (B,S,H,P); dt: (B,S,H); a: (H,) negative; b/c: (B,S,G,N).

    S must be a multiple of CHUNK (wrapper pads).  Returns y: (B,S,H,P).
    """
    bsz, s, h, p = x.shape
    _, _, g, n = b.shape
    assert s % CHUNK == 0 and h % g == 0
    group = h // g
    grid = (bsz, h, s // CHUNK)
    from jax.experimental.pallas import tpu as pltpu

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    a2d = a.reshape(h, 1)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, nchunks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, CHUNK, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (h_, 0)),
            pl.BlockSpec((1, CHUNK, 1, n), lambda b_, h_, c_: (b_, c_, h_ // group, 0)),
            pl.BlockSpec((1, CHUNK, 1, n), lambda b_, h_, c_: (b_, c_, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, dt, a2d, b, c)
