"""Public wrapper for the SSD chunked scan + a vectorized jnp chunked form.

``ssd_scan_jnp`` is the same chunked math as the kernel but batched over
(B, H) with plain einsums + a short lax.scan over chunks — it lowers on
any backend (the CPU dry-run path) and serves as the production fallback.

Registry entries: ``ref`` (sequential oracle), ``jnp`` (vectorized
chunked form — the only impl supporting ``return_state=True``, the
prefill -> decode cache handoff), ``interpret``, ``pallas`` (TPU).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ssd_scan.ref import ssd_scan_reference
from repro.kernels.ssd_scan.ssd_kernel import CHUNK, ssd_scan_pallas


def ssd_scan_jnp(x, dt, a, b, c, chunk: int = CHUNK, return_state: bool = False):
    """Chunked SSD, vectorized. Shapes as in ssd_scan_pallas."""
    bsz, s, h, p = x.shape
    _, _, g, n = b.shape
    group = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, chunk, g, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, chunk, g, n)
    bf = jnp.repeat(bf, group, axis=3)  # (B,NC,L,H,N)
    cf = jnp.repeat(cf, group, axis=3)

    da = dtf * a[None, None, None, :]  # (B,NC,L,H)
    cum = jnp.cumsum(da, axis=2)

    # Intra-chunk dual form.  Mask the exponent (not the exp) — the upper
    # triangle has cum[t] - cum[j] > 0, which overflows exp to inf and
    # would poison the tril multiply with inf * 0 = NaN.
    scores = jnp.einsum("bclhn,bcjhn->bchlj", cf, bf)
    cum_h = jnp.moveaxis(cum, 3, 2)  # (B,NC,H,L)
    diff = cum_h[..., :, None] - cum_h[..., None, :]  # (B,NC,H,L,L)
    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    w = jnp.exp(jnp.where(tril, diff, -jnp.inf))  # w[b,c,h,t,j]
    dt_h = jnp.moveaxis(dtf, 3, 2)  # (B,NC,H,L)
    s_mat = scores * w * dt_h[..., None, :]
    y_intra = jnp.einsum("bchlj,bcjhp->bclhp", s_mat, xf)

    # Chunk states and the cross-chunk scan.
    decay_end = jnp.exp(cum_h[..., -1:] - cum_h)  # (B,NC,H,L)
    chunk_state = jnp.einsum("bclhn,bchl,bclhp->bchnp", bf, decay_end * dt_h, xf)
    chunk_decay = jnp.exp(cum_h[..., -1])  # (B,NC,H)

    def scan_fn(h0, inp):
        cs, cd = inp  # (B,H,N,P), (B,H)
        h_new = h0 * cd[..., None, None] + cs
        return h_new, h0

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,NC,H,N,P) state entering each chunk

    y_inter = jnp.einsum("bclhn,bchnp->bclhp", cf, h_prevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, sp, h, p)[:, :s]
    if return_state:
        # Padded steps carry dt=0 -> decay exp(0)=1 and zero contribution,
        # so h_final is exactly the state at position S.
        return y.astype(x.dtype), h_final  # (B, H, N, P)
    return y.astype(x.dtype)


@partial(jax.jit, static_argnames=("return_state",))
def _ssd_ref(x, dt, a, b, c, *, return_state=False):
    return ssd_scan_reference(x, dt, a, b, c)


@partial(jax.jit, static_argnames=("return_state",))
def _ssd_jnp(x, dt, a, b, c, *, return_state=False):
    return ssd_scan_jnp(x, dt, a, b, c, return_state=return_state)


@partial(jax.jit, static_argnames=("return_state", "interpret"))
def _ssd_kernel(x, dt, a, b, c, *, return_state=False, interpret=False):
    s = x.shape[1]
    pad = (-s) % CHUNK
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = ssd_scan_pallas(x, dt, a, b, c, interpret=interpret)
    return y[:, :s]


def _supports_state(return_state: bool = False) -> bool:
    return not return_state


def _examples() -> list:
    cases = []
    for i, (bsz, s, h, p, g, n) in enumerate(
            [(2, 320, 4, 64, 2, 32), (1, 128, 2, 32, 1, 16), (1, 96, 2, 32, 1, 16)]):
        key = jax.random.PRNGKey(i)
        x = jax.random.normal(jax.random.fold_in(key, 1), (bsz, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (bsz, s, h)))
        a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (h,)) * 0.5)
        b = jax.random.normal(jax.random.fold_in(key, 4), (bsz, s, g, n)) / n**0.5
        c = jax.random.normal(jax.random.fold_in(key, 5), (bsz, s, g, n)) / n**0.5
        cases.append(((x, dt, a, b, c), {}))
    return cases


registry.register_op("ssd_scan", oracle="ref", examples=_examples,
                     compare={"kind": "rel", "tol": 1e-4})
registry.register_impl("ssd_scan", "ref", supports=_supports_state)(_ssd_ref)
registry.register_impl("ssd_scan", "jnp", priority=20)(_ssd_jnp)
registry.register_impl("ssd_scan", "interpret", selectable=False,
                       supports=_supports_state)(
    partial(_ssd_kernel, interpret=True))
registry.register_impl("ssd_scan", "pallas", priority=30,
                       available=registry.on_tpu, supports=_supports_state)(
    partial(_ssd_kernel, interpret=False))


def ssd_scan(x, dt, a, b, c, impl: str | None = None, return_state: bool = False):
    """SSD scan through the kernel registry.

    ``return_state=True`` (the prefill -> decode cache handoff) also
    returns the final (B,H,N,P) state; only the ``jnp`` implementation
    supports it — pinning any other impl raises a ValueError naming the
    impl, and auto-selection routes around it.
    """
    kimpl = registry.resolve("ssd_scan", impl, return_state=return_state)
    return kimpl.fn(x, dt, a, b, c, return_state=return_state)
