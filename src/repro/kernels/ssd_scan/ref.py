"""Pure-jnp oracle: naive token-by-token SSD recurrence via lax.scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_reference(
    x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array
) -> jax.Array:
    """Sequential evaluation of h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T,
    y_t = C_t h_t.  x: (B,S,H,P), dt: (B,S,H), a: (H,), b/c: (B,S,G,N).
    """
    bsz, s, h, p = x.shape
    _, _, g, n = b.shape
    group = h // g
    bf = jnp.repeat(b, group, axis=2).astype(jnp.float32)  # (B,S,H,N)
    cf = jnp.repeat(c, group, axis=2).astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        alpha = jnp.exp(dtt * a[None, :])  # (B,H)
        state = state * alpha[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt * dtt[..., None], xt
        )
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
