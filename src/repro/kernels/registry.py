"""Unified kernel-dispatch registry: one backend policy for every SPRING op.

Every Pallas op family (``masked_matmul``, ``mask_pack`` / ``mask_unpack`` /
``dangling_filter``, ``stochastic_round``, ``flash_attention``,
``ssd_scan``) registers its implementations here with capability
predicates, and every public wrapper resolves through :func:`resolve`
instead of a hand-rolled ``if impl == "auto"`` ladder.  The registry is
the single place where

  * backend selection lives — ``auto`` picks the highest-priority
    implementation whose availability predicate passes on the current
    backend (Pallas on TPU, the best vectorized lowering elsewhere);
  * whole-program pinning lives — a :class:`KernelPolicy` (global default
    + per-op overrides) threaded through ``SpringConfig`` and settable
    ambiently via the ``SPRING_KERNEL_IMPL`` env var or the
    :func:`kernel_policy` context manager;
  * per-op dispatch counters and instrumentation metrics live (tile-skip
    fraction from ``masked_matmul``, wire bytes from ``mask_compress``),
    feeding ``perfmodel/spring_model.py`` and ``launch/roofline_report``;
  * the parity contract lives — each op registers example inputs and a
    comparison spec, from which ``tests/test_kernel_registry.py`` and
    ``benchmarks/bench_kernels.py --smoke`` generate oracle-vs-impl
    cross-checks for every registered (op, impl) pair runnable on the
    current backend.  A kernel that is not registered cannot be exercised
    by CI's kernel-parity job, and the registration-completeness test
    fails if a ``kernels/<op>/ops.py`` package registers nothing.

Resolution precedence (highest first):

  1. an explicit concrete ``impl=`` argument at the call site (this is
     how ``SpringConfig.kernels`` reaches the ops: model code passes
     ``impl=ctx.kernel_impl(op)``);
  2. a per-op override in the active policy — strict: unknown or
     unavailable implementations raise;
  3. the active policy's global default — soft: ops that do not register
     that implementation fall back to ``auto`` (so
     ``SPRING_KERNEL_IMPL=jnp`` pins what it can and leaves the rest
     sensible), but an *unavailable* registered implementation still
     raises (asking for ``pallas`` on CPU is an error, not a shrug);
  4. ``auto`` — highest-priority available *selectable* implementation
     that supports the call's capability kwargs (``interpret`` is
     registered everywhere but never auto-selected: it is a test mode).
"""

from __future__ import annotations

import contextlib
import dataclasses
import importlib
import os
import threading
from typing import Any, Callable, Optional

import jax

ENV_VAR = "SPRING_KERNEL_IMPL"

#: The closed set of implementation names an op may register.
IMPL_NAMES = ("ref", "jnp", "interpret", "pallas")

#: ops.py modules that self-register on import (lazy to avoid cycles).
_OP_MODULES = (
    "repro.kernels.masked_matmul.ops",
    "repro.kernels.masked_matmul.backward",
    "repro.kernels.mask_compress.ops",
    "repro.kernels.kv_cache.ops",
    "repro.kernels.stochastic_round.ops",
    "repro.kernels.flash_attention.ops",
    "repro.kernels.ssd_scan.ops",
    "repro.dist.collectives",
)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _true() -> bool:
    return True


# ---------------------------------------------------------------------------
# Registration records.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation of one op."""

    op: str
    name: str  # one of IMPL_NAMES
    fn: Callable
    #: auto picks the highest-priority available+selectable impl.
    priority: int = 0
    #: can this impl execute on the current backend at all?
    available: Callable[[], bool] = _true
    #: eligible for auto-selection (interpret mode is explicit-only).
    selectable: bool = True
    #: include in the generated parity suite (aliases opt out).
    parity: bool = True
    #: per-call capability predicate over capability kwargs
    #: (e.g. ``return_state`` for ssd_scan); None = supports everything.
    supports: Optional[Callable[..., bool]] = None

    def supports_call(self, **caps: Any) -> bool:
        return self.supports is None or bool(self.supports(**caps))


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Parity/oracle contract for one op."""

    name: str
    oracle: str = "ref"
    #: zero-arg callable -> list of (args, kwargs) example invocations.
    examples: Optional[Callable[[], list]] = None
    #: comparison spec for the parity harness:
    #:   {"kind": "exact"} | {"kind": "allclose", "atol": a, "rtol": r}
    #:   | {"kind": "rel", "tol": t}  (max-abs error over max-abs oracle)
    compare: tuple = (("kind", "exact"),)

    def compare_spec(self) -> dict:
        return dict(self.compare)


_OPS: dict[str, OpSpec] = {}
_IMPLS: dict[str, dict[str, KernelImpl]] = {}
_IMPORTED = False


def ensure_registered() -> None:
    """Import every kernels/*/ops.py so their registrations run."""
    global _IMPORTED
    if _IMPORTED:
        return
    for mod in _OP_MODULES:
        importlib.import_module(mod)
    _IMPORTED = True


def register_op(
    name: str,
    *,
    oracle: str = "ref",
    examples: Optional[Callable[[], list]] = None,
    compare: Optional[dict] = None,
) -> None:
    cmp = tuple(sorted((compare or {"kind": "exact"}).items()))
    _OPS[name] = OpSpec(name=name, oracle=oracle, examples=examples, compare=cmp)
    _IMPLS.setdefault(name, {})


def register_impl(
    op: str,
    name: str,
    *,
    priority: int = 0,
    available: Callable[[], bool] = _true,
    selectable: bool = True,
    parity: bool = True,
    supports: Optional[Callable[..., bool]] = None,
) -> Callable[[Callable], Callable]:
    """Decorator: register ``fn`` as the ``name`` implementation of ``op``."""
    if name not in IMPL_NAMES:
        raise ValueError(f"impl name {name!r} not in {IMPL_NAMES}")
    if op not in _OPS:
        raise ValueError(f"register_op({op!r}) must run before register_impl")

    def deco(fn: Callable) -> Callable:
        _IMPLS[op][name] = KernelImpl(
            op=op, name=name, fn=fn, priority=priority, available=available,
            selectable=selectable, parity=parity, supports=supports,
        )
        return fn

    return deco


def ops() -> list[str]:
    ensure_registered()
    return sorted(_OPS)


def op_spec(op: str) -> OpSpec:
    ensure_registered()
    return _OPS[op]


def impls(op: str) -> dict[str, KernelImpl]:
    ensure_registered()
    if op not in _IMPLS:
        raise KeyError(f"unknown kernel op {op!r}; registered: {sorted(_OPS)}")
    return dict(_IMPLS[op])


# ---------------------------------------------------------------------------
# Policy: global default + per-op overrides.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Immutable (hashable) backend policy threaded through SpringConfig.

    ``default`` applies to every op that registers it; ``overrides`` pins
    specific ops and is strict.  ``"auto"`` defers to capability-based
    selection.
    """

    default: str = "auto"
    overrides: tuple = ()  # tuple[(op, impl), ...] — hashable for jit

    def __post_init__(self):
        names = ("auto",) + IMPL_NAMES
        if self.default not in names:
            raise ValueError(
                f"unknown kernel impl {self.default!r}; choose from {names}")
        for op, name in self.overrides:
            if name not in names:
                raise ValueError(
                    f"unknown kernel impl {name!r} for op {op!r}; "
                    f"choose from {names}")
        if self.overrides:  # a misspelled op would silently pin nothing
            ensure_registered()
            for op, _ in self.overrides:
                if op not in _OPS:
                    raise ValueError(
                        f"unknown kernel op {op!r} in policy overrides; "
                        f"registered ops: {sorted(_OPS)}")

    def impl_for(self, op: str) -> str:
        return dict(self.overrides).get(op, self.default)

    @property
    def is_auto(self) -> bool:
        return self.default == "auto" and not self.overrides

    @classmethod
    def parse(cls, spec: str) -> "KernelPolicy":
        """Parse ``"ref"`` / ``"ssd_scan=jnp"`` / ``"ref,ssd_scan=jnp"``.

        Bare tokens set the global default; ``op=impl`` tokens add per-op
        overrides.  Op names are validated against the registry.
        """
        default = "auto"
        overrides: list[tuple[str, str]] = []
        for token in (t.strip() for t in (spec or "").split(",")):
            if not token:
                continue
            if "=" in token:
                op, _, name = token.partition("=")
                op, name = op.strip(), name.strip()
                ensure_registered()
                if op not in _OPS:
                    raise ValueError(
                        f"unknown kernel op {op!r} in policy spec {spec!r}; "
                        f"registered ops: {sorted(_OPS)}")
                overrides.append((op, name))
            else:
                default = token
        return cls(default=default, overrides=tuple(overrides))

    def describe(self) -> str:
        parts = ([] if self.default == "auto" else [self.default])
        parts += [f"{op}={name}" for op, name in self.overrides]
        return ",".join(parts) or "auto"


AUTO_POLICY = KernelPolicy()


class _PolicyStack(threading.local):
    def __init__(self):
        self.stack: list[KernelPolicy] = []


_POLICY = _PolicyStack()


def current_policy() -> KernelPolicy:
    """Active ambient policy: context manager > SPRING_KERNEL_IMPL > auto."""
    if _POLICY.stack:
        return _POLICY.stack[-1]
    env = os.environ.get(ENV_VAR)
    if env:
        return KernelPolicy.parse(env)
    return AUTO_POLICY


@contextlib.contextmanager
def kernel_policy(policy=None, /, default: Optional[str] = None, **per_op: str):
    """Scope an ambient kernel policy (tests, benchmarks, reports).

    ``kernel_policy("interpret")``, ``kernel_policy(default="ref")``,
    ``kernel_policy(ssd_scan="jnp")`` and ``kernel_policy(policy_obj)``
    all work; the previous policy is restored on exit.
    """
    if policy is None:
        policy = KernelPolicy(default=default or "auto",
                              overrides=tuple(sorted(per_op.items())))
    elif isinstance(policy, str):
        policy = KernelPolicy.parse(policy)
    elif not isinstance(policy, KernelPolicy):
        raise TypeError(f"expected KernelPolicy | str, got {type(policy)}")
    _POLICY.stack.append(policy)
    try:
        yield policy
    finally:
        _POLICY.stack.pop()


# ---------------------------------------------------------------------------
# Dispatch counters + instrumentation metrics.
#
# Both live in the process-wide telemetry MetricsRegistry
# (repro.telemetry.metrics.default_registry) rather than module-level
# dicts: one labeled home for every runtime measurement, with explicit
# snapshot()/reset() isolation (a conftest autouse fixture resets it per
# test, so counts no longer leak across tests and benchmarks sharing one
# process).  dispatch_counts()/reset_dispatch_counts() survive as the
# op-keyed views the dry-run and tests consume.
# ---------------------------------------------------------------------------

#: MetricsRegistry family names (the Prometheus-visible spellings).
DISPATCH_METRIC = "spring_kernel_dispatch_total"
KERNEL_METRIC_PREFIX = "spring_kernel_"


def _metrics_registry():
    from repro.telemetry.metrics import default_registry

    return default_registry()


def _record_dispatch(op: str, name: str) -> None:
    _metrics_registry().inc(
        DISPATCH_METRIC, op=op, impl=name,
        help="kernel-registry resolutions per (op, impl)")


def dispatch_counts() -> dict[str, dict[str, int]]:
    """{op: {impl: resolutions}} since the last reset.

    Counts are *resolutions*: one per eager call, one per trace under jit
    (resolution is trace-time — the compiled program embeds the choice).
    """
    snap = _metrics_registry().snapshot().get(DISPATCH_METRIC)
    out: dict[str, dict[str, int]] = {}
    if snap is None:
        return out
    for cell in snap["cells"]:
        labels = cell["labels"]
        out.setdefault(labels["op"], {})[labels["impl"]] = int(cell["value"])
    return out


def reset_dispatch_counts() -> None:
    _metrics_registry().reset(DISPATCH_METRIC)


class _Metrics(threading.local):
    def __init__(self):
        self.rows: Optional[list] = None


_METRICS = _Metrics()


@contextlib.contextmanager
def record_kernel_metrics():
    """Collect per-op instrumentation rows from eager calls in the block.

    Ops contribute host-side scalars only when operands are concrete
    (mirrors ``memstash.instrument``): ``masked_matmul`` notes its
    tile-skip fraction, ``mask_pack`` its wire bytes.  Under jit tracing
    the hooks are no-ops, keeping compiled programs free of host syncs.
    """
    prev = _METRICS.rows
    _METRICS.rows = []
    try:
        yield _METRICS.rows
    finally:
        _METRICS.rows = prev


def metrics_recording() -> bool:
    return _METRICS.rows is not None


def metrics_active() -> bool:
    """Should eager hooks compute their host-side scalars?  True inside a
    ``record_kernel_metrics`` block *or* when a telemetry scope is active
    — the scalars cost a device read, so they stay gated either way."""
    if _METRICS.rows is not None:
        return True
    from repro import telemetry

    return telemetry.enabled()


def note_metric(op: str, **values: float) -> None:
    """Record one eager instrumentation row.

    Rows flow to the thread-local recorder (the ``record_kernel_metrics``
    API perfmodel consumes) and, always, into the telemetry
    MetricsRegistry as labeled histograms
    (``spring_kernel_<key>{op=...}``) so ``serve --json`` /
    ``benchmarks/run.py --json`` snapshots carry them.
    """
    if _METRICS.rows is not None:
        _METRICS.rows.append(dict(values, op=op))
    reg = _metrics_registry()
    for key, v in values.items():
        reg.observe(KERNEL_METRIC_PREFIX + key, float(v), op=op,
                    help=f"eager kernel instrumentation: {key} per op")


def metric_summary(rows: list) -> dict[str, dict[str, float]]:
    """Mean of each recorded metric key per op: {op: {key: mean}}."""
    acc: dict[str, dict[str, list]] = {}
    for row in rows:
        op = row["op"]
        for k, v in row.items():
            if k == "op":
                continue
            acc.setdefault(op, {}).setdefault(k, []).append(float(v))
    return {op: {k: sum(v) / len(v) for k, v in kv.items()}
            for op, kv in acc.items()}


# ---------------------------------------------------------------------------
# Resolution.
# ---------------------------------------------------------------------------


def _auto_pick(op: str, **caps: Any) -> KernelImpl:
    cands = [
        k for k in _IMPLS[op].values()
        if k.selectable and k.available() and k.supports_call(**caps)
    ]
    if not cands:
        raise ValueError(
            f"kernel op {op!r}: no available implementation on backend "
            f"{jax.default_backend()!r} for capabilities {caps}")
    return max(cands, key=lambda k: k.priority)


def resolve(op: str, impl: Optional[str] = None, *, _count: bool = True,
            **caps: Any) -> KernelImpl:
    """Resolve one op invocation to a registered implementation.

    ``impl``: explicit call-site choice (wins), ``None``/``"auto"`` to
    defer to the ambient policy.  Capability kwargs (e.g.
    ``return_state=True``) constrain auto-selection and validate explicit
    picks — a pinned impl that cannot serve the call raises a
    ``ValueError`` naming the impl and the ops that could.

    ``_count=False`` marks a *planning* resolution (config threading,
    resolution tables): it is excluded from ``dispatch_counts()`` so only
    the public-wrapper resolution that actually invokes the impl counts.
    """
    ensure_registered()
    if op not in _OPS:
        raise KeyError(f"unknown kernel op {op!r}; registered: {sorted(_OPS)}")

    strict = True
    requested = impl if impl not in (None, "auto") else None
    if requested is None:
        pol = current_policy()
        over = dict(pol.overrides).get(op)
        if over is not None and over != "auto":
            requested = over
        elif pol.default != "auto":
            requested, strict = pol.default, False

    if requested is None:
        kimpl = _auto_pick(op, **caps)
    else:
        if requested not in IMPL_NAMES:
            raise ValueError(
                f"unknown kernel impl {requested!r} for op {op!r}; "
                f"choose from {('auto',) + IMPL_NAMES}")
        kimpl = _IMPLS[op].get(requested)
        if kimpl is None:
            if strict:
                raise ValueError(
                    f"kernel op {op!r} has no {requested!r} implementation; "
                    f"registered: {sorted(_IMPLS[op])}")
            kimpl = _auto_pick(op, **caps)  # soft global default
        elif not kimpl.available():
            raise ValueError(
                f"kernel op {op!r} impl {requested!r} is not available on "
                f"backend {jax.default_backend()!r}")
        elif not kimpl.supports_call(**caps):
            if strict:
                ok = sorted(n for n, k in _IMPLS[op].items()
                            if k.supports_call(**caps))
                raise ValueError(
                    f"kernel op {op!r}: impl {requested!r} does not support "
                    f"{caps}; supported by: {ok or 'none'}")
            kimpl = _auto_pick(op, **caps)
    if _count:
        _record_dispatch(op, kimpl.name)
    return kimpl


def resolve_with(policy: Optional[KernelPolicy], op: str, **caps: Any) -> KernelImpl:
    """Resolve ``op`` under a config-threaded policy (SpringConfig.kernels).

    An ``auto`` policy defers to the ambient policy (context manager /
    env var); a concrete one scopes itself for this resolution so its
    global default keeps soft-fallback semantics.  This is a *planning*
    resolution (the chosen impl name is then passed to the public
    wrapper, which resolves again), so it does not count as a dispatch.
    """
    if policy is None or policy.is_auto:
        return resolve(op, _count=False, **caps)
    with kernel_policy(policy):
        return resolve(op, _count=False, **caps)


def resolution_table(policy: Optional[KernelPolicy] = None,
                     **caps_by_op: dict) -> dict[str, str]:
    """{op: impl-or-error} the given (or ambient) policy resolves to now.

    Never raises: errors are reported inline as ``"error: ..."`` so the
    table can be embedded in dry-run / benchmark JSON unconditionally.
    An ``auto`` policy is not pushed (mirrors ``resolve_with``), so the
    table reflects the ambient env/context policy the calls actually saw.
    """
    ensure_registered()
    ctx = (kernel_policy(policy) if policy is not None and not policy.is_auto
           else contextlib.nullcontext())
    out = {}
    with ctx:
        for op in sorted(_OPS):
            try:
                out[op] = resolve(op, _count=False, **caps_by_op.get(op, {})).name
            except (ValueError, KeyError) as e:
                out[op] = f"error: {e}"
    return out


def capability_table() -> dict[str, dict[str, dict]]:
    """Static view for docs/tests: {op: {impl: {available, selectable,
    priority, oracle}}} on the current backend."""
    ensure_registered()
    out: dict[str, dict[str, dict]] = {}
    for op in sorted(_OPS):
        out[op] = {
            name: {
                "available": bool(k.available()),
                "selectable": k.selectable,
                "priority": k.priority,
                "oracle": name == _OPS[op].oracle,
            }
            for name, k in sorted(_IMPLS[op].items())
        }
    return out


def compare_outputs(op: str, got: Any, want: Any,
                    case_compare: Optional[dict] = None) -> float:
    """Check ``got`` against the oracle output ``want`` under the op's
    registered comparison spec (or a per-case override), raising
    AssertionError on violation.  Returns the measured deviation (0.0 for
    exact specs) — the parity harness and the bench smoke sweep both use
    this, so the OpSpec.compare contract has exactly one interpreter.
    """
    import numpy as np

    spec = case_compare or op_spec(op).compare_spec()
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    worst = 0.0
    for g, w in zip(got_l, want_l):
        g = np.asarray(g, dtype=np.float64)
        w = np.asarray(w, dtype=np.float64)
        if spec["kind"] == "exact":
            assert (g == w).all(), f"{op}: impl must be bit-identical to oracle"
        elif spec["kind"] == "allclose":
            err = float(np.max(np.abs(g - w))) if g.size else 0.0
            assert err <= spec["atol"] + spec.get("rtol", 0.0) * float(np.max(np.abs(w))), \
                f"{op}: max err {err} > atol {spec['atol']}"
            worst = max(worst, err)
        elif spec["kind"] == "rel":
            denom = float(np.max(np.abs(w))) + 1e-12
            rel = float(np.max(np.abs(g - w))) / denom
            assert rel <= spec["tol"], f"{op}: rel err {rel} > {spec['tol']}"
            worst = max(worst, rel)
        else:
            raise ValueError(f"unknown compare kind {spec['kind']!r}")
    return worst


def parity_pairs() -> list[tuple[str, str]]:
    """Every (op, impl) pair the parity harness should cross-check against
    the op's oracle on the *current* backend."""
    ensure_registered()
    pairs = []
    for op in sorted(_OPS):
        oracle = _OPS[op].oracle
        for name, k in sorted(_IMPLS[op].items()):
            if name == oracle or not k.parity or not k.available():
                continue
            pairs.append((op, name))
    return pairs
