"""Public wrapper for the sparsity-aware fixed-point matmul.

Handles padding to MXU tiles, occupancy-mask computation (the packed
binary masks AND-reduced per tile — SPRING's pre-compute sparsity stage),
and registers its implementations with ``repro.kernels.registry``:

  ref        dense f32 matmul + identical SR epilogue (vectorized oracle;
             the CPU production path)
  interpret  the Pallas kernel in interpret mode (tests)
  pallas     the Pallas kernel (TPU)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.masked_matmul.mm_kernel import BK, BM, BN, masked_matmul_pallas, padded_dims
from repro.kernels.masked_matmul.ref import masked_matmul_reference


def _occupancy(a: jax.Array, tm: int, tn: int) -> jax.Array:
    m, n = a.shape
    t = a.reshape(m // tm, tm, n // tn, tn)
    return jnp.any(t != 0.0, axis=(1, 3)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("il", "fl", "apply_sr"))
def _mm_ref(x, w, seed, *, il=4, fl=16, apply_sr=True):
    return masked_matmul_reference(x, w, seed, il=il, fl=fl, apply_sr=apply_sr)


@partial(jax.jit, static_argnames=("il", "fl", "apply_sr", "interpret"))
def _mm_kernel(x, w, seed, *, il=4, fl=16, apply_sr=True, interpret=False):
    m, k = x.shape
    _, n = w.shape
    m_pad, n_pad, k_pad = padded_dims(m, n, k)
    xp = jnp.pad(x.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, k_pad - k), (0, n_pad - n)))
    x_occ = _occupancy(xp, BM, BK)
    w_occ = _occupancy(wp, BK, BN)
    out = masked_matmul_pallas(
        xp, wp, x_occ, w_occ, seed,
        il=il, fl=fl, apply_sr=apply_sr, interpret=interpret,
    )
    return out[:m, :n]


def _example_operands(seed: int, shape, sparsity: float = 0.5, fl: int = 8):
    key = jax.random.PRNGKey(seed)
    v = jnp.round(jax.random.normal(key, shape) * 2**6) / 2**fl
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) > sparsity
    return v * keep


def _examples() -> list:
    cases = []
    for m, k, n in [(128, 128, 128), (100, 70, 50), (64, 512, 200)]:
        x = _example_operands(m * 7 + k, (m, k))
        w = _example_operands(n * 13 + k, (k, n))
        cases.append(((x, w, jnp.uint32(5)), {}))
    # block-pruned operands: whole MXU tiles skipped, plus the SR-off path
    x = _example_operands(0, (256, 384), 0.3).at[:128, :256].set(0.0)
    w = _example_operands(1, (384, 256), 0.3).at[256:, 128:].set(0.0)
    cases.append(((x, w, jnp.uint32(3)), {}))
    cases.append(((x, w, jnp.uint32(3)), {"apply_sr": False},
                  {"kind": "allclose", "atol": 1e-6, "rtol": 0.0}))
    return cases


registry.register_op("masked_matmul", oracle="ref", examples=_examples,
                     compare={"kind": "exact"})
registry.register_impl("masked_matmul", "ref", priority=10)(_mm_ref)
registry.register_impl("masked_matmul", "interpret", selectable=False)(
    partial(_mm_kernel, interpret=True))
registry.register_impl("masked_matmul", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_mm_kernel, interpret=False))


def masked_matmul(
    x: jax.Array,
    w: jax.Array,
    seed: jax.Array | None = None,
    *,
    il: int = 4,
    fl: int = 16,
    apply_sr: bool = True,
    impl: str | None = None,
    backward: str | None = None,
) -> jax.Array:
    """Sparsity-aware ``x @ w`` on the Q(il,fl) grid with SR epilogue.

    x: (M, K) float32 grid values (zeros = skippable); w: (K, N).
    ``impl`` pins a registered implementation; None defers to the active
    :class:`~repro.kernels.registry.KernelPolicy`.

    ``backward`` selects the sparsity-aware training direction: None/"none"
    differentiates through the resolved forward impl (dense autodiff; the
    Pallas paths are not differentiable), while "auto" or a concrete impl
    name wraps the call in a ``custom_vjp`` whose dL/dx / dL/dw are the
    registry-resolved ``masked_matmul_dx`` / ``masked_matmul_dw`` kernels —
    tile skipping applies in both directions (DESIGN.md §8).
    """
    if seed is None:
        seed = jnp.uint32(0)
    kimpl = registry.resolve("masked_matmul", impl)
    if registry.metrics_active() and not isinstance(x, jax.core.Tracer) \
            and not isinstance(w, jax.core.Tracer):
        registry.note_metric("masked_matmul",
                             tile_skip=float(tile_skip_fraction(x, w)))
    if backward in (None, "none"):
        return kimpl.fn(x, w, seed, il=il, fl=fl, apply_sr=apply_sr)
    from repro.kernels.masked_matmul.backward import mm_call_with_backward

    return mm_call_with_backward(x, w, seed, il=il, fl=fl, apply_sr=apply_sr,
                                 fwd_impl=kimpl.name, bwd_impl=backward)


def tile_skip_fraction(x: jax.Array, w: jax.Array) -> jax.Array:
    """Fraction of (i,j,k) MXU grid steps skipped for these operands.

    The roofline compute-term scales by (1 - skip_fraction) on TPU; this
    is the analytically-reportable speedup of the kernel (§Perf).
    """
    m, k = x.shape
    _, n = w.shape
    m_pad, n_pad, k_pad = padded_dims(m, n, k)
    xp = jnp.pad(x.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, k_pad - k), (0, n_pad - n)))
    x_occ = _occupancy(xp, BM, BK).astype(jnp.float32)  # (Mi, Kk)
    w_occ = _occupancy(wp, BK, BN).astype(jnp.float32)  # (Kk, Nj)
    issued = jnp.einsum("ik,kj->", x_occ, w_occ)
    total = x_occ.shape[0] * w_occ.shape[0] * w_occ.shape[1]
    return 1.0 - issued / total
