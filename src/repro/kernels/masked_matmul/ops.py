"""Jitted public wrapper for the sparsity-aware fixed-point matmul.

Handles padding to MXU tiles, occupancy-mask computation (the packed
binary masks AND-reduced per tile — SPRING's pre-compute sparsity stage),
and backend dispatch (pallas | interpret | ref).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.masked_matmul.mm_kernel import BK, BM, BN, masked_matmul_pallas, padded_dims
from repro.kernels.masked_matmul.ref import masked_matmul_reference


def _occupancy(a: jax.Array, tm: int, tn: int) -> jax.Array:
    m, n = a.shape
    t = a.reshape(m // tm, tm, n // tn, tn)
    return jnp.any(t != 0.0, axis=(1, 3)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("il", "fl", "apply_sr", "impl"))
def masked_matmul(
    x: jax.Array,
    w: jax.Array,
    seed: jax.Array | None = None,
    *,
    il: int = 4,
    fl: int = 16,
    apply_sr: bool = True,
    impl: str = "auto",
) -> jax.Array:
    """Sparsity-aware ``x @ w`` on the Q(il,fl) grid with SR epilogue.

    x: (M, K) float32 grid values (zeros = skippable); w: (K, N).
    """
    if seed is None:
        seed = jnp.uint32(0)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return masked_matmul_reference(x, w, seed, il=il, fl=fl, apply_sr=apply_sr)

    m, k = x.shape
    _, n = w.shape
    m_pad, n_pad, k_pad = padded_dims(m, n, k)
    xp = jnp.pad(x.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, k_pad - k), (0, n_pad - n)))
    x_occ = _occupancy(xp, BM, BK)
    w_occ = _occupancy(wp, BK, BN)
    out = masked_matmul_pallas(
        xp,
        wp,
        x_occ,
        w_occ,
        seed,
        il=il,
        fl=fl,
        apply_sr=apply_sr,
        interpret=(impl == "interpret"),
    )
    return out[:m, :n]


def tile_skip_fraction(x: jax.Array, w: jax.Array) -> jax.Array:
    """Fraction of (i,j,k) MXU grid steps skipped for these operands.

    The roofline compute-term scales by (1 - skip_fraction) on TPU; this
    is the analytically-reportable speedup of the kernel (§Perf).
    """
    m, k = x.shape
    _, n = w.shape
    m_pad, n_pad, k_pad = padded_dims(m, n, k)
    xp = jnp.pad(x.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, k_pad - k), (0, n_pad - n)))
    x_occ = _occupancy(xp, BM, BK).astype(jnp.float32)  # (Mi, Kk)
    w_occ = _occupancy(wp, BK, BN).astype(jnp.float32)  # (Kk, Nj)
    issued = jnp.einsum("ik,kj->", x_occ, w_occ)
    total = x_occ.shape[0] * w_occ.shape[0] * w_occ.shape[1]
    return 1.0 - issued / total
