"""Sparsity-aware backward kernels for the masked matmul (SPRING training).

SPRING's central claim is that binary-mask sparsity pays off *in training*:
activations stay ReLU-sparse, the ReLU VJP zeroes the cotangent wherever
the forward activation was zero (Sarma et al. 2021's activation-based
gradient output sparsity), so both backward GEMMs of ``y = x @ w``

  dL/dx = g @ w.T        (cotangent  x  transposed weights)
  dL/dw = x.T @ g        (stashed activation  x  cotangent)

inherit mask-structured sparsity and are served by the same tile-skipping
machinery as the forward pass.  This module registers them as first-class
registry ops (``masked_matmul_dx`` / ``masked_matmul_dw``) with the full
impl ladder:

  ref        dense fp32 transpose matmul (oracle; the CPU production path)
  jnp        occupancy-gated block einsum — the vectorized lowering that
             materializes the tile-AND gate explicitly (numerics-identical:
             a gated-out tile contributes exactly +0.0)
  interpret  the Pallas tile-skipping kernel in interpret mode (tests)
  pallas     the Pallas tile-skipping kernel (TPU)

Gradients are *not* SR-rounded here: SPRING accumulates gradients at MAC
width and applies stochastic rounding at the weight update (the optimizer's
job), so every impl runs the kernel with ``apply_sr=False`` and the
comparison contract is relative (fp32 summation-order slack), not exact.

``mm_call_with_backward`` is the ``jax.custom_vjp`` that ``ops.masked_matmul``
routes through when a ``backward=`` policy is given: forward runs the
registry-resolved forward impl unchanged; backward resolves dx/dw through
the registry so ``--backward-sparsity`` / ``KernelPolicy`` pins apply to the
training direction independently of the forward one.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.masked_matmul import ops as mm_ops
from repro.kernels.masked_matmul.mm_kernel import BK, BM, BN, padded_dims

__all__ = [
    "masked_matmul_dx",
    "masked_matmul_dw",
    "mm_call_with_backward",
    "backward_tile_skip",
    "sparsity_probe",
]


# ---------------------------------------------------------------------------
# Shared lowerings.  Both backward ops are (A, B) -> A' @ B' for a fixed
# transpose pattern, so each impl is one parameterized function.
# ---------------------------------------------------------------------------


def _dense_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def _blocked_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Occupancy-gated block matmul: the vectorized (jnp) realization of
    SPRING's tile-AND gate.  Tiles whose joint occupancy is empty are
    multiplied by a 0.0 gate, contributing exactly +0.0 to the fp32
    accumulator — same numerics contract as the Pallas kernel's skip."""
    m, k = a.shape
    _, n = b.shape
    m_pad, n_pad, k_pad = padded_dims(m, n, k)
    ap = jnp.pad(a.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    bp = jnp.pad(b.astype(jnp.float32), ((0, k_pad - k), (0, n_pad - n)))
    at = ap.reshape(m_pad // BM, BM, k_pad // BK, BK).transpose(0, 2, 1, 3)
    bt = bp.reshape(k_pad // BK, BK, n_pad // BN, BN).transpose(0, 2, 1, 3)
    a_occ = jnp.any(at != 0.0, axis=(2, 3))  # (Mi, Kk)
    b_occ = jnp.any(bt != 0.0, axis=(2, 3))  # (Kk, Nj)
    gate = (a_occ[:, :, None] & b_occ[None, :, :]).astype(jnp.float32)
    out = jnp.einsum("ikab,kjbc,ikj->ijac", at, bt, gate)
    return out.transpose(0, 2, 1, 3).reshape(m_pad, n_pad)[:m, :n]


def _kernel_dot(a: jax.Array, b: jax.Array, *, interpret: bool) -> jax.Array:
    """The forward Pallas lowering reused with the SR epilogue disabled:
    tile-skipped fp32 accumulate of ``a @ b`` (same padding/occupancy
    geometry as the forward — single-sourced in ops._mm_kernel)."""
    return mm_ops._mm_kernel(a, b, jnp.uint32(0), apply_sr=False,
                             interpret=interpret)


# dx: (M, N) cotangent x (K, N) weights -> (M, K)
@partial(jax.jit, static_argnames=("il", "fl"))
def _dx_ref(g, w, *, il=4, fl=16):
    del il, fl  # gradients stay fp32; SR happens at the weight update
    return _dense_dot(g, w.T)


@partial(jax.jit, static_argnames=("il", "fl"))
def _dx_jnp(g, w, *, il=4, fl=16):
    del il, fl
    return _blocked_dot(g, w.T)


@partial(jax.jit, static_argnames=("il", "fl", "interpret"))
def _dx_kernel(g, w, *, il=4, fl=16, interpret=False):
    del il, fl
    return _kernel_dot(g, w.T, interpret=interpret)


# dw: (M, K) stashed activation x (M, N) cotangent -> (K, N)
@partial(jax.jit, static_argnames=("il", "fl"))
def _dw_ref(x, g, *, il=4, fl=16):
    del il, fl
    return _dense_dot(x.T, g)


@partial(jax.jit, static_argnames=("il", "fl"))
def _dw_jnp(x, g, *, il=4, fl=16):
    del il, fl
    return _blocked_dot(x.T, g)


@partial(jax.jit, static_argnames=("il", "fl", "interpret"))
def _dw_kernel(x, g, *, il=4, fl=16, interpret=False):
    del il, fl
    return _kernel_dot(x.T, g, interpret=interpret)


# ---------------------------------------------------------------------------
# Registration: parity examples model the training shapes — a ReLU-masked
# cotangent against sparse weights/activations, dense and empty extremes.
# ---------------------------------------------------------------------------


def _sparse_mat(seed: int, shape, sparsity: float) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, shape) * 0.1
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) > sparsity
    return v * keep


def _dx_examples() -> list:
    cases = []
    for m, k, n, s in [(128, 128, 128, 0.5), (100, 70, 50, 0.3), (64, 200, 512, 0.7)]:
        g = _sparse_mat(m + n, (m, n), s)
        w = _sparse_mat(k * 3 + n, (k, n), s)
        cases.append(((g, w), {}))
    # whole-tile-sparse cotangent (block-pruned) and the all-zero extreme
    g = _sparse_mat(0, (256, 256), 0.2).at[:128, :].set(0.0)
    cases.append(((g, _sparse_mat(1, (256, 256), 0.2)), {}))
    cases.append(((jnp.zeros((64, 64)), _sparse_mat(2, (64, 64), 0.5)), {}))
    return cases


def _dw_examples() -> list:
    cases = []
    for m, k, n, s in [(128, 128, 128, 0.5), (100, 70, 50, 0.3), (512, 64, 200, 0.7)]:
        x = _sparse_mat(m * 5 + k, (m, k), s)
        g = _sparse_mat(m + n * 7, (m, n), s)
        cases.append(((x, g), {}))
    x = _sparse_mat(3, (256, 384), 0.2).at[:, 256:].set(0.0)
    cases.append(((x, _sparse_mat(4, (256, 256), 0.2)), {}))
    cases.append(((_sparse_mat(5, (64, 64), 0.5), jnp.zeros((64, 64))), {}))
    return cases


_BWD_COMPARE = {"kind": "rel", "tol": 1e-5}

registry.register_op("masked_matmul_dx", oracle="ref", examples=_dx_examples,
                     compare=_BWD_COMPARE)
registry.register_impl("masked_matmul_dx", "ref", priority=10)(_dx_ref)
registry.register_impl("masked_matmul_dx", "jnp", priority=5)(_dx_jnp)
registry.register_impl("masked_matmul_dx", "interpret", selectable=False)(
    partial(_dx_kernel, interpret=True))
registry.register_impl("masked_matmul_dx", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_dx_kernel, interpret=False))

registry.register_op("masked_matmul_dw", oracle="ref", examples=_dw_examples,
                     compare=_BWD_COMPARE)
registry.register_impl("masked_matmul_dw", "ref", priority=10)(_dw_ref)
registry.register_impl("masked_matmul_dw", "jnp", priority=5)(_dw_jnp)
registry.register_impl("masked_matmul_dw", "interpret", selectable=False)(
    partial(_dw_kernel, interpret=True))
registry.register_impl("masked_matmul_dw", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_dw_kernel, interpret=False))


# ---------------------------------------------------------------------------
# Public wrappers (registry-dispatched, instrumented).
# ---------------------------------------------------------------------------


def backward_tile_skip(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tile-skip fraction of one backward GEMM ``a @ b`` (pre-transpose
    operands already applied) — the backward counterpart of
    ``ops.tile_skip_fraction``, shared MXU tile geometry."""
    return mm_ops.tile_skip_fraction(a, b)


def _note_skip(op: str, a: jax.Array, b: jax.Array) -> None:
    if registry.metrics_active() and not isinstance(a, jax.core.Tracer) \
            and not isinstance(b, jax.core.Tracer):
        registry.note_metric(op, tile_skip=float(backward_tile_skip(a, b)))


def masked_matmul_dx(g: jax.Array, w: jax.Array, *, il: int = 4, fl: int = 16,
                     impl: str | None = None) -> jax.Array:
    """dL/dx = g @ w.T through a registry-resolved sparsity-aware kernel.

    g: (M, N) cotangent (ReLU-masked positions are structural zeros);
    w: (K, N) weights.  Returns (M, K) fp32.
    """
    kimpl = registry.resolve("masked_matmul_dx", impl)
    _note_skip("masked_matmul_dx", g, w.T)
    return kimpl.fn(g, w, il=il, fl=fl)


def masked_matmul_dw(x: jax.Array, g: jax.Array, *, il: int = 4, fl: int = 16,
                     impl: str | None = None) -> jax.Array:
    """dL/dw = x.T @ g through a registry-resolved sparsity-aware kernel.

    x: (M, K) forward activation (the stashed sparse tensor the backward
    pass re-reads); g: (M, N) cotangent.  Returns (K, N) fp32.
    """
    kimpl = registry.resolve("masked_matmul_dw", impl)
    _note_skip("masked_matmul_dw", x.T, g)
    return kimpl.fn(x, g, il=il, fl=fl)


# ---------------------------------------------------------------------------
# The custom_vjp the public ``masked_matmul`` wrapper routes through.
# ---------------------------------------------------------------------------


def _float0_zero(seed: jax.Array):
    # integer primal -> float0 cotangent (custom_vjp contract for int args)
    return np.zeros(np.shape(seed), dtype=jax.dtypes.float0)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _mm_bw(x, w, seed, il, fl, apply_sr, fwd_impl, bwd_impl):
    return registry.impls("masked_matmul")[fwd_impl].fn(
        x, w, seed, il=il, fl=fl, apply_sr=apply_sr)


def _mm_bw_fwd(x, w, seed, il, fl, apply_sr, fwd_impl, bwd_impl):
    y = registry.impls("masked_matmul")[fwd_impl].fn(
        x, w, seed, il=il, fl=fl, apply_sr=apply_sr)
    # Residual: the (sparse) operands only — never the dense accumulator.
    # The SR epilogue is straight-through in the backward (DESIGN.md §8):
    # range clipping is handled by the caller's STE quantizer, keeping the
    # residual at exactly what SPRING's stash stores.
    return y, (x, w, seed)


def _mm_bw_bwd(il, fl, apply_sr, fwd_impl, bwd_impl, res, g):
    x, w, seed = res
    impl = None if bwd_impl == "auto" else bwd_impl
    dx = masked_matmul_dx(g, w, il=il, fl=fl, impl=impl)
    dw = masked_matmul_dw(x, g, il=il, fl=fl, impl=impl)
    return dx, dw, _float0_zero(seed)


_mm_bw.defvjp(_mm_bw_fwd, _mm_bw_bwd)


def sparsity_probe(density: float = 0.5, size: int = 512,
                   seed: int = 0) -> dict:
    """Measured fwd/bwd tile-skip fractions at a given tile-granular density.

    Runs one eager ``masked_matmul`` forward + backward on ``size``-square
    operands whose 128x128 tiles are kept with probability ``density``
    (block-pruned operands — the granularity SPRING's pre-compute module
    skips at), and reports what the instrumentation hooks measured.  The
    dry-run embeds this in its JSON so backward tile-skip is attributable
    per cell even though the lowered program itself never executes there.
    """
    key = jax.random.PRNGKey(seed)

    def tile_sparse(k, shape):
        v = jax.random.normal(k, shape) * 0.05
        keep = jax.random.uniform(
            jax.random.fold_in(k, 1), (shape[0] // BM, shape[1] // BN)
        ) < density
        if density < 1.0:  # at least one skippable tile per operand
            keep = keep.at[0, 0].set(False)
        return v * jnp.repeat(jnp.repeat(keep, BM, 0), BN, 1)

    x = tile_sparse(jax.random.fold_in(key, 0), (size, size))
    w = tile_sparse(jax.random.fold_in(key, 1), (size, size))

    def loss(x, w):
        y = mm_ops.masked_matmul(x, w, apply_sr=False, backward="auto")
        return jnp.sum(jax.nn.relu(y) ** 2)

    with registry.record_kernel_metrics() as rows:
        mm_ops.masked_matmul(x, w, apply_sr=False)  # eager fwd: records skip
        jax.grad(loss, argnums=(0, 1))(x, w)        # eager bwd: dx/dw skips
    s = registry.metric_summary(rows)
    dx = s.get("masked_matmul_dx", {}).get("tile_skip")
    dw = s.get("masked_matmul_dw", {}).get("tile_skip")
    bwd = [v for v in (dx, dw) if v is not None]
    return {
        "density": density,
        "size": size,
        "forward_tile_skip": s.get("masked_matmul", {}).get("tile_skip"),
        "backward_tile_skip_dx": dx,
        "backward_tile_skip_dw": dw,
        "backward_tile_skip": sum(bwd) / len(bwd) if bwd else None,
    }


def mm_call_with_backward(
    x: jax.Array,
    w: jax.Array,
    seed: jax.Array,
    *,
    il: int,
    fl: int,
    apply_sr: bool,
    fwd_impl: str,
    bwd_impl: str,
) -> jax.Array:
    """Forward through ``fwd_impl`` with dx/dw routed through the
    sparsity-aware backward ops (``bwd_impl``: "auto" or a concrete name).

    A concrete ``bwd_impl`` is validated eagerly so a bad pin fails at the
    call site, not inside the backward trace.
    """
    if bwd_impl != "auto":
        registry.resolve("masked_matmul_dx", bwd_impl, _count=False)
        registry.resolve("masked_matmul_dw", bwd_impl, _count=False)
    return _mm_bw(x, w, seed, il, fl, apply_sr, fwd_impl, bwd_impl)
