"""Pure-jnp oracle for the masked matmul kernel.

Dense f32 matmul of the same operands + the identical SR epilogue
(same counters, same hash).  Tile skipping must not change results —
the oracle does *not* skip anything, which is the point of the test.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.masked_matmul.mm_kernel import padded_dims
from repro.kernels.prng import hash_uint32, uniform_from_bits


def masked_matmul_reference(
    x: jax.Array,
    w: jax.Array,
    seed: jax.Array,
    *,
    il: int = 4,
    fl: int = 16,
    apply_sr: bool = True,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    _, n_pad, _ = padded_dims(m, n, k)
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if not apply_sr:
        return y
    eps = 2.0**-fl
    min_v, max_v = -(2.0**il), 2.0**il - eps
    xc = jnp.clip(y, min_v, max_v)
    scaled = xc * jnp.float32(2.0**fl)
    lo = jnp.floor(scaled)
    frac = scaled - lo
    gi = jax.lax.broadcasted_iota(jnp.uint32, y.shape, 0)
    gj = jax.lax.broadcasted_iota(jnp.uint32, y.shape, 1)
    counter = gi * jnp.uint32(n_pad) + gj
    u = uniform_from_bits(hash_uint32(counter, seed.astype(jnp.uint32)))
    rounded = lo + (u < frac).astype(jnp.float32)
    return jnp.clip(rounded * jnp.float32(eps), min_v, max_v)
