"""Pallas TPU kernel: sparsity-aware fixed-point matmul with SR epilogue.

This is the MXU-granular realization of SPRING's pre-compute sparsity
module + MAC lanes (paper Figs. 6-8, DESIGN.md §2/P1):

  * Operands are Q(IL,FL) grid values.  Per-(128x128)-tile *occupancy
    masks* (the AND-reduction of SPRING's element binary masks over a
    tile) are computed outside and streamed in as scalars.
  * The grid walks (M/bm, N/bn, K/bk); a k-step issues the MXU matmul
    only when ``x_occ[i,k] AND w_occ[k,j]`` — the AND-mask gate of
    Fig. 7(a) lifted to tile granularity.  All-zero tiles cost no MXU
    work ("ineffectual computations are completely skipped").
  * The epilogue applies stochastic rounding (paper Eq. 4) back to
    Q(IL,FL) using the same counter-based xorshift stream as
    ``kernels/stochastic_round``.

Numerics note: skipping a tile whose joint occupancy is empty adds
exactly 0.0 to the f32 accumulator, so outputs are bit-identical to the
dense evaluation of the same (masked) operands — SPRING's dangling
non-zeros never influence results, they only waste work when not skipped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.prng import hash_uint32, uniform_from_bits

BM = 128
BN = 128
BK = 128


def padded_dims(m: int, n: int, k: int) -> tuple[int, int, int]:
    return (pl.cdiv(m, BM) * BM, pl.cdiv(n, BN) * BN, pl.cdiv(k, BK) * BK)


def _mm_kernel(
    x_ref,
    w_ref,
    xo_ref,
    wo_ref,
    seed_ref,
    out_ref,
    *,
    k_steps: int,
    n_pad: int,
    fl: int,
    min_v: float,
    max_v: float,
    apply_sr: bool,
):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    occupied = (xo_ref[0, 0] & wo_ref[0, 0]) != 0

    @pl.when(occupied)
    def _mac():
        out_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    if apply_sr:

        @pl.when(k == k_steps - 1)
        def _epilogue():
            acc = out_ref[...]
            scale = jnp.float32(2.0**fl)
            xc = jnp.clip(acc, min_v, max_v)
            scaled = xc * scale
            lo = jnp.floor(scaled)
            frac = scaled - lo
            rows = jax.lax.broadcasted_iota(jnp.uint32, acc.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.uint32, acc.shape, 1)
            gi = jnp.uint32(i) * jnp.uint32(BM) + rows
            gj = jnp.uint32(j) * jnp.uint32(BN) + cols
            counter = gi * jnp.uint32(n_pad) + gj
            u = uniform_from_bits(hash_uint32(counter, seed_ref[0, 0]))
            rounded = lo + (u < frac).astype(jnp.float32)
            out_ref[...] = jnp.clip(rounded * jnp.float32(2.0**-fl), min_v, max_v)


def masked_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    x_occ: jax.Array,
    w_occ: jax.Array,
    seed: jax.Array,
    *,
    il: int = 4,
    fl: int = 16,
    apply_sr: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """(M,K) @ (K,N) with tile skipping. Inputs must be block-padded.

    x_occ: (M/BM, K/BK) int32; w_occ: (K/BK, N/BN) int32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % BM == 0 and n % BN == 0 and k % BK == 0
    grid = (m // BM, n // BN, k // BK)
    eps = 2.0**-fl
    kernel = functools.partial(
        _mm_kernel,
        k_steps=grid[2],
        n_pad=n,
        fl=fl,
        min_v=-(2.0**il),
        max_v=2.0**il - eps,
        apply_sr=apply_sr,
    )
    kwargs = {}
    if not interpret:
        from jax.experimental.pallas import tpu as pltpu

        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, x_occ.astype(jnp.int32), w_occ.astype(jnp.int32), seed.astype(jnp.uint32).reshape(1, 1))
