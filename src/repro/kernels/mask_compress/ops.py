"""Public wrappers for mask packing / dangling filtering with padding.

Three registered ops: ``mask_pack`` (values -> packed occupancy words),
``mask_unpack`` (its inverse) and ``dangling_filter`` (zero each operand
where the other is zero — SPRING's pre-compute filter).  ``mask_unpack``
is a shift-and-test on the VPU lanes on every backend, so its
``interpret``/``pallas`` registrations alias the same vectorized lowering
(kept so whole-program policy pins resolve uniformly); the aliases are
excluded from the parity suite.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.mask_compress.mc_kernel import COLS, ROWS, dangling_filter_pallas, mask_pack_pallas


def _pad2d(x: jax.Array) -> tuple[jax.Array, int, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = ROWS * COLS
    padded = (n + block - 1) // block * block
    return jnp.pad(flat, (0, padded - n)).reshape(-1, COLS), n, padded


@jax.jit
def _pack_ref(x):
    from repro.core.masking import pack_mask_bits

    x2d, _, _ = _pad2d(x)
    return pack_mask_bits(x2d.reshape(-1) != 0.0)


@partial(jax.jit, static_argnames=("interpret",))
def _pack_kernel(x, *, interpret):
    x2d, _, _ = _pad2d(x)
    words = mask_pack_pallas(x2d, interpret=interpret)
    return words.reshape(-1)


@partial(jax.jit, static_argnames=("length",))
def _unpack_ref(words, length):
    from repro.core.masking import unpack_mask_bits

    return unpack_mask_bits(words.reshape(-1), length)


@jax.jit
def _dangling_ref(a, w):
    joint = (a != 0.0) & (w != 0.0)
    return jnp.where(joint, a, 0.0), jnp.where(joint, w, 0.0)


@partial(jax.jit, static_argnames=("interpret",))
def _dangling_kernel(a, w, *, interpret):
    a2d, n, _ = _pad2d(a)
    w2d, _, _ = _pad2d(w)
    af, wf = dangling_filter_pallas(a2d, w2d, interpret=interpret)
    return af.reshape(-1)[:n].reshape(a.shape), wf.reshape(-1)[:n].reshape(w.shape)


def _sparse_vec(seed: int, n: int, sparsity: float) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (n,)) * (
        jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > sparsity)


def _pack_examples() -> list:
    return [((_sparse_vec(5, 777, 0.4),), {}),
            ((_sparse_vec(6, 4096, 0.6),), {}),
            ((_sparse_vec(7, 1000, 0.5).reshape(10, 100),), {})]


def _dangling_examples() -> list:
    return [((_sparse_vec(0, 5000, 0.5), _sparse_vec(2, 5000, 0.6)), {}),
            ((_sparse_vec(3, 640, 0.3).reshape(32, 20),
              _sparse_vec(4, 640, 0.7).reshape(32, 20)), {})]


registry.register_op("mask_pack", oracle="ref", examples=_pack_examples,
                     compare={"kind": "exact"})
registry.register_impl("mask_pack", "ref", priority=10)(_pack_ref)
registry.register_impl("mask_pack", "interpret", selectable=False)(
    partial(_pack_kernel, interpret=True))
registry.register_impl("mask_pack", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_pack_kernel, interpret=False))

registry.register_op("mask_unpack", oracle="ref")
registry.register_impl("mask_unpack", "ref", priority=10)(_unpack_ref)
registry.register_impl("mask_unpack", "interpret", selectable=False,
                       parity=False)(_unpack_ref)
registry.register_impl("mask_unpack", "pallas", priority=30, parity=False,
                       available=registry.on_tpu)(_unpack_ref)

registry.register_op("dangling_filter", oracle="ref",
                     examples=_dangling_examples, compare={"kind": "exact"})
registry.register_impl("dangling_filter", "ref", priority=10)(_dangling_ref)
registry.register_impl("dangling_filter", "interpret", selectable=False)(
    partial(_dangling_kernel, interpret=True))
registry.register_impl("dangling_filter", "pallas", priority=30,
                       available=registry.on_tpu)(
    partial(_dangling_kernel, interpret=False))


def mask_pack(x: jax.Array, impl: str | None = None) -> jax.Array:
    """Flattened packed occupancy mask words for any-shaped ``x``."""
    kimpl = registry.resolve("mask_pack", impl)
    words = kimpl.fn(x)
    if registry.metrics_active() and not isinstance(words, jax.core.Tracer):
        # measured wire bytes of the packed representation: 1 bit/elem in
        # whole uint32 words, ceil(n/32)*4 — the mask term of the
        # perfmodel traffic formula, matching memstash accounting (the
        # kernel's ROWS*COLS lane padding is not wire traffic)
        registry.note_metric("mask_pack", wire_bytes=float(-(-x.size // 32) * 4))
    return words


def mask_unpack(words: jax.Array, length: int, impl: str | None = None) -> jax.Array:
    """Packed mask words -> (length,) bool occupancy (``mask_pack`` inverse)."""
    kimpl = registry.resolve("mask_unpack", impl)
    return kimpl.fn(words, length)


def dangling_filter(a: jax.Array, w: jax.Array,
                    impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Zero each operand where the other is zero (pre-compute filter)."""
    assert a.shape == w.shape
    kimpl = registry.resolve("dangling_filter", impl)
    return kimpl.fn(a, w)
