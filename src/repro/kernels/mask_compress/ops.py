"""Jitted wrappers for mask packing / dangling filtering with padding."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mask_compress.mc_kernel import COLS, ROWS, dangling_filter_pallas, mask_pack_pallas


def _pad2d(x: jax.Array) -> tuple[jax.Array, int, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = ROWS * COLS
    padded = (n + block - 1) // block * block
    return jnp.pad(flat, (0, padded - n)).reshape(-1, COLS), n, padded


@partial(jax.jit, static_argnames=("impl",))
def mask_pack(x: jax.Array, impl: str = "auto") -> jax.Array:
    """Flattened packed occupancy mask words for any-shaped ``x``."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    x2d, n, _ = _pad2d(x)
    if impl == "ref":
        from repro.core.masking import pack_mask_bits

        return pack_mask_bits(x2d.reshape(-1) != 0.0)
    words = mask_pack_pallas(x2d, interpret=(impl == "interpret"))
    return words.reshape(-1)


@partial(jax.jit, static_argnames=("length", "impl"))
def mask_unpack(words: jax.Array, length: int, impl: str = "auto") -> jax.Array:
    """Packed mask words -> (length,) bool occupancy (``mask_pack`` inverse).

    The unpack is a shift-and-test on the VPU lanes either way, so the
    "pallas"/"interpret" impls share the vectorized path with "ref" — the
    switch exists so the memstash restore path mirrors the pack dispatch.
    """
    del impl  # single vectorized lowering; see docstring
    from repro.core.masking import unpack_mask_bits

    return unpack_mask_bits(words.reshape(-1), length)


@partial(jax.jit, static_argnames=("impl",))
def dangling_filter(a: jax.Array, w: jax.Array, impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Zero each operand where the other is zero (pre-compute filter)."""
    assert a.shape == w.shape
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        joint = (a != 0.0) & (w != 0.0)
        return jnp.where(joint, a, 0.0), jnp.where(joint, w, 0.0)
    a2d, n, _ = _pad2d(a)
    w2d, _, _ = _pad2d(w)
    af, wf = dangling_filter_pallas(a2d, w2d, interpret=(impl == "interpret"))
    return af.reshape(-1)[:n].reshape(a.shape), wf.reshape(-1)[:n].reshape(w.shape)
