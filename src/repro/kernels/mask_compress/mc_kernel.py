"""Pallas TPU kernels for SPRING's binary-mask machinery (paper Figs. 5-7).

Two kernels:

  * ``mask_pack``: dense f32 block -> packed uint32 mask words (1 bit per
    element, 32 per word — the Fig. 5 storage format).  Realized as a
    shift-and-reduce over 32-lane groups on the VPU.
  * ``dangling_filter``: the pre-compute sparsity module's mask generation
    + dangling-data filter (Figs. 7a/7b) on dense-layout operand tiles:
    joint = (a != 0) & (w != 0); each operand keeps only joint survivors.

The zero-collapsing shifter (Fig. 7c) is a data-dependent compaction; on
TPU that is a cumsum+scatter which XLA already emits well, so it stays in
``core/masking.py`` (DESIGN.md §2/P1).  The element-serial Algorithm 1 is
the oracle in ``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
COLS = 1024  # lanes; must be a multiple of 32
WORDS = COLS // 32


def _pack_kernel(x_ref, out_ref):
    bits = (x_ref[...] != 0.0).astype(jnp.uint32)  # (ROWS, COLS)
    b = bits.reshape(ROWS, WORDS, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (ROWS, WORDS, 32), 2)
    out_ref[...] = (b << shifts).sum(axis=2).astype(jnp.uint32)


def mask_pack_pallas(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(R, COLS) f32 -> (R, COLS/32) uint32 packed occupancy mask."""
    r, c = x.shape
    assert c == COLS and r % ROWS == 0, (x.shape,)
    return pl.pallas_call(
        _pack_kernel,
        grid=(r // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, WORDS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, WORDS), jnp.uint32),
        interpret=interpret,
    )(x.astype(jnp.float32))


def _filter_kernel(a_ref, w_ref, a_out_ref, w_out_ref):
    a = a_ref[...]
    w = w_ref[...]
    joint = (a != 0.0) & (w != 0.0)  # Fig. 7(a): AND of the binary masks
    a_out_ref[...] = jnp.where(joint, a, 0.0)  # Fig. 7(b): dangling filtered
    w_out_ref[...] = jnp.where(joint, w, 0.0)


def dangling_filter_pallas(
    a: jax.Array, w: jax.Array, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Dense-layout pre-compute sparsity filter on (R, COLS) operand tiles."""
    r, c = a.shape
    assert a.shape == w.shape and c == COLS and r % ROWS == 0
    return pl.pallas_call(
        _filter_kernel,
        grid=(r // ROWS,),
        in_specs=[
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, COLS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.float32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=interpret,
    )(a.astype(jnp.float32), w.astype(jnp.float32))
