"""Oracles for the binary-mask machinery, including the *faithful*
element-serial Algorithm 1 from the paper (sequential scanning and
filtering mechanism) — the ground truth the vectorized/kernel forms are
tested against.
"""

from __future__ import annotations

import numpy as np


def algorithm1_filter(
    in_data: np.ndarray, output_mask: np.ndarray, filter_mask: np.ndarray
) -> np.ndarray:
    """Verbatim Algorithm 1 (paper §3.1).

    in_data: the zero-free value stream of one operand (its non-zeros in
    order).  output_mask: dense AND-mask bits.  filter_mask: dense bits of
    this operand's dangling positions (own_mask XOR output_mask).
    Returns the stream with dangling entries zeroed in place (the
    zero-collapsing shifter then compacts it — ``collapse_zeros``).
    """
    out_data = np.zeros_like(in_data)
    data_pointer = 0
    for mask_pointer in range(len(output_mask)):
        if output_mask[mask_pointer] == 1:
            out_data[data_pointer] = in_data[data_pointer]
            data_pointer += 1
        elif filter_mask[mask_pointer] == 1:
            out_data[data_pointer] = 0
            data_pointer += 1
    return out_data


def collapse_zeros(stream: np.ndarray) -> np.ndarray:
    """Fig. 7(c) zero-collapsing shifter, element-serial."""
    out = np.zeros_like(stream)
    p = 0
    for v in stream:
        if v != 0:
            out[p] = v
            p += 1
    return out


def precompute_module_reference(a_dense: np.ndarray, w_dense: np.ndarray):
    """Full pre-compute sparsity module, element-serial (oracle).

    Returns (a_matched, w_matched, out_mask_bits): aligned zero-free
    streams (padded with zeros to dense length) + the AND mask.
    """
    a_dense = np.asarray(a_dense, np.float32)
    w_dense = np.asarray(w_dense, np.float32)
    a_bits = (a_dense != 0).astype(np.int32)
    w_bits = (w_dense != 0).astype(np.int32)
    out_bits = a_bits & w_bits
    a_filter = a_bits ^ out_bits
    w_filter = w_bits ^ out_bits
    a_stream = np.concatenate([a_dense[a_dense != 0], np.zeros(len(a_dense) - (a_dense != 0).sum(), np.float32)])
    w_stream = np.concatenate([w_dense[w_dense != 0], np.zeros(len(w_dense) - (w_dense != 0).sum(), np.float32)])
    a_matched = collapse_zeros(algorithm1_filter(a_stream, out_bits, a_filter))
    w_matched = collapse_zeros(algorithm1_filter(w_stream, out_bits, w_filter))
    return a_matched, w_matched, out_bits


def mask_pack_reference(x: np.ndarray) -> np.ndarray:
    """(R, C) -> (R, C/32) uint32, bit i of word w = element 32*w+i."""
    r, c = x.shape
    bits = (x != 0).astype(np.uint32).reshape(r, c // 32, 32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits << shifts).sum(axis=2).astype(np.uint32)


def dangling_filter_reference(a: np.ndarray, w: np.ndarray):
    joint = (a != 0) & (w != 0)
    return np.where(joint, a, 0).astype(np.float32), np.where(joint, w, 0).astype(np.float32)


def mask_unpack_reference(words: np.ndarray, length: int) -> np.ndarray:
    """(W,) uint32 packed words -> (length,) {0,1} bits (mask_pack inverse)."""
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[:, None] >> shifts) & np.uint32(1)
    return bits.reshape(-1)[:length].astype(np.int32)


def stash_roundtrip_reference(x: np.ndarray) -> np.ndarray:
    """Element-serial memstash oracle: collapse non-zeros behind the packed
    mask, then re-expand — what ``memstash.compress``/``decompress`` do
    vectorized.  Returns the reconstructed dense array."""
    flat = x.reshape(-1)
    stream = np.zeros_like(flat)
    p = 0
    for v in flat:
        if v != 0:
            stream[p] = v
            p += 1
    bits = (flat != 0).astype(np.int32)
    out = np.zeros_like(flat)
    q = 0
    for i, b in enumerate(bits):
        if b:
            out[i] = stream[q]
            q += 1
    return out.reshape(x.shape)
