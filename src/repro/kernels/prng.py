"""Counter-based PRNG shared by Pallas kernels and their jnp oracles.

SPRING drives its stochastic-rounding module from an LFSR (paper §3.2).
An LFSR is bit-serial; the TPU-native equivalent in the same
linear-shift-register family is a counter-based xorshift/finalizer hash:
each output element hashes (seed, element counter) into uniform bits, so
the stream is stateless, order-independent and identical between the
kernel and the pure-jnp reference (exact-equality testable).

The mix is the murmur3/splitmix 32-bit finalizer — full-avalanche, built
from xor-shift-multiply ops that exist on the TPU VPU and in interpret
mode alike.
"""

from __future__ import annotations

import jax.numpy as jnp


def hash_uint32(counter: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Full-avalanche 32-bit finalizer of (counter ^ seed-mixed) values.

    counter: any-shape uint32 (element indices); seed: scalar uint32.
    Returns uniform uint32 of counter.shape.
    """
    z = counter.astype(jnp.uint32) + (seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9))
    z = (z ^ (z >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    z = (z ^ (z >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    z = z ^ (z >> jnp.uint32(16))
    return z


def uniform_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 -> float32 uniform in [0, 1) with 24-bit resolution."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
