"""PagedServingEngine: the serving engine on the paged COW KV pool.

Same contract as :class:`repro.serving.engine.ServingEngine` (submit /
step / run / summary, bit-identical tokens per request) with the
slot-monolithic pool swapped for fixed-size packed pages:

  * KV rows live in :class:`PagedKVStore` frames; a :class:`BlockTable`
    maps (request, block) -> frame and shares pure prefix blocks
    copy-on-write between requests;
  * prompts install page-by-page (*chunked prefill*): at most
    ``prefill_chunk`` page writes land per tick engine-wide, so a long
    prompt never stalls the decode tick of requests already resident —
    a request decodes once its last page is in;
  * admission is *density-aware*: logical frames overcommit the physical
    page budget, and requests are admitted while their pages — costed at
    the pool's measured packed density — fit the physical bits.  When
    density rises and live bits exceed the budget, the most recently
    admitted requests spill: their exact packed page bits move to host
    memory and resume — bit-identically, by construction — once the pool
    drains.

Per-tick decode is gather -> compute -> scatter (see ``store.py``); the
key bit-identity trick is that the *gather* table is captured before the
write page is claimed, so a COW fork reads the shared frame's content
while its write-back lands in the private copy — the "copy" is the
full-page write-back itself.
"""

from __future__ import annotations

import collections
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.serving import kvpool
from repro.serving.engine import ServingEngine
from repro.serving.paging.admission import AdmissionController
from repro.serving.paging.allocator import PageAllocator, PageError
from repro.serving.paging.blocktable import BlockTable, chain_keys
from repro.serving.paging.scheduler import PagedScheduler
from repro.serving.paging.store import PagedKVStore, prompt_rows
from repro.telemetry.sketch import QuantileSketch


def extract_slot_state(state: dict, slot) -> dict:
    """One slot's dense (non-paged) cache state, for spill payloads."""

    def one(path, leaf):
        ax = kvpool.slot_axis(path)
        starts = [0] * leaf.ndim
        starts[ax] = slot
        sizes = list(leaf.shape)
        sizes[ax] = 1
        return jax.lax.dynamic_slice(leaf, tuple(starts), tuple(sizes))

    return jax.tree_util.tree_map_with_path(one, state)


def restore_slot_state(state: dict, payload: dict, slot) -> dict:
    """Inverse of :func:`extract_slot_state` into (possibly another) slot."""

    def one(path, leaf):
        ax = kvpool.slot_axis(path)
        p = jnp.asarray(kvpool._lookup(payload, path)).astype(leaf.dtype)
        starts = [0] * leaf.ndim
        starts[ax] = slot
        return jax.lax.dynamic_update_slice(leaf, p, tuple(starts))

    return jax.tree_util.tree_map_with_path(one, state)


class PagedServingEngine(ServingEngine):
    """Continuous batching over paged, copy-on-write packed KV storage."""

    backend_kind = "paged"

    def __init__(self, arch, step_cfg, *, page_tokens: int = 8,
                 num_pages: Optional[int] = None, overcommit: float = 1.5,
                 prefix_cache: bool = True, prefill_chunk: Optional[int] = 8,
                 **kw):
        if page_tokens < 1:
            raise ValueError(f"page_tokens must be >= 1, got {page_tokens}")
        if overcommit < 1.0:
            raise ValueError(f"overcommit must be >= 1.0, got {overcommit}")
        self.page_tokens = page_tokens
        self._num_pages_arg = num_pages
        self.overcommit = overcommit
        self.prefix_cache = prefix_cache
        self.prefill_chunk = prefill_chunk
        super().__init__(arch, step_cfg, **kw)

    # -- backend construction ------------------------------------------------

    def _make_scheduler(self, n_slots: int) -> PagedScheduler:
        return PagedScheduler(n_slots, policy=self.shed_policy)

    def _build_pool(self) -> None:
        pt = self.page_tokens
        self.max_blocks = -(-self.max_len // pt)
        # default physical budget: the dense-equivalent of the monolithic
        # pool (every slot can hold max_len rows with nothing shared)
        num_pages = (self.n_slots * self.max_blocks
                     if self._num_pages_arg is None else self._num_pages_arg)
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        logical = int(math.ceil(num_pages * self.overcommit))

        self.store = PagedKVStore(
            self.cfg, self.n_slots, pt, self.max_blocks,
            n_frames=PageAllocator.RESERVED + logical,
            pack_impl=self._kv_pack_impl, unpack_impl=self._kv_unpack_impl)
        self.alloc = PageAllocator(logical)
        self.table = BlockTable(self.alloc, pt, prefix_cache=self.prefix_cache)
        self.admission = AdmissionController(
            self.store.page_elems, self.store.page_mask_bits, num_pages)
        self.store_arrays = self.store.init_arrays()
        self.state = self.store.init_state()

        store, decode = self.store, self._decode_step

        def paged_decode(params, tokens, arrays, state, table, wframe, wblock,
                         active, key):
            cache = store.assemble(arrays, state, table)
            logits, new_cache = decode(params, tokens, cache, key)
            merged = kvpool.merge_active(new_cache, cache, active)
            new_arrays = store.writeback(arrays, merged, wframe, wblock)
            return logits, new_arrays, store.strip(merged)

        self._paged_decode = jax.jit(paged_decode)
        self._pad = jax.jit(store.pad_prefill)
        self._install_block = jax.jit(store.install_block)
        self._install_state = jax.jit(kvpool.install_prefill)
        self._extract_frame = jax.jit(store.extract_frame)
        self._restore_frame = jax.jit(store.restore_frame)
        self._extract_state = jax.jit(extract_slot_state)
        self._restore_state = jax.jit(restore_slot_state)
        self._live_nnz = jax.jit(store.live_nnz)

        # host-side paging state
        self._pos = np.zeros((self.n_slots,), np.int64)  # device pos mirror
        self._slot_rid: dict[int, int] = {}
        self._resident_order: list[int] = []  # slots, admission order
        self._installing: dict = {}  # slot -> (padded pages, pending deque)
        self._pending_frame_set: set = set()  # allocated, not yet written
        self._install_budget = 0
        self._reserved_frames = 0
        self._reserved_bits = 0.0
        self._live_bits = 0.0
        self._density: Optional[float] = None  # None until first measurement
        self.page_util_sketch = QuantileSketch()
        self.peak_page_utilization = 0.0

    # -- submission ----------------------------------------------------------

    def submit(self, req) -> int:
        # one request alone must fit the physical budget, or admission
        # could never make progress on it (the per-request analogue of
        # the base engine's max_len guard)
        rows = prompt_rows(self.cfg, len(req.prompt)) + req.max_tokens + 1
        pages_needed = -(-rows // self.page_tokens)
        if pages_needed > self.admission.num_pages:
            raise ValueError(
                f"request {req.rid}: needs {pages_needed} pages "
                f"({rows} rows at {self.page_tokens} tokens/page), physical "
                f"budget is {self.admission.num_pages} pages")
        return super().submit(req)

    # -- admission -----------------------------------------------------------

    def _density_est(self) -> float:
        """Measured pool density for admission projections: conservative
        1.0 while nothing has been measured, floored away from zero so a
        nearly-empty pool can't project pages as free."""
        return 1.0 if self._density is None else self._density

    def _projected_live(self) -> float:
        """Live bits plus the projected cost of allocated-but-unwritten
        (pending-install) frames, costed at the measured density."""
        return (self._live_bits + len(self._pending_frame_set)
                * self.admission.page_bits(self._density_est()))

    def _plan(self, req):
        n_fill = prompt_rows(self.cfg, len(req.prompt))
        n_blocks = -(-n_fill // self.page_tokens)
        # VLM prompts never share: chain keys hash tokens only, and the
        # image prefix rows make equal-token prompts content-distinct
        share = self.prefix_cache and req.img_embeds is None
        keys = (chain_keys(req.prompt, self.page_tokens, n_fill) if share
                else [None] * n_blocks)
        plan = (self.table.plan_prompt(req.prompt, n_fill) if share
                else [None] * n_blocks)
        return plan, keys

    def _can_admit(self, req) -> bool:
        plan, _ = self._plan(req)
        n_new = sum(1 for hit in plan if hit is None)
        if n_new > self.alloc.n_free - self._reserved_frames:
            return False
        d = self._density_est()
        if not self.admission.admits(
                self._projected_live() + self._reserved_bits, n_new, d):
            return False
        self._reserved_frames += n_new
        self._reserved_bits += n_new * self.admission.page_bits(d)
        return True

    def _can_resume(self, spilled) -> bool:
        pay = spilled.payload
        if pay["n_frames"] > self.alloc.n_free - self._reserved_frames:
            return False
        if not self.admission.admits_exact(
                self._projected_live() + self._reserved_bits,
                pay["wire_bits"]):
            return False
        self._reserved_frames += pay["n_frames"]
        self._reserved_bits += pay["wire_bits"]
        return True

    def _admit_phase(self) -> None:
        self._shed_phase()
        self._install_budget = (10 ** 9 if self.prefill_chunk is None
                                else self.prefill_chunk)
        self._reserved_frames = 0
        self._reserved_bits = 0.0
        with telemetry.span("serve.tick.schedule"):
            admitted = self.sched.admit_paged(self._can_resume,
                                              self._can_admit)
        # drain older requests' pending page installs before new prompts
        # compete for the per-tick chunk budget
        for slot in [s for s in self._resident_order if s in self._installing]:
            self._pump_installs(slot)
        for tracker, spilled in admitted:
            if spilled is not None:
                self._resume_one(tracker, spilled)
            else:
                self._admit_one(tracker)  # base prefill/sample/bookkeeping
        self._reserved_frames = 0
        self._reserved_bits = 0.0

    def _install_request(self, tracker, pcache) -> None:
        """Admission commit: open the block table, adopt shared prefix
        frames, queue the rest for chunked install, write slot state."""
        req, slot, rid = tracker.req, tracker.slot, tracker.req.rid
        plan, keys = self._plan(req)
        self.table.open(rid)
        pending = collections.deque()
        for b, hit in enumerate(plan):
            if hit is not None:
                self.table.adopt_block(rid, hit)
            else:
                f = self.table.append_block(rid)
                self._pending_frame_set.add(f)
                pending.append((b, f, keys[b]))
        pages = self._pad(pcache)
        self.state = self._install_state(
            self.state, pcache, jnp.asarray(slot, jnp.int32), len(req.prompt))
        self._installing[slot] = (pages, pending)
        self._slot_rid[slot] = rid
        self._resident_order.append(slot)
        self._pos[slot] = len(req.prompt)
        self._pump_installs(slot)
        jax.block_until_ready(jax.tree_util.tree_leaves(self.store_arrays)[0])

    def _pump_installs(self, slot: int) -> None:
        """Write pending prompt pages for ``slot`` while the per-tick
        chunk budget lasts; a fully-installed slot starts decoding."""
        pages, pending = self._installing[slot]
        while pending and self._install_budget > 0:
            b, f, key = pending.popleft()
            self.store_arrays = self._install_block(
                self.store_arrays, pages, jnp.asarray(b, jnp.int32),
                jnp.asarray(f, jnp.int32))
            self._pending_frame_set.discard(f)
            if key is not None:
                # content is now really there -> safe to share from
                self.table.register(f, key)
            self._install_budget -= 1
        if not pending:
            del self._installing[slot]

    def _resume_one(self, tracker, spilled) -> None:
        """Restore a spilled request into a fresh slot: exact packed page
        bits and slot state back onto the device, nothing recomputed —
        resumption is bit-identical by construction."""
        req, slot, pay = tracker.req, tracker.slot, spilled.payload
        with telemetry.span("serve.tick.resume", rid=req.rid, slot=slot):
            self._ledger.install(slot)
            self.table.open(req.rid)
            for content in pay["frames"]:
                f = self.table.grow(req.rid)
                self.store_arrays = self._restore_frame(
                    self.store_arrays, content, jnp.asarray(f, jnp.int32))
            self.state = self._restore_state(
                self.state, pay["state"], jnp.asarray(slot, jnp.int32))
            jax.block_until_ready(
                jax.tree_util.tree_leaves(self.store_arrays)[0])
        self._pos[slot] = pay["pos"]
        self._next_tok[slot] = pay["next_tok"]
        self._slot_rid[slot] = req.rid
        self._resident_order.append(slot)
        self._results[req.rid].slot = slot

    # -- spill ---------------------------------------------------------------

    def _spill_slot(self, slot: int) -> None:
        """Preempt the request in ``slot``: its exact packed page bits and
        slot state move to host memory, its frames free immediately."""
        tracker = self.sched.active[slot]
        rid = tracker.req.rid
        with telemetry.span("serve.tick.spill", rid=rid, slot=slot):
            frames = self.table.frames_of(rid)
            contents, nnz = [], 0.0
            for f in frames:
                c = jax.device_get(self._extract_frame(
                    self.store_arrays, jnp.asarray(f, jnp.int32)))
                contents.append(c)
                nnz += sum(float(np.sum(leaf["nnz"])) for leaf in c.values())
            payload = {
                "frames": contents,
                "state": jax.device_get(self._extract_state(
                    self.state, jnp.asarray(slot, jnp.int32))),
                "pos": int(self._pos[slot]),
                "next_tok": int(self._next_tok[slot]),
                # exact resume cost: shared frames were copied out, so the
                # request pays for private copies when it comes back
                "wire_bits": (nnz * self.admission.value_bits
                              + len(frames) * self.store.page_mask_bits),
                "n_frames": len(frames),
            }
            self._ledger.release(slot)
            self.table.release(rid)
            del self._slot_rid[slot]
            self._resident_order.remove(slot)
            self._pos[slot] = 0
            self.sched.preempt(slot, payload)

    # -- decode tick ---------------------------------------------------------

    def _decode_slots(self) -> list:
        # a request decodes only once every prompt page is installed
        return sorted(s for s in self.sched.active
                      if s not in self._installing)

    def _claim_write_page(self, s: int, candidates: set):
        """Secure the frame slot ``s`` writes this tick (growing or
        copy-on-write-forking its current block), spilling the most
        recently admitted unprepared request on page exhaustion.  Returns
        ``(write_frame, write_block, read_frames)`` or None if ``s``
        itself was the spill victim.  ``read_frames`` is captured *before*
        the claim: a COW fork gathers the shared frame's content while
        its write-back lands in the private copy, and a freshly grown
        block gathers the null page (exact zeros)."""
        rid = self._slot_rid[s]
        wb = int(self._pos[s]) // self.page_tokens
        row = self.table.frames_of(rid)
        n0 = len(row)
        while True:
            try:
                while self.table.n_blocks(rid) <= wb:
                    self.table.grow(rid)
                frame, _cow = self.table.ensure_writable(rid, wb)
                return frame, wb, row
            except PageError:
                victim = next((v for v in reversed(self._resident_order)
                               if v in candidates and v in self.sched.active),
                              None)
                if victim is None:  # unreachable: s itself is a candidate
                    raise
                if victim == s:
                    self.table.truncate(rid, n0)  # drop half-grown blocks
                    self._spill_slot(s)
                    return None
                self._spill_slot(victim)
                candidates.discard(victim)

    def _dispatch_decode(self, slots):
        wframe = np.ones((self.n_slots,), np.int32)  # default: scratch sink
        wblock = np.zeros((self.n_slots,), np.int32)
        read_rows, prepared = {}, []
        unprepared = set(slots)
        for s in list(slots):
            if s not in self.sched.active:
                continue  # spilled while an earlier slot claimed its page
            got = self._claim_write_page(s, unprepared)
            unprepared.discard(s)
            if got is None:
                continue
            wframe[s], wblock[s], read_rows[s] = got[0], got[1], got[2]
            prepared.append(s)
        if not prepared:
            return None, [], 0.0
        table_np = np.zeros((self.n_slots, self.max_blocks), np.int32)
        for s, row in read_rows.items():
            table_np[s, :len(row)] = row  # tail stays 0: the null page
        active = np.zeros((self.n_slots,), bool)
        active[prepared] = True
        t0 = time.monotonic()
        with telemetry.span("serve.tick.decode", active=len(prepared)):
            logits, self.store_arrays, self.state = self._paged_decode(
                self.params, jnp.asarray(self._next_tok, jnp.int32),
                self.store_arrays, self.state, jnp.asarray(table_np),
                jnp.asarray(wframe), jnp.asarray(wblock), jnp.asarray(active),
                jax.random.PRNGKey(self.decode_steps))
            logits = jax.block_until_ready(logits)
        return logits, prepared, time.monotonic() - t0

    def _post_sample(self, slots) -> None:
        for s in slots:
            self._pos[s] += 1

    def release_slot(self, slot: int) -> None:
        self._ledger.release(slot)
        rid = self._slot_rid.pop(slot)
        self.table.release(rid)
        self._resident_order.remove(slot)
        self._pos[slot] = 0
        # no device work: freed frames drop out of the accounting mask
        # and are fully rewritten before any table references them again

    # -- accounting / spill-on-over-budget -----------------------------------

    def _pool_stats(self) -> dict:
        """Wire stats over *written* allocated frames (pending-install
        frames hold stale bits until their page write lands); one device
        reduction, like the monolithic pool's stats."""
        mask = np.zeros((self.store.n_frames,), np.float32)
        counted = [f for f in self.alloc.allocated_frames()
                   if f not in self._pending_frame_set]
        if counted:
            mask[np.asarray(counted)] = 1.0
        nnz = float(self._live_nnz(self.store_arrays, jnp.asarray(mask)))
        return self.store.wire_stats(nnz, len(counted),
                                     self.admission.num_pages)

    def _post_stats(self, stats) -> None:
        self._live_bits = stats["kv_wire_bytes"] * 8.0
        if stats["kv_elems"]:
            self._density = max(stats["kv_density"], 0.05)
        util = self.admission.utilization(self._live_bits)
        self.page_util_sketch.add(util)
        self.peak_page_utilization = max(self.peak_page_utilization, util)
        # the defined spill path: measured live bits exceeded the physical
        # budget (density spiked past the admission-time projection) ->
        # preempt most-recently-admitted residents until the pool fits
        while (self.admission.over_budget(self._live_bits)
               and len(self._resident_order) > 1):
            victim = next((s for s in reversed(self._resident_order)
                           if s not in self._installing), None)
            if victim is None:
                break
            self._spill_slot(victim)
            self._live_bits = self._pool_stats()["kv_wire_bytes"] * 8.0

    def _backend_gauges(self, m) -> None:
        m.set("spring_pages_allocated", self.alloc.n_allocated,
              help="allocated page frames")
        m.set("spring_pages_free", self.alloc.n_free,
              help="free page frames")
        m.set("spring_pages_utilization",
              self.admission.utilization(self._live_bits),
              help="live packed bits / physical page budget")
        m.set("spring_pages_shared", len(self.table.shared_frames()),
              help="frames referenced by more than one request")
        m.set("spring_pages_prefix_hits_total", self.table.prefix_hits,
              help="prompt blocks adopted from the prefix cache")
        m.set("spring_pages_cow_copies_total", self.table.cow_copies,
              help="copy-on-write page forks")
        m.set("spring_pages_spills_total", self.sched.n_spills,
              help="requests preempted to host memory")

    # -- elastic: rescale / snapshot / restore (DESIGN.md §13) ---------------

    def _flush_installs(self) -> None:
        """Land every pending chunked prompt-page write now.  Page content
        is fixed at prefill, and per-request tokens are batch-composition
        invariant, so landing installs early never changes any request's
        output — it only lets the slot decode sooner."""
        self._install_budget = 10 ** 9
        for slot in [s for s in self._resident_order if s in self._installing]:
            self._pump_installs(slot)
        self._install_budget = 0

    def _pre_snapshot(self) -> None:
        self._flush_installs()

    def _pre_rescale(self) -> None:
        self._flush_installs()

    def rescale(self, slots: Optional[int] = None,
                num_pages: Optional[int] = None) -> None:
        """Grow/shrink slots and/or the physical page budget live.  Every
        in-flight or queued request must still fit the new budget alone
        (checked before any mutation — a too-small budget would park a
        request on the spill path forever)."""
        new_pages = (self.admission.num_pages if num_pages is None
                     else int(num_pages))
        if new_pages < 1:
            raise ValueError(f"rescale: num_pages must be >= 1, "
                             f"got {new_pages}")
        inflight = ([t.req for t in self.sched.active.values()]
                    + list(self.sched._queue)
                    + [s.req for s in self.sched._spilled])
        for req in inflight:
            rows = prompt_rows(self.cfg, len(req.prompt)) + req.max_tokens + 1
            need = -(-rows // self.page_tokens)
            if need > new_pages:
                raise ValueError(
                    f"rescale: request {req.rid} needs {need} pages, new "
                    f"physical budget is {new_pages} — drain or shed it "
                    f"first")
        # page-utilization history survives the pool rebuild
        sketch, peak = self.page_util_sketch, self.peak_page_utilization
        self._num_pages_arg = new_pages
        super().rescale(slots)
        self.page_util_sketch, self.peak_page_utilization = sketch, peak

    def _signature(self) -> dict:
        sig = super()._signature()
        sig.update(page_tokens=self.page_tokens,
                   num_pages=self.admission.num_pages,
                   overcommit=self.overcommit,
                   prefix_cache=self.prefix_cache,
                   max_blocks=self.max_blocks)
        return sig

    def _reconfigure(self, sig: dict) -> None:
        if (int(sig["n_slots"]) != self.n_slots
                or int(sig["num_pages"]) != self.admission.num_pages):
            self.n_slots = int(sig["n_slots"])
            self._num_pages_arg = int(sig["num_pages"])
            self._build_pool()

    def _snapshot_backend(self) -> dict:
        from repro.serving.elastic.snapshot import tree_to_host_leaves

        assert not self._installing and not self._pending_frame_set, (
            "_pre_snapshot must flush chunked installs first")
        return {
            "store": tree_to_host_leaves(self.store_arrays),
            "state": tree_to_host_leaves(self.state),
            "alloc": {
                "capacity": self.alloc.capacity,
                "free": list(self.alloc._free),
                "ref": [[f, n] for f, n in sorted(self.alloc._ref.items())],
            },
            "table": {
                "blocks": [[rid, list(fr)]
                           for rid, fr in sorted(self.table.blocks.items())],
                "index": [[k, f] for k, f in self.table._index.items()],
                "frame_key": [[f, k]
                              for f, k in self.table._frame_key.items()],
                "prefix_hits": self.table.prefix_hits,
                "cow_copies": self.table.cow_copies,
            },
            "pos": self._pos.copy(),
            "slot_rid": [[s, r] for s, r in sorted(self._slot_rid.items())],
            "resident_order": list(self._resident_order),
            "density": self._density,
            "live_bits": self._live_bits,
            "page_util_sketch": self.page_util_sketch.to_dict(),
            "peak_page_utilization": self.peak_page_utilization,
        }

    def _restore_backend(self, b: dict) -> None:
        from repro.serving.elastic.snapshot import (SnapshotError,
                                                    leaves_to_tree)

        if int(b["alloc"]["capacity"]) != self.alloc.capacity:
            raise SnapshotError(
                f"snapshot has {b['alloc']['capacity']} logical frames, "
                f"engine has {self.alloc.capacity}")
        self.store_arrays = leaves_to_tree(self.store_arrays, b["store"],
                                           "page store")
        self.state = leaves_to_tree(self.state, b["state"], "slot state")
        self.alloc._free = [int(f) for f in b["alloc"]["free"]]
        self.alloc._ref = {int(f): int(n) for f, n in b["alloc"]["ref"]}
        t = b["table"]
        self.table.blocks = {int(r): [int(f) for f in fr]
                             for r, fr in t["blocks"]}
        self.table._index = {k: int(f) for k, f in t["index"]}
        self.table._frame_key = {int(f): k for f, k in t["frame_key"]}
        self.table.prefix_hits = int(t["prefix_hits"])
        self.table.cow_copies = int(t["cow_copies"])
        self._pos = np.asarray(b["pos"]).astype(np.int64).copy()
        self._slot_rid = {int(s): int(r) for s, r in b["slot_rid"]}
        self._resident_order = [int(s) for s in b["resident_order"]]
        self._installing = {}
        self._pending_frame_set = set()
        self._install_budget = 0
        self._reserved_frames = 0
        self._reserved_bits = 0.0
        self._density = (None if b["density"] is None
                         else float(b["density"]))
        self._live_bits = float(b["live_bits"])
        self.page_util_sketch = QuantileSketch.from_dict(
            b["page_util_sketch"])
        self.peak_page_utilization = float(b["peak_page_utilization"])

    # -- invariants / reporting ----------------------------------------------

    def step(self) -> None:
        super().step()
        self.alloc.check_invariants()
        self.table.check_invariants()

    def summary(self) -> dict:
        out = super().summary()
        out["paging"] = {
            "page_tokens": self.page_tokens,
            "num_pages": self.admission.num_pages,
            "logical_frames": self.alloc.capacity,
            "overcommit": self.overcommit,
            "prefix_cache": self.prefix_cache,
            "max_blocks": self.max_blocks,
            "peak_active": self.peak_active,
            "prefix_hits": self.table.prefix_hits,
            "cow_copies": self.table.cow_copies,
            "spills": self.sched.n_spills,
            "resumes": self.sched.n_resumes,
            "allocated_frames": self.alloc.n_allocated,
            "free_frames": self.alloc.n_free,
            "budget_bits": self.admission.budget_bits,
            "peak_page_utilization": self.peak_page_utilization,
            "page_utilization": self.page_util_sketch.percentiles(),
        }
        return out
