"""Block table: (request, block-range) -> page frames, with chain-hash
prefix sharing and copy-on-write.

One *logical block* covers ``page_tokens`` consecutive cache rows across
**every** paged leaf of every layer (DESIGN.md §12) — a single frame id
per block keeps the table one ``(n_slots, max_blocks)`` int32 array on
the device side, and prefix reuse naturally shares all layers at once
(position ``i``'s KV depends only on tokens ``<= i``, per layer).

Prefix sharing is a weak chain-hash index over *pure* blocks: a block is
registered under the hash chain of every prompt token it covers, so two
prompts sharing a prefix hit the same chain keys.  The final partial
block is keyed by the full prompt (content + fill count), so identical
prompts share even their partial tail and fork lazily on first decode
write.  Hash chains are tuples of ints — python hashes those
deterministically (no PYTHONHASHSEED dependence).

COW protocol: before any in-place write to block ``b`` of request ``r``,
call :meth:`ensure_writable`.  A frame with refcount > 1 is copied-on-
write (the caller rewrites the whole block from its assembled dense
cache, so "copy" is implicit in the full-page write-back); a frame with
refcount 1 is written in place, which *invalidates* its index entry —
its content no longer matches the registered hash.  Either way the
returned frame has refcount 1 and is referenced by no other request:
COW never aliases a written page.
"""

from __future__ import annotations

from typing import Optional

from repro.serving.paging.allocator import PageAllocator, PageError


def chain_keys(tokens, page_tokens: int, n_fill: int) -> list[tuple]:
    """Index keys for the blocks covering ``n_fill`` prompt rows: one
    ``("full", chain_hash)`` per complete block, plus one
    ``("partial", chain_hash, fill)`` for a trailing partial block."""
    toks = tuple(int(t) for t in tokens)
    keys: list[tuple] = []
    h = 0
    n_blocks = (n_fill + page_tokens - 1) // page_tokens
    for b in range(n_blocks):
        lo, hi = b * page_tokens, min((b + 1) * page_tokens, n_fill)
        h = hash((h, toks[lo:hi]))
        keys.append(("full", h) if hi - lo == page_tokens
                    else ("partial", h, hi - lo))
    return keys


class BlockTable:
    """Per-request frame lists over one shared :class:`PageAllocator`."""

    def __init__(self, allocator: PageAllocator, page_tokens: int,
                 prefix_cache: bool = True):
        if page_tokens < 1:
            raise PageError(f"page_tokens must be >= 1, got {page_tokens}")
        self.allocator = allocator
        self.page_tokens = page_tokens
        self.prefix_cache = prefix_cache
        self.blocks: dict[int, list[int]] = {}  # rid -> frames, block order
        self._index: dict[tuple, int] = {}      # chain key -> pure frame
        self._frame_key: dict[int, tuple] = {}  # inverse (weak: dies w/ frame)
        # counters surfaced as telemetry
        self.prefix_hits = 0
        self.cow_copies = 0

    # -- state views --------------------------------------------------------

    def frames_of(self, rid: int) -> list[int]:
        return list(self.blocks[rid])

    def n_blocks(self, rid: int) -> int:
        return len(self.blocks[rid])

    def shared_frames(self) -> set:
        """Frames referenced by more than one request."""
        return {f for frames in self.blocks.values() for f in frames
                if self.allocator.refcount(f) > 1}

    def check_invariants(self) -> None:
        refs: dict[int, int] = {}
        for frames in self.blocks.values():
            for f in frames:
                refs[f] = refs.get(f, 0) + 1
        for f, n in refs.items():
            assert self.allocator.refcount(f) == n, (
                f"frame {f}: allocator refcount {self.allocator.refcount(f)} "
                f"!= {n} table references")
        assert set(refs) == set(self.allocator.allocated_frames()), (
            "allocator/table frame sets diverged")
        for key, f in self._index.items():
            assert self._frame_key.get(f) == key, "index/inverse diverged"
            assert self.allocator.refcount(f) >= 1, "index holds freed frame"

    # -- request lifecycle --------------------------------------------------

    def open(self, rid: int) -> None:
        if rid in self.blocks:
            raise PageError(f"request {rid} already has a block table")
        self.blocks[rid] = []

    def plan_prompt(self, tokens, n_fill: int) -> list[Optional[int]]:
        """Sharing plan for a prompt covering ``n_fill`` rows: per block,
        the pure frame to adopt (prefix-cache hit) or None (must install).
        Read-only — admission gating calls this before committing."""
        keys = chain_keys(tokens, self.page_tokens, n_fill)
        if not self.prefix_cache:
            return [None] * len(keys)
        return [self._index.get(k) for k in keys]

    def append_block(self, rid: int, key: Optional[tuple] = None) -> int:
        """Allocate a fresh frame as the next block of ``rid``; register
        it under ``key`` (a pure prompt block) when prefix caching."""
        frame = self.allocator.alloc()
        self.blocks[rid].append(frame)
        if key is not None and self.prefix_cache and key not in self._index:
            self._index[key] = frame
            self._frame_key[frame] = key
        return frame

    def register(self, frame: int, key: tuple) -> None:
        """Index a frame whose *content* now matches ``key`` — called when
        the block's page bits are actually written (registering at
        allocation time would let another request adopt a frame whose
        install is still pending)."""
        if (self.prefix_cache and key not in self._index
                and frame not in self._frame_key):
            self._index[key] = frame
            self._frame_key[frame] = key

    def adopt_block(self, rid: int, frame: int) -> int:
        """Share an existing pure frame as the next block of ``rid``."""
        self.allocator.incref(frame)
        self.blocks[rid].append(frame)
        self.prefix_hits += 1
        return frame

    def ensure_writable(self, rid: int, block_idx: int) -> tuple[int, bool]:
        """Return ``(frame, cow)`` such that writing the whole block into
        ``frame`` is safe: no other request references it, and no stale
        index entry claims its content."""
        frames = self.blocks[rid]
        old = frames[block_idx]
        if self.allocator.refcount(old) > 1:
            new = self.allocator.alloc()  # caller rewrites the full page
            self.allocator.decref(old)
            frames[block_idx] = new
            self.cow_copies += 1
            return new, True
        self._invalidate(old)  # in-place write: content diverges from hash
        return old, False

    def grow(self, rid: int) -> int:
        """Append one fresh (private, unregistered) block — decode spilled
        past the last allocated block."""
        frame = self.allocator.alloc()
        self.blocks[rid].append(frame)
        return frame

    def truncate(self, rid: int, n_blocks: int) -> None:
        """Drop blocks past ``n_blocks`` (rollback for a partially-grown
        request that is being preempted before its write landed)."""
        while len(self.blocks[rid]) > n_blocks:
            f = self.blocks[rid].pop()
            if self.allocator.decref(f) == 0:
                self._invalidate(f)

    def release(self, rid: int) -> list[int]:
        """Drop every block of ``rid``; returns the frames that became
        free.  Double release raises :class:`PageError`."""
        if rid not in self.blocks:
            raise PageError(f"double free: request {rid} has no block table "
                            f"(already released?)")
        freed = []
        for f in self.blocks.pop(rid):
            if self.allocator.decref(f) == 0:
                self._invalidate(f)
                freed.append(f)
        return freed

    def _invalidate(self, frame: int) -> None:
        key = self._frame_key.pop(frame, None)
        if key is not None:
            self._index.pop(key, None)
