"""Page-aware scheduler: FCFS admission gated by page feasibility, plus
the preempt/resume lifecycle the spill path needs.

Extends :class:`repro.serving.scheduler.SlotScheduler` — the base
invariants (no slot leak, no double-book, FCFS) still hold and are still
checked; the additions are

  * *gated* admission: a request is admitted only when the page gate
    accepts it, with strict head-of-line blocking (a blocked head stalls
    everything behind it — no small-request overtaking, so a large
    request can never starve);
  * *preemption*: a spilled request leaves its slot without retiring —
    its tokens-so-far and an opaque engine payload (the exact packed
    page bits) park in a resume queue that drains, oldest first, ahead
    of new admissions;
  * per-tick token recording for a *subset* of active slots (requests
    still installing pages don't decode this tick).

Like the base class: pure python, no jax, property-tested directly.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Any, Callable, Optional

from repro.serving.request import Request
from repro.serving.scheduler import RequestTracker, SlotScheduler


@dataclasses.dataclass
class SpilledRequest:
    """A preempted in-flight request: everything needed to resume it
    bit-identically (the engine owns the payload's meaning)."""

    req: Request
    tokens: list
    payload: Any  # engine-side: packed page bits + pos + next token


class PagedScheduler(SlotScheduler):
    """FCFS over slots *and* pages; preempted requests resume first."""

    def __init__(self, n_slots: int):
        super().__init__(n_slots)
        self._spilled: list[SpilledRequest] = []  # oldest (lowest rid) first
        self.n_spills = 0
        self.n_resumes = 0

    # -- state views --------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self._spilled) or super().has_work()

    @property
    def spilled(self) -> int:
        return len(self._spilled)

    # -- gated admission ----------------------------------------------------

    def admit_paged(
        self,
        can_resume: Callable[[SpilledRequest], bool],
        can_admit: Callable[[Request], bool],
    ) -> list[tuple[RequestTracker, Optional[SpilledRequest]]]:
        """Fill free slots: spilled requests first (oldest first), then
        the FCFS queue, each gated by the caller's page feasibility check.
        Head-of-line blocking is strict in both queues *and* across them:
        a blocked spilled head stalls new admissions too, so the spill
        path can never be starved by a stream of small requests."""
        out: list[tuple[RequestTracker, Optional[SpilledRequest]]] = []
        while self._free and self._spilled:
            if not can_resume(self._spilled[0]):
                return out
            spilled = self._spilled.pop(0)
            slot = self._free.pop(0)
            tracker = RequestTracker(spilled.req, slot)
            tracker.tokens = list(spilled.tokens)
            self.active[slot] = tracker
            self.n_resumes += 1
            # no admission_log append: the rid was logged when first
            # admitted (the FCFS seal tracks first admissions only)
            out.append((tracker, spilled))
        while self._free and self._queue:
            if not can_admit(self._queue[0]):
                return out
            slot = self._free.pop(0)
            req = self._queue.popleft()
            tracker = RequestTracker(req, slot)
            self.active[slot] = tracker
            self.admission_log.append(req.rid)
            out.append((tracker, None))
        return out

    # -- preemption ---------------------------------------------------------

    def preempt(self, slot: int, payload: Any) -> SpilledRequest:
        """Evict the request in ``slot`` without retiring it: the slot
        frees immediately, the request parks in the resume queue (kept in
        rid order — original FCFS order among spilled requests)."""
        tracker = self.active.pop(slot)
        bisect.insort(self._free, slot)
        spilled = SpilledRequest(req=tracker.req, tokens=list(tracker.tokens),
                                 payload=payload)
        bisect.insort(self._spilled, spilled, key=lambda s: s.req.rid)
        self.n_spills += 1
        return spilled

    # -- decode-tick token recording ---------------------------------------

    def record_tokens(self, token_by_slot: dict) -> list[RequestTracker]:
        """Like the base class, but only for the slots present in
        ``token_by_slot`` — slots still installing prompt pages get no
        token this tick."""
        done = []
        for slot in sorted(token_by_slot):
            if self.active[slot].append(int(token_by_slot[slot])):
                done.append(self.retire(slot))
        return done
