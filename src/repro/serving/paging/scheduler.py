"""Page-aware scheduler: FCFS admission gated by page feasibility, plus
the preempt/resume lifecycle the spill path needs.

The machinery (gated admission with strict head-of-line blocking,
preemption into a resume queue, subset token recording) moved into the
base :class:`repro.serving.scheduler.SlotScheduler` when spring-survive
made it load-bearing for *both* backends (monolithic rescale/restore
spills too — DESIGN.md §13).  This subclass survives as the historical
name plus the ``admit_paged`` spelling the paged engine/tests use.
"""

from __future__ import annotations

from repro.serving.scheduler import (  # noqa: F401  (re-export)
    RequestTracker,
    ShedPolicy,
    SlotScheduler,
    SpilledRequest,
)


class PagedScheduler(SlotScheduler):
    """FCFS over slots *and* pages; preempted requests resume first."""

    #: historical spelling of the gated admission entry point
    admit_paged = SlotScheduler.admit_gated
