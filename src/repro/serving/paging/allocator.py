"""Free-list page-frame allocator with per-frame refcounts.

Pure python on purpose (like the scheduler): the hypothesis property
suite (tests/test_paging.py) drives thousands of alloc/share/release
streams against the invariants —

  * conservation: ``n_free + n_allocated == capacity`` always;
  * no leaks: refcounts hit zero exactly at release, and a frame whose
    refcount reaches zero is immediately reusable;
  * no double-free: ``decref`` on a free frame raises :class:`PageError`
    instead of silently corrupting the free list;

— while the engine drives the same object per tick.

Two frame ids below :data:`PageAllocator.RESERVED` never enter the free
list:

  frame 0  the permanent *null page* (all-zero packed content).  Block
           table entries beyond a request's allocated blocks point here,
           so a gather of the full (slot, max_blocks) frame table
           reconstructs exactly the zero tail a monolithic pool slot
           carries.
  frame 1  the *scratch sink*: inactive slots' decode write-back lands
           here.  Never referenced by any block table and excluded from
           wire accounting, so garbage writes are invisible.
"""

from __future__ import annotations

import bisect


class PageError(ValueError):
    """Page accounting violation (double free, unknown frame, exhaustion)."""


class PageAllocator:
    """Fixed pool of page frames; lowest-free-first allocation so the
    engine's frame choices are deterministic for a given request stream."""

    #: frames below this id are the null page / scratch sink (see module doc)
    RESERVED = 2

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise PageError(f"page capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._free: list[int] = list(range(self.RESERVED,
                                           self.RESERVED + capacity))
        self._ref: dict[int, int] = {}

    # -- state views --------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._ref)

    def allocated_frames(self) -> list[int]:
        return sorted(self._ref)

    def refcount(self, frame: int) -> int:
        return self._ref.get(frame, 0)

    def check_invariants(self) -> None:
        assert self.n_free + self.n_allocated == self.capacity, (
            f"frame leak: {self.n_free} free + {self.n_allocated} allocated "
            f"!= {self.capacity}")
        assert set(self._free).isdisjoint(self._ref), "frame double-booked"
        assert all(r >= 1 for r in self._ref.values()), "zombie refcount"

    # -- lifecycle ----------------------------------------------------------

    def alloc(self) -> int:
        """Claim the lowest free frame with refcount 1."""
        if not self._free:
            raise PageError(f"out of pages: all {self.capacity} frames live")
        frame = self._free.pop(0)
        self._ref[frame] = 1
        return frame

    def try_alloc(self):
        """``alloc`` that returns None instead of raising on exhaustion."""
        return self.alloc() if self._free else None

    def incref(self, frame: int) -> int:
        if frame not in self._ref:
            raise PageError(f"incref on unallocated frame {frame}")
        self._ref[frame] += 1
        return self._ref[frame]

    def decref(self, frame: int) -> int:
        """Drop one reference; at zero the frame returns to the free list.
        Returns the remaining refcount (0 = freed)."""
        if frame not in self._ref:
            raise PageError(
                f"double free: frame {frame} is not allocated (released "
                f"twice, or never allocated)")
        self._ref[frame] -= 1
        if self._ref[frame] == 0:
            del self._ref[frame]
            bisect.insort(self._free, frame)
            return 0
        return self._ref[frame]
