"""spring-pages: paged, copy-on-write KV pool with density-aware
admission control (DESIGN.md §12).

Layers, host side first:

  allocator   free-list frame allocator + per-frame refcounts
  blocktable  (request, block) -> frame mapping, chain-hash prefix
              sharing, copy-on-write forks
  admission   density-aware byte budget (20*d + 1 bits/elem pages)
  scheduler   FCFS admission gated on page feasibility; spill/resume
  store       packed page arrays + the jit-able gather/scatter programs
  engine      PagedServingEngine: the serving engine on pages
"""

from repro.serving.paging.admission import AdmissionController
from repro.serving.paging.allocator import PageAllocator, PageError
from repro.serving.paging.blocktable import BlockTable, chain_keys
from repro.serving.paging.engine import PagedServingEngine
from repro.serving.paging.scheduler import PagedScheduler, SpilledRequest
from repro.serving.paging.store import PagedKVStore, prompt_rows

__all__ = [
    "AdmissionController",
    "BlockTable",
    "PageAllocator",
    "PageError",
    "PagedKVStore",
    "PagedScheduler",
    "PagedServingEngine",
    "SpilledRequest",
    "chain_keys",
    "prompt_rows",
]
