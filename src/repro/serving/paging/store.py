"""Packed page store: the device-side half of spring-pages.

Every *pageable* cache leaf (full-attention ``k``/``v``, MLA ``ckv``/
``krope`` — token-indexed content whose row ``i`` depends only on tokens
``<= i``) is stored as fixed-size pages of ``page_tokens`` consecutive
cache rows, binary-mask packed per page via the ``kv_pack`` registry op:

  values  (*lead, n_frames, page_elems)   leaf dtype, nonzeros front-packed
  mask    (*lead, n_frames, n_words)      uint32 occupancy bits
  nnz     (*lead, n_frames)               int32

One logical frame id addresses the same page slot across all leaves and
layers, so the whole mapping is one ``(n_slots, max_blocks)`` int32
frame table.  Everything else — sliding-window rings, O(1) ssm/conv/
rglru state, int8 mirror caches, the per-slot ``pos`` vector — is *slot
state*: it lives in a dense slot-indexed tree exactly like the
monolithic pool's non-packed leaves (``strip`` leaves ``None`` holes
where the paged leaves go; ``assemble`` fills them back in).

The decode tick is gather -> compute -> scatter: ``assemble`` unpacks
the referenced frames into the dense working cache the unchanged decode
step eats (frame 0 = null page supplies the zero tail, so the working
cache is bit-identical to a monolithic pool slot), and ``writeback``
re-packs exactly one page per slot — the only page a decode step can
touch — into its frame.  ``kv_pack``/``kv_unpack`` round-trip bit-
exactly, so pages preserve KV bits through any number of ticks, shares,
spills and resumes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masking import MASK_WORD_BITS
from repro.kernels import registry
from repro.kernels.kv_cache.ops import KV_VALUE_BITS, _n_words
from repro.serving.kvpool import PACKED_SEQ_AXIS

#: cache leaf kinds stored paged: token-indexed content.  Rings are
#: fixed-size per-slot windows (position-independent storage) and stay
#: slot state, like the O(1) ssm/conv/rglru leaves.
PAGED_LEAVES = ("k", "v", "ckv", "krope")


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static layout of one paged cache leaf."""

    key: str          # stable id, path keys joined with "/"
    keys: tuple       # raw path keys into the cache tree
    dtype: jnp.dtype
    lead: tuple       # dims before the slot axis (layer group for units)
    tail: tuple       # dims after the seq axis (heads, head_dim, ...)
    elems: int        # page_tokens * prod(tail): packed block length
    words: int        # mask words per page

    @property
    def lead_n(self) -> int:
        return len(self.lead)

    @property
    def lead_prod(self) -> int:
        return int(math.prod(self.lead)) if self.lead else 1


def _path_keys(path) -> tuple:
    return tuple(getattr(p, "key", getattr(p, "idx", None)) for p in path)


def _get(tree, keys):
    node = tree
    for k in keys:
        node = node[k]
    return node


def _set(tree, keys, value) -> None:
    node = tree
    for k in keys[:-1]:
        node = node[k]
    node[keys[-1]] = value


def _clone(tree):
    """Structure-deep copy (dicts/tuples/lists), leaves by reference, so
    ``_set`` on the clone never aliases the input tree."""
    if isinstance(tree, dict):
        return {k: _clone(v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(_clone(v) for v in tree)
    return tree


def prompt_rows(cfg, prompt_len: int) -> int:
    """Cache rows a prefill fills: the prompt plus any VLM image prefix."""
    return prompt_len + (getattr(cfg, "vlm_prefix_len", 0) or 0)


class PagedKVStore:
    """Layout + jit-able programs over the packed page arrays."""

    def __init__(self, cfg, n_slots: int, page_tokens: int, max_blocks: int,
                 n_frames: int, dtype=jnp.bfloat16,
                 pack_impl: Optional[str] = None,
                 unpack_impl: Optional[str] = None):
        from repro.models.lm import lm_init_cache

        self.cfg = cfg
        self.n_slots = n_slots
        self.page_tokens = page_tokens
        self.max_blocks = max_blocks
        self.n_frames = n_frames
        self.tokens_cap = max_blocks * page_tokens  # working-cache seq len
        self.dtype = jnp.dtype(dtype)
        self._pack_impl = pack_impl
        self._unpack_impl = unpack_impl

        template = jax.eval_shape(
            lambda: lm_init_cache(cfg, n_slots, self.tokens_cap, dtype))
        self.leaves: dict[str, LeafSpec] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
            keys = _path_keys(path)
            name = str(keys[-1]) if keys else ""
            if name not in PAGED_LEAVES:
                continue
            slot_ax = 1 if str(keys[0]).startswith("unit_") else 0
            seq_ax = leaf.ndim + PACKED_SEQ_AXIS[name]
            assert seq_ax == slot_ax + 1, (keys, leaf.shape)
            tail = tuple(leaf.shape[seq_ax + 1:])
            elems = page_tokens * int(math.prod(tail)) if tail else page_tokens
            spec = LeafSpec(
                key="/".join(str(k) for k in keys), keys=keys,
                dtype=jnp.dtype(leaf.dtype), lead=tuple(leaf.shape[:slot_ax]),
                tail=tail, elems=elems, words=_n_words(elems))
            self.leaves[spec.key] = spec
        assert self.leaves, f"{cfg.name}: no pageable cache leaves"

        #: dense elems / stored mask bits of one logical page, summed over
        #: every paged leaf — the admission controller's page unit
        self.page_elems = sum(s.lead_prod * s.elems
                              for s in self.leaves.values())
        self.page_mask_bits = sum(s.lead_prod * s.words * MASK_WORD_BITS
                                  for s in self.leaves.values())
        self.page_dense_fp32_bytes = self.page_elems * 4.0

    # -- array construction --------------------------------------------------

    def init_arrays(self) -> dict:
        """All-zero page arrays (frame 0 stays all-zero forever: the null
        page unallocated table entries gather)."""
        out = {}
        for key, s in self.leaves.items():
            out[key] = {
                "values": jnp.zeros((*s.lead, self.n_frames, s.elems), s.dtype),
                "mask": jnp.zeros((*s.lead, self.n_frames, s.words), jnp.uint32),
                "nnz": jnp.zeros((*s.lead, self.n_frames), jnp.int32),
            }
        return out

    def init_state(self) -> dict:
        """Dense slot-state tree: the full cache with paged leaves
        stripped to ``None`` holes and a per-slot position vector."""
        from repro.models.lm import lm_init_cache

        state = lm_init_cache(self.cfg, self.n_slots, self.tokens_cap,
                              self.dtype)
        state["pos"] = jnp.zeros((self.n_slots,), jnp.int32)
        return self.strip(state)

    def strip(self, cache: dict) -> dict:
        """Replace every paged leaf with ``None`` (jax treats None as an
        empty subtree, so the result jits as the slot-state pytree)."""
        out = _clone(cache)
        for s in self.leaves.values():
            _set(out, s.keys, None)
        return out

    # -- gather: pages -> dense working cache --------------------------------

    def assemble(self, store: dict, state: dict, table) -> dict:
        """Reconstruct the dense cache tree: gather each slot's frames
        (``table``: (n_slots, max_blocks) int32) and unpack them into
        contiguous rows.  Unallocated blocks gather frame 0 — exact
        zeros, the same tail a monolithic pool slot carries — and rows
        past ``pos`` are masked out of attention by the decode step's
        validity masks, so the assembled cache decodes bit-identically
        to the monolithic pool."""
        unpack = registry.resolve("kv_unpack", self._unpack_impl).fn
        cache = _clone(state)
        for key, s in self.leaves.items():
            g_v = jnp.take(store[key]["values"], table, axis=s.lead_n)
            g_m = jnp.take(store[key]["mask"], table, axis=s.lead_n)
            dense = jax.vmap(lambda v, m: unpack(v, m, length=s.elems))(
                g_v.reshape(-1, s.elems), g_m.reshape(-1, s.words))
            dense = dense.reshape(*s.lead, self.n_slots, self.tokens_cap,
                                  *s.tail)
            _set(cache, s.keys, dense.astype(s.dtype))
        return cache

    # -- scatter: one page per row back into frames --------------------------

    def _write_page(self, arrays: dict, dense, s: LeafSpec, slot, tok0,
                    frame, pack) -> dict:
        """Pack the ``page_tokens`` rows at ``tok0`` of ``slot`` and
        store them in ``frame`` (all three scalars may be traced)."""
        starts = (0,) * s.lead_n + (slot, tok0) + (0,) * len(s.tail)
        sizes = s.lead + (1, self.page_tokens) + s.tail
        block = jax.lax.dynamic_slice(dense, starts, sizes)
        packed = jax.vmap(pack)(block.reshape(-1, s.elems))
        fstarts = (0,) * s.lead_n + (frame, 0)
        return {
            "values": jax.lax.dynamic_update_slice(
                arrays["values"],
                packed["values"].reshape(*s.lead, 1, s.elems).astype(
                    arrays["values"].dtype), fstarts),
            "mask": jax.lax.dynamic_update_slice(
                arrays["mask"], packed["mask"].reshape(*s.lead, 1, s.words),
                fstarts),
            "nnz": jax.lax.dynamic_update_slice(
                arrays["nnz"],
                packed["nnz"].reshape(*s.lead, 1).astype(jnp.int32),
                fstarts[:-1]),
        }

    def writeback(self, store: dict, cache: dict, write_frame,
                  write_block) -> dict:
        """Per slot, re-pack the one page its decode step wrote (block
        ``write_block[slot]`` into frame ``write_frame[slot]``).  The
        engine routes inactive slots' frames to the scratch sink, so
        their garbage never lands in a live frame."""
        pack = registry.resolve("kv_pack", self._pack_impl).fn
        new = {k: dict(v) for k, v in store.items()}
        for key, s in self.leaves.items():
            dense = _get(cache, s.keys)
            for slot in range(self.n_slots):
                new[key] = self._write_page(
                    new[key], dense, s, slot,
                    write_block[slot] * self.page_tokens, write_frame[slot],
                    pack)
        return new

    # -- chunked prefill install ---------------------------------------------

    def pad_prefill(self, pcache: dict) -> dict:
        """Extract a batch-1 prefill cache's paged leaves, zero-padded to
        the working seq length so any block can be sliced (compiled per
        prompt length, like the prefill program itself).  Returns a flat
        ``{leaf key: dense leaf}`` dict — the only part of the prefill
        cache the chunked page installer needs to keep alive."""
        out = {}
        for key, s in self.leaves.items():
            leaf = _get(pcache, s.keys)
            seq_ax = s.lead_n + 1  # batch(=1) axis sits at lead_n
            extra = self.tokens_cap - leaf.shape[seq_ax]
            assert extra >= 0, (
                f"{key}: prefill length {leaf.shape[seq_ax]} exceeds page "
                f"capacity {self.tokens_cap}")
            if extra:
                pads = [(0, 0)] * leaf.ndim
                pads[seq_ax] = (0, extra)
                leaf = jnp.pad(leaf, pads)
            out[key] = leaf
        return out

    def install_block(self, store: dict, pcache_pages: dict, block_idx,
                      frame) -> dict:
        """Write one prompt block (all leaves) of a padded prefill
        (:meth:`pad_prefill` output) into ``frame`` — the unit of chunked
        prefill; the engine spreads a long prompt's blocks over ticks."""
        pack = registry.resolve("kv_pack", self._pack_impl).fn
        new = {k: dict(v) for k, v in store.items()}
        for key, s in self.leaves.items():
            new[key] = self._write_page(
                new[key], pcache_pages[key], s, 0,
                block_idx * self.page_tokens, frame, pack)
        return new

    # -- spill / resume -------------------------------------------------------

    def extract_frame(self, store: dict, frame) -> dict:
        """One frame's exact packed bits (for host-side spill storage)."""
        out = {}
        for key, s in self.leaves.items():
            starts = (0,) * s.lead_n + (frame,)
            out[key] = {
                "values": jax.lax.dynamic_slice(
                    store[key]["values"], starts + (0,),
                    s.lead + (1, s.elems)).reshape(*s.lead, s.elems),
                "mask": jax.lax.dynamic_slice(
                    store[key]["mask"], starts + (0,),
                    s.lead + (1, s.words)).reshape(*s.lead, s.words),
                "nnz": jax.lax.dynamic_slice(
                    store[key]["nnz"], starts,
                    s.lead + (1,)).reshape(s.lead),
            }
        return out

    def restore_frame(self, store: dict, payload: dict, frame) -> dict:
        """Inverse of :meth:`extract_frame`: bit-exact resume."""
        new = {k: dict(v) for k, v in store.items()}
        for key, s in self.leaves.items():
            p = payload[key]
            starts = (0,) * s.lead_n + (frame,)
            new[key] = {
                "values": jax.lax.dynamic_update_slice(
                    new[key]["values"],
                    jnp.asarray(p["values"]).reshape(*s.lead, 1, s.elems),
                    starts + (0,)),
                "mask": jax.lax.dynamic_update_slice(
                    new[key]["mask"],
                    jnp.asarray(p["mask"]).reshape(*s.lead, 1, s.words),
                    starts + (0,)),
                "nnz": jax.lax.dynamic_update_slice(
                    new[key]["nnz"],
                    jnp.asarray(p["nnz"]).reshape(*s.lead, 1), starts),
            }
        return new

    # -- wire accounting ------------------------------------------------------

    def live_nnz(self, store: dict, alloc_mask) -> jax.Array:
        """Total nonzeros across allocated frames (``alloc_mask``:
        (n_frames,) 0/1 float32) — the one device reduction behind the
        per-tick density/wire stats."""
        acc = jnp.zeros((), jnp.float32)
        for key in self.leaves:
            acc = acc + jnp.sum(store[key]["nnz"].astype(jnp.float32)
                                * alloc_mask)
        return acc

    def wire_stats(self, nnz_total: float, n_allocated: int,
                   num_pages: int, value_bits: int = KV_VALUE_BITS) -> dict:
        """Same surface as ``kvpool.pool_wire_stats`` computed over
        allocated frames, with the dense-fp32 baseline taken at the
        *physical* budget (``num_pages`` dense pages — what a dense
        allocator would keep resident)."""
        elems = n_allocated * self.page_elems
        mask_bits = n_allocated * self.page_mask_bits
        wire_bits = nnz_total * value_bits + mask_bits
        wire_bytes = wire_bits / 8.0
        dense_fp32 = num_pages * self.page_dense_fp32_bytes
        return {
            "kv_elems": float(elems),
            "kv_nnz": float(nnz_total),
            "kv_density": nnz_total / elems if elems else 0.0,
            "kv_wire_bytes": wire_bytes,
            "kv_logical_bytes": float(
                n_allocated * self.page_elems * self.dtype.itemsize),
            "kv_dense_fp32_bytes": dense_fp32,
            "kv_compression_vs_fp32": (dense_fp32 / wire_bytes
                                       if wire_bytes else 0.0),
        }
