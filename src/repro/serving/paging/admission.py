"""Density-aware admission control: overcommit logical pages against
measured packed bits.

The physical budget is ``num_pages`` *dense* pages of SPRING wire
storage — exactly what a dense allocator would hand the pool.  A packed
page at density ``d`` costs ``20*d + 1`` bits/elem (values at the 20-bit
storage width + 1 occupancy bit, the memstash/kvpool formula), so the
same physical bytes hold ``~ (20 + 1) / (20*d + 1)`` packed pages: at
the natural half-full occupancy of a rolling decode pool that is ~2x
the dense page count.  Admission projects a candidate's page cost at the
pool's *measured* density (conservative 1.0 while the pool is empty) and
admits while the projection fits the budget; the logical frame pool is
capped at ``ceil(num_pages * overcommit)`` so the block tables stay
bounded however sparse the traffic.

When density spikes (pages fill in, projections go stale), live bits can
exceed the budget: the engine's defined spill path preempts the most
recently admitted requests — their exact packed bits move to host memory
— until the pool fits again.  `tests/test_paging.py` seals that after a
spill the resident set never exceeds the physical budget, and that
spilled requests resume bit-identically.
"""

from __future__ import annotations

from repro.core.masking import MASK_WORD_BITS
from repro.kernels.kv_cache.ops import KV_VALUE_BITS


class AdmissionController:
    """Byte-budget arithmetic; pure, stateless between calls."""

    def __init__(self, page_elems: int, page_mask_bits: int, num_pages: int,
                 value_bits: int = KV_VALUE_BITS):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.page_elems = page_elems          # dense elems per page, all leaves
        self.page_mask_bits = page_mask_bits  # stored mask words per page
        self.num_pages = num_pages
        self.value_bits = value_bits
        #: the physical allocation: num_pages fully-dense packed pages
        self.budget_bits = num_pages * self.page_bits(1.0)

    def page_bits(self, density: float) -> float:
        """Wire bits of one packed page at ``density`` (20*d + 1 form:
        values at the storage width + the mask words actually stored)."""
        return self.page_elems * self.value_bits * density + self.page_mask_bits

    def projected_bits(self, live_bits: float, n_new_pages: int,
                       density: float) -> float:
        return live_bits + n_new_pages * self.page_bits(density)

    def admits(self, live_bits: float, n_new_pages: int,
               density: float) -> bool:
        """Admit iff the candidate's pages, costed at the measured pool
        density, still fit the physical budget."""
        return (self.projected_bits(live_bits, n_new_pages, density)
                <= self.budget_bits)

    def admits_exact(self, live_bits: float, exact_bits: float) -> bool:
        """Resume-path gate: a spilled request's packed bits are known
        exactly, no density projection needed."""
        return live_bits + exact_bits <= self.budget_bits

    def over_budget(self, live_bits: float) -> bool:
        return live_bits > self.budget_bits

    def utilization(self, live_bits: float) -> float:
        return live_bits / self.budget_bits if self.budget_bits else 0.0


def mask_word_bits(n_words: int) -> int:
    return n_words * MASK_WORD_BITS
