"""spring-serve: continuous-batching inference engine with a
sparsity-compressed KV cache (DESIGN.md §9).

  request    Request / RequestResult — the unit of serving work
  scheduler  FCFS slot admission + request lifecycle (pure python,
             property-tested without jax)
  kvpool     slot-indexed persistent KV cache, seq-bearing leaves stored
             binary-mask packed via the kv_pack/kv_unpack registry ops
  steps      prefill/decode step builders shared with the launchers
  engine     ServingEngine — joins the scheduler to the jitted steps
  paging     spring-pages: paged, copy-on-write KV pool with
             density-aware admission control (DESIGN.md §12); the
             engine serves on it when ``serving.pages`` is set
"""

from repro.serving.request import Request, RequestResult  # noqa: F401
from repro.serving.scheduler import RequestTracker, SlotScheduler  # noqa: F401
