"""FCFS slot admission + request lifecycle.

Model-agnostic on purpose: the scheduler never touches jax, so the
hypothesis property suite (tests/test_serving_scheduler.py) can drive
thousands of arrival/length streams against the invariants —

  * no slot leaks: every admitted request returns its slot on retirement,
    and ``len(active) + len(free) == n_slots`` at every tick;
  * no starvation: admission order is exactly submission order (FCFS);
  * exact completion: a request retires with ``min(steps-to-eos,
    max_tokens)`` tokens, never more, never fewer;

— while the engine drives the same object with real jitted steps.
"""

from __future__ import annotations

import bisect
import collections
from typing import Optional

from repro.serving.request import Request


class RequestTracker:
    """One in-flight request: its slot, emitted tokens, finish rule."""

    def __init__(self, req: Request, slot: int):
        self.req = req
        self.slot = slot
        self.tokens: list = []
        self.finished_by: Optional[str] = None

    @property
    def finished(self) -> bool:
        return self.finished_by is not None

    def append(self, tok: int) -> bool:
        """Record one emitted token; returns True when the request is done
        (EOS emitted — included in the output — or max_tokens reached)."""
        assert not self.finished, f"request {self.req.rid} already finished"
        self.tokens.append(tok)
        if self.req.eos_id is not None and tok == self.req.eos_id:
            self.finished_by = "eos"
        elif len(self.tokens) >= self.req.max_tokens:
            self.finished_by = "max_tokens"
        return self.finished


class SlotScheduler:
    """Fixed slot pool + FCFS queue; requests join mid-flight and retire
    independently, freed slots refill from the queue on the next tick."""

    def __init__(self, n_slots: int):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free: list[int] = list(range(n_slots))  # kept sorted
        self._queue: collections.deque[Request] = collections.deque()
        self.active: dict[int, RequestTracker] = {}
        #: rids in admission order (the FCFS seal)
        self.admission_log: list[int] = []
        self._submit_log: list[int] = []

    # -- state views --------------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def occupancy(self) -> float:
        return len(self.active) / self.n_slots

    def has_work(self) -> bool:
        return bool(self._queue or self.active)

    def check_invariants(self) -> None:
        assert len(self.active) + len(self._free) == self.n_slots, (
            f"slot leak: {len(self.active)} active + {len(self._free)} free "
            f"!= {self.n_slots}")
        assert set(self._free).isdisjoint(self.active), "slot double-booked"
        assert self.admission_log == self._submit_log[: len(self.admission_log)], (
            "FCFS violated: admissions diverged from submission order")

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self._queue.append(req)
        self._submit_log.append(req.rid)

    def admit(self) -> list[RequestTracker]:
        """Pop FCFS into free slots (lowest slot first, deterministic)."""
        out = []
        while self._free and self._queue:
            slot = self._free.pop(0)
            req = self._queue.popleft()
            tracker = RequestTracker(req, slot)
            self.active[slot] = tracker
            self.admission_log.append(req.rid)
            out.append(tracker)
        return out

    def retire(self, slot: int) -> RequestTracker:
        tracker = self.active.pop(slot)
        bisect.insort(self._free, slot)
        return tracker

    def record_tokens(self, token_by_slot: dict) -> list[RequestTracker]:
        """Append one decode tick's token per active slot; retire and
        return the trackers that finished on this tick."""
        done = []
        for slot in sorted(self.active):
            if self.active[slot].append(int(token_by_slot[slot])):
                done.append(self.retire(slot))
        return done
