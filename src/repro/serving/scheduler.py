"""FCFS slot admission + request lifecycle, with elastic extensions.

Model-agnostic on purpose: the scheduler never touches jax, so the
hypothesis property suites (tests/test_serving_scheduler.py,
tests/test_elastic.py) can drive thousands of arrival/length streams
against the invariants —

  * no slot leaks: every admitted request returns its slot on retirement,
    and ``len(active) + len(free) == n_slots`` at every tick;
  * no starvation: admission order is exactly submission order (FCFS) —
    unless a :class:`ShedPolicy` explicitly reorders by priority/deadline;
  * exact completion: a request retires with ``min(steps-to-eos,
    max_tokens)`` tokens, never more, never fewer;
  * no silent loss: every submitted request ends either completed or
    typed-rejected (``"queue_full"`` at submit, ``"deadline"`` at shed) —
    the spring-survive seal;

— while the engine drives the same object with real jitted steps.

spring-survive additions (DESIGN.md §13):

  * *preemption*: a spilled request leaves its slot without retiring —
    its tokens-so-far and an opaque engine payload (the exact packed KV
    bits) park in a resume queue that drains, highest priority first
    (rid order within a class), ahead of new admissions;
  * *gated* admission (:meth:`admit_gated`): spilled requests resume
    first, then the queue, each gated by a caller feasibility check with
    strict head-of-line blocking;
  * *load shedding*: queue-depth rejection at submit, admission-deadline
    expiry at tick boundaries, both returning typed reasons;
  * *rescaling*: :meth:`rescale` re-sizes the slot pool of a drained
    (all-spilled) scheduler without touching queue/spill/log state.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
from typing import Any, Callable, Optional

from repro.serving.request import Request

#: typed rejection reasons (the only ways a request is ever refused)
REJECT_QUEUE_FULL = "queue_full"
REJECT_DEADLINE = "deadline"


@dataclasses.dataclass(frozen=True)
class ShedPolicy:
    """Load-shedding + SLO-aware admission knobs (all off by default).

    ``max_queue_depth``   submit-time shed: a request arriving at a full
                          queue is rejected with ``"queue_full"``.
    ``deadline_ticks``    admission deadline: a request still queued
                          ``deadline_ticks`` ticks after submission is
                          shed with ``"deadline"`` (per-request
                          ``Request.deadline_ticks`` overrides this).
    ``deadline_aware``    EDF variant of FCFS: admission pops the queued
                          request with the earliest absolute deadline
                          (FCFS among equal/absent deadlines).
    ``priority_aware``    admission pops the highest ``Request.priority``
                          first (FCFS within a class).
    """

    max_queue_depth: Optional[int] = None
    deadline_ticks: Optional[int] = None
    deadline_aware: bool = False
    priority_aware: bool = False

    def __post_init__(self):
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth}")
        if self.deadline_ticks is not None and self.deadline_ticks < 0:
            raise ValueError(
                f"deadline_ticks must be >= 0, got {self.deadline_ticks}")

    @property
    def reorders(self) -> bool:
        """True when admission order may diverge from submission order
        (the FCFS seal is then checked per-class instead of globally)."""
        return self.deadline_aware or self.priority_aware


@dataclasses.dataclass
class SpilledRequest:
    """A preempted in-flight request: everything needed to resume it
    bit-identically (the engine owns the payload's meaning)."""

    req: Request
    tokens: list
    payload: Any  # engine-side: exact packed KV bits + pos + next token


class RequestTracker:
    """One in-flight request: its slot, emitted tokens, finish rule."""

    def __init__(self, req: Request, slot: int):
        self.req = req
        self.slot = slot
        self.tokens: list = []
        self.finished_by: Optional[str] = None

    @property
    def finished(self) -> bool:
        return self.finished_by is not None

    def append(self, tok: int) -> bool:
        """Record one emitted token; returns True when the request is done
        (EOS emitted — included in the output — or max_tokens reached)."""
        assert not self.finished, f"request {self.req.rid} already finished"
        self.tokens.append(tok)
        if self.req.eos_id is not None and tok == self.req.eos_id:
            self.finished_by = "eos"
        elif len(self.tokens) >= self.req.max_tokens:
            self.finished_by = "max_tokens"
        return self.finished


class SlotScheduler:
    """Fixed slot pool + FCFS queue; requests join mid-flight and retire
    independently, freed slots refill from the queue on the next tick.
    With a :class:`ShedPolicy`, admission may shed (queue depth /
    deadlines) and reorder (priority / EDF); without one the behavior is
    byte-for-byte the historical FCFS scheduler."""

    def __init__(self, n_slots: int, policy: Optional[ShedPolicy] = None):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.policy = policy
        self._free: list[int] = list(range(n_slots))  # kept sorted
        self._queue: collections.deque[Request] = collections.deque()
        #: rid -> (enqueue tick, absolute deadline tick or None)
        self._queue_meta: dict[int, tuple[int, Optional[int]]] = {}
        self.active: dict[int, RequestTracker] = {}
        #: rids in admission order (the FCFS seal)
        self.admission_log: list[int] = []
        self._submit_log: list[int] = []
        #: (rid, reason) for every typed rejection, submission order
        self.shed_log: list[tuple[int, str]] = []
        #: preempted requests, highest priority first (rid order within)
        self._spilled: list[SpilledRequest] = []
        self.n_spills = 0
        self.n_resumes = 0

    # -- state views --------------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def spilled(self) -> int:
        return len(self._spilled)

    @property
    def occupancy(self) -> float:
        return len(self.active) / self.n_slots

    def has_work(self) -> bool:
        return bool(self._queue or self.active or self._spilled)

    def check_invariants(self) -> None:
        assert len(self.active) + len(self._free) == self.n_slots, (
            f"slot leak: {len(self.active)} active + {len(self._free)} free "
            f"!= {self.n_slots}")
        assert set(self._free).isdisjoint(self.active), "slot double-booked"
        if self.policy is None or not self.policy.reorders:
            # FCFS seal: admission order is submission order with the
            # typed-rejected rids removed (shedding skips, never reorders)
            shed = {rid for rid, _ in self.shed_log}
            expect = [r for r in self._submit_log if r not in shed]
            assert self.admission_log == expect[:len(self.admission_log)], (
                "FCFS violated: admissions diverged from submission order")
        # conservation: every submitted rid is queued, active, spilled,
        # admitted (possibly retired) or typed-rejected — never lost
        seen = (set(self._queue_meta)
                | {t.req.rid for t in self.active.values()}
                | {s.req.rid for s in self._spilled}
                | set(self.admission_log)
                | {rid for rid, _ in self.shed_log})
        assert set(self._submit_log) <= seen, (
            f"request lost silently: {set(self._submit_log) - seen}")

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request, tick: int = 0) -> Optional[str]:
        """Enqueue ``req``; returns a typed rejection reason (and records
        it in ``shed_log``) instead of queueing when the policy sheds."""
        self._submit_log.append(req.rid)
        pol = self.policy
        if (pol is not None and pol.max_queue_depth is not None
                and len(self._queue) >= pol.max_queue_depth):
            self.shed_log.append((req.rid, REJECT_QUEUE_FULL))
            return REJECT_QUEUE_FULL
        deadline = None
        rel = req.deadline_ticks if req.deadline_ticks is not None else (
            pol.deadline_ticks if pol is not None else None)
        if rel is not None:
            deadline = tick + rel
        self._queue.append(req)
        self._queue_meta[req.rid] = (tick, deadline)
        return None

    def shed_expired(self, tick: int) -> list[tuple[Request, str]]:
        """Drop queued requests whose admission deadline passed before
        ``tick``; returns ``(request, reason)`` pairs (reason is always
        ``"deadline"``) so the engine can record typed rejections."""
        shed = []
        kept: collections.deque[Request] = collections.deque()
        for req in self._queue:
            _, deadline = self._queue_meta[req.rid]
            if deadline is not None and tick > deadline:
                del self._queue_meta[req.rid]
                self.shed_log.append((req.rid, REJECT_DEADLINE))
                shed.append((req, REJECT_DEADLINE))
            else:
                kept.append(req)
        self._queue = kept
        return shed

    # -- admission ordering (policy-aware) -----------------------------------

    def _next_index(self) -> int:
        """Queue index of the next admission: FIFO head unless the policy
        reorders, then (priority desc, deadline asc, submission order)."""
        pol = self.policy
        if pol is None or not pol.reorders:
            return 0

        def key(pair):
            idx, req = pair
            prio = -req.priority if pol.priority_aware else 0
            if pol.deadline_aware:
                _, deadline = self._queue_meta[req.rid]
                dl = deadline if deadline is not None else float("inf")
            else:
                dl = 0
            return (prio, dl, idx)  # idx: FCFS within a class

        return min(enumerate(self._queue), key=key)[0]

    def _peek_next(self) -> Request:
        return self._queue[self._next_index()]

    def _pop_next(self) -> Request:
        idx = self._next_index()
        req = self._queue[idx]
        del self._queue[idx]
        del self._queue_meta[req.rid]
        return req

    def admit(self) -> list[RequestTracker]:
        """Pop into free slots (lowest slot first, deterministic); policy
        order (FCFS by default).  Ungated form — engines with spill or
        feasibility gates use :meth:`admit_gated`."""
        assert not self._spilled, (
            "spilled requests pending: use admit_gated so they resume first")
        return [t for t, _ in self.admit_gated(lambda s: True, lambda r: True)]

    def admit_gated(
        self,
        can_resume: Callable[[SpilledRequest], bool],
        can_admit: Callable[[Request], bool],
    ) -> list[tuple[RequestTracker, Optional[SpilledRequest]]]:
        """Fill free slots: spilled requests first (highest priority,
        then oldest), then the queue in policy order, each gated by the
        caller's feasibility check.  Head-of-line blocking is strict in
        both queues *and* across them: a blocked spilled head stalls new
        admissions too, so the spill path can never be starved by a
        stream of small requests."""
        out: list[tuple[RequestTracker, Optional[SpilledRequest]]] = []
        while self._free and self._spilled:
            if not can_resume(self._spilled[0]):
                return out
            spilled = self._spilled.pop(0)
            slot = self._free.pop(0)
            tracker = RequestTracker(spilled.req, slot)
            tracker.tokens = list(spilled.tokens)
            self.active[slot] = tracker
            self.n_resumes += 1
            # no admission_log append: the rid was logged when first
            # admitted (the FCFS seal tracks first admissions only)
            out.append((tracker, spilled))
        while self._free and self._queue:
            if not can_admit(self._peek_next()):
                return out
            slot = self._free.pop(0)
            req = self._pop_next()
            tracker = RequestTracker(req, slot)
            self.active[slot] = tracker
            self.admission_log.append(req.rid)
            out.append((tracker, None))
        return out

    def retire(self, slot: int) -> RequestTracker:
        tracker = self.active.pop(slot)
        bisect.insort(self._free, slot)
        return tracker

    # -- preemption ---------------------------------------------------------

    def preempt(self, slot: int, payload: Any) -> SpilledRequest:
        """Evict the request in ``slot`` without retiring it: the slot
        frees immediately, the request parks in the resume queue (highest
        priority first; rid order — original FCFS — within a class, so
        shrinking below occupancy leaves exactly the lowest-priority
        requests on the spill path)."""
        tracker = self.active.pop(slot)
        bisect.insort(self._free, slot)
        spilled = SpilledRequest(req=tracker.req, tokens=list(tracker.tokens),
                                 payload=payload)
        bisect.insort(self._spilled, spilled,
                      key=lambda s: (-s.req.priority, s.req.rid))
        self.n_spills += 1
        return spilled

    # -- rescaling ----------------------------------------------------------

    def rescale(self, n_slots: int) -> None:
        """Re-size the slot pool.  The engine spills every active request
        first (the repack path), so only queue/spill/log state carries
        over; the free list is rebuilt for the new size."""
        if n_slots <= 0:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        assert not self.active, (
            "rescale requires a drained pool: spill active requests first")
        self.n_slots = n_slots
        self._free = list(range(n_slots))

    # -- decode-tick token recording ----------------------------------------

    def record_tokens(self, token_by_slot: dict) -> list[RequestTracker]:
        """Append one decode tick's token per slot in ``token_by_slot``;
        retire and return the trackers that finished on this tick.  Slots
        absent from the dict (still installing pages on the paged
        backend) get no token this tick."""
        done = []
        for slot in sorted(token_by_slot):
            if self.active[slot].append(int(token_by_slot[slot])):
                done.append(self.retire(slot))
        return done
