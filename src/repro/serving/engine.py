"""ServingEngine: continuous batching over a fixed slot pool.

One engine owns

  * a :class:`SlotScheduler` (FCFS admission, mid-flight join/retire),
  * a packed :mod:`kvpool` (persistent, slot-indexed, binary-mask
    compressed KV state),
  * three jitted programs: per-request prefill (batch 1, compiled per
    prompt length), slot install (prefilled KV written into the pool),
    and the pooled decode step (unpack -> attend -> merge active rows ->
    repack, all inside one XLA program).

Serving numerics: quantized modes round to nearest (``stochastic=False``)
— stochastic rounding draws its noise batch-wide, which would make a
request's tokens depend on who shares its batch; nearest rounding is
elementwise, so generation is a function of the request alone (the
batch-composition invariance tests/test_serving.py seals).  The paper's
SR argument is about training convergence, not inference.

Token accounting matches the static path it replaced: the prefill's
argmax/sample is *fed* as the first decode input (not reported), and
every decode step emits one reported token; ``max_tokens`` bounds the
reported tokens, EOS is included in them.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.serving import kvpool
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import SlotScheduler
from repro.serving.steps import make_decode_step, make_prefill_step
from repro.telemetry.sketch import QuantileSketch


class ServingEngine:
    """Continuous-batching engine for decoder-only LM archs.

    ``arch`` is any arch view (``configs.base.ResolvedArch``); encdec
    archs are served by the one-shot static fallback in ``launch/serve``.
    """

    @classmethod
    def from_spec(cls, spec, *, params=None, mesh=None, resolved=None):
        """Build an engine from a ``run="serve"`` RunSpec: the slot pool,
        pool length, sampling mode, numerics, and kernel policy all come
        from the spec (``resolved`` may pass a pre-computed
        ``spec.resolve()`` to avoid resolving twice)."""
        r = resolved if resolved is not None else spec.resolve()
        s = spec.serving
        kw = dict(
            params=params,
            n_slots=spec.shape.batch if s.slots is None else s.slots,
            max_len=spec.shape.prompt_len + spec.shape.gen + 1,
            greedy=s.greedy, mesh=mesh, reduced=False, seed=spec.seeds.seed)
        if getattr(s, "pages", False) and cls is ServingEngine:
            # serving.pages flips the backend to the paged COW pool; the
            # engine contract (submit/step/run/summary) is unchanged
            from repro.serving.paging.engine import PagedServingEngine

            return PagedServingEngine(
                r.view, r.step, page_tokens=s.page_tokens,
                num_pages=s.num_pages, overcommit=s.overcommit,
                prefix_cache=s.prefix_cache, **kw)
        return cls(r.view, r.step, **kw)

    def __init__(self, arch, step_cfg, *, params=None, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True, mesh=None,
                 reduced: bool = True, seed: int = 0):
        assert not arch.is_encdec, "engine serves decoder-only LMs"
        self.cfg = arch.reduced() if reduced else arch.config
        self.step_cfg = step_cfg
        self.greedy = greedy
        self.n_slots = n_slots
        self.max_len = max_len
        if params is None:
            from repro.models.lm import lm_init

            params = lm_init(jax.random.PRNGKey(seed), self.cfg)
        self.params = params

        # KV-pool ops honor the config-threaded KernelPolicy like every
        # other registry op (CLI --kernel-impl pins them too); resolution
        # happens once here, planning-style, like SpringContext.kernel_impl
        from repro.kernels import registry

        pol = step_cfg.spring.kernels
        self._kv_pack_impl = registry.resolve_with(pol, "kv_pack").name
        self._kv_unpack_impl = registry.resolve_with(pol, "kv_unpack").name

        self.sched = self._make_scheduler(n_slots)
        self._ledger = kvpool.SlotLedger(n_slots)
        self._next_tok = np.zeros((n_slots,), np.int64)
        self._results: dict[int, RequestResult] = {}
        self._requests: dict[int, Request] = {}
        self._next_rid = 0
        self._t0 = time.monotonic()

        self._prefill = jax.jit(make_prefill_step(arch, step_cfg, mesh=mesh,
                                                  reduced=reduced))
        self._decode_step = make_decode_step(arch, step_cfg, mesh=mesh,
                                             reduced=reduced)
        self._build_backend()

        # metrics
        self.decode_steps = 0
        self.tick = 0  # scheduler ticks (every step() call, incl. admit-only)
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.occupancy_sum = 0.0
        self.tokens_emitted = 0
        self.peak_kv_wire_bytes = 0.0
        self._peak_stats: Optional[dict] = None
        self._wire_bytes_sum = 0.0
        self._density_sum = 0.0
        self.finite = True
        # latency attribution: mergeable quantile sketches, always on
        # (pure-python adds — a handful of dict ops per tick, invisible
        # next to a jitted decode step).  Spans/gauges go through the
        # ambient telemetry scope and cost nothing when it is disabled.
        self.queue_sketch = QuantileSketch()
        self.ttft_sketch = QuantileSketch()
        self.token_sketch = QuantileSketch()
        #: most concurrent resident (installed) requests seen — the
        #: capacity number bench_paging compares across pool backends
        self.peak_active = 0

    # -- backend construction (overridden by the paged engine) --------------

    def _make_scheduler(self, n_slots: int) -> SlotScheduler:
        return SlotScheduler(n_slots)

    def _build_backend(self) -> None:
        """Build the KV storage + the jitted programs against it.  The
        base backend is the slot-monolithic packed pool; the paged engine
        overrides this with the page store while reusing the whole
        scheduling/sampling/accounting shell."""
        self.pool = kvpool.init_pool(self.cfg, self.n_slots, self.max_len,
                                     impl=self._kv_pack_impl)
        decode = self._decode_step

        def pooled_decode(params, tokens, pool, active, key):
            cache = kvpool.unpack_cache(pool, self._kv_unpack_impl)
            logits, new_cache = decode(params, tokens, cache, key)
            merged = kvpool.merge_active(new_cache, cache, active)
            return logits, kvpool.pack_cache(merged, self._kv_pack_impl)

        def install(pool, prefill_cache, slot, prompt_len):
            # packed splice: only the new slot's blocks are (re)packed
            return kvpool.install_packed(pool, prefill_cache, slot,
                                         prompt_len, impl=self._kv_pack_impl)

        self._decode = jax.jit(pooled_decode)
        self._install = jax.jit(install)
        self._release = jax.jit(kvpool.release_packed)

    def _pool_stats(self) -> dict:
        """Current wire stats of the live KV storage (one device sync)."""
        return kvpool.pool_wire_stats(self.pool)

    def release_slot(self, slot: int) -> None:
        """Free one installed slot.  Double release raises ValueError via
        the ledger *before* the pure jitted zeroing op runs — silently
        re-zeroing a free slot used to corrupt occupancy accounting."""
        self._ledger.release(slot)
        self.pool = self._release(self.pool, jnp.asarray(slot, jnp.int32))

    # -- submission ---------------------------------------------------------

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def submit(self, req: Request) -> int:
        if len(req.prompt) + req.max_tokens + 1 > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_tokens "
                f"{req.max_tokens} + 1 exceeds pool max_len {self.max_len}")
        self.sched.submit(req)
        self._requests[req.rid] = req
        self._results[req.rid] = RequestResult(rid=req.rid, tokens=[],
                                               submit_s=self._now(),
                                               enqueue_tick=self.tick)
        return req.rid

    def submit_prompt(self, prompt, max_tokens: int, **kw) -> int:
        rid = self._next_rid
        self._next_rid = rid + 1
        return self.submit(Request(rid=rid,
                                   prompt=tuple(int(t) for t in prompt),
                                   max_tokens=max_tokens, **kw))

    # -- one scheduler tick: admissions + one pooled decode step ------------

    def _sample(self, tracker, row_logits, draw_idx: int) -> int:
        """``draw_idx`` counts the request's draws (0 = the fed prefill
        token, 1.. = decode emissions) so no two draws share a key."""
        if self.greedy:
            return int(jnp.argmax(row_logits, -1))
        key = jax.random.fold_in(jax.random.PRNGKey(tracker.req.seed), draw_idx)
        return int(jax.random.categorical(key, row_logits))

    def step(self) -> None:
        with telemetry.span("serve.tick", tick=self.tick):
            self._step_body()
        self.tick += 1

    def _step_body(self) -> None:
        self._admit_phase()
        self.peak_active = max(self.peak_active, len(self.sched.active))
        slots = self._decode_slots()
        if not slots:
            return
        logits, slots, step_s = self._dispatch_decode(slots)
        if not slots:
            return
        self.decode_s += step_s
        self.decode_steps += 1
        self.occupancy_sum += len(slots) / self.n_slots
        self.finite &= bool(jnp.all(jnp.isfinite(logits[np.asarray(slots)])))

        with telemetry.span("serve.tick.sample", active=len(slots)):
            # greedy argmax is batch-wide: one dispatch for the whole tick
            # (per-slot device round-trips would serialize the hot loop)
            greedy_toks = (np.asarray(jnp.argmax(logits, -1))
                           if self.greedy else None)
            token_by_slot = {}
            for slot in slots:
                tracker = self.sched.active[slot]
                tok = (int(greedy_toks[slot]) if greedy_toks is not None
                       else self._sample(tracker, logits[slot],
                                         len(tracker.tokens) + 1))
                token_by_slot[slot] = tok
                self._next_tok[slot] = tok
                res = self._results[tracker.req.rid]
                if not tracker.tokens:
                    res.first_token_s = self._now()
                    res.first_token_tick = self.tick
                    self.ttft_sketch.add(res.first_token_s - res.submit_s)
                # every decoded request got one token this tick: attribute
                # the tick's decode wall time as its per-token latency
                self.token_sketch.add(step_s)
        self._post_sample(slots)
        with telemetry.span("serve.tick.repack"):
            for tracker in self.sched.record_tokens(token_by_slot):
                res = self._results[tracker.req.rid]
                res.tokens = list(tracker.tokens)
                res.done_s = self._now()
                res.finish_tick = self.tick
                res.finished_by = tracker.finished_by
                self.tokens_emitted += len(tracker.tokens)
                self.release_slot(tracker.slot)
            stats = self._pool_stats()
        self._post_stats(stats)
        if stats["kv_wire_bytes"] >= self.peak_kv_wire_bytes:
            self.peak_kv_wire_bytes = stats["kv_wire_bytes"]
            self._peak_stats = stats
        self._wire_bytes_sum += stats["kv_wire_bytes"]
        self._density_sum += stats["kv_density"]
        if telemetry.enabled():
            # tick-level gauges in the one metrics registry (scrapeable /
            # snapshot into serve --json); disabled path skips the writes
            m = telemetry.metrics()
            m.set("spring_serve_tick_utilization",
                  len(slots) / self.n_slots,
                  help="active slots / pool slots at the last decode tick")
            m.set("spring_serve_kv_pool_density", stats["kv_density"],
                  help="measured KV-pool density at the last decode tick")
            m.set("spring_serve_kv_pool_wire_bytes", stats["kv_wire_bytes"],
                  help="packed KV-pool wire bytes at the last decode tick")
            m.inc("spring_serve_tokens_total", len(slots),
                  help="decode tokens emitted")
            m.observe("spring_serve_decode_step_s", step_s,
                      help="decode-step wall seconds")
            self._backend_gauges(m)

    # -- tick phases (the paged engine overrides the backend-specific ones) --

    def _admit_phase(self) -> None:
        with telemetry.span("serve.tick.schedule"):
            admitted = self.sched.admit()
        for tracker in admitted:
            self._admit_one(tracker)

    def _admit_one(self, tracker) -> None:
        req = tracker.req
        t0 = time.monotonic()
        with telemetry.span("serve.tick.prefill", rid=req.rid,
                            prompt_len=len(req.prompt)):
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
            if req.img_embeds is not None:
                batch["img_embeds"] = jnp.asarray(req.img_embeds)[None]
            logits, pcache = self._prefill(
                self.params, batch, jax.random.PRNGKey(req.seed))
        with telemetry.span("serve.tick.install", rid=req.rid,
                            slot=tracker.slot):
            self._ledger.install(tracker.slot)
            self._install_request(tracker, pcache)
        self.prefill_s += time.monotonic() - t0
        # the prefill token is fed, not reported (static-path contract)
        self._next_tok[tracker.slot] = self._sample(tracker, logits[0], 0)
        res = self._results[req.rid]
        res.admit_s = self._now()
        res.slot = tracker.slot
        self.queue_sketch.add(res.queue_s)

    def _install_request(self, tracker, pcache) -> None:
        self.pool = self._install(self.pool, pcache,
                                  jnp.asarray(tracker.slot, jnp.int32),
                                  len(tracker.req.prompt))
        jax.block_until_ready(jax.tree_util.tree_leaves(self.pool)[0])

    def _decode_slots(self) -> list:
        """Slots that take a decode step this tick."""
        return sorted(self.sched.active)

    def _dispatch_decode(self, slots):
        """Run the jitted decode over ``slots``; returns ``(logits, slots,
        step_s)`` — the slot list may shrink (the paged backend can spill
        a slot while claiming its write page)."""
        active = np.zeros((self.n_slots,), bool)
        active[slots] = True
        t0 = time.monotonic()
        with telemetry.span("serve.tick.decode", active=len(slots)):
            logits, self.pool = self._decode(
                self.params, jnp.asarray(self._next_tok, jnp.int32), self.pool,
                jnp.asarray(active), jax.random.PRNGKey(self.decode_steps))
            logits = jax.block_until_ready(logits)
        return logits, slots, time.monotonic() - t0

    def _post_sample(self, slots) -> None:
        """Backend hook between sampling and retirement."""

    def _post_stats(self, stats) -> None:
        """Backend hook after the per-tick pool measurement."""

    def _backend_gauges(self, m) -> None:
        """Backend-specific telemetry gauges (paged pool occupancy etc.)."""

    def run(self) -> dict:
        """Drain the queue; returns results + engine metrics."""
        while self.sched.has_work():
            self.step()
            self.sched.check_invariants()
        return self.summary()

    # -- metrics ------------------------------------------------------------

    def summary(self) -> dict:
        results = [self._results[r] for r in sorted(self._results)]
        # headline KV numbers are taken at peak wire occupancy — the pool
        # drains as requests retire, so end-of-run stats under-report
        stats = self._peak_stats or self._pool_stats()
        per_request = [
            {
                "rid": r.rid,
                "tokens": list(r.tokens),
                "n_tokens": len(r.tokens),
                "latency_s": r.latency_s,
                "queue_s": r.queue_s,
                "ttft_s": r.first_token_s - r.submit_s,
                "enqueue_tick": r.enqueue_tick,
                "first_token_tick": r.first_token_tick,
                "finish_tick": r.finish_tick,
                "decode_ticks": r.decode_ticks,
                "finished_by": r.finished_by,
                "slo_met": r.slo_met(self._requests[r.rid]),
            }
            for r in results
        ]
        steps = max(self.decode_steps, 1)
        mean_wire = self._wire_bytes_sum / steps
        # latency attribution: queue-wait / TTFT / per-token percentiles
        # from the engine's always-on streaming sketches (DESIGN.md §11)
        latency = {
            "queue_s": self.queue_sketch.percentiles(),
            "ttft_s": self.ttft_sketch.percentiles(),
            "token_s": self.token_sketch.percentiles(),
            "ticks": self.tick,
            # fraction of scheduler ticks that reached a decode dispatch
            "tick_utilization": (self.decode_steps / self.tick
                                 if self.tick else 0.0),
        }
        return {
            "per_request": per_request,
            "latency": latency,
            # per-step KV traffic: a dense engine re-reads the full
            # allocated pool each decode step at fp32; SPRING's interface
            # moves the packed live bytes + mask (DESIGN.md §9.3)
            "kv_mean_wire_bytes": mean_wire,
            "kv_mean_density": self._density_sum / steps,
            "kv_traffic_reduction_vs_fp32": (
                stats["kv_dense_fp32_bytes"] / mean_wire if mean_wire else 0.0),
            "decode_steps": self.decode_steps,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tokens_per_s": (self.tokens_emitted / self.decode_s
                             if self.decode_s else 0.0),
            "mean_occupancy": (self.occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0),
            "peak_kv_wire_bytes": self.peak_kv_wire_bytes,
            "peak_active": self.peak_active,
            "finite": self.finite,
            **stats,
        }
