"""ServingEngine: continuous batching over a fixed slot pool.

One engine owns

  * a :class:`SlotScheduler` (FCFS admission, mid-flight join/retire),
  * a packed :mod:`kvpool` (persistent, slot-indexed, binary-mask
    compressed KV state),
  * three jitted programs: per-request prefill (batch 1, compiled per
    prompt length), slot install (prefilled KV written into the pool),
    and the pooled decode step (unpack -> attend -> merge active rows ->
    repack, all inside one XLA program).

Serving numerics: quantized modes round to nearest (``stochastic=False``)
— stochastic rounding draws its noise batch-wide, which would make a
request's tokens depend on who shares its batch; nearest rounding is
elementwise, so generation is a function of the request alone (the
batch-composition invariance tests/test_serving.py seals).  The paper's
SR argument is about training convergence, not inference.

Token accounting matches the static path it replaced: the prefill's
argmax/sample is *fed* as the first decode input (not reported), and
every decode step emits one reported token; ``max_tokens`` bounds the
reported tokens, EOS is included in them.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.runtime.resilience import StragglerWatchdog
from repro.serving import kvpool
from repro.serving.request import Request, RequestResult
from repro.serving.scheduler import ShedPolicy, SlotScheduler
from repro.serving.steps import make_decode_step, make_prefill_step
from repro.telemetry.sketch import QuantileSketch


def _shed_from_section(s) -> Optional[ShedPolicy]:
    """ShedPolicy from a ServingSection; None when every knob is off (the
    scheduler is then byte-for-byte the historical FCFS one)."""
    if (s.max_queue_depth is None and s.deadline_ticks is None
            and not s.deadline_aware and not s.priority_aware):
        return None
    return ShedPolicy(max_queue_depth=s.max_queue_depth,
                      deadline_ticks=s.deadline_ticks,
                      deadline_aware=s.deadline_aware,
                      priority_aware=s.priority_aware)


class ServingEngine:
    """Continuous-batching engine for decoder-only LM archs.

    ``arch`` is any arch view (``configs.base.ResolvedArch``); encdec
    archs are served by the one-shot static fallback in ``launch/serve``.
    """

    @classmethod
    def from_spec(cls, spec, *, params=None, mesh=None, resolved=None):
        """Build an engine from a ``run="serve"`` RunSpec: the slot pool,
        pool length, sampling mode, numerics, and kernel policy all come
        from the spec (``resolved`` may pass a pre-computed
        ``spec.resolve()`` to avoid resolving twice)."""
        r = resolved if resolved is not None else spec.resolve()
        s = spec.serving
        kw = dict(
            params=params,
            n_slots=spec.shape.batch if s.slots is None else s.slots,
            max_len=spec.shape.prompt_len + spec.shape.gen + 1,
            greedy=s.greedy, mesh=mesh, reduced=False, seed=spec.seeds.seed,
            spec_hash=spec.state_hash(), shed=_shed_from_section(s),
            snapshot_every=s.snapshot_every,
            snapshot_path=s.snapshot_path or (
                "spring_snapshot.npz" if s.snapshot_every else ""))
        if getattr(s, "pages", False) and cls is ServingEngine:
            # serving.pages flips the backend to the paged COW pool; the
            # engine contract (submit/step/run/summary) is unchanged
            from repro.serving.paging.engine import PagedServingEngine

            return PagedServingEngine(
                r.view, r.step, page_tokens=s.page_tokens,
                num_pages=s.num_pages, overcommit=s.overcommit,
                prefix_cache=s.prefix_cache, **kw)
        return cls(r.view, r.step, **kw)

    #: snapshot/restore artifact tag — snapshots from one pool backend
    #: never restore into the other (the packed layouts differ)
    backend_kind = "monolithic"

    def __init__(self, arch, step_cfg, *, params=None, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True, mesh=None,
                 reduced: bool = True, seed: int = 0,
                 spec_hash: Optional[str] = None,
                 shed: Optional[ShedPolicy] = None,
                 snapshot_every: int = 0, snapshot_path: str = "",
                 watchdog: Optional[StragglerWatchdog] = None):
        assert not arch.is_encdec, "engine serves decoder-only LMs"
        self.cfg = arch.reduced() if reduced else arch.config
        self.step_cfg = step_cfg
        self.greedy = greedy
        self.n_slots = n_slots
        self.max_len = max_len
        self.spec_hash = spec_hash
        self.shed_policy = shed
        self.snapshot_every = int(snapshot_every)
        self.snapshot_path = snapshot_path
        # tick-time straggler detection: serving ticks are bimodal
        # (prefill+compile ticks dwarf steady decode ticks), so the
        # default threshold is loose and warmup covers first compiles
        self.watchdog = watchdog if watchdog is not None else (
            StragglerWatchdog(threshold=4.0, warmup_steps=5))
        if params is None:
            from repro.models.lm import lm_init

            params = lm_init(jax.random.PRNGKey(seed), self.cfg)
        self.params = params

        # KV-pool ops honor the config-threaded KernelPolicy like every
        # other registry op (CLI --kernel-impl pins them too); resolution
        # happens once here, planning-style, like SpringContext.kernel_impl
        from repro.kernels import registry

        pol = step_cfg.spring.kernels
        self._kv_pack_impl = registry.resolve_with(pol, "kv_pack").name
        self._kv_unpack_impl = registry.resolve_with(pol, "kv_unpack").name

        self.sched = self._make_scheduler(n_slots)
        self._ledger = kvpool.SlotLedger(n_slots)
        self._next_tok = np.zeros((n_slots,), np.int64)
        self._results: dict[int, RequestResult] = {}
        self._requests: dict[int, Request] = {}
        self._next_rid = 0
        self._t0 = time.monotonic()

        self._prefill = jax.jit(make_prefill_step(arch, step_cfg, mesh=mesh,
                                                  reduced=reduced))
        self._decode_step = make_decode_step(arch, step_cfg, mesh=mesh,
                                             reduced=reduced)
        self._build_backend()

        # metrics
        self.decode_steps = 0
        self.tick = 0  # scheduler ticks (every step() call, incl. admit-only)
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.occupancy_sum = 0.0
        self.tokens_emitted = 0
        self.peak_kv_wire_bytes = 0.0
        self._peak_stats: Optional[dict] = None
        self._wire_bytes_sum = 0.0
        self._density_sum = 0.0
        self.finite = True
        # latency attribution: mergeable quantile sketches, always on
        # (pure-python adds — a handful of dict ops per tick, invisible
        # next to a jitted decode step).  Spans/gauges go through the
        # ambient telemetry scope and cost nothing when it is disabled.
        self.queue_sketch = QuantileSketch()
        self.ttft_sketch = QuantileSketch()
        self.token_sketch = QuantileSketch()
        #: most concurrent resident (installed) requests seen — the
        #: capacity number bench_paging compares across pool backends
        self.peak_active = 0
        # spring-survive counters (DESIGN.md §13)
        self.n_rejected: dict = {}  # reason -> count
        self.n_rescales = 0
        self.n_snapshots = 0
        self.n_restores = 0
        self.slow_ticks = 0

    # -- backend construction (overridden by the paged engine) --------------

    def _make_scheduler(self, n_slots: int) -> SlotScheduler:
        return SlotScheduler(n_slots, policy=self.shed_policy)

    def _build_backend(self) -> None:
        """Build the jitted programs + the KV storage.  The base backend
        is the slot-monolithic packed pool; the paged engine overrides
        ``_build_pool`` with the page store while reusing the whole
        scheduling/sampling/accounting shell.  Programs and storage are
        split so :meth:`rescale`/:meth:`restore` can rebuild the pool at
        a new size without re-wrapping the jits (shape changes retrace
        through the existing jit caches)."""
        self._build_programs()
        self._build_pool()

    def _build_pool(self) -> None:
        self.pool = kvpool.init_pool(self.cfg, self.n_slots, self.max_len,
                                     impl=self._kv_pack_impl)

    def _build_programs(self) -> None:
        decode = self._decode_step

        def pooled_decode(params, tokens, pool, active, key):
            cache = kvpool.unpack_cache(pool, self._kv_unpack_impl)
            logits, new_cache = decode(params, tokens, cache, key)
            merged = kvpool.merge_active(new_cache, cache, active)
            return logits, kvpool.pack_cache(merged, self._kv_pack_impl)

        def install(pool, prefill_cache, slot, prompt_len):
            # packed splice: only the new slot's blocks are (re)packed
            return kvpool.install_packed(pool, prefill_cache, slot,
                                         prompt_len, impl=self._kv_pack_impl)

        self._decode = jax.jit(pooled_decode)
        self._install = jax.jit(install)
        self._release = jax.jit(kvpool.release_packed)
        # spill/resume: one slot's exact packed bits out of / into the pool
        self._extract_slot = jax.jit(kvpool.extract_slot_packed)
        self._restore_slot = jax.jit(kvpool.restore_slot_packed)

    def _pool_stats(self) -> dict:
        """Current wire stats of the live KV storage (one device sync)."""
        return kvpool.pool_wire_stats(self.pool)

    def release_slot(self, slot: int) -> None:
        """Free one installed slot.  Double release raises ValueError via
        the ledger *before* the pure jitted zeroing op runs — silently
        re-zeroing a free slot used to corrupt occupancy accounting."""
        self._ledger.release(slot)
        self.pool = self._release(self.pool, jnp.asarray(slot, jnp.int32))

    # -- submission ---------------------------------------------------------

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def submit(self, req: Request) -> int:
        if len(req.prompt) + req.max_tokens + 1 > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_tokens "
                f"{req.max_tokens} + 1 exceeds pool max_len {self.max_len}")
        self._requests[req.rid] = req
        self._results[req.rid] = RequestResult(rid=req.rid, tokens=[],
                                               submit_s=self._now(),
                                               enqueue_tick=self.tick)
        reason = self.sched.submit(req, tick=self.tick)
        if reason is not None:
            self._reject(req.rid, reason)
        return req.rid

    def _reject(self, rid: int, reason: str) -> None:
        """Record a typed rejection: the request is finished, carries no
        tokens, and its result says exactly why (never silent loss)."""
        res = self._results[rid]
        res.rejected = reason
        res.finished_by = "rejected"
        res.done_s = self._now()
        res.finish_tick = self.tick
        self.n_rejected[reason] = self.n_rejected.get(reason, 0) + 1
        if telemetry.enabled():
            telemetry.metrics().inc(
                "spring_serve_shed_total", 1,
                help="requests shed with a typed rejection reason")

    def submit_prompt(self, prompt, max_tokens: int, **kw) -> int:
        rid = self._next_rid
        self._next_rid = rid + 1
        return self.submit(Request(rid=rid,
                                   prompt=tuple(int(t) for t in prompt),
                                   max_tokens=max_tokens, **kw))

    # -- one scheduler tick: admissions + one pooled decode step ------------

    def _sample(self, tracker, row_logits, draw_idx: int) -> int:
        """``draw_idx`` counts the request's draws (0 = the fed prefill
        token, 1.. = decode emissions) so no two draws share a key."""
        if self.greedy:
            return int(jnp.argmax(row_logits, -1))
        key = jax.random.fold_in(jax.random.PRNGKey(tracker.req.seed), draw_idx)
        return int(jax.random.categorical(key, row_logits))

    def step(self) -> None:
        self.watchdog.step_start()
        with telemetry.span("serve.tick", tick=self.tick):
            self._step_body()
        self.tick += 1
        ev = self.watchdog.step_end(self.tick)
        if ev.slow:
            self.slow_ticks += 1
        if telemetry.enabled():
            m = telemetry.metrics()
            m.set("spring_serve_tick_ewma_s", ev.ewma,
                  help="EWMA of serving-tick wall seconds (watchdog)")
            if ev.slow:
                m.inc("spring_serve_slow_ticks_total", 1,
                      help="serving ticks the straggler watchdog flagged")

    def _step_body(self) -> None:
        self._admit_phase()
        self.peak_active = max(self.peak_active, len(self.sched.active))
        slots = self._decode_slots()
        if not slots:
            return
        logits, slots, step_s = self._dispatch_decode(slots)
        if not slots:
            return
        self.decode_s += step_s
        self.decode_steps += 1
        self.occupancy_sum += len(slots) / self.n_slots
        self.finite &= bool(jnp.all(jnp.isfinite(logits[np.asarray(slots)])))

        with telemetry.span("serve.tick.sample", active=len(slots)):
            # greedy argmax is batch-wide: one dispatch for the whole tick
            # (per-slot device round-trips would serialize the hot loop)
            greedy_toks = (np.asarray(jnp.argmax(logits, -1))
                           if self.greedy else None)
            token_by_slot = {}
            for slot in slots:
                tracker = self.sched.active[slot]
                tok = (int(greedy_toks[slot]) if greedy_toks is not None
                       else self._sample(tracker, logits[slot],
                                         len(tracker.tokens) + 1))
                token_by_slot[slot] = tok
                self._next_tok[slot] = tok
                res = self._results[tracker.req.rid]
                if not tracker.tokens:
                    res.first_token_s = self._now()
                    res.first_token_tick = self.tick
                    self.ttft_sketch.add(res.first_token_s - res.submit_s)
                # every decoded request got one token this tick: attribute
                # the tick's decode wall time as its per-token latency
                self.token_sketch.add(step_s)
        self._post_sample(slots)
        with telemetry.span("serve.tick.repack"):
            for tracker in self.sched.record_tokens(token_by_slot):
                res = self._results[tracker.req.rid]
                res.tokens = list(tracker.tokens)
                res.done_s = self._now()
                res.finish_tick = self.tick
                res.finished_by = tracker.finished_by
                self.tokens_emitted += len(tracker.tokens)
                self.release_slot(tracker.slot)
            stats = self._pool_stats()
        self._post_stats(stats)
        if stats["kv_wire_bytes"] >= self.peak_kv_wire_bytes:
            self.peak_kv_wire_bytes = stats["kv_wire_bytes"]
            self._peak_stats = stats
        self._wire_bytes_sum += stats["kv_wire_bytes"]
        self._density_sum += stats["kv_density"]
        if telemetry.enabled():
            # tick-level gauges in the one metrics registry (scrapeable /
            # snapshot into serve --json); disabled path skips the writes
            m = telemetry.metrics()
            m.set("spring_serve_tick_utilization",
                  len(slots) / self.n_slots,
                  help="active slots / pool slots at the last decode tick")
            m.set("spring_serve_kv_pool_density", stats["kv_density"],
                  help="measured KV-pool density at the last decode tick")
            m.set("spring_serve_kv_pool_wire_bytes", stats["kv_wire_bytes"],
                  help="packed KV-pool wire bytes at the last decode tick")
            m.inc("spring_serve_tokens_total", len(slots),
                  help="decode tokens emitted")
            m.observe("spring_serve_decode_step_s", step_s,
                      help="decode-step wall seconds")
            self._backend_gauges(m)

    # -- tick phases (the paged engine overrides the backend-specific ones) --

    def _admit_phase(self) -> None:
        self._shed_phase()
        with telemetry.span("serve.tick.schedule"):
            admitted = self.sched.admit_gated(self._can_resume,
                                              self._can_admit)
        for tracker, spilled in admitted:
            if spilled is not None:
                self._resume_one(tracker, spilled)
            else:
                self._admit_one(tracker)

    def _shed_phase(self) -> None:
        """Expire queued requests whose admission deadline passed."""
        for req, reason in self.sched.shed_expired(self.tick):
            self._reject(req.rid, reason)

    def _can_admit(self, req) -> bool:
        """Admission feasibility gate (the paged backend projects page
        budgets here); the monolithic pool always has room for a free
        slot's request."""
        return True

    def _can_resume(self, spilled) -> bool:
        return True

    def _admit_one(self, tracker) -> None:
        req = tracker.req
        t0 = time.monotonic()
        with telemetry.span("serve.tick.prefill", rid=req.rid,
                            prompt_len=len(req.prompt)):
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
            if req.img_embeds is not None:
                batch["img_embeds"] = jnp.asarray(req.img_embeds)[None]
            logits, pcache = self._prefill(
                self.params, batch, jax.random.PRNGKey(req.seed))
        with telemetry.span("serve.tick.install", rid=req.rid,
                            slot=tracker.slot):
            self._ledger.install(tracker.slot)
            self._install_request(tracker, pcache)
        self.prefill_s += time.monotonic() - t0
        # the prefill token is fed, not reported (static-path contract)
        self._next_tok[tracker.slot] = self._sample(tracker, logits[0], 0)
        res = self._results[req.rid]
        res.admit_s = self._now()
        res.slot = tracker.slot
        self.queue_sketch.add(res.queue_s)

    def _install_request(self, tracker, pcache) -> None:
        self.pool = self._install(self.pool, pcache,
                                  jnp.asarray(tracker.slot, jnp.int32),
                                  len(tracker.req.prompt))
        jax.block_until_ready(jax.tree_util.tree_leaves(self.pool)[0])

    # -- spill / resume (monolithic backend; the paged engine overrides) -----

    def _spill_slot(self, slot: int) -> None:
        """Preempt the request in ``slot``: its exact packed pool bits
        move to host memory, the slot frees, the request parks in the
        scheduler's resume queue."""
        tracker = self.sched.active[slot]
        with telemetry.span("serve.tick.spill", rid=tracker.req.rid,
                            slot=slot):
            payload = {
                "slot_state": jax.device_get(self._extract_slot(
                    self.pool, jnp.asarray(slot, jnp.int32))),
                "next_tok": int(self._next_tok[slot]),
            }
            self._ledger.release(slot)
            self.pool = self._release(self.pool, jnp.asarray(slot, jnp.int32))
            self._next_tok[slot] = 0
            self.sched.preempt(slot, payload)

    def _resume_one(self, tracker, spilled) -> None:
        """Splice a spilled request's exact packed bits into its new slot
        — nothing recomputed, resumption is bit-identical by
        construction."""
        slot, pay = tracker.slot, spilled.payload
        with telemetry.span("serve.tick.resume", rid=tracker.req.rid,
                            slot=slot):
            self._ledger.install(slot)
            self.pool = self._restore_slot(self.pool, pay["slot_state"],
                                           jnp.asarray(slot, jnp.int32))
            jax.block_until_ready(jax.tree_util.tree_leaves(self.pool)[0])
        self._next_tok[slot] = pay["next_tok"]
        self._results[tracker.req.rid].slot = slot

    # -- elastic: rescale / snapshot / restore (DESIGN.md §13) ---------------

    def rescale(self, slots: Optional[int] = None) -> None:
        """Re-size the slot pool on a live engine without dropping work:
        every active request spills (exact packed bits), the pool is
        rebuilt at the new size, and the resume queue drains back in on
        the following ticks — highest priority first, so shrinking below
        occupancy leaves exactly the lowest-priority requests parked on
        the spill path."""
        new = self.n_slots if slots is None else int(slots)
        if new < 1:
            raise ValueError(f"rescale: slots must be >= 1, got {new}")
        with telemetry.span("serve.rescale", slots=new):
            self._pre_rescale()
            for slot in sorted(self.sched.active):
                self._spill_slot(slot)
            self.sched.rescale(new)
            self.n_slots = new
            self._ledger = kvpool.SlotLedger(new)
            self._next_tok = np.zeros((new,), np.int64)
            self._build_pool()
        self.n_rescales += 1

    def _pre_rescale(self) -> None:
        """Backend hook before the spill-everything phase of a rescale."""

    def _pre_snapshot(self) -> None:
        """Backend hook before state capture (the paged engine flushes
        chunked prompt installs here so no half-installed trees exist)."""

    def _signature(self) -> dict:
        """Structural identity a snapshot must match to restore (pool
        geometry fields — ``n_slots`` here, plus page geometry on the
        paged engine — are adapted by rebuilding instead)."""
        return {
            "n_slots": self.n_slots, "max_len": self.max_len,
            "greedy": self.greedy,
            "kv_pack_impl": self._kv_pack_impl,
            "kv_unpack_impl": self._kv_unpack_impl,
            "vocab": int(self.cfg.vocab), "d_model": int(self.cfg.d_model),
        }

    def _reconfigure(self, sig: dict) -> None:
        """Adapt pool geometry to a snapshot taken at another size."""
        new = int(sig["n_slots"])
        if new != self.n_slots:
            self.n_slots = new
            self._build_pool()

    def _snapshot_backend(self) -> dict:
        from repro.serving.elastic.snapshot import tree_to_host_leaves

        return {"pool": tree_to_host_leaves(self.pool)}

    def _restore_backend(self, b: dict) -> None:
        from repro.serving.elastic.snapshot import leaves_to_tree

        self.pool = leaves_to_tree(self.pool, b["pool"], "kv pool")

    def snapshot(self) -> dict:
        """Full engine state as one pure host tree (see
        ``serving/elastic/snapshot.py`` for the format)."""
        from repro.serving import elastic

        self._pre_snapshot()
        snap = elastic.build_snapshot(self)
        self.n_snapshots += 1
        if telemetry.enabled():
            telemetry.metrics().inc("spring_serve_snapshots_total", 1,
                                    help="engine snapshots taken")
        return snap

    def restore(self, snap: dict) -> None:
        """Restore this engine to a snapshot's exact state; the restored
        engine emits the exact remaining tokens of every in-flight
        request.  Raises :class:`~repro.serving.elastic.SnapshotError` on
        version / spec-hash / structure mismatch, before any mutation."""
        from repro.serving import elastic

        elastic.apply_snapshot(self, snap)
        self.n_restores += 1
        if telemetry.enabled():
            telemetry.metrics().inc("spring_serve_restores_total", 1,
                                    help="engine restores applied")

    def save_snapshot(self, path: Optional[str] = None) -> str:
        from repro.serving import elastic

        return elastic.save_snapshot(
            self.snapshot(), path or self.snapshot_path
            or "spring_snapshot.npz")

    def restore_file(self, path: str) -> None:
        from repro.serving import elastic

        self.restore(elastic.load_snapshot(path))

    def _decode_slots(self) -> list:
        """Slots that take a decode step this tick."""
        return sorted(self.sched.active)

    def _dispatch_decode(self, slots):
        """Run the jitted decode over ``slots``; returns ``(logits, slots,
        step_s)`` — the slot list may shrink (the paged backend can spill
        a slot while claiming its write page)."""
        active = np.zeros((self.n_slots,), bool)
        active[slots] = True
        t0 = time.monotonic()
        with telemetry.span("serve.tick.decode", active=len(slots)):
            logits, self.pool = self._decode(
                self.params, jnp.asarray(self._next_tok, jnp.int32), self.pool,
                jnp.asarray(active), jax.random.PRNGKey(self.decode_steps))
            logits = jax.block_until_ready(logits)
        return logits, slots, time.monotonic() - t0

    def _post_sample(self, slots) -> None:
        """Backend hook between sampling and retirement."""

    def _post_stats(self, stats) -> None:
        """Backend hook after the per-tick pool measurement."""

    def _backend_gauges(self, m) -> None:
        """Backend-specific telemetry gauges (paged pool occupancy etc.)."""

    def run(self) -> dict:
        """Drain the queue; returns results + engine metrics.  With
        ``snapshot_every`` set, a restartable snapshot lands on disk every
        N ticks (crash recovery: ``restore_file`` + ``run`` again)."""
        while self.sched.has_work():
            self.step()
            self.sched.check_invariants()
            if (self.snapshot_every > 0
                    and self.tick % self.snapshot_every == 0):
                self.save_snapshot()
        return self.summary()

    # -- metrics ------------------------------------------------------------

    def summary(self) -> dict:
        results = [self._results[r] for r in sorted(self._results)]
        # headline KV numbers are taken at peak wire occupancy — the pool
        # drains as requests retire, so end-of-run stats under-report
        stats = self._peak_stats or self._pool_stats()
        per_request = [
            {
                "rid": r.rid,
                "tokens": list(r.tokens),
                "n_tokens": len(r.tokens),
                "latency_s": r.latency_s,
                "queue_s": r.queue_s,
                "ttft_s": r.first_token_s - r.submit_s,
                "enqueue_tick": r.enqueue_tick,
                "first_token_tick": r.first_token_tick,
                "finish_tick": r.finish_tick,
                "decode_ticks": r.decode_ticks,
                "finished_by": r.finished_by,
                "status": r.status,
                "rejected": r.rejected,
                "slo_met": r.slo_met(self._requests[r.rid]),
            }
            for r in results
        ]
        steps = max(self.decode_steps, 1)
        mean_wire = self._wire_bytes_sum / steps
        # latency attribution: queue-wait / TTFT / per-token percentiles
        # from the engine's always-on streaming sketches (DESIGN.md §11)
        latency = {
            "queue_s": self.queue_sketch.percentiles(),
            "ttft_s": self.ttft_sketch.percentiles(),
            "token_s": self.token_sketch.percentiles(),
            "ticks": self.tick,
            # fraction of scheduler ticks that reached a decode dispatch
            "tick_utilization": (self.decode_steps / self.tick
                                 if self.tick else 0.0),
        }
        return {
            "per_request": per_request,
            "latency": latency,
            # per-step KV traffic: a dense engine re-reads the full
            # allocated pool each decode step at fp32; SPRING's interface
            # moves the packed live bytes + mask (DESIGN.md §9.3)
            "kv_mean_wire_bytes": mean_wire,
            "kv_mean_density": self._density_sum / steps,
            "kv_traffic_reduction_vs_fp32": (
                stats["kv_dense_fp32_bytes"] / mean_wire if mean_wire else 0.0),
            "decode_steps": self.decode_steps,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "tokens_per_s": (self.tokens_emitted / self.decode_s
                             if self.decode_s else 0.0),
            "mean_occupancy": (self.occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0),
            "peak_kv_wire_bytes": self.peak_kv_wire_bytes,
            "peak_active": self.peak_active,
            "finite": self.finite,
            # spring-survive: shedding / preemption / elasticity counters
            "elastic": {
                "rejected": dict(self.n_rejected),
                "n_rejected": sum(self.n_rejected.values()),
                "n_spills": self.sched.n_spills,
                "n_resumes": self.sched.n_resumes,
                "n_rescales": self.n_rescales,
                "n_snapshots": self.n_snapshots,
                "n_restores": self.n_restores,
                "slow_ticks": self.slow_ticks,
            },
            **stats,
        }
