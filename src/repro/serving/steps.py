"""Serving step builders: the jitted prefill / decode programs.

Moved out of ``runtime/train.py`` when the continuous-batching engine
landed (runtime.train re-exports them for the dry-run and older callers).
Both builders take any *arch view* exposing ``is_encdec`` +
``config``/``reduced()`` — ``configs.base.ResolvedArch`` is the canonical
one (it replaced the per-launcher ``class _A`` shims).

Decode steps accept a scalar ``cache["pos"]`` (static batch: every row at
one depth) or a per-slot (B,) vector (the engine's slot pool); see
``models/lm.lm_decode_step``.
"""

from __future__ import annotations

import jax

from repro.core.spring_ops import KeyGen
from repro.models import encdec as ed_mod
from repro.models import lm as lm_mod
from repro.models.layers import SpringContext
from repro.runtime.sharding import DEFAULT_RULES, sharding_context


def _rules_for(step_cfg):
    if not step_cfg.rules_override:
        return None
    rules = dict(DEFAULT_RULES)
    rules.update(dict(step_cfg.rules_override))
    return rules


def _ctx_for(step_cfg, key) -> SpringContext:
    keys = KeyGen(key) if step_cfg.spring.is_quantized else None
    return SpringContext(cfg=step_cfg.spring, keys=keys,
                         prune_ratio=step_cfg.prune_ratio,
                         int8_cache=step_cfg.int8_cache)


def make_prefill_step(arch, step_cfg, mesh=None, reduced: bool = False):
    cfg = arch.reduced() if reduced else arch.config

    if arch.is_encdec:
        def prefill(params, batch, key):
            with sharding_context(mesh, _rules_for(step_cfg)):
                ctx = _ctx_for(step_cfg, key)
                cache = ed_mod.encdec_init_cache(
                    params, cfg, batch["frames"], ctx, max_len=batch["tokens"].shape[1]
                )
                # teacher-forced pass to fill self-KV is decode-looped in
                # serving; dry-run measures encoder + cross-KV build + one
                # full decoder pass (the dominant prefill compute)
                enc = ed_mod.encode(params, cfg, batch["frames"], ctx)
                h = ed_mod.decode_hidden(params, cfg, batch["tokens"], enc, ctx)
                logits = h[:, -1] @ params["embed"]["embedding"].T
                return logits, cache
        return prefill

    def prefill(params, batch, key):
        with sharding_context(mesh, _rules_for(step_cfg)):
            return lm_mod.lm_prefill(params, cfg, batch["tokens"],
                                     _ctx_for(step_cfg, key),
                                     batch.get("img_embeds"))
    return prefill


def make_decode_step(arch, step_cfg, mesh=None, reduced: bool = False):
    cfg = arch.reduced() if reduced else arch.config

    if arch.is_encdec:
        def decode(params, tokens, cache, key):
            with sharding_context(mesh, _rules_for(step_cfg)):
                return ed_mod.encdec_decode_step(params, cfg, tokens, cache,
                                                 _ctx_for(step_cfg, key))
        return decode

    def decode(params, tokens, cache, key):
        with sharding_context(mesh, _rules_for(step_cfg)):
            return lm_mod.lm_decode_step(params, cfg, tokens, cache,
                                         _ctx_for(step_cfg, key))
    return decode
