"""Slot-indexed persistent KV cache, stored binary-mask compressed.

The pool mirrors the ``lm_init_cache`` tree, with every seq-bearing leaf
(full-attention k/v, MLA latent + rope key, sliding-window rings)
replaced by a :class:`PackedKV` record — the ``kv_pack`` registry format
applied per (layer-stack, slot) block: non-zeros collapsed to the front
of a dense-length value buffer + 1 packed occupancy bit per element.
O(1) state caches (ssm/conv/rglru) and the per-slot position vector pass
through dense.  ``unpack``/``pack`` round-trip bit-exactly, so the decode
step — which unpacks on read inside the jitted program, attends, and
repacks — is numerically identical to decoding against the dense cache.

The natural sparsity is *occupancy*: a slot that has decoded p of
max_len positions carries density ~ p/max_len, so the pool's wire bytes
(``20*density + 1`` bits/elem, the memstash/perfmodel formula) track the
live KV state while a dense fp32 pool pays for the full allocation —
that is the measured compression ``bench_serving`` reports.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.masking import MASK_WORD_BITS
from repro.kernels import registry
from repro.kernels.kv_cache.ops import KV_VALUE_BITS, _n_words

#: seq axis (negative, from the end) of each packable cache leaf kind;
#: the slot axis is the one just before it.  Superset of lm.pad_cache's
#: table: rings are fixed-size (never padded) but compress like any block.
PACKED_SEQ_AXIS = {"k": -3, "v": -3, "ckv": -2, "krope": -2,
                   "k_ring": -3, "v_ring": -3}


@jax.tree_util.register_pytree_node_class
class PackedKV:
    """One cache leaf in packed form; static shape/dtype ride the treedef."""

    def __init__(self, values, mask, nnz, shape, dtype):
        self.values = values  # (*lead, block_len) leaf dtype
        self.mask = mask      # (*lead, ceil(block_len/32)) uint32
        self.nnz = nnz        # (*lead,) int32
        self.shape = tuple(shape)   # original dense leaf shape
        self.dtype = jnp.dtype(dtype)

    def tree_flatten(self):
        return (self.values, self.mask, self.nnz), (self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, mask, nnz = children
        shape, dtype = aux
        return cls(values, mask, nnz, shape, dtype)

    @property
    def block_len(self) -> int:
        return int(self.values.shape[-1])

    @property
    def n_blocks(self) -> int:
        return int(math.prod(self.values.shape[:-1]))


def _leaf_name(path) -> str:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    return names[-1] if names else ""


def slot_axis(path) -> int:
    """Slot (batch) axis of a cache leaf: unit-scanned leaves stack the
    layer group in front of it."""
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    return 1 if names and names[0].startswith("unit_") else 0


def _vmapped(fn, x2d):
    return jax.vmap(fn)(x2d)


def pack_cache(cache: dict, impl: Optional[str] = None) -> dict:
    """Dense cache tree (with (S,) ``pos``) -> pool tree with PackedKV
    leaves.  Resolution through the kv_pack registry op happens once per
    trace; the op's impl then runs vmapped over (stack, slot) blocks."""
    pack_fn = registry.resolve("kv_pack", impl).fn

    def one(path, leaf):
        name = _leaf_name(path)
        ax_neg = PACKED_SEQ_AXIS.get(name)
        if ax_neg is None or not hasattr(leaf, "ndim"):
            return leaf
        ax = leaf.ndim + ax_neg  # first block dim (seq)
        lead = leaf.shape[:ax]
        block = int(math.prod(leaf.shape[ax:]))
        flat = leaf.reshape(-1, block)
        packed = _vmapped(pack_fn, flat)
        nb = flat.shape[0]
        return PackedKV(
            values=packed["values"].reshape(*lead, block),
            mask=packed["mask"].reshape(*lead, _n_words(block)),
            nnz=packed["nnz"].reshape(lead),
            shape=leaf.shape, dtype=leaf.dtype,
        ) if nb else leaf

    return jax.tree_util.tree_map_with_path(one, cache)


def unpack_cache(pool: dict, impl: Optional[str] = None) -> dict:
    """Pool tree -> dense cache tree (``pack_cache`` inverse, bit-exact)."""
    unpack_fn = registry.resolve("kv_unpack", impl).fn

    def one(leaf):
        if not isinstance(leaf, PackedKV):
            return leaf
        block = leaf.block_len
        flat_v = leaf.values.reshape(-1, block)
        flat_m = leaf.mask.reshape(-1, _n_words(block))
        dense = jax.vmap(lambda v, m: unpack_fn(v, m, length=block))(flat_v, flat_m)
        return dense.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(
        one, pool, is_leaf=lambda x: isinstance(x, PackedKV))


def init_pool(cfg, n_slots: int, max_len: int, dtype=jnp.bfloat16,
              impl: Optional[str] = None) -> dict:
    """Empty packed pool: ``lm_init_cache`` over the slot dimension with a
    per-slot position vector (zeros; slots are installed mid-flight)."""
    from repro.models.lm import lm_init_cache

    cache = lm_init_cache(cfg, n_slots, max_len, dtype)
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return pack_cache(cache, impl)


# -- mid-flight slot surgery (all called inside jitted engine programs) ------


def _is_packed(x) -> bool:
    return isinstance(x, PackedKV)


def install_packed(pool: dict, prefill_cache: dict, slot, prompt_len,
                   impl: Optional[str] = None) -> dict:
    """Write one prefilled request (batch-1 cache) into ``slot`` of the
    *packed* pool directly: only the new slot's blocks are packed and
    spliced in — the other slots' compressed state is untouched (an O(1)
    logical change must not cost a full-pool decompress/recompress).
    Every leaf's whole slot row is overwritten (seq tails zero-padded),
    so a reused slot carries no stale KV from its previous tenant.
    ``slot`` is a traced scalar."""
    pack_fn = registry.resolve("kv_pack", impl).fn

    def one(path, pool_leaf):
        name = _leaf_name(path)
        if name == "pos":
            return pool_leaf.at[slot].set(jnp.asarray(prompt_len, jnp.int32))
        p_leaf = _lookup(prefill_cache, path)
        if not _is_packed(pool_leaf):  # O(1) state leaves stay dense
            ax = slot_axis(path)
            starts = [0] * pool_leaf.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(
                pool_leaf, p_leaf.astype(pool_leaf.dtype), tuple(starts))
        ax_seq = len(pool_leaf.shape) + PACKED_SEQ_AXIS[name]
        slot_ax = ax_seq - 1  # slot sits just before the seq axis
        row = p_leaf.astype(pool_leaf.dtype)
        extra = pool_leaf.shape[ax_seq] - row.shape[ax_seq]
        assert extra >= 0, (
            f"{name}: prefill length {row.shape[ax_seq]} exceeds pool "
            f"max_len {pool_leaf.shape[ax_seq]}")
        if extra:
            pads = [(0, 0)] * row.ndim
            pads[ax_seq] = (0, extra)
            row = jnp.pad(row, pads)
        block = pool_leaf.block_len
        packed = _vmapped(pack_fn, row.reshape(-1, block))

        def splice(store, new, ndim):
            shape = list(store.shape)
            shape[slot_ax] = 1
            starts = [0] * ndim
            starts[slot_ax] = slot
            return jax.lax.dynamic_update_slice(
                store, new.reshape(shape), tuple(starts))

        return PackedKV(
            values=splice(pool_leaf.values, packed["values"],
                          pool_leaf.values.ndim),
            mask=splice(pool_leaf.mask, packed["mask"], pool_leaf.mask.ndim),
            nnz=splice(pool_leaf.nnz, packed["nnz"], pool_leaf.nnz.ndim),
            shape=pool_leaf.shape, dtype=pool_leaf.dtype,
        )

    return jax.tree_util.tree_map_with_path(one, pool, is_leaf=_is_packed)


def release_packed(pool: dict, slot) -> dict:
    """Zero one slot's blocks in the *packed* pool (position, occupancy,
    values) so a retired request stops counting toward density/wire
    accounting immediately — without touching the other slots."""

    def one(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            return leaf.at[slot].set(0)
        if not _is_packed(leaf):
            ax = slot_axis(path)
            idx = (slice(None),) * ax + (slot,)
            return leaf.at[idx].set(jnp.zeros((), leaf.dtype))
        slot_ax = len(leaf.shape) + PACKED_SEQ_AXIS[name] - 1
        idx = (slice(None),) * slot_ax + (slot,)
        return PackedKV(
            values=leaf.values.at[idx].set(jnp.zeros((), leaf.values.dtype)),
            mask=leaf.mask.at[idx].set(jnp.uint32(0)),
            nnz=leaf.nnz.at[idx].set(jnp.int32(0)),
            shape=leaf.shape, dtype=leaf.dtype,
        )

    return jax.tree_util.tree_map_with_path(one, pool, is_leaf=_is_packed)


def install_prefill(dense_pool: dict, prefill_cache: dict, slot,
                    prompt_len) -> dict:
    """Write one prefilled request (batch-1 cache) into ``slot`` of the
    dense pool tree: every leaf's whole slot row is overwritten (seq tails
    zero-padded), so a reused slot carries no stale KV from its previous
    tenant.  ``slot`` is a traced scalar."""

    def one(path, pool_leaf, p_leaf=None):
        name = _leaf_name(path)
        if name == "pos":
            return pool_leaf.at[slot].set(jnp.asarray(prompt_len, jnp.int32))
        p_leaf = _lookup(prefill_cache, path)
        ax = slot_axis(path)
        seq_neg = PACKED_SEQ_AXIS.get(name)
        row = p_leaf.astype(pool_leaf.dtype)
        if seq_neg is not None:
            sax = row.ndim + seq_neg
            extra = pool_leaf.shape[sax] - row.shape[sax]
            assert extra >= 0, (
                f"{name}: prefill length {row.shape[sax]} exceeds pool "
                f"max_len {pool_leaf.shape[sax]}")
            if extra:
                pads = [(0, 0)] * row.ndim
                pads[sax] = (0, extra)
                row = jnp.pad(row, pads)
        starts = [0] * pool_leaf.ndim
        starts[ax] = slot
        return jax.lax.dynamic_update_slice(pool_leaf, row, tuple(starts))

    return jax.tree_util.tree_map_with_path(one, dense_pool)


def _slice_axis(arr, ax: int, slot):
    starts = [0] * arr.ndim
    starts[ax] = slot
    sizes = list(arr.shape)
    sizes[ax] = 1
    return jax.lax.dynamic_slice(arr, tuple(starts), tuple(sizes))


def _splice_axis(arr, row, ax: int, slot):
    starts = [0] * arr.ndim
    starts[ax] = slot
    return jax.lax.dynamic_update_slice(arr, row.astype(arr.dtype),
                                        tuple(starts))


def extract_slot_packed(pool: dict, slot) -> dict:
    """One slot's row of the *packed* pool, bit-exact: PackedKV leaves
    become ``{"values", "mask", "nnz"}`` dicts of the slot's compressed
    blocks (copied, never re-packed), dense state leaves and ``pos``
    contribute their slot rows.  The spring-survive spill/rescale payload
    for the monolithic backend — :func:`restore_slot_packed` splices it
    back (possibly into another slot / another pool of the same shape)
    with every bit intact.  ``slot`` is a traced scalar."""

    def one(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            return jax.lax.dynamic_slice(leaf, (slot,), (1,))
        if not _is_packed(leaf):
            return _slice_axis(leaf, slot_axis(path), slot)
        slot_ax = len(leaf.shape) + PACKED_SEQ_AXIS[name] - 1
        return {"values": _slice_axis(leaf.values, slot_ax, slot),
                "mask": _slice_axis(leaf.mask, slot_ax, slot),
                "nnz": _slice_axis(leaf.nnz, slot_ax, slot)}

    return jax.tree_util.tree_map_with_path(one, pool, is_leaf=_is_packed)


def restore_slot_packed(pool: dict, payload: dict, slot) -> dict:
    """Inverse of :func:`extract_slot_packed`: splice a slot payload's
    exact packed bits into ``slot`` of the pool."""

    def one(path, leaf):
        name = _leaf_name(path)
        p = _lookup(payload, path)
        if name == "pos":
            return jax.lax.dynamic_update_slice(
                leaf, jnp.asarray(p, leaf.dtype), (slot,))
        if not _is_packed(leaf):
            return _splice_axis(leaf, jnp.asarray(p), slot_axis(path), slot)
        slot_ax = len(leaf.shape) + PACKED_SEQ_AXIS[name] - 1
        return PackedKV(
            values=_splice_axis(leaf.values, jnp.asarray(p["values"]),
                                slot_ax, slot),
            mask=_splice_axis(leaf.mask, jnp.asarray(p["mask"]),
                              slot_ax, slot),
            nnz=_splice_axis(leaf.nnz, jnp.asarray(p["nnz"]), slot_ax, slot),
            shape=leaf.shape, dtype=leaf.dtype,
        )

    return jax.tree_util.tree_map_with_path(one, pool, is_leaf=_is_packed)


def _lookup(tree: dict, path):
    node: Any = tree
    for p in path:
        node = node[getattr(p, "key", getattr(p, "idx", None))]
    return node


def merge_active(new_cache: dict, old_cache: dict, active) -> dict:
    """Keep the decode step's updates only for active slots (idle slots
    must not advance position or accrete garbage KV)."""

    def one(path, new_leaf, old_leaf):
        ax = 0 if _leaf_name(path) == "pos" else slot_axis(path)
        shape = [1] * new_leaf.ndim
        shape[ax] = active.shape[0]
        return jnp.where(active.reshape(shape), new_leaf, old_leaf)

    return jax.tree_util.tree_map_with_path(one, new_cache, old_cache)


def release_slot(dense_pool: dict, slot) -> dict:
    """Zero one slot's rows (and its position) so a retired request stops
    counting toward density/wire accounting immediately."""

    def one(path, leaf):
        name = _leaf_name(path)
        if name == "pos":
            return leaf.at[slot].set(0)
        ax = slot_axis(path)
        idx = (slice(None),) * ax + (slot,)
        return leaf.at[idx].set(jnp.zeros((), leaf.dtype))

    return jax.tree_util.tree_map_with_path(one, dense_pool)


# -- host-side slot accounting ------------------------------------------------


class SlotLedger:
    """Host-side occupancy ledger guarding install/release pairing.

    ``release_packed`` is a pure jitted op: releasing a slot that is
    already free silently re-zeroes it, and the engine-side bookkeeping
    built on top (occupancy, density denominators, peak stats) drifts
    without any visible error.  The ledger makes the pairing explicit —
    double release (and double install) raise :class:`ValueError` at the
    call site instead of corrupting pool accounting downstream."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._occupied: set = set()

    @property
    def occupied(self) -> list:
        return sorted(self._occupied)

    def _check(self, slot: int) -> int:
        slot = int(slot)
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        return slot

    def install(self, slot: int) -> None:
        slot = self._check(slot)
        if slot in self._occupied:
            raise ValueError(
                f"slot {slot} is already installed (released nowhere?)")
        self._occupied.add(slot)

    def release(self, slot: int) -> None:
        slot = self._check(slot)
        if slot not in self._occupied:
            raise ValueError(
                f"double release: slot {slot} is not installed (released "
                f"twice, or never installed)")
        self._occupied.discard(slot)


# -- wire accounting ----------------------------------------------------------


def pool_wire_stats(pool: dict, value_bits: int = KV_VALUE_BITS) -> dict:
    """Measured SPRING-interface traffic of the packed pool vs its dense
    footprints.  Same accounting as ``memstash.format``: live values at
    the 20-bit storage width + the mask words actually stored; the fp32
    baseline is the full dense allocation a GPU serving engine keeps
    resident (and what ``bench_serving`` reports the ratio against)."""
    mask_bits = 0.0
    elems = 0
    logical_bytes = 0.0
    nnz_acc = jnp.zeros((), jnp.float32)  # one device sync for the pool
    for leaf in jax.tree_util.tree_leaves(
            pool, is_leaf=lambda x: isinstance(x, PackedKV)):
        if not isinstance(leaf, PackedKV):
            continue
        n = leaf.n_blocks * leaf.block_len
        nnz_acc = nnz_acc + jnp.sum(leaf.nnz).astype(jnp.float32)
        mask_bits += leaf.n_blocks * _n_words(leaf.block_len) * MASK_WORD_BITS
        elems += n
        logical_bytes += n * leaf.dtype.itemsize
    nnz_total = float(nnz_acc)
    wire_bits = nnz_total * value_bits + mask_bits
    dense_fp32 = elems * 4.0
    wire_bytes = wire_bits / 8.0
    return {
        "kv_elems": float(elems),
        "kv_nnz": nnz_total,
        "kv_density": nnz_total / elems if elems else 0.0,
        "kv_wire_bytes": wire_bytes,
        "kv_logical_bytes": logical_bytes,
        "kv_dense_fp32_bytes": dense_fp32,
        "kv_compression_vs_fp32": dense_fp32 / wire_bytes if wire_bytes else 0.0,
    }
