"""ChaosHarness: drive a serving engine through arbitrary failure
schedules and prove nothing observable changes.

The harness steps an engine tick-by-tick while injecting events at
chosen *harness-step* boundaries (not ``engine.tick`` — a rewind moves
the engine's tick counter backwards, while the harness clock only moves
forward, so every scheduled event fires exactly once):

  ``snapshot``    stash an in-memory snapshot (becomes the rewind target)
  ``rewind``      restore the last stash — the engine re-executes the
                  interval, re-emitting the *same* tokens
  ``kill``        process death: snapshot, abandon the live engine (or
                  swap in a freshly built one via ``make_engine``),
                  restore into the survivor
  ``roundtrip``   snapshot -> .npz on disk -> load -> restore, with a
                  byte-exactness check on the serialized artifact
  ``rescale``     grow/shrink slots (and pages, on the paged backend)
                  on the live engine

The seal (tests/test_elastic.py): for any event schedule hypothesis can
dream up, every completed request's token list is bit-identical to the
uninterrupted run — SPRING's packed-bits snapshot is exact, so chaos is
invisible in the output.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Callable, Optional

from repro.serving.elastic import snapshot as snapshot_mod


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One injected failure: ``kind`` at harness step ``at``.

    ``slots``/``num_pages`` parameterize ``rescale`` (None = keep).
    """

    at: int
    kind: str  # "snapshot" | "rewind" | "kill" | "roundtrip" | "rescale"
    slots: Optional[int] = None
    num_pages: Optional[int] = None

    KINDS = ("snapshot", "rewind", "kill", "roundtrip", "rescale")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown chaos event kind {self.kind!r}")
        if self.at < 0:
            raise ValueError(f"event step must be >= 0, got {self.at}")


class ChaosHarness:
    """Run ``engine`` to completion under an event schedule.

    ``make_engine`` (optional) builds a cold replacement engine for
    ``kill`` events — true process death.  Without it, a kill restores
    into the same object, which exercises the identical code path minus
    engine construction (and keeps jit caches warm for property suites).
    """

    def __init__(self, engine, events, *,
                 make_engine: Optional[Callable[[], object]] = None,
                 max_steps: int = 10_000, tmp_dir: Optional[str] = None):
        self.engine = engine
        self.make_engine = make_engine
        self.max_steps = max_steps
        self.tmp_dir = tmp_dir or tempfile.gettempdir()
        self._pending: dict[int, list[ChaosEvent]] = {}
        for ev in events:
            self._pending.setdefault(ev.at, []).append(ev)
        self.applied: list[ChaosEvent] = []

    def run(self) -> dict:
        """Drain the engine under chaos; returns its final summary."""
        steps = 0
        stash = None
        while self.engine.sched.has_work():
            for ev in self._pending.pop(steps, ()):
                stash = self._apply(ev, stash)
                self.applied.append(ev)
            if not self.engine.sched.has_work():
                break  # a rewind target may itself be fully drained
            self.engine.step()
            self.engine.sched.check_invariants()
            steps += 1
            if steps > self.max_steps:
                raise RuntimeError(
                    f"chaos run still has work after {self.max_steps} steps")
        return self.engine.summary()

    # -- event application ----------------------------------------------------

    def _apply(self, ev: ChaosEvent, stash):
        eng = self.engine
        if ev.kind == "snapshot":
            return eng.snapshot()
        if ev.kind == "rewind":
            if stash is not None:
                eng.restore(stash)
            return stash
        if ev.kind == "kill":
            snap = eng.snapshot()
            survivor = self.make_engine() if self.make_engine else eng
            survivor.restore(snap)
            self.engine = survivor
            return stash
        if ev.kind == "roundtrip":
            snap = eng.snapshot()
            fd, path = tempfile.mkstemp(suffix=".npz", dir=self.tmp_dir)
            os.close(fd)
            try:
                snapshot_mod.save_snapshot(snap, path)
                eng.restore(snapshot_mod.load_snapshot(path))
            finally:
                os.unlink(path)
            return stash
        if ev.kind == "rescale":
            kw = {}
            if ev.num_pages is not None:
                if eng.backend_kind != "paged":
                    raise ValueError(
                        "num_pages rescale needs the paged backend")
                kw["num_pages"] = ev.num_pages
            eng.rescale(ev.slots, **kw)
            return stash
        raise AssertionError(f"unreachable: {ev.kind}")
