"""spring-survive: elastic serving under failure and overload.

Snapshot/restore (exact packed-bits engine state, versioned and
spec-hash-stamped), live slot/page rescaling, and the chaos harness that
seals them against the uninterrupted oracle (DESIGN.md §13).
"""

from repro.serving.elastic.chaos import ChaosEvent, ChaosHarness
from repro.serving.elastic.snapshot import (
    SNAPSHOT_VERSION,
    SnapshotError,
    apply_snapshot,
    build_snapshot,
    check_compatible,
    load_snapshot,
    save_snapshot,
)

__all__ = [
    "SNAPSHOT_VERSION",
    "SnapshotError",
    "ChaosEvent",
    "ChaosHarness",
    "apply_snapshot",
    "build_snapshot",
    "check_compatible",
    "load_snapshot",
    "save_snapshot",
]
