"""spring-survive engine snapshots: one versioned, spec-hash-stamped
artifact per engine, bit-exact on the packed KV pool (DESIGN.md §13).

SPRING's binary-mask format is what makes this cheap and *verifiable*:
the pool's wire state is ``20*density + 1`` bits/elem of exact packed
values + occupancy words, so a snapshot is small (the live KV bits, not
the dense allocation) and a restore can be checked bit-identically —
the restored engine emits the exact remaining tokens of every in-flight
request, because everything a token depends on is captured:

  * the packed pool bits (monolithic pool leaves, or paged store frames
    + dense slot state), copied, never re-packed;
  * scheduler state — queue (policy metadata included), active trackers
    with tokens-so-far, spill queue with exact packed payloads,
    admission/submission/shed logs;
  * per-request sampling keys (each ``Request.seed``; draw indices are
    the tracker token counts) and the engine tick counters
    (``tick``/``decode_steps``) that feed the decode-step PRNG key;
  * the slot ledger, per-slot next-token feed, results so far, and the
    latency sketches (mergeable, bit-exact ``to_dict`` round-trip).

The artifact is a pure host tree (dicts/lists/scalars/numpy arrays) —
``save_snapshot``/``load_snapshot`` serialize it to a single ``.npz``
(arrays + JSON metadata; bfloat16 stored as uint16 bit patterns) and the
round-trip is byte-exact.  ``version`` gates the format;
``spec_hash`` stamps the producing RunSpec like every other artifact in
this repo, and a restore under a different spec hash is rejected with
:class:`SnapshotError` before any state is touched.
"""

from __future__ import annotations

import io
import json
from typing import Any, Optional

import numpy as np

SNAPSHOT_VERSION = 1

#: signature fields that must match exactly between snapshot and engine
#: (n_slots / num_pages are *adapted* by rebuilding the pool instead)
_STRICT_SIG = ("max_len", "greedy", "kv_pack_impl", "kv_unpack_impl",
               "vocab", "d_model", "page_tokens", "overcommit",
               "prefix_cache")


class SnapshotError(ValueError):
    """Snapshot format/compatibility violation (wrong version, wrong
    spec hash, structural mismatch with the restoring engine)."""


# -- pure-tree codec: nested python tree <-> (JSON meta, array list) ---------


def _encode(node, arrays: list) -> Any:
    if node is None or isinstance(node, (bool, int, str)):
        return node
    if isinstance(node, float):
        return node
    if isinstance(node, (np.bool_, np.integer, np.floating)):
        return node.item()
    if hasattr(node, "dtype") and hasattr(node, "shape"):  # np / jax array
        a = np.asarray(node)
        tag = {"__a__": len(arrays)}
        arrays.append(a)
        return tag
    if isinstance(node, tuple):
        return {"__t__": [_encode(x, arrays) for x in node]}
    if isinstance(node, list):
        return [_encode(x, arrays) for x in node]
    if isinstance(node, dict):
        if all(isinstance(k, str) and not k.startswith("__") for k in node):
            return {k: _encode(v, arrays) for k, v in node.items()}
        return {"__d__": [[_encode(k, arrays), _encode(v, arrays)]
                          for k, v in node.items()]}
    raise SnapshotError(f"snapshot tree holds unsupported type {type(node)}")


def _decode(node, arrays: list) -> Any:
    if isinstance(node, dict):
        if "__a__" in node:
            return arrays[node["__a__"]]
        if "__t__" in node:
            return tuple(_decode(x, arrays) for x in node["__t__"])
        if "__d__" in node:
            return {_decode(k, arrays): _decode(v, arrays)
                    for k, v in node["__d__"]}
        return {k: _decode(v, arrays) for k, v in node.items()}
    if isinstance(node, list):
        return [_decode(x, arrays) for x in node]
    return node


def _storable(a: np.ndarray) -> tuple[np.ndarray, str]:
    """npz-safe view + dtype tag (bfloat16 is stored as its uint16 bit
    pattern — the round-trip is byte-exact by construction)."""
    name = a.dtype.name
    if name == "bfloat16":
        return a.view(np.uint16), name
    return a, name


def _unstore(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name == "bfloat16":
        import jax.numpy as jnp

        return a.view(jnp.bfloat16)
    return a


def save_snapshot(snap: dict, path: str) -> str:
    """Write a snapshot tree to one ``.npz`` file; byte-exact round-trip
    with :func:`load_snapshot` (sealed by tests/test_elastic.py)."""
    arrays: list[np.ndarray] = []
    meta = _encode(snap, arrays)
    payload = {}
    dtypes = []
    for i, a in enumerate(arrays):
        stored, name = _storable(np.ascontiguousarray(a))
        payload[f"a{i}"] = stored
        dtypes.append(name)
    header = json.dumps({"meta": meta, "dtypes": dtypes})
    payload["__meta__"] = np.frombuffer(header.encode("utf-8"), np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **payload)
    with open(path, "wb") as f:  # single atomic-ish write of the buffer
        f.write(buf.getvalue())
    return path


def load_snapshot(path: str) -> dict:
    with np.load(path) as z:
        header = json.loads(bytes(z["__meta__"]).decode("utf-8"))
        arrays = [_unstore(z[f"a{i}"], name)
                  for i, name in enumerate(header["dtypes"])]
        return _decode(header["meta"], arrays)


# -- request / result / scheduler (de)serialization ---------------------------


def _req_dict(req) -> dict:
    return {
        "rid": req.rid, "prompt": list(req.prompt),
        "max_tokens": req.max_tokens, "eos_id": req.eos_id,
        "slo_ms": req.slo_ms, "seed": req.seed,
        "img_embeds": (None if req.img_embeds is None
                       else np.asarray(req.img_embeds)),
        "priority": req.priority, "deadline_ticks": req.deadline_ticks,
    }


def _req_from(d: dict):
    from repro.serving.request import Request

    return Request(
        rid=int(d["rid"]), prompt=tuple(int(t) for t in d["prompt"]),
        max_tokens=int(d["max_tokens"]),
        eos_id=None if d["eos_id"] is None else int(d["eos_id"]),
        slo_ms=d["slo_ms"], seed=int(d["seed"]),
        img_embeds=d["img_embeds"], priority=int(d["priority"]),
        deadline_ticks=(None if d["deadline_ticks"] is None
                        else int(d["deadline_ticks"])))


def _result_dict(r) -> dict:
    return {
        "rid": r.rid, "tokens": list(r.tokens), "submit_s": r.submit_s,
        "admit_s": r.admit_s, "first_token_s": r.first_token_s,
        "done_s": r.done_s, "enqueue_tick": r.enqueue_tick,
        "first_token_tick": r.first_token_tick, "finish_tick": r.finish_tick,
        "slot": r.slot, "finished_by": r.finished_by, "rejected": r.rejected,
    }


def _result_from(d: dict):
    from repro.serving.request import RequestResult

    return RequestResult(rid=int(d["rid"]),
                         tokens=[int(t) for t in d["tokens"]],
                         submit_s=d["submit_s"], admit_s=d["admit_s"],
                         first_token_s=d["first_token_s"], done_s=d["done_s"],
                         enqueue_tick=int(d["enqueue_tick"]),
                         first_token_tick=int(d["first_token_tick"]),
                         finish_tick=int(d["finish_tick"]),
                         slot=int(d["slot"]), finished_by=d["finished_by"],
                         rejected=d["rejected"])


def _sched_dict(sched) -> dict:
    return {
        "n_slots": sched.n_slots,
        "queue": [_req_dict(r) for r in sched._queue],
        "queue_meta": [[rid, tick, deadline] for rid, (tick, deadline)
                       in sched._queue_meta.items()],
        "active": [{"slot": s, "rid": t.req.rid, "tokens": list(t.tokens)}
                   for s, t in sorted(sched.active.items())],
        "admission_log": list(sched.admission_log),
        "submit_log": list(sched._submit_log),
        "shed_log": [[rid, reason] for rid, reason in sched.shed_log],
        "spilled": [{"req": _req_dict(s.req), "tokens": list(s.tokens),
                     "payload": s.payload} for s in sched._spilled],
        "n_spills": sched.n_spills,
        "n_resumes": sched.n_resumes,
    }


def _sched_restore(engine, d: dict, requests: dict):
    """Fresh scheduler of the engine's class, repopulated exactly."""
    from repro.serving.scheduler import RequestTracker, SpilledRequest

    sched = type(engine.sched)(int(d["n_slots"]), policy=engine.shed_policy)
    import collections

    sched._queue = collections.deque(
        requests.get(int(q["rid"])) or _req_from(q) for q in d["queue"])
    sched._queue_meta = {
        int(rid): (int(tick), None if deadline is None else int(deadline))
        for rid, tick, deadline in d["queue_meta"]}
    for row in d["active"]:
        slot, rid = int(row["slot"]), int(row["rid"])
        tracker = RequestTracker(requests[rid], slot)
        tracker.tokens = [int(t) for t in row["tokens"]]
        sched.active[slot] = tracker
    sched._free = sorted(set(range(sched.n_slots)) - set(sched.active))
    sched.admission_log = [int(r) for r in d["admission_log"]]
    sched._submit_log = [int(r) for r in d["submit_log"]]
    sched.shed_log = [(int(rid), reason) for rid, reason in d["shed_log"]]
    sched._spilled = [
        SpilledRequest(req=requests.get(int(s["req"]["rid"]))
                       or _req_from(s["req"]),
                       tokens=[int(t) for t in s["tokens"]],
                       payload=s["payload"])
        for s in d["spilled"]]
    sched.n_spills = int(d["n_spills"])
    sched.n_resumes = int(d["n_resumes"])
    return sched


# -- sketches -----------------------------------------------------------------


def _sketch_dict(sk) -> dict:
    return sk.to_dict()


def _sketch_from(d: dict):
    from repro.telemetry.sketch import QuantileSketch

    return QuantileSketch.from_dict(d)


# -- engine snapshot / restore ------------------------------------------------


def build_snapshot(engine) -> dict:
    """One pure host tree capturing the engine's full serving state."""
    snap = {
        "version": SNAPSHOT_VERSION,
        "kind": engine.backend_kind,
        "spec_hash": engine.spec_hash,
        "signature": engine._signature(),
        "tick": engine.tick,
        "decode_steps": engine.decode_steps,
        "next_rid": engine._next_rid,
        "next_tok": np.asarray(engine._next_tok).copy(),
        "ledger": list(engine._ledger.occupied),
        "scheduler": _sched_dict(engine.sched),
        "requests": [_req_dict(r) for _, r in sorted(engine._requests.items())],
        "results": [_result_dict(r) for _, r in sorted(engine._results.items())],
        "metrics": {
            "now_s": engine._now(),
            "prefill_s": engine.prefill_s,
            "decode_s": engine.decode_s,
            "occupancy_sum": engine.occupancy_sum,
            "tokens_emitted": engine.tokens_emitted,
            "peak_kv_wire_bytes": engine.peak_kv_wire_bytes,
            "peak_stats": engine._peak_stats,
            "wire_bytes_sum": engine._wire_bytes_sum,
            "density_sum": engine._density_sum,
            "finite": engine.finite,
            "peak_active": engine.peak_active,
            "queue_sketch": _sketch_dict(engine.queue_sketch),
            "ttft_sketch": _sketch_dict(engine.ttft_sketch),
            "token_sketch": _sketch_dict(engine.token_sketch),
            "n_rejected": dict(engine.n_rejected),
            "n_rescales": engine.n_rescales,
            "slow_ticks": engine.slow_ticks,
        },
        "backend": engine._snapshot_backend(),
    }
    return snap


def check_compatible(engine, snap: dict) -> None:
    """Reject a snapshot the engine cannot restore, before touching any
    state.  Version gate, backend kind, spec-hash stamp, then the strict
    structural signature (pool geometry that cannot be adapted)."""
    if not isinstance(snap, dict) or "version" not in snap:
        raise SnapshotError("not an engine snapshot (no version field)")
    if snap["version"] != SNAPSHOT_VERSION:
        raise SnapshotError(
            f"snapshot version {snap['version']} != supported "
            f"{SNAPSHOT_VERSION}")
    if snap["kind"] != engine.backend_kind:
        raise SnapshotError(
            f"snapshot is for a {snap['kind']} pool, engine is "
            f"{engine.backend_kind}")
    ours, theirs = engine.spec_hash, snap.get("spec_hash")
    if ours is not None and theirs is not None and ours != theirs:
        raise SnapshotError(
            f"snapshot spec_hash {theirs} != engine spec_hash {ours}: "
            "refusing to restore state produced under a different RunSpec")
    sig, mine = snap["signature"], engine._signature()
    for key in _STRICT_SIG:
        if key in sig or key in mine:
            if sig.get(key) != mine.get(key):
                raise SnapshotError(
                    f"snapshot signature mismatch on {key!r}: "
                    f"{sig.get(key)!r} != {mine.get(key)!r}")


def apply_snapshot(engine, snap: dict) -> None:
    """Restore ``engine`` to the snapshot's exact state.  The pool is
    adapted (rebuilt) if the snapshot was taken at a different
    ``n_slots``/``num_pages``; everything else must match (see
    :func:`check_compatible`)."""
    check_compatible(engine, snap)
    engine._reconfigure(snap["signature"])

    requests = {int(d["rid"]): _req_from(d) for d in snap["requests"]}
    engine._requests = requests
    engine._results = {int(d["rid"]): _result_from(d)
                       for d in snap["results"]}
    engine._next_rid = int(snap["next_rid"])
    engine.tick = int(snap["tick"])
    engine.decode_steps = int(snap["decode_steps"])
    engine._next_tok = np.asarray(snap["next_tok"]).astype(np.int64).copy()

    from repro.serving import kvpool

    ledger = kvpool.SlotLedger(engine.n_slots)
    for slot in snap["ledger"]:
        ledger.install(int(slot))
    engine._ledger = ledger
    engine.sched = _sched_restore(engine, snap["scheduler"], requests)

    m = snap["metrics"]
    import time

    engine._t0 = time.monotonic() - float(m["now_s"])
    engine.prefill_s = float(m["prefill_s"])
    engine.decode_s = float(m["decode_s"])
    engine.occupancy_sum = float(m["occupancy_sum"])
    engine.tokens_emitted = int(m["tokens_emitted"])
    engine.peak_kv_wire_bytes = float(m["peak_kv_wire_bytes"])
    engine._peak_stats = m["peak_stats"]
    engine._wire_bytes_sum = float(m["wire_bytes_sum"])
    engine._density_sum = float(m["density_sum"])
    engine.finite = bool(m["finite"])
    engine.peak_active = int(m["peak_active"])
    engine.queue_sketch = _sketch_from(m["queue_sketch"])
    engine.ttft_sketch = _sketch_from(m["ttft_sketch"])
    engine.token_sketch = _sketch_from(m["token_sketch"])
    engine.n_rejected = {k: int(v) for k, v in m["n_rejected"].items()}
    engine.n_rescales = int(m["n_rescales"])
    engine.slow_ticks = int(m["slow_ticks"])

    engine._restore_backend(snap["backend"])


# -- device-tree leaf helpers (used by the engines' backend hooks) ------------


def tree_to_host_leaves(tree) -> list:
    """Flatten a device tree to host numpy leaves (treedef is implied by
    the engine's freshly built structure at restore time)."""
    import jax

    return [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(
        jax.device_get(tree))]


def leaves_to_tree(template, leaves: list, what: str):
    """Unflatten host leaves against ``template``'s structure, validating
    leaf count/shape/dtype — a mismatch means the snapshot was taken
    under a different architecture and is rejected."""
    import jax
    import jax.numpy as jnp

    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(leaves):
        raise SnapshotError(
            f"{what}: snapshot has {len(leaves)} leaves, engine expects "
            f"{len(t_leaves)} — architecture mismatch")
    out = []
    for i, (t, l) in enumerate(zip(t_leaves, leaves)):
        if tuple(t.shape) != tuple(np.asarray(l).shape):
            raise SnapshotError(
                f"{what} leaf {i}: snapshot shape {tuple(np.asarray(l).shape)}"
                f" != engine shape {tuple(t.shape)}")
        out.append(jnp.asarray(l).astype(t.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
