"""The unit of serving work: one prompt -> one bounded generation.

A request owns its PRNG seed, so sampled generations are a function of
the request alone — never of which strangers happened to share its batch
(the batch-composition invariance the parity suite seals).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    prompt: tuple  # token ids
    max_tokens: int
    #: generation stops when this token is emitted (it is included in the
    #: output) or when max_tokens is reached, whichever comes first
    eos_id: Optional[int] = None
    #: soft latency target (submit -> done), recorded per request so the
    #: engine's metrics can attribute SLO misses; admission stays FCFS
    slo_ms: Optional[float] = None
    #: per-request PRNG seed for sampling (greedy decode ignores it)
    seed: int = 0
    #: optional VLM prefix embeddings, (P, d_model) — threaded to prefill
    img_embeds: Optional[Any] = None
    #: priority class (higher = more important).  With a priority-aware
    #: ShedPolicy, admission pops higher classes first and a shrinking
    #: pool evicts lower classes to the spill path first; otherwise
    #: recorded but inert (admission stays FCFS).
    priority: int = 0
    #: admission deadline in scheduler ticks from submission: still
    #: queued after this many ticks -> typed-rejected ("deadline").
    #: None defers to the policy-level default (ShedPolicy.deadline_ticks).
    deadline_ticks: Optional[int] = None

    def __post_init__(self):
        if self.max_tokens <= 0:
            raise ValueError(f"request {self.rid}: max_tokens must be >= 1")
        if len(self.prompt) == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.deadline_ticks is not None and self.deadline_ticks < 0:
            raise ValueError(
                f"request {self.rid}: deadline_ticks must be >= 0")


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: list
    #: wall-clock milestones (engine-relative seconds)
    submit_s: float = 0.0
    admit_s: float = 0.0
    first_token_s: float = 0.0
    done_s: float = 0.0
    #: scheduler-tick milestones (engine tick counter; -1 = not reached).
    #: Wall-clock varies run to run, but tick indices are deterministic
    #: for a given arrival order, so latency *structure* (how many ticks
    #: a request queued, how long it decoded) is recoverable from any
    #: saved artifact.
    enqueue_tick: int = -1
    first_token_tick: int = -1
    finish_tick: int = -1
    slot: int = -1
    finished_by: str = "max_tokens"  # "eos" | "max_tokens" | "rejected"
    #: typed rejection reason ("queue_full" | "deadline") — None when the
    #: request was (or will be) served.  A rejected request has no tokens
    #: and its finished_by is "rejected"; nothing is ever dropped without
    #: one of these two markers (the spring-survive no-silent-loss seal).
    rejected: Optional[str] = None

    @property
    def status(self) -> str:
        if self.rejected is not None:
            return "rejected"
        return "completed" if self.finish_tick >= 0 else "pending"

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submit_s

    @property
    def queue_s(self) -> float:
        return self.admit_s - self.submit_s

    @property
    def decode_ticks(self) -> int:
        """Ticks spent decoding (first token -> finish), -1 if unfinished."""
        if self.first_token_tick < 0 or self.finish_tick < 0:
            return -1
        return self.finish_tick - self.first_token_tick + 1

    def slo_met(self, req: Request) -> Optional[bool]:
        if req.slo_ms is None:
            return None
        return self.latency_s * 1e3 <= req.slo_ms


def make_requests(prompts: Sequence[Sequence[int]], max_tokens: int,
                  *, eos_id: Optional[int] = None, seed: int = 0,
                  slo_ms: Optional[float] = None,
                  img_embeds=None) -> list[Request]:
    """Batch constructor: one request per prompt, rid = submission order,
    per-request seeds folded off the base ``seed``."""
    return [
        Request(rid=i, prompt=tuple(int(t) for t in p), max_tokens=max_tokens,
                eos_id=eos_id, slo_ms=slo_ms, seed=seed + i,
                img_embeds=None if img_embeds is None else img_embeds[i])
        for i, p in enumerate(prompts)
    ]
