"""Mamba-2 block (SSD) [arXiv:2405.21060] — assigned arch mamba2-780m.

Structure per block: in_proj -> split(z, xBC, dt); short causal depthwise
conv over xBC; SSD scan (kernels/ssd_scan: Pallas on TPU, chunked jnp
elsewhere); gated RMSNorm(y * silu(z)); out_proj.  Decode keeps a
(conv_state, ssm_state) pair per layer — O(1) in sequence length, which
is what makes the long_500k cell runnable for this arch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ops import ssd_scan
from repro.models.layers import SpringContext, dense_apply, dense_init, rmsnorm_apply, rmsnorm_init
from repro.runtime.sharding import constrain

CONV_K = 4


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_inner: int  # = n_heads * head_dim
    n_heads: int
    d_state: int = 128
    n_groups: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssm_init(key, d: int, spec: SSMSpec):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * spec.d_inner + 2 * spec.n_groups * spec.d_state + spec.n_heads
    return {
        "in_proj": dense_init(k1, d, proj_out),
        "conv_w": jax.random.normal(k2, (CONV_K, spec.conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((spec.conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, spec.n_heads).astype(jnp.float32)),
        "dt_bias": jnp.zeros((spec.n_heads,), jnp.float32),
        "d_skip": jnp.ones((spec.n_heads,), jnp.float32),
        "norm": rmsnorm_init(spec.d_inner),
        "out_proj": dense_init(k3, spec.d_inner, d),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width CONV_K, via shifted adds. x: (B,S,C)."""
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(CONV_K):
        shift = CONV_K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i]
    return (out + b).astype(x.dtype)


def ssm_apply(
    params,
    x: jax.Array,
    ctx: SpringContext,
    spec: SSMSpec,
    cache: Optional[dict] = None,
    return_cache: bool = False,
):
    """cache: {"conv": (B, CONV_K-1, conv_dim), "ssm": (B, H, N, P)}."""
    b, s, _ = x.shape
    di, h, n, g = spec.d_inner, spec.n_heads, spec.d_state, spec.n_groups
    p = spec.head_dim

    zxbcdt = dense_apply(params["in_proj"], x, ctx, w_logical=("w_embed", "w_mlp"))
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, di + spec.conv_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])  # (H,)

    if cache is None:
        xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]).astype(jnp.float32)).astype(x.dtype)
        xs, bm, cm = jnp.split(xbc, [di, di + g * n], axis=-1)
        xs = constrain(xs.reshape(b, s, h, p), ("batch", "seq", "heads", None))
        if return_cache:
            # resolve through the registry with the state-handoff
            # capability: auto routes to the jnp impl, a pinned impl that
            # cannot return state raises with the impl named
            imp = ctx.kernel_impl("ssd_scan", return_state=True)
            y, final_state = ssd_scan(xs, dt, a, bm.reshape(b, s, g, n), cm.reshape(b, s, g, n),
                                      impl=imp, return_state=True)
            new_cache = {"conv": zxbcdt[:, s - (CONV_K - 1):, di: di + spec.conv_dim].astype(jnp.bfloat16),
                         "ssm": final_state.astype(jnp.bfloat16)}
        else:
            y = ssd_scan(xs, dt, a, bm.reshape(b, s, g, n), cm.reshape(b, s, g, n),
                         impl=ctx.kernel_impl("ssd_scan"))
            new_cache = None
    else:
        assert s == 1
        conv_state = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)  # (B,K,conv)
        acc = (conv_state.astype(jnp.float32) * params["conv_w"][None]).sum(axis=1) + params["conv_b"]
        xbc1 = jax.nn.silu(acc).astype(x.dtype)  # (B, conv_dim)
        xs, bm, cm = jnp.split(xbc1, [di, di + g * n], axis=-1)
        xs = xs.reshape(b, h, p)
        bmr = jnp.repeat(bm.reshape(b, g, n), h // g, axis=1)
        cmr = jnp.repeat(cm.reshape(b, g, n), h // g, axis=1)
        dt1 = dt[:, 0]  # (B,H)
        alpha = jnp.exp(dt1 * a[None, :])
        ssm = cache["ssm"].astype(jnp.float32) * alpha[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bmr * dt1[..., None], xs.astype(jnp.float32)
        )
        y = jnp.einsum("bhn,bhnp->bhp", cmr, ssm).reshape(b, 1, h, p)
        new_cache = {"conv": conv_state[:, 1:], "ssm": ssm.astype(cache["ssm"].dtype)}
        xs = xs.reshape(b, 1, h, p)

    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di)
    y = rmsnorm_apply(params["norm"], y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = dense_apply(params["out_proj"], y, ctx, w_logical=("w_mlp", "w_embed"),
                      out_logical=("batch", "seq", "embed"))
    return out, new_cache


def ssm_init_cache(batch: int, spec: SSMSpec, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, spec.conv_dim), dtype),
        "ssm": jnp.zeros((batch, spec.n_heads, spec.d_state, spec.head_dim), dtype),
    }
