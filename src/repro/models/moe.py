"""Mixture-of-Experts FFN with capacity-bounded top-k dispatch (GShard
style), shardable as expert parallelism over the ``model`` mesh axis.

Assigned MoE archs: olmoe-1b-7b (64e, top-8) and deepseek-v2-lite (64
routed top-6 + 2 shared).  Dispatch is scatter/gather with static
capacity ``C = ceil(T * top_k / E) * capacity_factor`` so every shape is
jit-static; tokens overflowing an expert's capacity are dropped (their
combine weight contributes nothing) — standard GShard semantics, recorded
in DESIGN.md.  FLOPs scale with activated capacity, not E, so the
roofline sees the true MoE compute.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import SpringContext, dense_init
from repro.core.spring_ops import spring_matmul
from repro.runtime.sharding import constrain


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    n_shared: int = 0  # shared (always-on) experts, deepseek-style
    shared_d_ff: int = 0
    capacity_factor: float = 1.25


def moe_init(key, d: int, spec: MoESpec):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, f = spec.n_experts, spec.d_ff
    scale_in = 1.0 / (d**0.5)
    scale_out = 1.0 / (f**0.5)
    p = {
        "router": dense_init(kr, d, e, scale=0.02),
        "w_gate": jax.random.normal(kg, (e, d, f), jnp.float32) * scale_in,
        "w_up": jax.random.normal(ku, (e, d, f), jnp.float32) * scale_in,
        "w_down": jax.random.normal(kd, (e, f, d), jnp.float32) * scale_out,
    }
    if spec.n_shared:
        from repro.models.layers import swiglu_init

        p["shared"] = swiglu_init(ks, d, spec.shared_d_ff * spec.n_shared)
    return p


def _expert_ffn(buf: jax.Array, params, ctx: SpringContext) -> jax.Array:
    """(E, C, d) -> (E, C, d) batched swiglu through SPRING numerics."""
    w_gate = constrain(params["w_gate"], ("w_experts", "w_embed", None))
    w_up = constrain(params["w_up"], ("w_experts", "w_embed", None))
    w_down = constrain(params["w_down"], ("w_experts", None, "w_embed"))
    if ctx.cfg.mode == "dense":
        dt = ctx.cfg.dense_dtype
        g = jnp.einsum("ecd,edf->ecf", buf.astype(dt), w_gate.astype(dt))
        u = jnp.einsum("ecd,edf->ecf", buf.astype(dt), w_up.astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        return jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))
    # quantized path: per-expert spring matmuls via vmap-free reshape
    e, c, d = buf.shape
    f = w_gate.shape[-1]

    def one(args):
        b, wg, wu, wd = args
        g = spring_matmul(b, wg, ctx.cfg, ctx.keys)
        u = spring_matmul(b, wu, ctx.cfg, ctx.keys)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * u
        return spring_matmul(h, wd, ctx.cfg, ctx.keys)

    return jax.lax.map(one, (buf, w_gate, w_up, w_down))


MOE_TOKEN_CHUNK = 32768  # cap dispatch-buffer size at prefill scale


def moe_apply(params, x: jax.Array, ctx: SpringContext, spec: MoESpec):
    """x: (B, S, d) -> (B, S, d), plus aux load-balancing loss.

    Token streams larger than MOE_TOKEN_CHUNK are processed in chunks
    (remat'd, scanned) so the (E, C, d) dispatch buffers never hold the
    k-times-replicated copy of a 1M-token prefill at once.
    """
    b, s, d = x.shape
    if b * s > MOE_TOKEN_CHUNK and s % 2 == 0:
        nc = 1
        tc = s
        while b * tc > MOE_TOKEN_CHUNK and tc % 2 == 0:
            tc //= 2
            nc *= 2

        @jax.checkpoint
        def one(xc):
            return moe_apply(params, xc, ctx, spec)

        xs = x.reshape(b, nc, tc, d).swapaxes(0, 1)  # (nc, B, tc, d)
        ys, auxs = jax.lax.map(one, xs)
        y = ys.swapaxes(0, 1).reshape(b, s, d)
        return y, auxs.mean()
    t = b * s
    e, k = spec.n_experts, spec.top_k
    cap = int((t * k / e) * spec.capacity_factor + 0.999)
    cap = max(cap, 4)

    flat = x.reshape(t, d)
    logits = jnp.einsum(
        "td,de->te", flat.astype(jnp.float32), params["router"]["kernel"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)

    dispatched = jnp.zeros((e, cap, d), flat.dtype)

    # position of each (token, slot) within its expert = assignments before
    # it in flattened token-major order (a static, consistent priority rule)
    onehots = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (T, k, E)
    flat_oh = onehots.reshape(t * k, e)
    pos_all = jnp.cumsum(flat_oh, axis=0) - flat_oh  # (T*k, E)
    pos = jnp.take_along_axis(
        pos_all, gate_idx.reshape(t * k, 1), axis=1
    ).reshape(t, k)
    ce = flat_oh.sum(axis=0).astype(jnp.float32) / (t * k)
    aux_loss = e * jnp.sum(me * ce)

    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)
    drop_e = jnp.where(keep, gate_idx, e)  # out-of-range expert -> dropped

    # scatter tokens into (E, C, d)
    dispatched = dispatched.at[drop_e.reshape(-1), safe_pos.reshape(-1)].set(
        jnp.repeat(flat[:, None, :], k, axis=1).reshape(t * k, d), mode="drop"
    )
    dispatched = constrain(dispatched, ("experts_act", "capacity", "embed"))

    out_buf = _expert_ffn(dispatched, params, ctx)  # (E, C, d)
    out_buf = constrain(out_buf, ("experts_act", "capacity", "embed"))

    gathered = out_buf[jnp.where(keep, gate_idx, 0).reshape(-1), safe_pos.reshape(-1)]
    gathered = gathered.reshape(t, k, d).astype(jnp.float32)
    w = jnp.where(keep, gate_vals, 0.0)
    combined = jnp.einsum("tkd,tk->td", gathered, w)
    y = combined.reshape(b, s, d).astype(x.dtype)
    y = constrain(y, ("batch", "seq", "embed"))

    if spec.n_shared:
        from repro.models.layers import swiglu_apply

        y = y + swiglu_apply(params["shared"], x, ctx)
    return y, aux_loss
