"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Assigned arch recurrentgemma-9b: layers alternate 2 recurrent blocks to 1
local-attention block.  The recurrent temporal-mixing block is:

    x -> linear_x -> causal conv(4) -> RG-LRU ----\
    x -> linear_y -> GeLU -----------------------(*)--> linear_out

RG-LRU: r_t = sigmoid(W_r u); i_t = sigmoid(W_i u);
        a_t = exp(-c * softplus(L) * r_t);
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Train/prefill uses jax.lax.associative_scan (log-depth); decode is the
single-step recurrence — O(1) state, so long_500k runs for this arch.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import SpringContext, dense_apply, dense_init
from repro.models.ssm import CONV_K, _causal_conv
from repro.runtime.sharding import constrain

RGLRU_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_rnn: int  # lru width


def rglru_block_init(key, d: int, spec: RGLRUSpec):
    kx, ky, kr, ki, ko, kl = jax.random.split(key, 6)
    dr = spec.d_rnn
    return {
        "wx": dense_init(kx, d, dr),
        "wy": dense_init(ky, d, dr),
        "conv_w": jax.random.normal(kl, (CONV_K, dr), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_r": dense_init(kr, dr, dr),
        "w_i": dense_init(ki, dr, dr),
        # Lambda init so a^c in [0.9, 0.999] at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(jnp.linspace(0.9, 4.0, dr).astype(jnp.float32))),
        "wo": dense_init(ko, dr, d),
    }


def _rglru_scan(u: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array) -> jax.Array:
    """Associative scan of h_t = a_t h_{t-1} + b_t over the seq axis.

    u,r,i: (B, S, D) fp32.  Composition: (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2).
    """
    log_a = -RGLRU_C * jax.nn.softplus(lam) * r  # (B,S,D), negative
    a = jnp.exp(log_a)
    gated = i * u
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)) * gated

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(
    params,
    x: jax.Array,
    ctx: SpringContext,
    spec: RGLRUSpec,
    cache: Optional[dict] = None,
    return_cache: bool = False,
):
    """cache: {"conv": (B, CONV_K-1, d_rnn), "h": (B, d_rnn)}."""
    b, s, _ = x.shape
    u = dense_apply(params["wx"], x, ctx, w_logical=("w_embed", "w_mlp"))
    y_gate = dense_apply(params["wy"], x, ctx, w_logical=("w_embed", "w_mlp"))
    y_gate = jax.nn.gelu(y_gate.astype(jnp.float32)).astype(x.dtype)

    if cache is None:
        u_raw = u
        u = _causal_conv(u, params["conv_w"], params["conv_b"])
        uf = u.astype(jnp.float32)
        r = jax.nn.sigmoid(
            jnp.einsum("bsd,de->bse", uf, params["w_r"]["kernel"])
        )
        i = jax.nn.sigmoid(
            jnp.einsum("bsd,de->bse", uf, params["w_i"]["kernel"])
        )
        h = _rglru_scan(uf, r, i, params["lam"])
        new_cache = None
        if return_cache:
            new_cache = {"conv": u_raw[:, s - (CONV_K - 1):].astype(jnp.bfloat16),
                         "h": h[:, -1].astype(jnp.bfloat16)}
    else:
        assert s == 1
        conv_state = jnp.concatenate([cache["conv"], u.astype(cache["conv"].dtype)], axis=1)
        uf = ((conv_state.astype(jnp.float32) * params["conv_w"][None]).sum(axis=1) + params["conv_b"])  # (B,dr)
        r = jax.nn.sigmoid(uf @ params["w_r"]["kernel"])
        i = jax.nn.sigmoid(uf @ params["w_i"]["kernel"])
        log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r
        a = jnp.exp(log_a)
        h1 = a * cache["h"].astype(jnp.float32) + jnp.sqrt(
            jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0)
        ) * (i * uf)
        h = h1[:, None, :]
        new_cache = {"conv": conv_state[:, 1:], "h": h1.astype(cache["h"].dtype)}

    h = constrain(h.astype(x.dtype), ("batch", "seq", "mlp_act"))
    out = dense_apply(params["wo"], h * y_gate, ctx, w_logical=("w_mlp", "w_embed"),
                      out_logical=("batch", "seq", "embed"))
    return out, new_cache


def rglru_init_cache(batch: int, spec: RGLRUSpec, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, spec.d_rnn), dtype),
        "h": jnp.zeros((batch, spec.d_rnn), dtype),
    }
