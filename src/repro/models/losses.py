"""Chunked cross-entropy: the (tokens x vocab) logits tensor never
materializes whole.  Full chunks run under lax.scan with remat; a
remainder chunk (seq-1 is rarely chunk-divisible) is handled separately.
Peak live logits = global_batch x chunk x vocab, sharded over
(data, model) — the difference between fitting and 300 GB/chip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain

LOSS_CHUNK = 512


def chunked_softmax_xent(
    h: jax.Array,  # (B, N, d) final hidden states (pre-head)
    labels: jax.Array,  # (B, N) int32
    w_vocab: jax.Array,  # (d, V)
    chunk: int = LOSS_CHUNK,
    logits_dtype=jnp.float32,
) -> jax.Array:
    """Sum of token cross-entropies (caller normalizes).

    ``logits_dtype=bf16`` computes the head matmul in bf16 (LSE stays
    fp32) — halves loss-path HBM/collective traffic (§Perf lever)."""
    b, n, d = h.shape
    w_vocab = constrain(w_vocab, ("w_embed", "w_vocab"))

    @jax.checkpoint
    def chunk_ce(h_blk, y_blk):
        logits = jnp.einsum(
            "btd,dv->btv", h_blk.astype(logits_dtype), w_vocab.astype(logits_dtype)
        )
        logits = constrain(logits, ("batch", "seq", "vocab_act")).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_blk[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    c = min(chunk, n)
    n_full = n // c
    rem = n % c
    total = jnp.zeros((), jnp.float32)
    if n_full == 1 and rem == 0:
        return chunk_ce(h, labels)
    if n_full > 0:
        def body(acc, i):
            h_blk = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
            y_blk = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
            return acc + chunk_ce(h_blk, y_blk), None

        total, _ = jax.lax.scan(body, total, jnp.arange(n_full))
    if rem:
        total = total + chunk_ce(h[:, n_full * c :], labels[:, n_full * c :])
    return total
