"""Attention mixers: GQA (full / sliding-window) and DeepSeek MLA.

Two execution regimes:
  * train/prefill — memory-efficient chunked attention (lax.scan over
    query chunks, online accumulation is unnecessary since the full kv is
    visible per chunk; window shapes slice only the live kv band).  On
    TPU the Pallas flash kernel (kernels/flash_attention) is the drop-in;
    the jnp chunked form lowers everywhere and is what the dry-run costs.
  * decode — single new token against a KV cache (dense matvecs).  MLA
    uses the absorbed form: scores and values live in the 512-d latent,
    so the cache is (latent + shared rope key), not per-head k/v.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import SpringContext, dense_apply, dense_init, rope_apply
from repro.runtime.sharding import constrain

Q_CHUNK = 1024


def _pos_vec(pos: jax.Array, b: int) -> jax.Array:
    """Decode position(s) as a (B,) vector.

    The static serving path passes one scalar position for the whole
    batch; the continuous-batching engine passes a per-slot (B,) vector
    (slots sit at different depths mid-flight).  All decode-branch math is
    written against the vector form; a scalar broadcasts to it, so the
    two paths share one lowering and stay bit-identical when every row is
    at the same position.
    """
    return jnp.broadcast_to(pos, (b,)).astype(jnp.int32)


def _row_update(cache_leaf: jax.Array, new: jax.Array, slot_v: jax.Array) -> jax.Array:
    """Write row b's single new entry at seq index ``slot_v[b]``."""
    b = new.shape[0]
    return cache_leaf.at[jnp.arange(b), slot_v].set(
        new[:, 0].astype(cache_leaf.dtype))


def _q8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(batch,seq,head) int8 quantization of cache lines (SPRING P2
    applied to the KV cache: halves decode's HBM floor vs bf16)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.bfloat16)


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None  # sliding-window size (recurrentgemma local)
    qkv_bias: bool = False  # qwen2


def gqa_init(key, d: int, spec: AttnSpec):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, spec.n_heads * spec.head_dim, bias=spec.qkv_bias),
        "wk": dense_init(kk, d, spec.n_kv_heads * spec.head_dim, bias=spec.qkv_bias),
        "wv": dense_init(kv, d, spec.n_kv_heads * spec.head_dim, bias=spec.qkv_bias),
        "wo": dense_init(ko, spec.n_heads * spec.head_dim, d),
    }


def _chunked_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    q_chunk: int = Q_CHUNK,
) -> jax.Array:
    """Dense-math attention, scanned over query chunks to bound memory.

    Peak live intermediate is (B, H, q_chunk, S_kv_band) — for 32k prefill
    at q_chunk=1024 that is ~1/32 of the full score matrix.
    """
    b, s, h, d = q.shape
    skv = k.shape[1]  # != s for cross-attention (whisper decoder->encoder)
    dv = v.shape[-1]  # may differ from d (MLA: qk 192, v 128)
    kv_heads = k.shape[2]
    group = h // kv_heads
    scale = 1.0 / (d**0.5)
    qc = q_chunk if s % q_chunk == 0 else s  # fall back for odd small seqs
    nchunks = s // qc

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    @jax.checkpoint
    def one_chunk(ci):
        q_blk = jax.lax.dynamic_slice_in_dim(q, ci * qc, qc, axis=1).astype(jnp.float32)
        q_idx = ci * qc + jnp.arange(qc)
        if window is not None:
            # only the last (window + qc) keys can be visible to this chunk
            band = min(skv, window + qc)
            start = jnp.clip(ci * qc + qc - band, 0, skv - band)
            k_blk = jax.lax.dynamic_slice_in_dim(kf, start, band, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(vf, start, band, axis=1)
            k_idx = start + jnp.arange(band)
        else:
            k_blk, v_blk, k_idx = kf, vf, jnp.arange(skv)
        # (B, qc, H, D) x (B, Skv, KV, D) -> (B, H, qc, Skv)
        qh = q_blk.reshape(b, qc, kv_heads, group, d)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, k_blk) * scale
        mask = jnp.ones((qc, k_idx.shape[0]), bool)
        if causal:
            mask &= q_idx[:, None] >= k_idx[None, :]
        if window is not None:
            mask &= k_idx[None, :] > q_idx[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_blk)
        return out.reshape(b, qc, h, dv).astype(q.dtype)

    if nchunks == 1:
        return one_chunk(0)
    outs = jax.lax.map(one_chunk, jnp.arange(nchunks))  # (nc, B, qc, H, Dv)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)


def gqa_apply(
    params,
    x: jax.Array,
    ctx: SpringContext,
    spec: AttnSpec,
    positions: jax.Array,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    return_cache: bool = False,
):
    """Full-sequence attention (cache=None) or one-step decode (cache set).

    cache: {"k": (B, S_max, KV, D), "v": ...}; ``pos`` is the scalar decode
    position — the new kv is inserted at ``pos`` (ring-indexed when
    spec.window is set) and the updated cache is returned.
    """
    b, s, d_model = x.shape
    h, kv, d = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = dense_apply(params["wq"], x, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, s, h, d)
    k = dense_apply(params["wk"], x, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, s, kv, d)
    v = dense_apply(params["wv"], x, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, s, kv, d)
    q = constrain(rope_apply(q, positions, spec.rope_theta), ("batch", "seq", "heads", "head_dim"))
    k = constrain(rope_apply(k, positions, spec.rope_theta), ("batch", "seq", "kv_heads", "head_dim"))

    int8_cache = getattr(ctx, "int8_cache", False) and spec.window is None
    if cache is None:
        # Chunked jnp attention is the default lowering (it is what the
        # dry-run costs); a KernelPolicy pin reroutes the whole pass
        # through the flash_attention registry op (Pallas on TPU,
        # dense-softmax ref / interpret elsewhere).
        imp = ctx.kernel_pinned("flash_attention")
        if imp is not None:
            from repro.kernels.flash_attention.ops import flash_attention

            out = flash_attention(
                q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                causal=spec.causal, window=spec.window, impl=imp,
            ).swapaxes(1, 2).astype(x.dtype)
        else:
            out = _chunked_attention(q, k, v, causal=spec.causal, window=spec.window)
        new_cache = None
        if return_cache and int8_cache:
            kq, ks = _q8(k)
            vq, vs = _q8(v)
            new_cache = {"k_q8": kq, "k_sc": ks, "v_q8": vq, "v_sc": vs}
        elif return_cache:
            # prefill fills the serving cache; window caches are rings with
            # the invariant slot(p) = p % window for any prefill length
            kc, vc = k, v
            if spec.window is not None:
                w = spec.window
                if s >= w:
                    last = jnp.arange(s - w, s)
                    slots = last % w
                    kc = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, slots].set(k[:, -w:])
                    vc = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, slots].set(v[:, -w:])
                else:
                    kc = jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))
                    vc = jnp.pad(v, ((0, 0), (0, w - s), (0, 0), (0, 0)))
            kn = "k_ring" if spec.window is not None else "k"
            vn = "v_ring" if spec.window is not None else "v"
            new_cache = {kn: constrain(kc.astype(jnp.bfloat16), ("cache_batch", "cache_seq", "cache_heads", "head_dim")),
                         vn: constrain(vc.astype(jnp.bfloat16), ("cache_batch", "cache_seq", "cache_heads", "head_dim"))}
    elif int8_cache:
        assert s == 1
        pos_v = _pos_vec(pos, b)
        kq1, ks1 = _q8(k)
        vq1, vs1 = _q8(v)
        ckq = _row_update(cache["k_q8"], kq1, pos_v)
        cks = _row_update(cache["k_sc"], ks1, pos_v)
        cvq = _row_update(cache["v_q8"], vq1, pos_v)
        cvs = _row_update(cache["v_sc"], vs1, pos_v)
        ckq = constrain(ckq, ("cache_batch", "cache_seq", "cache_heads", "head_dim"))
        cvq = constrain(cvq, ("cache_batch", "cache_seq", "cache_heads", "head_dim"))
        group = h // kv
        qh = q.reshape(b, kv, group, d)
        # scale-factored dequant: the int8->f32 convert feeds the dot
        # directly (fuses on TPU; no dequantized cache buffer)
        scores = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                            ckq.astype(jnp.float32))
        scores = scores * jnp.moveaxis(cks.astype(jnp.float32), 1, 2)[:, :, None, :] / (d**0.5)
        valid = jnp.arange(ckq.shape[1])[None, :] <= pos_v[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        pv = p * jnp.moveaxis(cvs.astype(jnp.float32), 1, 2)[:, :, None, :]
        out = jnp.einsum("bkgs,bskd->bkgd", pv, cvq.astype(jnp.float32))
        out = out.reshape(b, 1, h, d).astype(x.dtype)
        new_cache = {"k_q8": ckq, "k_sc": cks, "v_q8": cvq, "v_sc": cvs}
    else:
        assert s == 1, "decode processes one token per step"
        pos_v = _pos_vec(pos, b)
        kn = "k_ring" if spec.window is not None else "k"
        vn = "v_ring" if spec.window is not None else "v"
        s_max = cache[kn].shape[1]
        slot_v = pos_v % s_max if spec.window is not None else pos_v
        ck = _row_update(cache[kn], k, slot_v)
        cv = _row_update(cache[vn], v, slot_v)
        ck = constrain(ck, ("cache_batch", "cache_seq", "cache_heads", "head_dim"))
        cv = constrain(cv, ("cache_batch", "cache_seq", "cache_heads", "head_dim"))
        group = h // kv
        qh = q.reshape(b, kv, group, d)
        scores = jnp.einsum(
            "bkgd,bskd->bkgs", qh.astype(jnp.float32), ck.astype(jnp.float32)
        ) / (d**0.5)
        idx = jnp.arange(s_max)
        if spec.window is not None:
            # ring invariant: slot i holds the latest position p <= pos with
            # p % s_max == i, i.e. p = pos - ((pos - i) mod s_max)
            abs_pos = pos_v[:, None] - jnp.mod(pos_v[:, None] - idx[None, :], s_max)
            valid = (abs_pos >= 0) & (abs_pos > pos_v[:, None] - spec.window)
        else:
            valid = idx[None, :] <= pos_v[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", p, cv.astype(jnp.float32))
        out = out.reshape(b, 1, h, d).astype(x.dtype)
        new_cache = {kn: ck, vn: cv}

    out = dense_apply(
        params["wo"], out.reshape(b, s, h * d), ctx,
        w_logical=("w_qkv", "w_embed"), out_logical=("batch", "seq", "embed"),
    )
    return out, new_cache


def gqa_init_cache(batch: int, spec: AttnSpec, max_len: int, dtype=jnp.bfloat16):
    if dtype == "int8" and spec.window is None:
        return {
            "k_q8": jnp.zeros((batch, max_len, spec.n_kv_heads, spec.head_dim), jnp.int8),
            "k_sc": jnp.zeros((batch, max_len, spec.n_kv_heads), jnp.bfloat16),
            "v_q8": jnp.zeros((batch, max_len, spec.n_kv_heads, spec.head_dim), jnp.int8),
            "v_sc": jnp.zeros((batch, max_len, spec.n_kv_heads), jnp.bfloat16),
        }
    if dtype == "int8":
        dtype = jnp.bfloat16  # ring/window caches stay bf16 (small)
    if spec.window is not None:
        return {
            "k_ring": jnp.zeros((batch, spec.window, spec.n_kv_heads, spec.head_dim), dtype),
            "v_ring": jnp.zeros((batch, spec.window, spec.n_kv_heads, spec.head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, max_len, spec.n_kv_heads, spec.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, spec.n_kv_heads, spec.head_dim), dtype),
    }


# --------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLASpec:
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


def mla_init(key, d: int, spec: MLASpec):
    kq, kkv, kr, kuk, kuv, ko = jax.random.split(key, 6)
    h = spec.n_heads
    return {
        "wq": dense_init(kq, d, h * (spec.qk_nope_dim + spec.qk_rope_dim)),
        "wdkv": dense_init(kkv, d, spec.kv_lora_rank),
        "wkr": dense_init(kr, d, spec.qk_rope_dim),
        "wuk": dense_init(kuk, spec.kv_lora_rank, h * spec.qk_nope_dim),
        "wuv": dense_init(kuv, spec.kv_lora_rank, h * spec.v_head_dim),
        "wo": dense_init(ko, h * spec.v_head_dim, d),
    }


def mla_apply(
    params,
    x: jax.Array,
    ctx: SpringContext,
    spec: MLASpec,
    positions: jax.Array,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    return_cache: bool = False,
):
    """cache: {"ckv": (B, S, rank), "krope": (B, S, dr)}; pos = decode slot."""
    b, s, _ = x.shape
    h, dn, dr, dv = spec.n_heads, spec.qk_nope_dim, spec.qk_rope_dim, spec.v_head_dim
    rank = spec.kv_lora_rank
    scale = 1.0 / ((dn + dr) ** 0.5)

    q = dense_apply(params["wq"], x, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = rope_apply(qr, positions, spec.rope_theta)
    ckv = dense_apply(params["wdkv"], x, ctx, w_logical=("w_embed", None))  # (B,S,rank)
    krope = rope_apply(
        dense_apply(params["wkr"], x, ctx, w_logical=("w_embed", None))[:, :, None, :],
        positions, spec.rope_theta,
    )[:, :, 0, :]  # (B, S, dr), shared across heads

    wuk = params["wuk"]["kernel"].reshape(rank, h, dn)
    wuv = params["wuv"]["kernel"].reshape(rank, h, dv)

    if cache is None:
        # prefill: expand latent to per-head keys/values (standard form)
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv.astype(jnp.float32), wuk).astype(x.dtype)
        vh = jnp.einsum("bsr,rhd->bshd", ckv.astype(jnp.float32), wuv).astype(x.dtype)
        k_full = jnp.concatenate([k_nope, jnp.broadcast_to(krope[:, :, None, :], (b, s, h, dr)).astype(x.dtype)], -1)
        q_full = jnp.concatenate([qn, qr], -1)
        out = _chunked_attention(q_full, k_full, vh, causal=True, window=None)
        out = out.reshape(b, s, h * dv)
        new_cache = None
        if return_cache:
            new_cache = {"ckv": ckv.astype(jnp.bfloat16), "krope": krope.astype(jnp.bfloat16)}
    else:
        assert s == 1
        pos_v = _pos_vec(pos, b)
        ck = _row_update(cache["ckv"], ckv, pos_v)
        cr = _row_update(cache["krope"], krope, pos_v)
        # absorbed decode: project q into the latent space, attend in latent
        q_lat = jnp.einsum("bhd,rhd->bhr", qn[:, 0].astype(jnp.float32), wuk)  # (B,H,rank)
        s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, ck.astype(jnp.float32))
        s_rope = jnp.einsum("bhd,bsd->bhs", qr[:, 0].astype(jnp.float32), cr.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale
        valid = jnp.arange(ck.shape[1])[None, :] <= pos_v[:, None]
        scores = jnp.where(valid[:, None, :], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhs,bsr->bhr", p, ck.astype(jnp.float32))
        out = jnp.einsum("bhr,rhd->bhd", ctx_lat, wuv).reshape(b, 1, h * dv).astype(x.dtype)
        new_cache = {"ckv": ck, "krope": cr}

    # (prefill path: _chunked_attention scales by 1/sqrt(dn+dr) internally,
    #  matching the decode path's explicit ``scale``.)
    out = dense_apply(params["wo"], out, ctx, w_logical=("w_qkv", "w_embed"),
                      out_logical=("batch", "seq", "embed"))
    return out, new_cache


def mla_init_cache(batch: int, spec: MLASpec, max_len: int, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((batch, max_len, spec.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, spec.qk_rope_dim), dtype),
    }
