"""Foundational layers. Functional style: ``*_init(key,...) -> params`` /
``*_apply(params, x, ctx, ...)``.  Every matmul funnels through
``core.spring_ops`` so the paper's numerics (dense | quant | quant_sparse)
apply uniformly across all architectures (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spring_ops import DENSE, KeyGen, SpringConfig, spring_matmul
from repro.memstash.config import MemstashConfig
from repro.runtime.sharding import constrain


@dataclasses.dataclass
class SpringContext:
    """Per-call numerics context threaded through every layer."""

    cfg: SpringConfig = DENSE
    keys: Optional[KeyGen] = None
    # Magnitude-pruning ratio for weight sparsity (LM archs; paper §2.2
    # cites 20-80% weight sparsity).  Masks are derived inline from a
    # Gaussian-calibrated threshold — no stored mask tensors.
    prune_ratio: float = 0.0
    # int8 KV cache (SPRING reduced precision applied to serving state)
    int8_cache: bool = False
    # Compressed-activation-stash policy for training (memstash subsystem);
    # None means every stash point resolves to "none".
    memstash: Optional[MemstashConfig] = None

    def stash_policy(self, name: str, elems: Optional[int] = None) -> str:
        """Resolve the checkpoint policy for one named stash point."""
        if self.memstash is None:
            return "none"
        return self.memstash.policy_for(name, elems)

    def kernel_impl(self, op: str, **caps) -> str:
        """Resolve a kernel op under this context's KernelPolicy.

        Returns the concrete impl name model code passes as ``impl=`` so
        every kernel call site dispatches through the registry with the
        config-threaded policy (CLI ``--kernel-impl``) taking effect.
        """
        from repro.kernels import registry

        return registry.resolve_with(self.cfg.kernels, op, **caps).name

    def backward_sparsity(self) -> str:
        """The backward-sparsity switch in force for this context.

        "none" unless the sparsity-aware custom_vjp backward is actually
        in force (same ``sparse_backward`` gate the spring ops dispatch
        on); otherwise the SpringConfig switch — "auto" or a pinned
        backward impl name.
        """
        return self.cfg.backward_sparsity if self.cfg.sparse_backward else "none"

    def kernel_pinned(self, op: str) -> Optional[str]:
        """Non-auto impl explicitly pinned for ``op``, else None.

        Used by call sites that have their own preferred non-kernel
        lowering (e.g. chunked jnp attention) and only reroute through
        the kernel wrapper when the user pinned a backend.
        """
        from repro.kernels import registry

        pol = self.cfg.kernels
        if pol.is_auto:
            pol = registry.current_policy()
        name = pol.impl_for(op)
        if name == "auto":
            return None
        if op in dict(pol.overrides):
            return name  # per-op pin: strict
        # soft global default: applies only where the op registers it
        return name if name in registry.impls(op) else None

    def maybe_prune(self, w: jax.Array) -> jax.Array:
        if self.prune_ratio <= 0.0:
            return w
        # For w ~ N(0, s): P(|w| < t) = erf(t / (s*sqrt(2)))
        t = jax.scipy.special.erfinv(jnp.float32(self.prune_ratio)) * math.sqrt(2.0)
        std = jnp.std(w.astype(jnp.float32)) + 1e-12
        return jnp.where(jnp.abs(w) >= t * std, w, 0.0).astype(w.dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float | None = None):
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    p = {"kernel": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_apply(
    params,
    x: jax.Array,
    ctx: SpringContext,
    *,
    w_logical: tuple = (None, None),
    out_logical: Optional[tuple] = None,
) -> jax.Array:
    w = constrain(params["kernel"], w_logical)
    w = ctx.maybe_prune(w)
    shape = x.shape
    y = spring_matmul(x.reshape(-1, shape[-1]), w, ctx.cfg, ctx.keys)
    y = y.reshape(*shape[:-1], w.shape[-1])
    if "bias" in params:
        y = (y + params["bias"].astype(y.dtype)).astype(y.dtype)
    if out_logical is not None:
        y = constrain(y, out_logical)
    return y


def embed_init(key, vocab: int, d: int):
    return {"embedding": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed_apply(params, tokens: jax.Array, ctx: SpringContext) -> jax.Array:
    emb = constrain(params["embedding"], ("w_vocab", "w_embed"))
    # quantized modes carry fp32 activations (the Q4.16 grid does not fit
    # in bf16); dense mode uses the configured compute dtype.
    act_dtype = jnp.float32 if ctx.cfg.is_quantized else ctx.cfg.dense_dtype
    y = jnp.take(emb, tokens, axis=0).astype(act_dtype)
    return constrain(y, ("batch", "seq", "embed"))


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings.
# --------------------------------------------------------------------------


def rope_apply(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Feed-forward blocks.
# --------------------------------------------------------------------------


def swiglu_init(key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff),
        "up": dense_init(k2, d, d_ff),
        "down": dense_init(k3, d_ff, d),
    }


def swiglu_apply(params, x: jax.Array, ctx: SpringContext) -> jax.Array:
    g = dense_apply(params["gate"], x, ctx, w_logical=("w_embed", "w_mlp"))
    u = dense_apply(params["up"], x, ctx, w_logical=("w_embed", "w_mlp"))
    h = constrain(jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * u, ("batch", "seq", "mlp_act"))
    return dense_apply(params["down"], h, ctx, w_logical=("w_mlp", "w_embed"),
                       out_logical=("batch", "seq", "embed"))


def gelu_mlp_init(key, d: int, d_ff: int, *, bias: bool = True):
    k1, k2 = jax.random.split(key)
    return {"fc1": dense_init(k1, d, d_ff, bias=bias), "fc2": dense_init(k2, d_ff, d, bias=bias)}


def gelu_mlp_apply(params, x: jax.Array, ctx: SpringContext) -> jax.Array:
    h = dense_apply(params["fc1"], x, ctx, w_logical=("w_embed", "w_mlp"))
    h = constrain(jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype), ("batch", "seq", "mlp_act"))
    return dense_apply(params["fc2"], h, ctx, w_logical=("w_mlp", "w_embed"),
                       out_logical=("batch", "seq", "embed"))
