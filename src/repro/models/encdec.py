"""Whisper-style encoder-decoder backbone (assigned arch whisper-medium,
[arXiv:2212.04356]).  The conv/mel frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, S_enc, d) —
the transformer backbone is what's modeled.

Encoder: non-causal self-attention + GELU MLP, LayerNorm, sinusoidal pos.
Decoder: causal self-attention + cross-attention + GELU MLP.
Decode caches: per-layer self KV (grows) + cross KV (computed once).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import AttnSpec, _chunked_attention
from repro.models.layers import (
    SpringContext,
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    gelu_mlp_apply,
    gelu_mlp_init,
    layernorm_apply,
    layernorm_init,
)
from repro.runtime.sharding import constrain


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    d_model: int
    vocab: int
    n_enc_layers: int
    n_dec_layers: int
    n_heads: int
    d_ff: int
    enc_seq: int = 1500  # whisper 30s @ 50Hz after conv stem
    remat: bool = True
    scan_unroll: bool = False  # dry-run cost mode (see LMConfig)
    supports_long_context: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def attn(self) -> AttnSpec:
        return AttnSpec(self.n_heads, self.n_heads, self.head_dim, causal=True)


def _sinusoid(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _mha_init(key, d: int, n_heads: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, d),
        "wk": dense_init(kk, d, d),
        "wv": dense_init(kv, d, d),
        "wo": dense_init(ko, d, d),
    }


def _project_qkv(params, xq, xkv, ctx, n_heads):
    b, sq, d = xq.shape
    skv = xkv.shape[1]
    hd = d // n_heads
    q = dense_apply(params["wq"], xq, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, sq, n_heads, hd)
    k = dense_apply(params["wk"], xkv, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, skv, n_heads, hd)
    v = dense_apply(params["wv"], xkv, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, skv, n_heads, hd)
    return q, k, v


def _mha(params, xq, xkv, ctx, n_heads, causal):
    q, k, v = _project_qkv(params, xq, xkv, ctx, n_heads)
    out = _chunked_attention(q, k, v, causal=causal, window=None)
    b, sq, h, hd = out.shape
    return dense_apply(params["wo"], out.reshape(b, sq, h * hd), ctx,
                       w_logical=("w_qkv", "w_embed"), out_logical=("batch", "seq", "embed"))


def encdec_init(key, cfg: EncDecConfig) -> dict:
    keys = jax.random.split(key, 6)
    d = cfg.d_model

    def enc_layer(i):
        ka, kf = jax.random.split(jax.random.fold_in(keys[0], i))
        return {
            "ln1": layernorm_init(d),
            "attn": _mha_init(ka, d, cfg.n_heads),
            "ln2": layernorm_init(d),
            "mlp": gelu_mlp_init(kf, d, cfg.d_ff, bias=True),
        }

    def dec_layer(i):
        ka, kx, kf = jax.random.split(jax.random.fold_in(keys[1], i), 3)
        return {
            "ln1": layernorm_init(d),
            "self_attn": _mha_init(ka, d, cfg.n_heads),
            "ln2": layernorm_init(d),
            "cross_attn": _mha_init(kx, d, cfg.n_heads),
            "ln3": layernorm_init(d),
            "mlp": gelu_mlp_init(kf, d, cfg.d_ff, bias=True),
        }

    return {
        "enc_in": dense_init(keys[2], d, d),  # stub frontend adapter
        "enc_layers": jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[enc_layer(i) for i in range(cfg.n_enc_layers)]
        ),
        "enc_ln": layernorm_init(d),
        "embed": embed_init(keys[3], cfg.vocab, d),
        "dec_layers": jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[dec_layer(i) for i in range(cfg.n_dec_layers)]
        ),
        "dec_ln": layernorm_init(d),
    }


def encode(params, cfg: EncDecConfig, frames: jax.Array, ctx: SpringContext) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    x = dense_apply(params["enc_in"], frames, ctx, w_logical=("w_embed", None))
    x = (x + _sinusoid(frames.shape[1], cfg.d_model).astype(x.dtype)[None])
    x = constrain(x, ("batch", "seq", "embed"))

    def body(h, lp):
        h = h + _mha(lp["attn"], layernorm_apply(lp["ln1"], h), layernorm_apply(lp["ln1"], h), ctx, cfg.n_heads, causal=False)
        h = h + gelu_mlp_apply(lp["mlp"], layernorm_apply(lp["ln2"], h), ctx)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"],
                        unroll=cfg.n_enc_layers if cfg.scan_unroll else 1)
    return layernorm_apply(params["enc_ln"], x)


def decode_hidden(
    params, cfg: EncDecConfig, tokens: jax.Array, enc_out: jax.Array, ctx: SpringContext
) -> jax.Array:
    """Teacher-forced decoder pass (training / prefill)."""
    x = embed_apply(params["embed"], tokens, ctx)
    x = x + _sinusoid(tokens.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(h, lp):
        h = h + _mha(lp["self_attn"], layernorm_apply(lp["ln1"], h), layernorm_apply(lp["ln1"], h), ctx, cfg.n_heads, causal=True)
        h = h + _mha(lp["cross_attn"], layernorm_apply(lp["ln2"], h), enc_out, ctx, cfg.n_heads, causal=False)
        h = h + gelu_mlp_apply(lp["mlp"], layernorm_apply(lp["ln3"], h), ctx)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"],
                        unroll=cfg.n_dec_layers if cfg.scan_unroll else 1)
    return layernorm_apply(params["dec_ln"], x)


def encdec_loss(params, cfg: EncDecConfig, frames, tokens, ctx) -> tuple[jax.Array, dict]:
    from repro.models.losses import chunked_softmax_xent

    enc_out = encode(params, cfg, frames, ctx)
    h = decode_hidden(params, cfg, tokens, enc_out, ctx)
    b, s, _ = h.shape
    total = chunked_softmax_xent(h[:, :-1], tokens[:, 1:], params["embed"]["embedding"].T)
    ce = total / (b * (s - 1))
    return ce, {"ce": ce}


# -- serving ---------------------------------------------------------------


def encdec_init_cache(params, cfg: EncDecConfig, frames, ctx, max_len: int, dtype=jnp.bfloat16) -> dict:
    """Run the encoder once; precompute per-layer cross K/V; empty self KV."""
    enc_out = encode(params, cfg, frames, ctx)
    b = frames.shape[0]
    hd = cfg.head_dim

    def cross_kv(lp):
        k = dense_apply(lp["cross_attn"]["wk"], enc_out, ctx, w_logical=("w_embed", "w_qkv"))
        v = dense_apply(lp["cross_attn"]["wv"], enc_out, ctx, w_logical=("w_embed", "w_qkv"))
        s = enc_out.shape[1]
        return {"k": k.reshape(b, s, cfg.n_heads, hd).astype(dtype),
                "v": v.reshape(b, s, cfg.n_heads, hd).astype(dtype)}

    # vmap over stacked layer params: one cross-KV projection per layer
    cross = jax.vmap(cross_kv)(params["dec_layers"])
    self_kv = {
        "k": jnp.zeros((cfg.n_dec_layers, b, max_len, cfg.n_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_dec_layers, b, max_len, cfg.n_heads, hd), dtype),
    }
    return {"pos": jnp.zeros((), jnp.int32), "cross": cross, "self": self_kv}


def encdec_decode_step(params, cfg: EncDecConfig, tokens, cache, ctx):
    """One decode token against (self KV + fixed cross KV)."""
    pos = cache["pos"]
    b = tokens.shape[0]
    hd = cfg.head_dim
    x = embed_apply(params["embed"], tokens[:, None], ctx)
    x = x + jax.lax.dynamic_slice_in_dim(_sinusoid(cache["self"]["k"].shape[2], cfg.d_model), pos, 1, 0).astype(x.dtype)[None]

    def body(carry, scanned):
        h = carry
        lp, cross, sk, sv = scanned
        hq = layernorm_apply(lp["ln1"], h)
        q, k, v = _project_qkv(lp["self_attn"], hq, hq, ctx, cfg.n_heads)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), pos, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), pos, axis=1)
        valid = jnp.arange(sk.shape[1]) <= pos
        scores = jnp.einsum("bhd,bshd->bhs", q[:, 0].astype(jnp.float32), sk.astype(jnp.float32)) / hd**0.5
        p = jax.nn.softmax(jnp.where(valid[None, None], scores, -1e30), -1)
        sa = jnp.einsum("bhs,bshd->bhd", p, sv.astype(jnp.float32)).reshape(b, 1, cfg.d_model).astype(h.dtype)
        h = h + dense_apply(lp["self_attn"]["wo"], sa, ctx, w_logical=("w_qkv", "w_embed"))

        hq = layernorm_apply(lp["ln2"], h)
        q = dense_apply(lp["cross_attn"]["wq"], hq, ctx, w_logical=("w_embed", "w_qkv")).reshape(b, 1, cfg.n_heads, hd)
        scores = jnp.einsum("bhd,bshd->bhs", q[:, 0].astype(jnp.float32), cross["k"].astype(jnp.float32)) / hd**0.5
        p = jax.nn.softmax(scores, -1)
        ca = jnp.einsum("bhs,bshd->bhd", p, cross["v"].astype(jnp.float32)).reshape(b, 1, cfg.d_model).astype(h.dtype)
        h = h + dense_apply(lp["cross_attn"]["wo"], ca, ctx, w_logical=("w_qkv", "w_embed"))
        h = h + gelu_mlp_apply(lp["mlp"], layernorm_apply(lp["ln3"], h), ctx)
        return h, (sk, sv)

    x, (sks, svs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["cross"], cache["self"]["k"], cache["self"]["v"])
    )
    x = layernorm_apply(params["dec_ln"], x)
    w_vocab = constrain(params["embed"]["embedding"].T, ("w_embed", "w_vocab"))
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32), w_vocab.astype(jnp.float32))
    new_cache = {"pos": pos + 1, "cross": cache["cross"], "self": {"k": sks, "v": svs}}
    return logits, new_cache
