"""The paper's seven evaluation CNNs (§4): Inception-ResNet-V2,
Inception-V3, MobileNet-V2, NASNet-mobile, PNASNet-mobile, ResNet-152-V2,
VGG-19 — as runnable JAX models whose conv/fc compute flows through the
SPRING ops (quant/sparse modes apply), plus a layer recorder that derives
the per-layer (MACs, bytes) tables the analytical perf model consumes.

VGG-19 / ResNet-152-V2 / MobileNet-V2 / Inception-V3 are structurally
faithful; Inception-ResNet-V2 and the two NAS cells use their published
block structure in simplified form (DESIGN.md §2/P4) — the paper's own
evaluation consumes only layer shapes.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.spring_ops import spring_conv2d, spring_matmul
from repro.memstash.stash import checkpoint_apply
from repro.models.layers import SpringContext


# --------------------------------------------------------------------------
# Layer recorder (perfmodel input).
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LayerRecord:
    kind: str  # conv | fc
    name: str
    macs: int  # per-example multiply-accumulates
    in_elems: int
    w_elems: int
    out_elems: int


class _Recorder(threading.local):
    def __init__(self):
        self.records: Optional[list[LayerRecord]] = None


_REC = _Recorder()


def _record(r: LayerRecord):
    if _REC.records is not None:
        _REC.records.append(r)


def trace_layers(model_fn: Callable[[], jax.Array]) -> list[LayerRecord]:
    """Run ``model_fn`` under jax.eval_shape, collecting layer records."""
    _REC.records = []
    try:
        jax.eval_shape(model_fn)
        return _REC.records
    finally:
        _REC.records = None


# --------------------------------------------------------------------------
# Parameterized building blocks (params created lazily per unique name).
# --------------------------------------------------------------------------


class ParamStore:
    """Name-addressed parameter store; init on first touch."""

    def __init__(self, key: jax.Array, params: Optional[dict] = None):
        self.key = key
        self.params = {} if params is None else params
        self.initializing = params is None

    def get(self, name: str, shape, scale: float) -> jax.Array:
        if name not in self.params:
            assert self.initializing, f"missing param {name}"
            k = jax.random.fold_in(self.key, hash(name) % (2**31))
            self.params[name] = jax.random.normal(k, shape, jnp.float32) * scale
        return self.params[name]


def conv(
    store: ParamStore,
    ctx: SpringContext,
    name: str,
    x: jax.Array,
    cout: int,
    k: int = 3,
    stride: int = 1,
    groups: int = 1,
    relu: bool = True,
    padding: str = "SAME",
) -> jax.Array:
    cin = x.shape[-1]
    kh, kw = (k, k) if isinstance(k, int) else k
    w = store.get(name, (kh, kw, cin // groups, cout), scale=(2.0 / (kh * kw * cin)) ** 0.5)
    b = store.get(name + "/b", (cout,), 0.0)

    def body(x_, wb):
        w_, b_ = wb
        y_ = spring_conv2d(x_, w_, ctx.cfg, ctx.keys, stride=(stride, stride),
                           padding=padding, feature_group_count=groups)
        y_ = y_ + b_.astype(y_.dtype)
        if relu:
            y_ = jax.nn.relu(y_)  # the paper's activation-sparsity source
        return y_

    # The conv input is the previous layer's post-ReLU map — the sparse
    # tensor the backward dW GEMM re-reads, i.e. SPRING's stash target.
    y = checkpoint_apply(body, ctx.stash_policy(name, int(x.size)), ctx.memstash,
                         name, x, (w, b))
    _record(LayerRecord(
        "conv", name,
        macs=int(y.shape[1] * y.shape[2] * cout * (kh * kw * cin // groups)),
        in_elems=int(x.shape[1] * x.shape[2] * cin),
        w_elems=int(kh * kw * (cin // groups) * cout),
        out_elems=int(y.shape[1] * y.shape[2] * cout),
    ))
    return y


def fc(store: ParamStore, ctx: SpringContext, name: str, x: jax.Array, cout: int,
       relu: bool = False) -> jax.Array:
    cin = x.shape[-1]
    w = store.get(name, (cin, cout), scale=(1.0 / cin) ** 0.5)
    b = store.get(name + "/b", (cout,), 0.0)

    def body(x_, wb):
        w_, b_ = wb
        y_ = spring_matmul(x_, w_, ctx.cfg, ctx.keys)
        y_ = y_ + b_.astype(y_.dtype)
        return jax.nn.relu(y_) if relu else y_

    y = checkpoint_apply(body, ctx.stash_policy(name, int(x.size)), ctx.memstash,
                         name, x, (w, b))
    _record(LayerRecord("fc", name, macs=cin * cout, in_elems=cin,
                        w_elems=cin * cout, out_elems=cout))
    return y


def maxpool(x, k=2, stride=2, padding="VALID"):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), padding
    )


def avgpool(x, k, stride, padding="SAME"):
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, stride, stride, 1), padding
    )
    return s / (k * k)


def gap(x):
    return x.mean(axis=(1, 2))


def sep_conv(store, ctx, name, x, cout, k=3, stride=1, relu=True):
    """Depthwise-separable conv (MobileNet/NAS cells)."""
    cin = x.shape[-1]
    y = conv(store, ctx, name + "/dw", x, cin, k=k, stride=stride, groups=cin, relu=False)
    return conv(store, ctx, name + "/pw", y, cout, k=1, relu=relu)


# --------------------------------------------------------------------------
# The seven CNNs.
# --------------------------------------------------------------------------


def vgg19(store: ParamStore, ctx: SpringContext, x: jax.Array) -> jax.Array:
    plan = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]
    for bi, (c, n) in enumerate(plan):
        for li in range(n):
            x = conv(store, ctx, f"c{bi}_{li}", x, c, k=3)
        x = maxpool(x)
    x = x.reshape(x.shape[0], -1)
    x = fc(store, ctx, "fc6", x, 4096, relu=True)
    x = fc(store, ctx, "fc7", x, 4096, relu=True)
    return fc(store, ctx, "fc8", x, 1000)


def resnet152_v2(store: ParamStore, ctx: SpringContext, x: jax.Array) -> jax.Array:
    def bottleneck(x, name, width, stride):
        cin = x.shape[-1]
        cout = width * 4
        h = conv(store, ctx, name + "/1", x, width, k=1, relu=True)
        h = conv(store, ctx, name + "/2", h, width, k=3, stride=stride, relu=True)
        h = conv(store, ctx, name + "/3", h, cout, k=1, relu=False)
        if cin != cout or stride != 1:
            x = conv(store, ctx, name + "/sc", x, cout, k=1, stride=stride, relu=False)
        return jax.nn.relu(x + h)

    x = conv(store, ctx, "stem", x, 64, k=7, stride=2)
    x = maxpool(x, 3, 2, "SAME")
    for si, (width, n, stride) in enumerate([(64, 3, 1), (128, 8, 2), (256, 36, 2), (512, 3, 2)]):
        for bi in range(n):
            x = bottleneck(x, f"s{si}b{bi}", width, stride if bi == 0 else 1)
    return fc(store, ctx, "head", gap(x), 1000)


def mobilenet_v2(store: ParamStore, ctx: SpringContext, x: jax.Array) -> jax.Array:
    def inv_res(x, name, expand, cout, stride):
        cin = x.shape[-1]
        h = x
        if expand != 1:
            h = conv(store, ctx, name + "/e", h, cin * expand, k=1)
        h = conv(store, ctx, name + "/dw", h, h.shape[-1], k=3, stride=stride,
                 groups=h.shape[-1])
        h = conv(store, ctx, name + "/p", h, cout, k=1, relu=False)
        if stride == 1 and cin == cout:
            h = x + h
        return h

    x = conv(store, ctx, "stem", x, 32, k=3, stride=2)
    plan = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    i = 0
    for t, c, n, s in plan:
        for bi in range(n):
            x = inv_res(x, f"b{i}", t, c, s if bi == 0 else 1)
            i += 1
    x = conv(store, ctx, "last", x, 1280, k=1)
    return fc(store, ctx, "head", gap(x), 1000)


def inception_v3(store: ParamStore, ctx: SpringContext, x: jax.Array) -> jax.Array:
    c = lambda n, x_, co, k=3, s=1, p="SAME", relu=True: conv(store, ctx, n, x_, co, k=k, stride=s, padding=p, relu=relu)

    # stem (299x299 -> 35x35x192)
    x = c("s1", x, 32, 3, 2, "VALID")
    x = c("s2", x, 32, 3, 1, "VALID")
    x = c("s3", x, 64, 3)
    x = maxpool(x, 3, 2)
    x = c("s4", x, 80, 1)
    x = c("s5", x, 192, 3, 1, "VALID")
    x = maxpool(x, 3, 2)

    def mixed_a(x, name, pool_ch):
        b0 = c(name + "/b0", x, 64, 1)
        b1 = c(name + "/b1b", c(name + "/b1a", x, 48, 1), 64, 5)
        b2 = c(name + "/b2c", c(name + "/b2b", c(name + "/b2a", x, 64, 1), 96, 3), 96, 3)
        b3 = c(name + "/b3", avgpool(x, 3, 1), pool_ch, 1)
        return jnp.concatenate([b0, b1, b2, b3], -1)

    x = mixed_a(x, "m5b", 32)
    x = mixed_a(x, "m5c", 64)
    x = mixed_a(x, "m5d", 64)

    # reduction to 17x17
    b0 = c("r6/b0", x, 384, 3, 2, "VALID")
    b1 = c("r6/b1c", c("r6/b1b", c("r6/b1a", x, 64, 1), 96, 3), 96, 3, 2, "VALID")
    x = jnp.concatenate([b0, b1, maxpool(x, 3, 2)], -1)

    def mixed_b(x, name, ch7):
        b0 = c(name + "/b0", x, 192, 1)
        b1 = c(name + "/b1c", c(name + "/b1b", c(name + "/b1a", x, ch7, 1), ch7, (1, 7)), 192, (7, 1))
        b2 = x
        for i, (co, k) in enumerate([(ch7, 1), (ch7, (7, 1)), (ch7, (1, 7)), (ch7, (7, 1)), (192, (1, 7))]):
            b2 = c(f"{name}/b2{i}", b2, co, k)
        b3 = c(name + "/b3", avgpool(x, 3, 1), 192, 1)
        return jnp.concatenate([b0, b1, b2, b3], -1)

    for name, ch7 in [("m6b", 128), ("m6c", 160), ("m6d", 160), ("m6e", 192)]:
        x = mixed_b(x, name, ch7)

    # reduction to 8x8
    b0 = c("r7/b0b", c("r7/b0a", x, 192, 1), 320, 3, 2, "VALID")
    b1 = c("r7/b1c", c("r7/b1bb", c("r7/b1b", c("r7/b1a", x, 192, 1), 192, (1, 7)), 192, (7, 1)), 192, 3, 2, "VALID")
    x = jnp.concatenate([b0, b1, maxpool(x, 3, 2)], -1)

    def mixed_c(x, name):
        b0 = c(name + "/b0", x, 320, 1)
        b1a = c(name + "/b1a", x, 384, 1)
        b1 = jnp.concatenate([c(name + "/b1b", b1a, 384, (1, 3)), c(name + "/b1c", b1a, 384, (3, 1))], -1)
        b2a = c(name + "/b2b", c(name + "/b2a", x, 448, 1), 384, 3)
        b2 = jnp.concatenate([c(name + "/b2c", b2a, 384, (1, 3)), c(name + "/b2d", b2a, 384, (3, 1))], -1)
        b3 = c(name + "/b3", avgpool(x, 3, 1), 192, 1)
        return jnp.concatenate([b0, b1, b2, b3], -1)

    x = mixed_c(x, "m7b")
    x = mixed_c(x, "m7c")
    return fc(store, ctx, "head", gap(x), 1000)


def inception_resnet_v2(store: ParamStore, ctx: SpringContext, x: jax.Array) -> jax.Array:
    c = lambda n, x_, co, k=3, s=1, p="SAME", relu=True: conv(store, ctx, n, x_, co, k=k, stride=s, padding=p, relu=relu)
    # stem as inception v3 up to 35x35, widened to 320
    x = c("s1", x, 32, 3, 2, "VALID")
    x = c("s2", x, 32, 3, 1, "VALID")
    x = c("s3", x, 64, 3)
    x = maxpool(x, 3, 2)
    x = c("s4", x, 80, 1)
    x = c("s5", x, 192, 3, 1, "VALID")
    x = maxpool(x, 3, 2)
    x = c("s6", x, 320, 1)

    def block35(x, name):  # 10x
        b0 = c(name + "/b0", x, 32, 1)
        b1 = c(name + "/b1b", c(name + "/b1a", x, 32, 1), 32, 3)
        b2 = c(name + "/b2c", c(name + "/b2b", c(name + "/b2a", x, 32, 1), 48, 3), 64, 3)
        up = c(name + "/up", jnp.concatenate([b0, b1, b2], -1), x.shape[-1], 1, relu=False)
        return jax.nn.relu(x + 0.17 * up)

    for i in range(10):
        x = block35(x, f"a{i}")
    # reduction A -> 17x17, 1088ch
    b0 = c("ra/b0", x, 384, 3, 2, "VALID")
    b1 = c("ra/b1c", c("ra/b1b", c("ra/b1a", x, 256, 1), 256, 3), 384, 3, 2, "VALID")
    x = jnp.concatenate([b0, b1, maxpool(x, 3, 2)], -1)

    def block17(x, name):  # 20x
        b0 = c(name + "/b0", x, 192, 1)
        b1 = c(name + "/b1c", c(name + "/b1b", c(name + "/b1a", x, 128, 1), 160, (1, 7)), 192, (7, 1))
        up = c(name + "/up", jnp.concatenate([b0, b1], -1), x.shape[-1], 1, relu=False)
        return jax.nn.relu(x + 0.1 * up)

    for i in range(20):
        x = block17(x, f"b{i}")
    # reduction B -> 8x8
    b0 = c("rb/b0b", c("rb/b0a", x, 256, 1), 384, 3, 2, "VALID")
    b1 = c("rb/b1b", c("rb/b1a", x, 256, 1), 288, 3, 2, "VALID")
    b2 = c("rb/b2c", c("rb/b2b", c("rb/b2a", x, 256, 1), 288, 3), 320, 3, 2, "VALID")
    x = jnp.concatenate([b0, b1, b2, maxpool(x, 3, 2)], -1)

    def block8(x, name):  # 10x
        b0 = c(name + "/b0", x, 192, 1)
        b1 = c(name + "/b1c", c(name + "/b1b", c(name + "/b1a", x, 192, 1), 224, (1, 3)), 256, (3, 1))
        up = c(name + "/up", jnp.concatenate([b0, b1], -1), x.shape[-1], 1, relu=False)
        return jax.nn.relu(x + 0.2 * up)

    for i in range(10):
        x = block8(x, f"c{i}")
    x = c("final", x, 1536, 1)
    return fc(store, ctx, "head", gap(x), 1000)


def _nas_cell(store, ctx, name, x, filters, stride=1):
    """Simplified NASNet/PNASNet cell: parallel separable convs + pool."""
    h = conv(store, ctx, name + "/sq", x, filters, k=1)
    b1 = sep_conv(store, ctx, name + "/s3a", h, filters, k=3, stride=stride)
    b2 = sep_conv(store, ctx, name + "/s3b", h, filters, k=3, stride=stride)
    b3 = sep_conv(store, ctx, name + "/s5a", h, filters, k=5, stride=stride)
    b4 = sep_conv(store, ctx, name + "/s5b", h, filters, k=5, stride=stride)
    b5 = sep_conv(store, ctx, name + "/s7", h, filters, k=7, stride=stride)
    b6 = avgpool(h, 3, stride)
    return jnp.concatenate([b1, b2, b3, b4, b5, b6], -1)


def _nas_net(store, ctx, x, base_filters: int, cells_per_stage: int):
    """NASNet/PNASNet-mobile skeleton: conv stem + 2 stem reduction cells
    (so normal cells run at 28x28, as published), then 3 stages of
    [N normal cells, reduction] with filter doubling."""
    x = conv(store, ctx, "stem", x, 32, k=3, stride=2)  # 112
    f = base_filters
    x = _nas_cell(store, ctx, "stem_r0", x, f // 2, stride=2)  # 56
    x = _nas_cell(store, ctx, "stem_r1", x, f, stride=2)  # 28
    for stage in range(3):
        for i in range(cells_per_stage):
            x = _nas_cell(store, ctx, f"n{stage}_{i}", x, f)
        if stage < 2:
            f *= 2
            x = _nas_cell(store, ctx, f"red{stage}", x, f, stride=2)
    return fc(store, ctx, "head", gap(x), 1000)


def nasnet_mobile(store, ctx, x):
    return _nas_net(store, ctx, x, base_filters=44, cells_per_stage=4)


def pnasnet_mobile(store, ctx, x):
    return _nas_net(store, ctx, x, base_filters=54, cells_per_stage=3)


# --------------------------------------------------------------------------
# Registry.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CNNDef:
    name: str
    fn: Callable
    input_hw: int
    train_batch: int = 32  # paper: TF-Slim defaults
    infer_batch: int = 100


PAPER_CNNS: dict[str, CNNDef] = {
    "inception_resnet_v2": CNNDef("inception_resnet_v2", inception_resnet_v2, 299),
    "inception_v3": CNNDef("inception_v3", inception_v3, 299),
    "mobilenet_v2": CNNDef("mobilenet_v2", mobilenet_v2, 224),
    "nasnet_mobile": CNNDef("nasnet_mobile", nasnet_mobile, 224),
    "pnasnet_mobile": CNNDef("pnasnet_mobile", pnasnet_mobile, 224),
    "resnet152_v2": CNNDef("resnet152_v2", resnet152_v2, 224),
    "vgg19": CNNDef("vgg19", vgg19, 224),
}


def cnn_init(key: jax.Array, cnn: CNNDef, input_hw: Optional[int] = None) -> dict:
    """Materialize params by a real tiny forward (init-on-first-touch)."""
    store = ParamStore(key)
    hw = input_hw or cnn.input_hw
    x = jnp.zeros((1, hw, hw, 3), jnp.float32)
    cnn.fn(store, SpringContext(), x)
    return store.params


def cnn_apply(params: dict, cnn: CNNDef, x: jax.Array, ctx: SpringContext) -> jax.Array:
    store = ParamStore(jax.random.PRNGKey(0), params)
    return cnn.fn(store, ctx, x)


def cnn_layer_table(cnn: CNNDef, input_hw: Optional[int] = None) -> list[LayerRecord]:
    """Per-layer MACs/bytes table at the paper's input resolution."""
    hw = input_hw or cnn.input_hw

    def run():
        store = ParamStore(jax.random.PRNGKey(0))
        x = jnp.zeros((1, hw, hw, 3), jnp.float32)
        return cnn.fn(store, SpringContext(), x)

    return trace_layers(run)
