"""Decoder-LM stack: pattern-composed blocks, scanned layer groups, remat,
chunked cross-entropy.  Covers 9 of the 10 assigned archs (whisper is in
``encdec.py``); internvl2's ViT frontend is a stub that prepends
precomputed patch embeddings (DESIGN.md §5).

Layer composition: a config names a repeating ``pattern_unit`` of
(mixer, ffn) kinds, scanned ``n_units`` times with stacked params (keeps
HLO size and compile time O(unit) instead of O(layers)), plus optional
unrolled ``prefix``/``suffix`` layers for patterns that don't divide the
layer count (recurrentgemma's 38 = 12x(rec,rec,local) + 2, deepseek's
dense first layer).

  mixers: "attn" | "local" | "mla" | "ssm" | "rglru"
  ffns:   "swiglu" | "gelu" | "moe" | "none"
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core.spring_ops import KeyGen
from repro.memstash.config import MemstashConfig
from repro.memstash.stash import stash_apply
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnSpec, MLASpec
from repro.models.layers import (
    SpringContext,
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    gelu_mlp_apply,
    gelu_mlp_init,
    layernorm_apply,
    layernorm_init,
    rmsnorm_apply,
    rmsnorm_init,
    swiglu_apply,
    swiglu_init,
)
from repro.models.moe import MoESpec
from repro.models.recurrent import RGLRUSpec
from repro.models.ssm import SSMSpec
from repro.models.losses import chunked_softmax_xent
from repro.runtime.sharding import constrain


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    vocab: int
    n_layers: int
    pattern_unit: tuple  # ((mixer, ffn), ...)
    n_units: int
    prefix: tuple = ()
    suffix: tuple = ()
    attn: Optional[AttnSpec] = None
    local_attn: Optional[AttnSpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None
    rglru: Optional[RGLRUSpec] = None
    moe: Optional[MoESpec] = None
    d_ff: int = 0
    norm: str = "rms"  # "rms" | "layer"
    mlp_bias: bool = False
    tie_embeddings: bool = False
    vlm_prefix_len: int = 0  # internvl2: image patch positions
    remat: bool = True
    # dry-run cost mode: fully unroll the layer scan so cost_analysis sees
    # every layer (XLA counts while bodies once; DESIGN.md §Roofline note)
    scan_unroll: bool = False
    # §Perf lever: bf16 loss-head matmul (LSE stays fp32)
    bf16_logits: bool = False
    # §Perf lever: remat policy — "full" recomputes everything; "block_io"
    # saves each block's output (skips re-forwarding through the TP
    # collectives and attention in the backward pass, costing one
    # activation per layer of memory); "stash" stores each scan unit's
    # residual input binary-mask compressed and restores it in backward
    # (the memstash subsystem — SPRING's RRAM activation store; applies
    # even with remat=False, since the stash is itself a checkpoint
    # strategy)
    remat_policy: str = "full"
    # set by configs: families where 500k-token full attention is intractable
    supports_long_context: bool = False

    def __post_init__(self):
        n = len(self.prefix) + len(self.pattern_unit) * self.n_units + len(self.suffix)
        assert n == self.n_layers, f"{self.name}: pattern covers {n} != {self.n_layers} layers"


# --------------------------------------------------------------------------
# Single block init/apply.
# --------------------------------------------------------------------------


def _norm_init(cfg: LMConfig):
    return rmsnorm_init(cfg.d_model) if cfg.norm == "rms" else layernorm_init(cfg.d_model)


def _norm_apply(cfg: LMConfig, p, x):
    return rmsnorm_apply(p, x) if cfg.norm == "rms" else layernorm_apply(p, x)


def block_init(key, cfg: LMConfig, kind: tuple) -> dict:
    mixer, ffn = kind
    km, kf = jax.random.split(key)
    p: dict[str, Any] = {"norm1": _norm_init(cfg)}
    if mixer == "attn":
        p["mixer"] = attn_mod.gqa_init(km, cfg.d_model, cfg.attn)
    elif mixer == "local":
        p["mixer"] = attn_mod.gqa_init(km, cfg.d_model, cfg.local_attn)
    elif mixer == "mla":
        p["mixer"] = attn_mod.mla_init(km, cfg.d_model, cfg.mla)
    elif mixer == "ssm":
        p["mixer"] = ssm_mod.ssm_init(km, cfg.d_model, cfg.ssm)
    elif mixer == "rglru":
        p["mixer"] = rec_mod.rglru_block_init(km, cfg.d_model, cfg.rglru)
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["norm2"] = _norm_init(cfg)
        if ffn == "swiglu":
            p["ffn"] = swiglu_init(kf, cfg.d_model, cfg.d_ff)
        elif ffn == "gelu":
            p["ffn"] = gelu_mlp_init(kf, cfg.d_model, cfg.d_ff, bias=cfg.mlp_bias)
        elif ffn == "moe":
            p["ffn"] = moe_mod.moe_init(kf, cfg.d_model, cfg.moe)
        else:
            raise ValueError(ffn)
    return p


def block_apply(
    params,
    x: jax.Array,
    ctx: SpringContext,
    cfg: LMConfig,
    kind: tuple,
    positions: jax.Array,
    cache: Optional[dict] = None,
    pos: Optional[jax.Array] = None,
    return_cache: bool = False,
):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    mixer, ffn = kind
    h = _norm_apply(cfg, params["norm1"], x)
    new_cache = None
    if mixer in ("attn", "local"):
        spec = cfg.attn if mixer == "attn" else cfg.local_attn
        out, new_cache = attn_mod.gqa_apply(params["mixer"], h, ctx, spec, positions, cache, pos, return_cache)
    elif mixer == "mla":
        out, new_cache = attn_mod.mla_apply(params["mixer"], h, ctx, cfg.mla, positions, cache, pos, return_cache)
    elif mixer == "ssm":
        out, new_cache = ssm_mod.ssm_apply(params["mixer"], h, ctx, cfg.ssm, cache, return_cache)
    elif mixer == "rglru":
        out, new_cache = rec_mod.rglru_block_apply(params["mixer"], h, ctx, cfg.rglru, cache, return_cache)
    else:
        raise ValueError(mixer)
    x = (x + out).astype(x.dtype)
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = _norm_apply(cfg, params["norm2"], x)
        if ffn == "swiglu":
            x = (x + swiglu_apply(params["ffn"], h, ctx)).astype(x.dtype)
        elif ffn == "gelu":
            x = (x + gelu_mlp_apply(params["ffn"], h, ctx)).astype(x.dtype)
        elif ffn == "moe":
            out, aux = moe_mod.moe_apply(params["ffn"], h, ctx, cfg.moe)
            x = (x + out).astype(x.dtype)
    return constrain(x, ("batch", "seq", "embed")), new_cache, aux


def block_init_cache(cfg: LMConfig, kind: tuple, batch: int, max_len: int, dtype=jnp.bfloat16):
    mixer, _ = kind
    if mixer == "attn":
        return attn_mod.gqa_init_cache(batch, cfg.attn, max_len, dtype)
    if mixer == "local":
        return attn_mod.gqa_init_cache(batch, cfg.local_attn, max_len, dtype)
    if mixer == "mla":
        return attn_mod.mla_init_cache(batch, cfg.mla, max_len,
                                       jnp.bfloat16 if dtype == "int8" else dtype)
    if mixer == "ssm":
        return ssm_mod.ssm_init_cache(batch, cfg.ssm,
                                      jnp.bfloat16 if dtype == "int8" else dtype)
    if mixer == "rglru":
        return rec_mod.rglru_init_cache(batch, cfg.rglru,
                                        jnp.bfloat16 if dtype == "int8" else dtype)
    raise ValueError(mixer)


# --------------------------------------------------------------------------
# Full model.
# --------------------------------------------------------------------------


def lm_init(key, cfg: LMConfig) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model),
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab)
    for i, kind in enumerate(cfg.prefix):
        params[f"prefix_{i}"] = block_init(jax.random.fold_in(keys[2], i), cfg, kind)
    for i, kind in enumerate(cfg.suffix):
        params[f"suffix_{i}"] = block_init(jax.random.fold_in(keys[3], i), cfg, kind)
    # scanned groups: one stacked param tree per unit position
    for u, kind in enumerate(cfg.pattern_unit):
        def init_one(i, u=u, kind=kind):
            return block_init(jax.random.fold_in(jax.random.fold_in(keys[4], u), i), cfg, kind)

        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[init_one(i) for i in range(cfg.n_units)]
        ) if cfg.n_units > 0 else None
        params[f"unit_{u}"] = stacked
    return params


def lm_hidden(
    params,
    cfg: LMConfig,
    tokens: jax.Array,
    ctx: SpringContext,
    img_embeds: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Token ids (B, S_text) [+ (B, P, d) image embeds] -> final hidden."""
    x = embed_apply(params["embed"], tokens, ctx)
    if cfg.vlm_prefix_len:
        assert img_embeds is not None
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.prefix):
        x, _, a = block_apply(params[f"prefix_{i}"], x, ctx, cfg, kind, positions)
        aux += a
    if cfg.n_units > 0:
        # scan over units; each scan step applies the unit's kinds in order
        # (so interleaved patterns like (rec, rec, local) keep layer order)
        def body(carry, unit_params):
            h, aux_c = carry
            for u, kind in enumerate(cfg.pattern_unit):
                h, _, a = block_apply(unit_params[u], h, ctx, cfg, kind, positions)
                h = checkpoint_name(h, "block_out")
                aux_c += a
            return (h, aux_c), None

        # memstash resolution: remat_policy="stash" nominates the residual
        # stream as a stash point, but the MemstashConfig still has the
        # last word (per_layer overrides / min_elems / policy "none"),
        # mirroring how the CNN path routes through ctx.stash_policy
        scfg = ctx.memstash if ctx.memstash is not None else MemstashConfig(policy="stash")
        stash_policy = (scfg.policy_for("lm/residual", int(x.size))
                        if cfg.remat_policy == "stash" else "none")

        if cfg.remat and cfg.remat_policy == "block_io":
            policy = jax.checkpoint_policies.save_only_these_names("block_out")
            body_fn = jax.checkpoint(body, policy=policy)
        elif stash_policy == "stash":
            # memstash: the unit's residual-stream input is stored
            # binary-mask compressed and restored for the backward
            # recompute (dense LM residuals degrade gracefully toward
            # the 20-vs-32-bit value width; see DESIGN.md §4.3).
            # Active regardless of cfg.remat — the stash *is* the
            # checkpointing strategy (compressed-input remat).  Every
            # traced value the unit needs (positions, SR key) must flow
            # through aux, not the closure: custom_vjp backward re-traces
            # inside the scan transpose, where closure-captured tracers
            # from the forward trace would leak as jaxpr consts.
            # draw a fresh subkey for the scanned units: reusing the base
            # key would replay the exact folds embed/prefix SR sites
            # already consumed (correlated rounding noise)
            base_key = ctx.keys.next() if ctx.keys is not None else None

            def body_fn(carry, unit_params):
                h, aux_c = carry

                def unit(h_, aux):
                    aux_cc, up, pos, k = aux
                    ctx_u = (dataclasses.replace(ctx, keys=KeyGen(k))
                             if k is not None else ctx)
                    for u, kind in enumerate(cfg.pattern_unit):
                        h_, _, a = block_apply(up[u], h_, ctx_u, cfg, kind, pos)
                        h_ = checkpoint_name(h_, "block_out")
                        aux_cc += a
                    return h_, aux_cc

                return stash_apply(unit, scfg, "lm/residual", h,
                                   (aux_c, unit_params, positions, base_key)), None
        elif cfg.remat or stash_policy == "remat":
            body_fn = jax.checkpoint(body)
        else:
            body_fn = body
        unit_stack = tuple(params[f"unit_{u}"] for u in range(len(cfg.pattern_unit)))
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), unit_stack,
                                   unroll=cfg.n_units if cfg.scan_unroll else 1)
    for i, kind in enumerate(cfg.suffix):
        x, _, a = block_apply(params[f"suffix_{i}"], x, ctx, cfg, kind, positions)
        aux += a
    x = _norm_apply(cfg, params["final_norm"], x)
    return x, aux


def _logits_kernel(params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return params["embed"]["embedding"].T  # (d, V)
    return params["lm_head"]["kernel"]


def lm_loss(
    params,
    cfg: LMConfig,
    tokens: jax.Array,
    ctx: SpringContext,
    img_embeds: Optional[jax.Array] = None,
    aux_weight: float = 0.01,
) -> tuple[jax.Array, dict]:
    """Next-token CE, chunked over the sequence so the (tokens x vocab)
    logits tensor never materializes whole (DESIGN.md §4)."""
    h, aux = lm_hidden(params, cfg, tokens, ctx, img_embeds)
    if cfg.vlm_prefix_len:
        h = h[:, cfg.vlm_prefix_len :]  # loss over text positions only
    b, s, d = h.shape
    inputs_h = h[:, :-1]
    labels = tokens[:, 1:]
    n = s - 1
    total = chunked_softmax_xent(
        inputs_h, labels, _logits_kernel(params, cfg),
        logits_dtype=jnp.bfloat16 if cfg.bf16_logits else jnp.float32)
    ce = total / (b * n)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# Serving: cache init + single-token decode step.
# --------------------------------------------------------------------------


def lm_init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    """dtype may be the string "int8" for quantized full-attention caches
    (other cache kinds fall back to bf16)."""
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    for i, kind in enumerate(cfg.prefix):
        cache[f"prefix_{i}"] = block_init_cache(cfg, kind, batch, max_len, dtype)
    for i, kind in enumerate(cfg.suffix):
        cache[f"suffix_{i}"] = block_init_cache(cfg, kind, batch, max_len, dtype)
    for u, kind in enumerate(cfg.pattern_unit):
        one = block_init_cache(cfg, kind, batch, max_len, dtype)
        cache[f"unit_{u}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_units,) + a.shape).copy(), one
        )
    return cache


def lm_decode_step(
    params,
    cfg: LMConfig,
    tokens: jax.Array,  # (B,) next-token ids
    cache: dict,
    ctx: SpringContext,
) -> tuple[jax.Array, dict]:
    """One decode step: returns (logits (B, V), updated cache).

    ``cache["pos"]`` may be a scalar (static serving: the whole batch sits
    at one depth) or a (B,) vector (continuous batching: each slot at its
    own depth).  The two lower to the same per-row math — a scalar is
    broadcast — so the engine and the static path stay bit-identical.
    """
    pos = cache["pos"]
    x = embed_apply(params["embed"], tokens[:, None], ctx)
    b = x.shape[0]
    positions = jnp.broadcast_to(
        jnp.reshape(pos, (-1, 1)) if getattr(pos, "ndim", 0) else pos,
        (b, 1)).astype(jnp.int32)
    new_cache: dict[str, Any] = {"pos": pos + 1}
    for i, kind in enumerate(cfg.prefix):
        x, c, _ = block_apply(params[f"prefix_{i}"], x, ctx, cfg, kind, positions,
                              cache[f"prefix_{i}"], pos)
        new_cache[f"prefix_{i}"] = c
    if cfg.n_units > 0:
        def body(h, scanned):
            unit_params, unit_caches = scanned
            new_cs = []
            for u, kind in enumerate(cfg.pattern_unit):
                h, c, _ = block_apply(unit_params[u], h, ctx, cfg, kind, positions,
                                      unit_caches[u], pos)
                new_cs.append(c)
            return h, tuple(new_cs)

        unit_params = tuple(params[f"unit_{u}"] for u in range(len(cfg.pattern_unit)))
        unit_caches = tuple(cache[f"unit_{u}"] for u in range(len(cfg.pattern_unit)))
        x, new_cs = jax.lax.scan(body, x, (unit_params, unit_caches),
                                 unroll=cfg.n_units if cfg.scan_unroll else 1)
        for u in range(len(cfg.pattern_unit)):
            new_cache[f"unit_{u}"] = new_cs[u]
    for i, kind in enumerate(cfg.suffix):
        x, c, _ = block_apply(params[f"suffix_{i}"], x, ctx, cfg, kind, positions,
                              cache[f"suffix_{i}"], pos)
        new_cache[f"suffix_{i}"] = c
    x = _norm_apply(cfg, params["final_norm"], x)
    logits = jnp.einsum(
        "bd,dv->bv", x[:, 0].astype(jnp.float32),
        constrain(_logits_kernel(params, cfg), ("w_embed", "w_vocab")).astype(jnp.float32),
    )
    return logits, new_cache


def lm_prefill(
    params,
    cfg: LMConfig,
    tokens: jax.Array,
    ctx: SpringContext,
    img_embeds: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """Inference prefill: full forward emitting the serving cache + the
    last-position logits (the production prefill -> decode handoff)."""
    x = embed_apply(params["embed"], tokens, ctx)
    if cfg.vlm_prefix_len:
        assert img_embeds is not None
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache: dict[str, Any] = {"pos": jnp.asarray(s, jnp.int32)}
    for i, kind in enumerate(cfg.prefix):
        x, c, _ = block_apply(params[f"prefix_{i}"], x, ctx, cfg, kind, positions,
                              return_cache=True)
        cache[f"prefix_{i}"] = c
    if cfg.n_units > 0:
        def body(h, unit_params):
            cs = []
            for u, kind in enumerate(cfg.pattern_unit):
                h, c, _ = block_apply(unit_params[u], h, ctx, cfg, kind, positions,
                                      return_cache=True)
                cs.append(c)
            return h, tuple(cs)

        unit_stack = tuple(params[f"unit_{u}"] for u in range(len(cfg.pattern_unit)))
        x, all_cs = jax.lax.scan(body, x, unit_stack,
                                 unroll=cfg.n_units if cfg.scan_unroll else 1)
        for u in range(len(cfg.pattern_unit)):
            cache[f"unit_{u}"] = all_cs[u]
    for i, kind in enumerate(cfg.suffix):
        x, c, _ = block_apply(params[f"suffix_{i}"], x, ctx, cfg, kind, positions,
                              return_cache=True)
        cache[f"suffix_{i}"] = c
    x = _norm_apply(cfg, params["final_norm"], x)
    logits = jnp.einsum(
        "bd,dv->bv", x[:, -1].astype(jnp.float32),
        constrain(_logits_kernel(params, cfg), ("w_embed", "w_vocab")).astype(jnp.float32),
    )
    return logits, cache


# seq-axis position (from the end) of each cache leaf kind, for padding
_CACHE_SEQ_AXIS = {"k": -3, "v": -3, "ckv": -2, "krope": -2,
                   "k_q8": -3, "v_q8": -3, "k_sc": -2, "v_sc": -2}


def pad_cache(cache: dict, extra: int) -> dict:
    """Grow attention caches by ``extra`` decode slots (prefill builds
    caches sized to the prompt; decoding needs headroom).  State caches
    (ssm/conv/rglru) are O(1) and pass through; ring (window) caches keep
    their fixed size."""
    if extra <= 0:
        return cache

    def one(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        leaf_name = names[-1] if names else ""
        ax = _CACHE_SEQ_AXIS.get(leaf_name)
        if ax is None or not hasattr(leaf, "ndim"):
            return leaf
        pads = [(0, 0)] * leaf.ndim
        pads[leaf.ndim + ax] = (0, extra)
        return jnp.pad(leaf, pads)

    return jax.tree_util.tree_map_with_path(one, cache)
