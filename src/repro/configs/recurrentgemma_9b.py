"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attn, pattern 2 recurrent : 1
local-attention [arXiv:2402.19427].  38 = 12 x (rec,rec,local) + 2 rec.
Sub-quadratic -> long_500k cell runs."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig
from repro.models.recurrent import RGLRUSpec


def _full() -> LMConfig:
    return LMConfig(
        name="recurrentgemma-9b", d_model=4096, vocab=256000, n_layers=38,
        pattern_unit=(("rglru", "swiglu"), ("rglru", "swiglu"), ("local", "swiglu")),
        n_units=12,
        suffix=(("rglru", "swiglu"), ("rglru", "swiglu")),
        local_attn=AttnSpec(n_heads=16, n_kv_heads=1, head_dim=256, window=2048),
        rglru=RGLRUSpec(d_rnn=4096),
        d_ff=12288, supports_long_context=True,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="recurrentgemma-9b-reduced", d_model=64, vocab=512, n_layers=5,
        pattern_unit=(("rglru", "swiglu"), ("rglru", "swiglu"), ("local", "swiglu")),
        n_units=1,
        suffix=(("rglru", "swiglu"), ("rglru", "swiglu")),
        local_attn=AttnSpec(n_heads=4, n_kv_heads=1, head_dim=16, window=16),
        rglru=RGLRUSpec(d_rnn=64),
        d_ff=192, supports_long_context=True, remat=False,
    )


ARCH = ArchDef("recurrentgemma-9b", "hybrid", _full(), reduced, "arXiv:2402.19427")
