"""Architecture registry: the 10 assigned archs (+ paper CNNs)."""

from repro.configs.base import SHAPES, ArchDef, ShapeSpec
from repro.configs.registry import ARCHS, get_arch

__all__ = ["SHAPES", "ArchDef", "ShapeSpec", "ARCHS", "get_arch"]
