"""Architecture registry: the 10 assigned archs (+ paper CNNs)."""

from repro.configs.base import (
    SHAPES,
    ArchDef,
    MemstashConfig,
    ResolvedArch,
    ShapeSpec,
    default_memstash,
)
from repro.configs.registry import ARCHS, get_arch

__all__ = ["SHAPES", "ArchDef", "MemstashConfig", "ResolvedArch", "ShapeSpec",
           "ARCHS", "default_memstash", "get_arch"]
