"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig


def _full() -> LMConfig:
    return LMConfig(
        name="minitron-4b", d_model=3072, vocab=256000, n_layers=32,
        pattern_unit=(("attn", "swiglu"),), n_units=32,
        attn=AttnSpec(n_heads=24, n_kv_heads=8, head_dim=128, rope_theta=10000.0),
        d_ff=9216,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="minitron-4b-reduced", d_model=96, vocab=512, n_layers=3,
        pattern_unit=(("attn", "swiglu"),), n_units=3,
        attn=AttnSpec(n_heads=6, n_kv_heads=2, head_dim=16),
        d_ff=256, remat=False,
    )


ARCH = ArchDef("minitron-4b", "dense", _full(), reduced, "arXiv:2407.14679")
