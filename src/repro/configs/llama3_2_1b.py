"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-1B]. head_dim=64,
tied embeddings, rope theta 500k."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig


def _full() -> LMConfig:
    return LMConfig(
        name="llama3.2-1b", d_model=2048, vocab=128256, n_layers=16,
        pattern_unit=(("attn", "swiglu"),), n_units=16,
        attn=AttnSpec(n_heads=32, n_kv_heads=8, head_dim=64, rope_theta=500_000.0),
        d_ff=8192, tie_embeddings=True,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="llama3.2-1b-reduced", d_model=128, vocab=512, n_layers=3,
        pattern_unit=(("attn", "swiglu"),), n_units=3,
        attn=AttnSpec(n_heads=8, n_kv_heads=2, head_dim=16, rope_theta=500_000.0),
        d_ff=384, tie_embeddings=True, remat=False,
    )


ARCH = ArchDef("llama3.2-1b", "dense", _full(), reduced, "hf:meta-llama/Llama-3.2-1B")
