"""whisper-medium [audio]: 24+24L enc-dec d_model=1024 16H d_ff=4096
vocab=51865 — conv/mel frontend is a STUB (precomputed frame embeddings)
[arXiv:2212.04356]."""
from repro.configs.base import ArchDef
from repro.models.encdec import EncDecConfig


def _full() -> EncDecConfig:
    return EncDecConfig(
        name="whisper-medium", d_model=1024, vocab=51865,
        n_enc_layers=24, n_dec_layers=24, n_heads=16, d_ff=4096, enc_seq=1500,
    )


def reduced() -> EncDecConfig:
    return EncDecConfig(
        name="whisper-medium-reduced", d_model=64, vocab=512,
        n_enc_layers=2, n_dec_layers=2, n_heads=4, d_ff=128, enc_seq=32,
        remat=False,
    )


ARCH = ArchDef("whisper-medium", "audio", _full(), reduced, "arXiv:2212.04356")
