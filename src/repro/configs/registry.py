"""Registry of the 10 assigned architectures (--arch <id>)."""

from __future__ import annotations

from repro.configs.base import ArchDef
from repro.configs.deepseek_v2_lite_16b import ARCH as _deepseek
from repro.configs.internvl2_26b import ARCH as _internvl2
from repro.configs.llama3_2_1b import ARCH as _llama
from repro.configs.mamba2_780m import ARCH as _mamba2
from repro.configs.minitron_4b import ARCH as _minitron
from repro.configs.mistral_nemo_12b import ARCH as _nemo
from repro.configs.olmoe_1b_7b import ARCH as _olmoe
from repro.configs.qwen2_7b import ARCH as _qwen2
from repro.configs.recurrentgemma_9b import ARCH as _rgemma
from repro.configs.whisper_medium import ARCH as _whisper

ARCHS: dict[str, ArchDef] = {
    a.arch_id: a
    for a in [
        _minitron, _nemo, _qwen2, _llama, _rgemma,
        _internvl2, _deepseek, _olmoe, _mamba2, _whisper,
    ]
}


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]
