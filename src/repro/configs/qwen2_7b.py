"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig


def _full() -> LMConfig:
    return LMConfig(
        name="qwen2-7b", d_model=3584, vocab=152064, n_layers=28,
        pattern_unit=(("attn", "swiglu"),), n_units=28,
        attn=AttnSpec(n_heads=28, n_kv_heads=4, head_dim=128,
                      rope_theta=1_000_000.0, qkv_bias=True),
        d_ff=18944,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="qwen2-7b-reduced", d_model=112, vocab=512, n_layers=3,
        pattern_unit=(("attn", "swiglu"),), n_units=3,
        attn=AttnSpec(n_heads=7, n_kv_heads=1, head_dim=16, qkv_bias=True),
        d_ff=320, remat=False,
    )


ARCH = ArchDef("qwen2-7b", "dense", _full(), reduced, "arXiv:2407.10671")
