"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16, i.e. MHA) per-expert
d_ff=1024 vocab=50304, 64 experts top-8 [arXiv:2409.02060; hf]."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig
from repro.models.moe import MoESpec


def _full() -> LMConfig:
    return LMConfig(
        name="olmoe-1b-7b", d_model=2048, vocab=50304, n_layers=16,
        pattern_unit=(("attn", "moe"),), n_units=16,
        attn=AttnSpec(n_heads=16, n_kv_heads=16, head_dim=128),
        moe=MoESpec(n_experts=64, top_k=8, d_ff=1024),
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="olmoe-1b-7b-reduced", d_model=64, vocab=512, n_layers=3,
        pattern_unit=(("attn", "moe"),), n_units=3,
        attn=AttnSpec(n_heads=4, n_kv_heads=4, head_dim=16),
        moe=MoESpec(n_experts=8, top_k=2, d_ff=48, capacity_factor=4.0), remat=False,
    )


ARCH = ArchDef("olmoe-1b-7b", "moe", _full(), reduced, "arXiv:2409.02060")
