"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend + InternLM2-20B backbone
[arXiv:2404.16821].  The ViT is a STUB: input_specs provides 256
precomputed patch embeddings prepended to the text sequence."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig


def _full() -> LMConfig:
    return LMConfig(
        name="internvl2-26b", d_model=6144, vocab=92553, n_layers=48,
        pattern_unit=(("attn", "swiglu"),), n_units=48,
        attn=AttnSpec(n_heads=48, n_kv_heads=8, head_dim=128, rope_theta=1_000_000.0),
        d_ff=16384, vlm_prefix_len=256,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="internvl2-26b-reduced", d_model=96, vocab=512, n_layers=3,
        pattern_unit=(("attn", "swiglu"),), n_units=3,
        attn=AttnSpec(n_heads=6, n_kv_heads=2, head_dim=16),
        d_ff=256, vlm_prefix_len=8, remat=False,
    )


ARCH = ArchDef("internvl2-26b", "vlm", _full(), reduced, "arXiv:2404.16821")
