"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060].
d_inner = 2*d = 3072, head_dim 64 -> 48 heads, 1 state group.
Attention-free -> long_500k cell runs."""
from repro.configs.base import ArchDef
from repro.models.lm import LMConfig
from repro.models.ssm import SSMSpec


def _full() -> LMConfig:
    return LMConfig(
        name="mamba2-780m", d_model=1536, vocab=50280, n_layers=48,
        pattern_unit=(("ssm", "none"),), n_units=48,
        ssm=SSMSpec(d_inner=3072, n_heads=48, d_state=128, n_groups=1),
        tie_embeddings=True, supports_long_context=True,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="mamba2-780m-reduced", d_model=64, vocab=512, n_layers=4,
        pattern_unit=(("ssm", "none"),), n_units=4,
        ssm=SSMSpec(d_inner=128, n_heads=4, d_state=16, n_groups=1),
        tie_embeddings=True, supports_long_context=True, remat=False,
    )


ARCH = ArchDef("mamba2-780m", "ssm", _full(), reduced, "arXiv:2405.21060")
