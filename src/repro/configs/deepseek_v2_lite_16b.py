"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (MLA kv_lora=512)
per-expert d_ff=1408 vocab=102400, 64 routed experts top-6 + 2 shared,
first layer dense MLP (d_ff=10944) [arXiv:2405.04434; hf]."""
from repro.configs.base import ArchDef
from repro.models.attention import MLASpec
from repro.models.lm import LMConfig
from repro.models.moe import MoESpec


def _full() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b", d_model=2048, vocab=102400, n_layers=27,
        prefix=(("mla", "swiglu"),),          # layer 0: dense MLP
        pattern_unit=(("mla", "moe"),), n_units=26,
        mla=MLASpec(n_heads=16, kv_lora_rank=512, qk_nope_dim=128,
                    qk_rope_dim=64, v_head_dim=128),
        moe=MoESpec(n_experts=64, top_k=6, d_ff=1408, n_shared=2, shared_d_ff=1408),
        d_ff=10944,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-lite-16b-reduced", d_model=64, vocab=512, n_layers=3,
        prefix=(("mla", "swiglu"),),
        pattern_unit=(("mla", "moe"),), n_units=2,
        mla=MLASpec(n_heads=4, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                    v_head_dim=16),
        moe=MoESpec(n_experts=8, top_k=2, d_ff=48, n_shared=2, shared_d_ff=48,
                    capacity_factor=4.0),
        d_ff=160, remat=False,
    )


ARCH = ArchDef("deepseek-v2-lite-16b", "moe", _full(), reduced, "arXiv:2405.04434")
