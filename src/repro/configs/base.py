"""Architecture registry scaffolding: shape cells + per-arch adapters.

Each assigned architecture file defines ``ARCH`` (an ``ArchDef``) with the
exact published config, a ``reduced()`` smoke-test variant of the same
family, and ``input_specs(shape)`` ShapeDtypeStruct stand-ins used by the
multi-pod dry-run (never allocated).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.memstash.config import MemstashConfig
from repro.models.encdec import EncDecConfig
from repro.models.lm import LMConfig


def default_memstash(family: str) -> MemstashConfig:
    """Recommended memstash policy per workload family.

    ``family`` is either the literal ``"cnn"`` (the paper CNN workloads,
    which are not ArchDefs) or an ``ArchDef.family`` value
    (dense | hybrid | vlm | moe | ssm | audio) — every LM-side family
    maps to remat.  CNNs carry genuinely sparse post-ReLU activations, so
    the compressed stash wins on memory traffic; LM residual streams are
    dense, where "stash" only buys the 20-vs-32-bit value width
    (measurable via ``repro.memstash.report``).
    """
    if family == "cnn":
        return MemstashConfig(policy="stash")
    return MemstashConfig(policy="remat")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ResolvedArch:
    """An arch view with one concrete config picked (full or reduced, or
    a launcher-modified copy).  This is what the step builders consume —
    it replaced the per-launcher ``class _A`` closure shims.  ``reduced()``
    returns the same config: resolution already happened."""

    is_encdec: bool
    config: Union[LMConfig, EncDecConfig]

    def reduced(self) -> Union[LMConfig, EncDecConfig]:
        return self.config


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str  # dense | hybrid | vlm | moe | ssm | audio
    config: Union[LMConfig, EncDecConfig]
    reduced: Callable[[], Union[LMConfig, EncDecConfig]]
    source: str = ""

    @property
    def is_encdec(self) -> bool:
        return isinstance(self.config, EncDecConfig)

    def applicable_shapes(self) -> list[str]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.config.supports_long_context:
            out.append("long_500k")
        return out

    def view(self, reduced: bool = False, config=None) -> ResolvedArch:
        """Resolve to a concrete-config arch view (``config`` overrides)."""
        if config is None:
            config = self.reduced() if reduced else self.config
        return ResolvedArch(self.is_encdec, config)

    def skipped_shapes(self) -> dict[str, str]:
        if not self.config.supports_long_context:
            return {"long_500k": "pure quadratic attention; 500k-token cell "
                                 "intractable by design (DESIGN.md §5)"}
        return {}

    # ---- input ShapeDtypeStructs per shape cell (dry-run stand-ins) ----

    def input_specs(self, shape_name: str, cfg=None) -> dict:
        cfg = cfg or self.config
        sh = SHAPES[shape_name]
        b, s = sh.global_batch, sh.seq_len
        tok = jnp.int32
        if self.is_encdec:
            d = cfg.d_model
            if sh.kind in ("train", "prefill"):
                return {
                    "frames": jax.ShapeDtypeStruct((b, cfg.enc_seq, d), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((b, s), tok),
                }
            return {"tokens": jax.ShapeDtypeStruct((b,), tok)}
        specs: dict = {}
        if sh.kind in ("train", "prefill"):
            text_len = s - cfg.vlm_prefix_len
            specs["tokens"] = jax.ShapeDtypeStruct((b, text_len), tok)
            if cfg.vlm_prefix_len:
                specs["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.vlm_prefix_len, cfg.d_model), jnp.bfloat16
                )
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b,), tok)
        return specs

    def cache_specs(self, shape_name: str, cfg=None, cache_dtype=None) -> dict:
        """ShapeDtypeStruct pytree for the serving cache at this shape."""
        cfg = cfg or self.config
        sh = SHAPES[shape_name]
        import jax.numpy as _jnp

        cache_dtype = cache_dtype or _jnp.bfloat16

        if self.is_encdec:
            from repro.models.encdec import encdec_init, encdec_init_cache
            from repro.models.layers import SpringContext

            def build():
                params = encdec_init(jax.random.PRNGKey(0), cfg)
                frames = jnp.zeros((sh.global_batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
                return encdec_init_cache(params, cfg, frames, SpringContext(), sh.seq_len)

            return jax.eval_shape(build)
        from repro.models.lm import lm_init_cache

        return jax.eval_shape(
            lambda: lm_init_cache(cfg, sh.global_batch, sh.seq_len, cache_dtype))
