"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].
head_dim=128 is explicit (not d_model / n_heads)."""
from repro.configs.base import ArchDef
from repro.models.attention import AttnSpec
from repro.models.lm import LMConfig


def _full() -> LMConfig:
    return LMConfig(
        name="mistral-nemo-12b", d_model=5120, vocab=131072, n_layers=40,
        pattern_unit=(("attn", "swiglu"),), n_units=40,
        attn=AttnSpec(n_heads=32, n_kv_heads=8, head_dim=128, rope_theta=1_000_000.0),
        d_ff=14336,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="mistral-nemo-12b-reduced", d_model=128, vocab=512, n_layers=3,
        pattern_unit=(("attn", "swiglu"),), n_units=3,
        attn=AttnSpec(n_heads=8, n_kv_heads=2, head_dim=16, rope_theta=1_000_000.0),
        d_ff=384, remat=False,
    )


ARCH = ArchDef("mistral-nemo-12b", "dense", _full(), reduced, "hf:mistralai/Mistral-Nemo-Base-2407")
