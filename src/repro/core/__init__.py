"""SPRING core: fixed-point SR arithmetic + binary-mask sparsity."""

from repro.core.fixedpoint import (
    SPRING_ACCUM_FORMAT,
    SPRING_FORMAT,
    FixedPointFormat,
    from_int,
    quantize_nearest,
    quantize_stochastic,
    quantize_stochastic_from_bits,
    ste_quantize_nearest,
    ste_quantize_stochastic,
    to_int,
)
from repro.core.masking import (
    MaskedVector,
    compression_ratio,
    density,
    mask_decode,
    mask_encode,
    pack_mask_bits,
    tile_occupancy,
    unpack_mask_bits,
)
from repro.core.sparsity import (
    MatchedOperands,
    apply_joint_mask,
    generate_masks,
    postcompute_sparsity,
    precompute_sparsity,
    sparse_dot,
)
from repro.core.spring_ops import (
    DENSE,
    QUANT,
    QUANT_SPARSE,
    KeyGen,
    SpringConfig,
    spring_conv2d,
    spring_einsum,
    spring_matmul,
)
