"""Reduced-precision fixed-point arithmetic with stochastic rounding (SPRING P2).

SPRING evaluates CNNs in Q(IL, FL) fixed point (paper Table 1: IL=4, FL=16)
and keeps *training* convergent by rounding stochastically (Eq. 4, after
Gupta et al. 2015) every time a value narrows back to the storage format.

Representation choice (TPU adaptation, DESIGN.md §2/P2): quantized tensors
are carried as float32 values *snapped to the fixed-point grid*
(``value = q * 2**-FL`` with ``q`` an integer in the IL+FL-bit range).
float32 represents every Q4.16 grid point exactly (20-bit significand
< 24-bit fp32 mantissa), matmuls run on the MXU/VPU natively, and
``to_int``/``from_int`` convert to the raw integer storage format used by
the binary-mask compression and checkpoint paths.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """Q(IL, FL) signed fixed-point format.

    ``il`` integer bits (including none for sign — sign is separate, as in
    the paper's IL+FL description with symmetric range), ``fl`` fractional
    bits.  Representable grid: ``{-2**il, ..., -eps, 0, eps, ..., 2**il - eps}``
    with ``eps = 2**-fl``.
    """

    il: int = 4
    fl: int = 16

    @property
    def eps(self) -> float:
        return 2.0 ** (-self.fl)

    @property
    def max_value(self) -> float:
        return 2.0**self.il - self.eps

    @property
    def min_value(self) -> float:
        return -(2.0**self.il)

    @property
    def bits(self) -> int:
        """Storage bits per element (sign + IL + FL), as in the paper."""
        return 1 + self.il + self.fl

    def tree_flatten(self):  # pragma: no cover - convenience
        return (), (self.il, self.fl)


# The paper's Table-1 format.
SPRING_FORMAT = FixedPointFormat(il=4, fl=16)
# Wider accumulator format (2x(IL+FL), paper MAC-lane internal width).
SPRING_ACCUM_FORMAT = FixedPointFormat(il=8, fl=32)


def _clip_to_range(x: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    return jnp.clip(x, fmt.min_value, fmt.max_value)


def quantize_nearest(x: jax.Array, fmt: FixedPointFormat = SPRING_FORMAT) -> jax.Array:
    """Deterministic round-to-nearest onto the Q(IL,FL) grid (paper Eq. 3)."""
    x = _clip_to_range(x.astype(jnp.float32), fmt)
    scaled = x * (2.0**fmt.fl)
    return jnp.round(scaled) * fmt.eps


def quantize_stochastic(
    key: jax.Array, x: jax.Array, fmt: FixedPointFormat = SPRING_FORMAT
) -> jax.Array:
    """Stochastic rounding onto the Q(IL,FL) grid (paper Eq. 4).

    ``Round(x) = floor(x)`` w.p. ``(floor(x)+eps-x)/eps`` else ``floor(x)+eps``,
    i.e. round down with probability proportional to proximity; unbiased:
    ``E[Round(x)] = x`` for in-range x.
    """
    x = _clip_to_range(x.astype(jnp.float32), fmt)
    scaled = x * (2.0**fmt.fl)
    lo = jnp.floor(scaled)
    frac = scaled - lo
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    rounded = lo + (u < frac).astype(jnp.float32)
    return _clip_to_range(rounded * fmt.eps, fmt)


def quantize_stochastic_from_bits(
    random_bits: jax.Array, x: jax.Array, fmt: FixedPointFormat = SPRING_FORMAT
) -> jax.Array:
    """SR driven by externally supplied uint32 random bits.

    This is the form the Pallas kernel implements (the paper drives its SR
    module from an LFSR; we use in-kernel xorshift32 bits — see
    ``kernels/stochastic_round``).  ``random_bits`` must be uint32 with
    ``x.shape``.
    """
    x = _clip_to_range(x.astype(jnp.float32), fmt)
    scaled = x * (2.0**fmt.fl)
    lo = jnp.floor(scaled)
    frac = scaled - lo
    # Map uint32 -> [0, 1) with 24-bit resolution (fp32-exact).
    u = (random_bits >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    rounded = lo + (u < frac).astype(jnp.float32)
    return _clip_to_range(rounded * fmt.eps, fmt)


# ---------------------------------------------------------------------------
# Straight-through-estimator wrappers: SPRING trains *through* the rounding
# (the rounding error is exposed to the network; gradients treat the
# quantizer as identity on the in-range region).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_quantize_nearest(x: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    return quantize_nearest(x, fmt)


def _ste_qn_fwd(x, fmt):
    return quantize_nearest(x, fmt), x


def _ste_qn_bwd(fmt, res, g):
    x = res
    in_range = (x >= fmt.min_value) & (x <= fmt.max_value)
    return (jnp.where(in_range, g, 0.0),)


ste_quantize_nearest.defvjp(_ste_qn_fwd, _ste_qn_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def ste_quantize_stochastic(key: jax.Array, x: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    return quantize_stochastic(key, x, fmt)


def _ste_qs_fwd(key, x, fmt):
    return quantize_stochastic(key, x, fmt), x


def _ste_qs_bwd(fmt, res, g):
    x = res
    in_range = (x >= fmt.min_value) & (x <= fmt.max_value)
    return (None, jnp.where(in_range, g, 0.0))


ste_quantize_stochastic.defvjp(_ste_qs_fwd, _ste_qs_bwd)


# ---------------------------------------------------------------------------
# Integer raw storage conversions (used by mask compression / checkpoints).
# ---------------------------------------------------------------------------


def to_int(x: jax.Array, fmt: FixedPointFormat = SPRING_FORMAT) -> jax.Array:
    """Grid-snapped float -> raw int32 (``q`` such that ``x = q * eps``)."""
    return jnp.round(x.astype(jnp.float32) * (2.0**fmt.fl)).astype(jnp.int32)


def from_int(q: jax.Array, fmt: FixedPointFormat = SPRING_FORMAT) -> jax.Array:
    return q.astype(jnp.float32) * fmt.eps


def quantization_noise_bound(fmt: FixedPointFormat) -> float:
    """Worst-case |x - Round(x)| for either rounding mode (< eps)."""
    return fmt.eps


def pytree_quantize_stochastic(key: jax.Array, tree: Any, fmt: FixedPointFormat) -> Any:
    """SR-quantize every leaf of a pytree with independent keys."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [quantize_stochastic(k, leaf, fmt) for k, leaf in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)
