"""Binary-mask sparsity encoding (SPRING P1, paper Fig. 5).

A dense vector is stored as (a) its non-zero values collapsed to the front
("zero-free" data) and (b) a 1-bit-per-element binary mask giving the
original positions.  The mask bits are packed 32-per-uint32 word, so the
storage overhead is exactly 1 bit/element — the paper's "at most 5%
overhead assuming 4 IL + 16 FL bits" (1/21).

Everything here is vectorized JAX with static shapes (the value buffer
keeps the dense length; ``nnz`` says how much of it is live).  The faithful
element-serial Algorithm-1 scan lives in ``kernels/mask_compress/ref.py``
as the oracle these vectorized forms are tested against.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

MASK_WORD_BITS = 32


class MaskedVector(NamedTuple):
    """Binary-mask compressed tensor (flat).

    values:  (padded_len,) float32 — non-zeros collapsed to the front,
             zero-padded tail.
    mask:    (ceil(padded_len/32),) uint32 — packed position bits.
    nnz:     () int32 — number of live values.
    length:  static python int — original dense length.
    """

    values: jax.Array
    mask: jax.Array
    nnz: jax.Array
    length: int


def _pad_to_words(n: int) -> int:
    return (n + MASK_WORD_BITS - 1) // MASK_WORD_BITS * MASK_WORD_BITS


def pack_mask_bits(bits: jax.Array) -> jax.Array:
    """(n,) bool -> (ceil(n/32),) uint32, bit i of word w = element 32*w+i."""
    n = bits.shape[0]
    padded = _pad_to_words(n)
    b = jnp.zeros((padded,), jnp.uint32).at[:n].set(bits.astype(jnp.uint32))
    b = b.reshape(-1, MASK_WORD_BITS)
    shifts = jnp.arange(MASK_WORD_BITS, dtype=jnp.uint32)
    return (b << shifts).sum(axis=1).astype(jnp.uint32)


def unpack_mask_bits(words: jax.Array, length: int) -> jax.Array:
    """(w,) uint32 -> (length,) bool."""
    shifts = jnp.arange(MASK_WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:length].astype(jnp.bool_)


def collapse_to_front(flat: jax.Array, bits: jax.Array, capacity_len: int) -> jax.Array:
    """Fig. 7(c) zero-collapsing shifter as a cumsum-scatter: elements
    whose ``bits`` are set move to the front of a ``(capacity_len,)``
    buffer (any dtype); dead — and overflow, when capacity < nnz —
    elements scatter off the end and drop.  Destination index of element
    i is ``cumsum(bits)[i] - 1`` when live."""
    dest = jnp.cumsum(bits.astype(jnp.int32)) - 1
    dest = jnp.where(bits, dest, capacity_len)
    return jnp.zeros((capacity_len,), flat.dtype).at[dest].set(flat, mode="drop")


def expand_from_mask(values: jax.Array, bits: jax.Array) -> jax.Array:
    """Inverse of ``collapse_to_front``: scatter front-collapsed values
    back to their ``bits`` positions; positions beyond the value buffer's
    capacity (overflow at compress time) decode as zero."""
    cap = values.shape[0]
    src = jnp.cumsum(bits.astype(jnp.int32)) - 1
    valid = bits & (src < cap)
    gathered = values[jnp.clip(src, 0, cap - 1)]
    return jnp.where(valid, gathered, jnp.zeros((), values.dtype))


def mask_encode(x: jax.Array) -> MaskedVector:
    """Dense (n,) -> binary-mask compressed form (vectorized zero-collapse)."""
    x = x.reshape(-1).astype(jnp.float32)
    n = x.shape[0]
    bits = x != 0.0
    return MaskedVector(
        values=collapse_to_front(x, bits, n),
        mask=pack_mask_bits(bits),
        nnz=bits.sum().astype(jnp.int32),
        length=n,
    )


def mask_decode(mv: MaskedVector) -> jax.Array:
    """Compressed form -> dense (length,)."""
    bits = unpack_mask_bits(mv.mask, mv.length)
    return expand_from_mask(mv.values, bits)


def compressed_bits(mv: MaskedVector, value_bits: int) -> jax.Array:
    """Total storage bits of the compressed form (paper Fig. 5 accounting)."""
    return mv.nnz * value_bits + jnp.int32(mv.length)


def compression_ratio(mv: MaskedVector, value_bits: int) -> jax.Array:
    """Dense bits / compressed bits. Fig. 5: 16 elems, 6 nnz, 16b -> 2.29x."""
    dense = mv.length * value_bits
    return dense / compressed_bits(mv, value_bits)


# ---------------------------------------------------------------------------
# Tile-occupancy masks: the TPU-granular adaptation of the mask-AND stage.
# ---------------------------------------------------------------------------


def tile_occupancy(dense: jax.Array, tile_m: int, tile_n: int) -> jax.Array:
    """(M, N) -> (M/tile_m, N/tile_n) bool; True where the tile has any nnz.

    This is what the ``masked_matmul`` Pallas kernel consumes to skip whole
    MXU tiles: the AND of activation & weight occupancy decides whether a
    (m, n, k) grid step issues.  M, N must be tile-divisible (callers pad).
    """
    m, n = dense.shape
    assert m % tile_m == 0 and n % tile_n == 0, (dense.shape, tile_m, tile_n)
    t = dense.reshape(m // tile_m, tile_m, n // tile_n, tile_n)
    return jnp.any(t != 0.0, axis=(1, 3))


def density(x: jax.Array) -> jax.Array:
    """Fraction of non-zero elements (1 - sparsity)."""
    return jnp.mean((x != 0.0).astype(jnp.float32))
