"""Activation-sparsity measurement (the paper's premise, quantified).

SPRING's training-phase claim rests on Rhu et al.'s observation that
ReLU-era CNNs average ~62% activation sparsity THROUGHOUT training
(paper §1).  This utility measures it on our runnable CNNs so the
perfmodel's sparsity inputs are grounded rather than assumed, and so the
LM-arch gap (SiLU/GELU produce ~0% exact zeros — DESIGN.md §5) is
demonstrable rather than asserted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def relu_sparsity_probe(apply_fn, *args) -> dict[str, float]:
    """Run ``apply_fn`` capturing post-ReLU sparsity via a tracer tag.

    Works by monkey-free interception: callers pass an ``apply_fn`` built
    against ``probed_relu`` below.
    """
    records: list[jax.Array] = []

    def probed_relu(x):
        y = jax.nn.relu(x)
        records.append(jnp.mean((y == 0.0).astype(jnp.float32)))
        return y

    out = apply_fn(probed_relu, *args)
    if not records:
        return {"mean_sparsity": 0.0, "layers": 0}
    vals = [float(r) for r in records]
    return {
        "mean_sparsity": sum(vals) / len(vals),
        "min_sparsity": min(vals),
        "max_sparsity": max(vals),
        "layers": len(vals),
        "output": out,
    }


def tensor_sparsity(x: jax.Array) -> float:
    return float(jnp.mean((x == 0.0).astype(jnp.float32)))
