"""Public SPRING compute ops: quantized, sparsity-aware matmul/conv.

Every linear/conv layer in the model zoo funnels through these.  Three
modes (``SpringMode``):

  dense        — plain bf16/fp32 baseline (the 'GPU' reference numerics).
  quant        — Q(IL,FL) fixed-point operands, fp32 accumulate, stochastic
                 rounding on the output (paper P2; training-safe via STE).
  quant_sparse — quant + binary-mask sparsity: dangling non-zeros are
                 filtered (numerics identical to quant with masked
                 operands) and, on TPU, all-zero MXU tiles are skipped by
                 the ``masked_matmul`` Pallas kernel (paper P1).

On CPU (this container, and the 512-host-device dry-run) the quant_sparse
path lowers to the vectorized jnp equivalent — Pallas-for-TPU cannot lower
on the CPU backend, and interpret-mode callbacks would poison
``cost_analysis``.  Backend selection is the ``kernels`` KernelPolicy:
each matmul resolves ``masked_matmul`` through ``repro.kernels.registry``
(auto picks Pallas on TPU, the differentiable jnp lowering elsewhere).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import (
    SPRING_FORMAT,
    FixedPointFormat,
    ste_quantize_nearest,
    ste_quantize_stochastic,
)
from repro.kernels.registry import KernelPolicy

SpringMode = Literal["dense", "quant", "quant_sparse"]

#: Backward-sparsity switch values: "none" differentiates through the
#: forward lowering (dense autodiff); "auto" routes dL/dX / dL/dW through
#: the registry-resolved masked_matmul_dx/dw kernels; a concrete impl name
#: pins the backward backend independently of the forward one.
BACKWARD_SPARSITY_CHOICES = ("none", "auto", "ref", "jnp", "interpret", "pallas")


@dataclasses.dataclass(frozen=True)
class SpringConfig:
    """Numerics configuration threaded through every model layer."""

    mode: SpringMode = "dense"
    fmt: FixedPointFormat = SPRING_FORMAT
    # Deterministic rounding for activations on the fwd of *inference*;
    # training always uses SR (the paper's convergence argument).
    stochastic: bool = True
    # Kernel-dispatch policy: per-op backend pins + global default,
    # resolved through repro.kernels.registry at every kernel call site.
    kernels: KernelPolicy = KernelPolicy()
    # Sparsity-aware backward pass (quant_sparse mode only): dL/dX and
    # dL/dW flow through the masked_matmul_dx/dw registry ops so tile
    # skipping and binary-mask wire savings apply to training, not just
    # the forward pass (DESIGN.md §8).  Forward numerics are unchanged.
    backward_sparsity: str = "auto"
    # Compute dtype of the dense baseline path.
    dense_dtype: jnp.dtype = jnp.bfloat16
    # §Perf levers for the quantized path:
    #  - weights updated by the SR fixed-point optimizer are ALREADY on the
    #    Q-grid: skip their runtime re-quantization (identity op)
    #  - operands can round-to-nearest (no RNG hash); SR stays on the MAC
    #    output, which is where the paper's convergence argument lives
    weights_pre_quantized: bool = False
    operand_rounding: str = "stochastic"  # "stochastic" | "nearest"

    def __post_init__(self):
        if self.backward_sparsity not in BACKWARD_SPARSITY_CHOICES:
            raise ValueError(
                f"unknown backward_sparsity {self.backward_sparsity!r}; "
                f"choose from {BACKWARD_SPARSITY_CHOICES}")

    @property
    def is_quantized(self) -> bool:
        return self.mode != "dense"

    @property
    def is_sparse(self) -> bool:
        return self.mode == "quant_sparse"

    @property
    def sparse_backward(self) -> bool:
        """True when the sparsity-aware custom_vjp backward is in force."""
        return self.is_sparse and self.backward_sparsity != "none"


DENSE = SpringConfig(mode="dense")
QUANT = SpringConfig(mode="quant")
QUANT_SPARSE = SpringConfig(mode="quant_sparse")

#: Canonical name -> base config for the three modes.  The single copy —
#: the launchers and the RunSpec resolver all import this one (the
#: per-launcher ``MODES = {...}`` dicts predate the RunSpec API).
MODES = {"dense": DENSE, "quant": QUANT, "quant_sparse": QUANT_SPARSE}


class KeyGen:
    """Deterministic per-trace key stream for SR sites.

    Each ``next()`` folds an incrementing counter into the base key, so a
    model with N rounding sites consumes N distinct, reproducible streams
    per step without threading keys through every layer signature.
    """

    def __init__(self, key: Optional[jax.Array]):
        self._key = key
        self._counter = 0

    def next(self) -> jax.Array:
        assert self._key is not None, "quantized mode requires an rng key"
        k = jax.random.fold_in(self._key, self._counter)
        self._counter += 1
        return k


def _q(x: jax.Array, cfg: SpringConfig, keys: Optional[KeyGen],
       role: str = "out") -> jax.Array:
    """Quantize one tensor onto the grid (STE for gradients).

    role: "act" | "weight" | "out" — weight quantization is skipped when
    weights_pre_quantized; operands may round-to-nearest (no RNG).
    """
    if role == "weight" and cfg.weights_pre_quantized:
        return x
    stochastic = cfg.stochastic
    if role in ("act", "weight") and cfg.operand_rounding == "nearest":
        stochastic = False
    if stochastic and keys is not None:
        return ste_quantize_stochastic(keys.next(), x, cfg.fmt)
    return ste_quantize_nearest(x, cfg.fmt)


def spring_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: SpringConfig = DENSE,
    keys: Optional[KeyGen] = None,
    w_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """``x @ w`` under the configured SPRING numerics.

    x: (..., K); w: (K, N); w_mask: optional (K, N) {0,1} pruning mask
    (the weight-sparsity source for LM archs; CNN activation sparsity
    arises naturally from ReLU and is captured by the value pattern).
    """
    if cfg.mode == "dense":
        if w_mask is not None:
            w = w * w_mask.astype(w.dtype)
        return jnp.matmul(
            x.astype(cfg.dense_dtype), w.astype(cfg.dense_dtype)
        ).astype(cfg.dense_dtype)

    xq = _q(x, cfg, keys, role="act")
    if w_mask is not None:
        w = w * w_mask.astype(w.dtype)
    wq = _q(w, cfg, keys, role="weight")

    if cfg.is_sparse:
        from repro.kernels import registry
        from repro.kernels.masked_matmul import ops as mm_ops

        # 2-D calls route the backward through the sparsity-aware dx/dw
        # kernels; batched matmuls (rare: MoE dispatch paths) keep dense
        # autodiff — the tiled kernels are 2-D by construction.
        bwd = cfg.backward_sparsity if cfg.sparse_backward \
            and xq.ndim == 2 and wq.ndim == 2 else "none"
        kimpl = registry.resolve_with(cfg.kernels, "masked_matmul")
        if kimpl.name in ("pallas", "interpret"):
            # tile-skipping kernel: SR epilogue fused on the MAC lanes
            # (the outer _q is then an on-grid identity); without the
            # custom_vjp backward this path is forward-only (Pallas calls
            # define no autodiff rule)
            y = mm_ops.masked_matmul(xq, wq, impl=kimpl.name, backward=bwd)
        elif bwd != "none":
            # "ref"/auto-CPU with sparse backward: the forward is the ref
            # impl with the SR epilogue disabled — bit-identical to the
            # dense jnp lowering below (ref(apply_sr=False) IS jnp.dot) —
            # while dL/dX / dL/dW resolve through masked_matmul_dx/dw.
            # The STE epilogue still comes from the outer _q.
            y = mm_ops.masked_matmul(xq, wq, impl="ref", apply_sr=False,
                                     backward=bwd)
        else:
            # "ref"/auto-CPU: the differentiable jnp lowering — fp32
            # accumulate on the fixed-point grid (DESIGN.md deviation 2)
            # with the SR epilogue applied below via the STE wrapper, so
            # gradients flow during quant_sparse training.
            y = jnp.matmul(xq.astype(jnp.float32), wq.astype(jnp.float32))
    else:
        # fp32 accumulate on the fixed-point grid (DESIGN.md deviation 2).
        y = jnp.matmul(xq.astype(jnp.float32), wq.astype(jnp.float32))

    # MAC-lane epilogue: stochastic rounding back to the storage format.
    return _q(y, cfg, keys)


# ---------------------------------------------------------------------------
# Sparsity-aware conv backward: both backward GEMMs of an NHWC conv are
# matmuls over patch matrices, so they route through the registry-resolved
# masked_matmul_dx/dw kernels exactly like the fc layers (DESIGN.md §8):
#
#   dW = patches(x).T @ g      — the stashed ReLU-sparse activation re-read
#   dX = patches~(g) @ rot(w)  — the ReLU-masked cotangent, stride-dilated
#
# where patches~ extracts windows of the cotangent with lhs_dilation=stride
# and transpose-conv padding, and rot(w) is the spatially-flipped weight.
# ---------------------------------------------------------------------------

import functools as _functools

from jax import lax as _lax

_CONV_DNUMS = ("NHWC", "HWIO", "NHWC")


def _conv_nhwc(x, w, stride, padding):
    return _lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=_CONV_DNUMS)


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _conv_with_sparse_bwd(x, w, stride, padding, bwd_impl):
    return _conv_nhwc(x, w, stride, padding)


def _conv_sb_fwd(x, w, stride, padding, bwd_impl):
    return _conv_nhwc(x, w, stride, padding), (x, w)


def _conv_sb_bwd(stride, padding, bwd_impl, res, g):
    from repro.kernels.masked_matmul.backward import (
        masked_matmul_dw, masked_matmul_dx)

    x, w = res
    impl = None if bwd_impl == "auto" else bwd_impl
    n, h, wd, cin = x.shape
    r, s, _, cout = w.shape
    oh, ow = g.shape[1], g.shape[2]
    g2 = g.reshape(-1, cout)

    # dW: im2col patches of the stashed sparse activation x the cotangent.
    # conv_general_dilated_patches orders the patch features (Cin, R, S).
    p = _lax.conv_general_dilated_patches(
        x, filter_shape=(r, s), window_strides=stride, padding=padding,
        dimension_numbers=_CONV_DNUMS)
    dw = masked_matmul_dw(p.reshape(-1, cin * r * s), g2, impl=impl)
    dw = dw.reshape(cin, r, s, cout).transpose(1, 2, 0, 3)

    # dX: transpose-conv as dilated cotangent patches x flipped weights.
    fwd_pads = _lax.padtype_to_pads((h, wd), (r, s), stride, padding)
    bwd_pads = [
        (k - 1 - plo, dim - (odim - 1) * st + plo - 1)
        for (plo, _), k, dim, odim, st in zip(
            fwd_pads, (r, s), (h, wd), (oh, ow), stride)
    ]
    pg = _lax.conv_general_dilated_patches(
        g, filter_shape=(r, s), window_strides=(1, 1), padding=bwd_pads,
        lhs_dilation=stride, dimension_numbers=_CONV_DNUMS)
    wt = w[::-1, ::-1].transpose(3, 0, 1, 2).reshape(cout * r * s, cin)
    dx = masked_matmul_dx(pg.reshape(-1, cout * r * s), wt.T, impl=impl)
    return dx.reshape(n, h, wd, cin), dw


_conv_with_sparse_bwd.defvjp(_conv_sb_fwd, _conv_sb_bwd)


def spring_conv2d(
    x: jax.Array,
    w: jax.Array,
    cfg: SpringConfig = DENSE,
    keys: Optional[KeyGen] = None,
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    feature_group_count: int = 1,
) -> jax.Array:
    """NHWC conv under SPRING numerics. w: (R, S, Cin/g, Cout)."""
    if cfg.mode == "dense":
        return jax.lax.conv_general_dilated(
            x.astype(cfg.dense_dtype),
            w.astype(cfg.dense_dtype),
            window_strides=stride,
            padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=feature_group_count,
        ).astype(cfg.dense_dtype)

    xq = _q(x, cfg, keys, role="act")
    wq = _q(w, cfg, keys, role="weight")
    if cfg.sparse_backward and feature_group_count == 1:
        # forward identical to the dense lowering below; backward GEMMs
        # (dX/dW) route through masked_matmul_dx/dw.  Grouped/depthwise
        # convs keep dense autodiff — their patch matrices interleave
        # groups and defeat the tiled kernels.
        y = _conv_with_sparse_bwd(
            xq.astype(jnp.float32), wq.astype(jnp.float32),
            tuple(stride), padding, cfg.backward_sparsity)
        return _q(y, cfg, keys)
    y = jax.lax.conv_general_dilated(
        xq.astype(jnp.float32),
        wq.astype(jnp.float32),
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count,
    )
    return _q(y, cfg, keys)


def spring_einsum(
    spec: str,
    a: jax.Array,
    b: jax.Array,
    cfg: SpringConfig = DENSE,
    keys: Optional[KeyGen] = None,
) -> jax.Array:
    """Einsum under SPRING numerics (attention logits/combines, routing)."""
    if cfg.mode == "dense":
        return jnp.einsum(spec, a.astype(cfg.dense_dtype), b.astype(cfg.dense_dtype))
    aq = _q(a, cfg, keys, role="act")
    bq = _q(b, cfg, keys, role="act")
    y = jnp.einsum(spec, aq.astype(jnp.float32), bq.astype(jnp.float32))
    return _q(y, cfg, keys)
