"""Public SPRING compute ops: quantized, sparsity-aware matmul/conv.

Every linear/conv layer in the model zoo funnels through these.  Three
modes (``SpringMode``):

  dense        — plain bf16/fp32 baseline (the 'GPU' reference numerics).
  quant        — Q(IL,FL) fixed-point operands, fp32 accumulate, stochastic
                 rounding on the output (paper P2; training-safe via STE).
  quant_sparse — quant + binary-mask sparsity: dangling non-zeros are
                 filtered (numerics identical to quant with masked
                 operands) and, on TPU, all-zero MXU tiles are skipped by
                 the ``masked_matmul`` Pallas kernel (paper P1).

On CPU (this container, and the 512-host-device dry-run) the quant_sparse
path lowers to the vectorized jnp equivalent — Pallas-for-TPU cannot lower
on the CPU backend, and interpret-mode callbacks would poison
``cost_analysis``.  Backend selection is the ``kernels`` KernelPolicy:
each matmul resolves ``masked_matmul`` through ``repro.kernels.registry``
(auto picks Pallas on TPU, the differentiable jnp lowering elsewhere).
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import (
    SPRING_FORMAT,
    FixedPointFormat,
    ste_quantize_nearest,
    ste_quantize_stochastic,
)
from repro.kernels.registry import KernelPolicy

SpringMode = Literal["dense", "quant", "quant_sparse"]


@dataclasses.dataclass(frozen=True)
class SpringConfig:
    """Numerics configuration threaded through every model layer."""

    mode: SpringMode = "dense"
    fmt: FixedPointFormat = SPRING_FORMAT
    # Deterministic rounding for activations on the fwd of *inference*;
    # training always uses SR (the paper's convergence argument).
    stochastic: bool = True
    # Kernel-dispatch policy: per-op backend pins + global default,
    # resolved through repro.kernels.registry at every kernel call site.
    kernels: KernelPolicy = KernelPolicy()
    # Compute dtype of the dense baseline path.
    dense_dtype: jnp.dtype = jnp.bfloat16
    # §Perf levers for the quantized path:
    #  - weights updated by the SR fixed-point optimizer are ALREADY on the
    #    Q-grid: skip their runtime re-quantization (identity op)
    #  - operands can round-to-nearest (no RNG hash); SR stays on the MAC
    #    output, which is where the paper's convergence argument lives
    weights_pre_quantized: bool = False
    operand_rounding: str = "stochastic"  # "stochastic" | "nearest"

    @property
    def is_quantized(self) -> bool:
        return self.mode != "dense"

    @property
    def is_sparse(self) -> bool:
        return self.mode == "quant_sparse"


DENSE = SpringConfig(mode="dense")
QUANT = SpringConfig(mode="quant")
QUANT_SPARSE = SpringConfig(mode="quant_sparse")


class KeyGen:
    """Deterministic per-trace key stream for SR sites.

    Each ``next()`` folds an incrementing counter into the base key, so a
    model with N rounding sites consumes N distinct, reproducible streams
    per step without threading keys through every layer signature.
    """

    def __init__(self, key: Optional[jax.Array]):
        self._key = key
        self._counter = 0

    def next(self) -> jax.Array:
        assert self._key is not None, "quantized mode requires an rng key"
        k = jax.random.fold_in(self._key, self._counter)
        self._counter += 1
        return k


def _q(x: jax.Array, cfg: SpringConfig, keys: Optional[KeyGen],
       role: str = "out") -> jax.Array:
    """Quantize one tensor onto the grid (STE for gradients).

    role: "act" | "weight" | "out" — weight quantization is skipped when
    weights_pre_quantized; operands may round-to-nearest (no RNG).
    """
    if role == "weight" and cfg.weights_pre_quantized:
        return x
    stochastic = cfg.stochastic
    if role in ("act", "weight") and cfg.operand_rounding == "nearest":
        stochastic = False
    if stochastic and keys is not None:
        return ste_quantize_stochastic(keys.next(), x, cfg.fmt)
    return ste_quantize_nearest(x, cfg.fmt)


def spring_matmul(
    x: jax.Array,
    w: jax.Array,
    cfg: SpringConfig = DENSE,
    keys: Optional[KeyGen] = None,
    w_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """``x @ w`` under the configured SPRING numerics.

    x: (..., K); w: (K, N); w_mask: optional (K, N) {0,1} pruning mask
    (the weight-sparsity source for LM archs; CNN activation sparsity
    arises naturally from ReLU and is captured by the value pattern).
    """
    if cfg.mode == "dense":
        if w_mask is not None:
            w = w * w_mask.astype(w.dtype)
        return jnp.matmul(
            x.astype(cfg.dense_dtype), w.astype(cfg.dense_dtype)
        ).astype(cfg.dense_dtype)

    xq = _q(x, cfg, keys, role="act")
    if w_mask is not None:
        w = w * w_mask.astype(w.dtype)
    wq = _q(w, cfg, keys, role="weight")

    if cfg.is_sparse:
        from repro.kernels import registry
        from repro.kernels.masked_matmul import ops as mm_ops

        kimpl = registry.resolve_with(cfg.kernels, "masked_matmul")
        if kimpl.name in ("pallas", "interpret"):
            # tile-skipping kernel: SR epilogue fused on the MAC lanes
            # (the outer _q is then an on-grid identity)
            y = mm_ops.masked_matmul(xq, wq, impl=kimpl.name)
        else:
            # "ref"/auto-CPU: the differentiable jnp lowering — fp32
            # accumulate on the fixed-point grid (DESIGN.md deviation 2)
            # with the SR epilogue applied below via the STE wrapper, so
            # gradients flow during quant_sparse training.
            y = jnp.matmul(xq.astype(jnp.float32), wq.astype(jnp.float32))
    else:
        # fp32 accumulate on the fixed-point grid (DESIGN.md deviation 2).
        y = jnp.matmul(xq.astype(jnp.float32), wq.astype(jnp.float32))

    # MAC-lane epilogue: stochastic rounding back to the storage format.
    return _q(y, cfg, keys)


def spring_conv2d(
    x: jax.Array,
    w: jax.Array,
    cfg: SpringConfig = DENSE,
    keys: Optional[KeyGen] = None,
    stride: tuple[int, int] = (1, 1),
    padding: str = "SAME",
    feature_group_count: int = 1,
) -> jax.Array:
    """NHWC conv under SPRING numerics. w: (R, S, Cin/g, Cout)."""
    if cfg.mode == "dense":
        return jax.lax.conv_general_dilated(
            x.astype(cfg.dense_dtype),
            w.astype(cfg.dense_dtype),
            window_strides=stride,
            padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=feature_group_count,
        ).astype(cfg.dense_dtype)

    xq = _q(x, cfg, keys, role="act")
    wq = _q(w, cfg, keys, role="weight")
    y = jax.lax.conv_general_dilated(
        xq.astype(jnp.float32),
        wq.astype(jnp.float32),
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count,
    )
    return _q(y, cfg, keys)


def spring_einsum(
    spec: str,
    a: jax.Array,
    b: jax.Array,
    cfg: SpringConfig = DENSE,
    keys: Optional[KeyGen] = None,
) -> jax.Array:
    """Einsum under SPRING numerics (attention logits/combines, routing)."""
    if cfg.mode == "dense":
        return jnp.einsum(spec, a.astype(cfg.dense_dtype), b.astype(cfg.dense_dtype))
    aq = _q(a, cfg, keys, role="act")
    bq = _q(b, cfg, keys, role="act")
    y = jnp.einsum(spec, aq.astype(jnp.float32), bq.astype(jnp.float32))
    return _q(y, cfg, keys)
