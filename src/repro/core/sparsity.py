"""Pre-/post-compute sparsity modules (SPRING P1, paper Figs. 6-7, Alg. 1).

The pre-compute sparsity module takes compressed activations+weights and
their binary masks and produces *matched* zero-free operand streams for the
MAC lanes:

  1. mask generation (Fig. 7a): ``out = a_mask AND w_mask``; per-operand
     filter masks ``a_filter = a_mask XOR out``, ``w_filter = w_mask XOR out``.
  2. dangling-data filter (Fig. 7b / Algorithm 1): drop non-zeros whose
     partner at the same index is zero.
  3. zero-collapsing shifter (Fig. 7c): re-compact the filtered stream.

The post-compute sparsity module re-encodes outputs after the activation
function so data stays zero-free in on-chip memory.

These are the *functional* (testable) forms.  The MXU-tile-granular kernel
realization of the same math is ``kernels/masked_matmul``; the faithful
sequential Algorithm-1 oracle is ``kernels/mask_compress/ref.py``.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.masking import (
    MaskedVector,
    mask_decode,
    mask_encode,
    pack_mask_bits,
    unpack_mask_bits,
)


class MatchedOperands(NamedTuple):
    """Output of the pre-compute sparsity module: aligned zero-free streams."""

    a_values: jax.Array  # (n,) float32, matched non-zeros collapsed to front
    w_values: jax.Array  # (n,) float32, aligned with a_values
    out_mask: jax.Array  # packed uint32 AND-mask
    n_matched: jax.Array  # () int32


def generate_masks(
    a_mask: jax.Array, w_mask: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fig. 7(a): output mask = AND; filter masks = XOR with the AND.

    All arguments/results are packed uint32 mask words — the hardware
    operates on the packed form directly (bitwise gates).
    """
    out = a_mask & w_mask
    a_filter = a_mask ^ out
    w_filter = w_mask ^ out
    return out, a_filter, w_filter


def _filter_and_collapse(
    values: jax.Array, own_mask_bits: jax.Array, out_mask_bits: jax.Array
) -> jax.Array:
    """Fig. 7(b)+(c) vectorized: drop dangling non-zeros, re-collapse.

    ``values`` is the zero-free stream for one operand; ``own_mask_bits``
    its dense position bits; ``out_mask_bits`` the AND bits.  An element of
    the stream survives iff its dense position is set in the AND mask.
    """
    n = own_mask_bits.shape[0]
    # position of each dense index inside the incoming zero-free stream
    src = jnp.cumsum(own_mask_bits.astype(jnp.int32)) - 1
    # dense-domain values (0 where own bit unset)
    dense_vals = jnp.where(own_mask_bits, values[jnp.clip(src, 0, n - 1)], 0.0)
    # keep only AND-mask survivors, then collapse
    kept = jnp.where(out_mask_bits, dense_vals, 0.0)
    dest = jnp.cumsum(out_mask_bits.astype(jnp.int32)) - 1
    dest = jnp.where(out_mask_bits, dest, n)
    return jnp.zeros((n,), jnp.float32).at[dest].set(kept, mode="drop")


def precompute_sparsity(a: MaskedVector, w: MaskedVector) -> MatchedOperands:
    """The full pre-compute sparsity module on compressed operands."""
    assert a.length == w.length, (a.length, w.length)
    out_words, _, _ = generate_masks(a.mask, w.mask)
    out_bits = unpack_mask_bits(out_words, a.length)
    a_bits = unpack_mask_bits(a.mask, a.length)
    w_bits = unpack_mask_bits(w.mask, w.length)
    return MatchedOperands(
        a_values=_filter_and_collapse(a.values, a_bits, out_bits),
        w_values=_filter_and_collapse(w.values, w_bits, out_bits),
        out_mask=out_words,
        n_matched=out_bits.sum().astype(jnp.int32),
    )


def sparse_dot(a: MaskedVector, w: MaskedVector) -> jax.Array:
    """Dot product evaluated entirely in the zero-free domain.

    Equals ``mask_decode(a) @ mask_decode(w)`` but only touches matched
    non-zero pairs — the MAC-lane computation of the paper.
    """
    m = precompute_sparsity(a, w)
    return jnp.dot(m.a_values, m.w_values)


def postcompute_sparsity(y: jax.Array) -> MaskedVector:
    """Post-compute sparsity module: re-encode after the activation fn."""
    return mask_encode(y)


def relu_then_encode(y: jax.Array) -> MaskedVector:
    """Common CNN path: ReLU creates the sparsity the encoder captures."""
    return postcompute_sparsity(jax.nn.relu(y))


# ---------------------------------------------------------------------------
# Dense-domain convenience forms (used by the model layers, where operands
# live as ordinary arrays and masks are semantic, e.g. pruning masks).
# ---------------------------------------------------------------------------


def apply_joint_mask(a: jax.Array, w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dense-domain equivalent of the dangling-data filter.

    Zeroing each operand where the other is zero changes nothing
    mathematically (the products were already zero) — which is exactly why
    SPRING can skip them.  Returned values are what the MAC lanes 'see'.
    """
    joint = (a != 0.0) & (w != 0.0)
    return jnp.where(joint, a, 0.0), jnp.where(joint, w, 0.0)


def mask_words_from_dense(x: jax.Array) -> jax.Array:
    """Packed occupancy mask of a dense array (flattened)."""
    return pack_mask_bits((x.reshape(-1) != 0.0))
