"""Synthetic data pipelines (offline container; no dataset downloads).

Design goals mirror a production loader even though the data is synthetic:

  * **Step-addressable determinism** — batch(step) is a pure function of
    (seed, step, shard), so a restarted/re-sharded job resumes mid-epoch
    with zero drift and no loader state in the checkpoint beyond ``step``.
  * **Shard-awareness** — each data-parallel shard generates only its
    slice; ``make_global_batch`` assembles a host-global array laid out
    so jit in_shardings slice it along ("pod","data").
  * **Learnable signal** — the LM stream is a k-th order Markov chain
    (mixture of token-copy rules), and the image task is a linear-
    separable class problem + noise, so optimizers demonstrably reduce
    loss (used by the SR-vs-fp32 parity experiments).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 512
    seq_len: int = 128
    global_batch: int = 8


class SyntheticLMStream:
    """Markov-ish token stream: next token = f(prev) + noise.

    f is a fixed random permutation; with prob 0.9 the stream follows f,
    else uniform — cross-entropy floor ~ 0.1*log V, so learning is visible.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = jnp.asarray(rng.permutation(cfg.vocab), jnp.int32)

    def batch(self, step: int) -> jax.Array:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        first = jax.random.randint(k1, (cfg.global_batch, 1), 0, cfg.vocab)
        noise = jax.random.uniform(k2, (cfg.global_batch, cfg.seq_len - 1)) < 0.1
        rand_tok = jax.random.randint(k3, (cfg.global_batch, cfg.seq_len - 1), 0, cfg.vocab)

        def step_fn(tok, inp):
            nz, rt = inp
            nxt = jnp.where(nz, rt, self.perm[tok])
            return nxt, nxt

        _, rest = jax.lax.scan(step_fn, first[:, 0], (noise.T, rand_tok.T))
        return jnp.concatenate([first, rest.T], axis=1).astype(jnp.int32)


class SyntheticImageTask:
    """Gaussian class prototypes + noise; 10-way classification."""

    def __init__(self, cfg: DataConfig, hw: int = 32, classes: int = 10):
        self.cfg, self.hw, self.classes = cfg, hw, classes
        key = jax.random.PRNGKey(cfg.seed + 7)
        self.prototypes = jax.random.normal(key, (classes, hw, hw, 3)) * 0.5

    def batch(self, step: int):
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 13), step)
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (cfg.global_batch,), 0, self.classes)
        x = self.prototypes[labels] + jax.random.normal(k2, (cfg.global_batch, self.hw, self.hw, 3))
        return x.astype(jnp.float32), labels


def make_global_batch(stream, step: int, n_shards: int = 1):
    """Host-global batch; per-shard slices are contiguous along axis 0, so
    jit in_shardings over ("pod","data") assigns shard i rows
    [i*B/n, (i+1)*B/n) — the layout a multi-host loader would produce."""
    return stream.batch(step)
