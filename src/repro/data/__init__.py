"""Deterministic, shard-aware synthetic data pipelines."""

from repro.data.pipeline import (
    DataConfig,
    SyntheticImageTask,
    SyntheticLMStream,
    make_global_batch,
)

__all__ = ["DataConfig", "SyntheticImageTask", "SyntheticLMStream", "make_global_batch"]
