"""Sessions: one object per execution mode, all driven by one RunSpec.

``TrainSession`` / ``ServeSession`` / ``DryrunSession`` own what the
launchers used to assemble by hand — jit program building, the data
stream, checkpointing, metrics sinks — and every ``run()`` result embeds
the canonical resolved spec (``spec`` / ``spec_hash`` / ``provenance``)
so any run is reproducible from one artifact.

The launchers (``repro.launch.train|serve|dryrun``) and examples are thin
adapters: parse ``--spec`` + ``--set`` (+ deprecated legacy flags), build
the RunSpec, hand it to :func:`session_for`.

The session bodies are verbatim ports of the pre-RunSpec launcher loops;
the serving parity suite (tests/test_serving.py) and the checkpoint
determinism tests (tests/test_system.py) seal them bit-for-bit.
"""

import dataclasses
import json
import logging
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.api.spec import RunSpec, SpecError, build_spec
from repro.configs import SHAPES

log = logging.getLogger("repro.train")


class Session:
    """Base: resolve the spec once, expose the reproducibility payload."""

    run_mode: str = ""

    def __init__(self, spec: RunSpec, *, mesh=None):
        if self.run_mode and spec.run != self.run_mode:
            raise SpecError(
                f"{type(self).__name__} needs a run={self.run_mode!r} spec, "
                f"got run={spec.run!r}")
        self.spec = spec
        self.resolved = spec.resolve()
        if mesh is None and spec.shape.mesh.explicit:
            # spring-mesh: an explicit topology in the spec builds its
            # own mesh (DESIGN.md §14); a caller-passed mesh still wins
            mesh = build_mesh(spec.shape.mesh)
        self.mesh = mesh

    def trace_path(self) -> str:
        """Effective Chrome-trace output path ('' = telemetry off).
        An enabled spec with no explicit path still writes a trace — the
        acceptance contract is that flipping ``telemetry.enabled`` alone
        yields a Perfetto-loadable artifact."""
        t = self.spec.telemetry
        if not t.enabled:
            return ""
        return t.trace_path or f"spring_{self.spec.run}_trace.json"

    def telemetry_scope(self):
        """Ambient spring-trace scope for this run (no-op when disabled);
        session bodies run inside it so engine/kernel/memstash spans land
        in one tracer, written to :meth:`trace_path` on exit."""
        t = self.spec.telemetry
        cfg = telemetry.TelemetryConfig(
            enabled=t.enabled, trace_path=self.trace_path(),
            sample_rate=t.sample_rate)
        return telemetry.scope(cfg, metadata={
            "run": self.spec.run, "spec_hash": self.spec.spec_hash()})

    def _with_payload(self, out: dict) -> dict:
        out.update(self.spec.payload())
        if self.spec.telemetry.enabled:
            tr = telemetry.tracer()
            out["telemetry"] = {
                "metrics": telemetry.metrics().snapshot(),
                "trace_path": self.trace_path(),
                "sample_rate": self.spec.telemetry.sample_rate,
                "spans": len(tr) if tr is not None else 0,
            }
        return out


class TrainSession(Session):
    """End-to-end training driver: data -> train_step -> checkpoint ->
    resume, with the straggler watchdog and loss metrics sink."""

    run_mode = "train"

    def run(self) -> dict:
        with self.telemetry_scope():
            return self._run_body()

    def _run_body(self) -> dict:
        from repro.checkpoint import CheckpointManager
        from repro.data.pipeline import DataConfig, SyntheticLMStream
        from repro.runtime.resilience import StragglerWatchdog
        from repro.runtime.train import TrainState, init_train_state, make_train_step

        spec, r = self.spec, self.resolved
        cfg, step_cfg, view = r.config, r.step, r.view
        seed = spec.seeds.seed

        data = SyntheticLMStream(DataConfig(
            seed=seed, vocab=cfg.vocab, seq_len=spec.shape.seq,
            global_batch=spec.shape.batch))
        state = init_train_state(jax.random.PRNGKey(seed), view, step_cfg,
                                 reduced=True)
        start_step = 0

        manager = (CheckpointManager(spec.train.ckpt_dir,
                                     every_steps=spec.train.ckpt_every)
                   if spec.train.ckpt_dir else None)
        if manager is not None:
            restored = manager.restore_or_none()
            if restored is not None:
                start_step, tree = restored
                state = TrainState(*tree)
                log.info("resumed from step %d", start_step)

        sharded = spec.shape.mesh.data > 1 and self.mesh is not None
        if sharded:
            # spring-mesh: packed-collective data parallelism — gradients
            # cross the wire binary-mask compressed, losses stay
            # bit-identical to the single-device oracle (DESIGN.md §14)
            from repro.dist.train import make_sharded_train_step

            step_fn = jax.jit(
                make_sharded_train_step(view, step_cfg, self.mesh),
                donate_argnums=(0,))
        else:
            step_fn = jax.jit(make_train_step(view, step_cfg, mesh=self.mesh),
                              donate_argnums=(0,))
        watchdog = StragglerWatchdog()
        losses = []
        steps = spec.train.steps
        meta = {"arch": spec.arch.id, "mode": spec.numerics.mode,
                "spec_hash": spec.spec_hash()}
        for step in range(start_step, steps):
            with telemetry.span("train.step", step=step):
                with telemetry.span("train.step.data"):
                    tokens = data.batch(step)
                watchdog.step_start()
                with telemetry.span("train.step.device"):
                    state, metrics = step_fn(state, {"tokens": tokens})
                    if telemetry.enabled():
                        # pin dispatch+compute inside the device span so
                        # the host span measures host work only; changes
                        # when we wait, never what is computed
                        jax.block_until_ready(metrics)
                with telemetry.span("train.step.host"):
                    loss = float(metrics["loss"])
                    watchdog.step_end(step)
                    losses.append(loss)
                    if step % spec.train.log_every == 0 or step == steps - 1:
                        log.info("step %d loss %.4f grad_norm %.3f", step,
                                 loss, float(metrics["grad_norm"]))
                    if manager is not None:
                        manager.maybe_save(step + 1,
                                           tuple(state.tree_flatten()[0]),
                                           meta)
        if manager is not None:
            manager.maybe_save(steps, tuple(state.tree_flatten()[0]), meta,
                               force=True)
        out = {
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "losses": losses,
            "slow_steps": sum(1 for e in watchdog.events if e.slow),
            "state": state,
            "mesh": spec.shape.mesh.label(),
        }
        if sharded:
            # measured wire accounting of one packed exchange at the
            # probe density (the jitted path's hooks are trace-inert)
            from repro.dist.collectives import collective_probe

            out["collective_probe"] = collective_probe(
                spec.sparsity.probe_density, world=spec.shape.mesh.data)
        return self._with_payload(out)


# -- serving ----------------------------------------------------------------


def synthetic_batch(arch, cfg, batch: int, prompt_len: int, key) -> dict:
    """The serving sessions' stand-in traffic (same construction the
    static path always used, so engine/static parity runs on identical
    prompts)."""
    if arch.is_encdec:
        return {
            "frames": jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model),
                                        jnp.bfloat16),
            "tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab),
        }
    out = {"tokens": jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)}
    if cfg.vlm_prefix_len:
        out["img_embeds"] = jax.random.normal(
            key, (batch, cfg.vlm_prefix_len, cfg.d_model), jnp.bfloat16)
    return out


class ServeSession(Session):
    """One-shot serving session: the continuous-batching engine (or, for
    encoder-decoder archs and ``serving.static`` specs, the pre-engine
    static reference path kept as the parity oracle)."""

    run_mode = "serve"

    def __init__(self, spec: RunSpec, *, mesh=None, params=None):
        super().__init__(spec, mesh=mesh)
        self.params = params

    def run(self) -> dict:
        with self.telemetry_scope():
            arch = self.resolved.arch
            if self.spec.serving.static or arch.is_encdec:
                # encoder-decoder archs keep the static loop (DESIGN.md §9)
                return self._with_payload(self._static())
            return self._with_payload(self._engine())

    def _static(self) -> dict:
        """The pre-engine static path: one fixed batch, prefill once,
        decode ``gen`` steps, throw the cache away.  Kept verbatim as the
        parity oracle the engine is sealed against."""
        from repro.serving.steps import make_decode_step, make_prefill_step

        spec, r = self.spec, self.resolved
        arch, view, cfg, step_cfg = r.arch, r.view, r.config, r.step
        batch, prompt_len, gen = (spec.shape.batch, spec.shape.prompt_len,
                                  spec.shape.gen)
        key = jax.random.PRNGKey(spec.seeds.seed)

        from repro.models import encdec as ed_mod
        from repro.models import lm as lm_mod

        init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
        params = self.params if self.params is not None else init(key, cfg)
        batch_inputs = synthetic_batch(arch, cfg, batch, prompt_len, key)

        sharded = (spec.shape.mesh.data > 1 and self.mesh is not None
                   and not arch.is_encdec)
        if sharded and batch % spec.shape.mesh.data:
            # indivisible request batch: replicate instead of sharding,
            # and say so through the same fallback counter the logical
            # rules use (satellite of DESIGN.md §14)
            from repro.runtime.sharding import note_mesh_fallback

            note_mesh_fallback("serve_batch")
            sharded = False
        if sharded:
            # spring-mesh: rows sharded over the data axis, logits cross
            # the wire binary-mask packed (DESIGN.md §14)
            from repro.dist.serve import (make_sharded_decode_step,
                                          make_sharded_prefill_step)

            prefill = jax.jit(make_sharded_prefill_step(
                view, step_cfg, self.mesh, reduced=True))
            decode = jax.jit(make_sharded_decode_step(
                view, step_cfg, self.mesh, reduced=True))
        else:
            prefill = jax.jit(make_prefill_step(view, step_cfg, mesh=self.mesh,
                                                reduced=True))
            decode = jax.jit(make_decode_step(view, step_cfg, mesh=self.mesh,
                                              reduced=True))

        t0 = time.monotonic()
        if arch.is_encdec:
            from repro.models.layers import SpringContext

            cache = ed_mod.encdec_init_cache(params, cfg, batch_inputs["frames"],
                                             SpringContext(),
                                             max_len=prompt_len + gen)
            logits = jnp.zeros((batch, cfg.vocab))
            next_tok = batch_inputs["tokens"][:, 0]
        else:
            # decode continues past the prompt: extend the cache buffers
            from repro.models.lm import pad_cache

            logits, cache = prefill(params, batch_inputs, key)
            cache = pad_cache(cache, gen)
            next_tok = jnp.argmax(logits, -1)
        t_prefill = time.monotonic() - t0

        tokens_out = []
        t0 = time.monotonic()
        for i in range(gen):
            logits, cache = decode(params, next_tok, cache,
                                   jax.random.fold_in(key, i))
            next_tok = (jnp.argmax(logits, -1) if spec.serving.greedy
                        else jax.random.categorical(
                            jax.random.fold_in(key, 1000 + i), logits))
            tokens_out.append(next_tok)
        jax.block_until_ready(logits)
        t_decode = time.monotonic() - t0

        seqs = jnp.stack(tokens_out, axis=1)
        out = {
            "generated": seqs,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": batch * gen / t_decode if t_decode else 0.0,
            "finite": bool(jnp.all(jnp.isfinite(logits))),
            "engine": False,
            "mesh": spec.shape.mesh.label(),
        }
        if sharded:
            from repro.dist.collectives import collective_probe

            out["collective_probe"] = collective_probe(
                spec.sparsity.probe_density, world=spec.shape.mesh.data)
        return out

    def _engine(self) -> dict:
        from repro.serving.engine import ServingEngine

        spec, r = self.spec, self.resolved
        arch, cfg = r.arch, r.config
        batch, prompt_len, gen = (spec.shape.batch, spec.shape.prompt_len,
                                  spec.shape.gen)
        # None means "default to batch" (the engine's from_spec applies
        # the same rule to slots; an explicit 0 must reach the engine's
        # own validation rather than being silently replaced)
        queue = spec.serving.queue
        n_requests = batch if queue is None else queue
        seed = spec.seeds.seed
        key = jax.random.PRNGKey(seed)

        from repro.models.lm import lm_init

        params = (self.params if self.params is not None
                  else lm_init(key, cfg))
        # queued requests beyond the first batch reuse the synthetic
        # construction with a folded key (distinct prompts, reproducible)
        prompts = []
        img = []
        for chunk in range((n_requests + batch - 1) // batch):
            bi = synthetic_batch(arch, cfg, batch, prompt_len,
                                 jax.random.fold_in(key, chunk) if chunk else key)
            for b in range(batch):
                prompts.append([int(t) for t in bi["tokens"][b]])
                img.append(bi.get("img_embeds")[b] if "img_embeds" in bi else None)
        prompts, img = prompts[:n_requests], img[:n_requests]

        engine = ServingEngine.from_spec(spec, params=params, mesh=self.mesh,
                                         resolved=r)
        if spec.serving.restore_path:
            # spring-survive resume: drain a saved snapshot's in-flight
            # work instead of submitting fresh requests — the restored
            # engine emits the exact remaining tokens of every request
            engine.restore_file(spec.serving.restore_path)
        else:
            for i, p in enumerate(prompts):
                engine.submit_prompt(p, gen, seed=seed + i, img_embeds=img[i])
        out = engine.run()
        # token lists may be ragged (EOS finishes / typed rejections):
        # stack only the uniform case, keep exact lists otherwise
        tok_lists = [req["tokens"] for req in out["per_request"]]
        lens = {len(t) for t in tok_lists}
        out["generated"] = (jnp.asarray(tok_lists, jnp.int32)
                            if len(lens) == 1 else tok_lists)
        out["engine"] = True
        out["slots"] = engine.n_slots
        out["mode"] = spec.numerics.mode
        return out


# -- dryrun -----------------------------------------------------------------


def build_mesh(mesh):
    """Mesh from a ``MeshSpec`` (or legacy kind string).  Explicit axis
    extents take precedence over ``kind`` (DESIGN.md §14)."""
    from repro.launch.mesh import make_debug_mesh, make_production_mesh

    kind = mesh
    if not isinstance(mesh, str):
        if mesh.explicit:
            from repro.dist.mesh import make_explicit_mesh

            return make_explicit_mesh(mesh.pod, mesh.data, mesh.model)
        kind = mesh.kind
    if kind == "single":
        return make_production_mesh(multi_pod=False)
    if kind == "multi":
        return make_production_mesh(multi_pod=True)
    if kind == "debug":
        return make_debug_mesh()
    if kind == "debug_multi":
        return make_debug_mesh(multi_pod=True)
    raise ValueError(kind)


def _param_counts(arch) -> tuple:
    """(total, active) parameter counts from init shapes (no allocation)."""
    from repro.models import encdec as ed_mod
    from repro.models import lm as lm_mod

    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), arch.config))
    total = emb = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if names[-1] == "embedding":
            emb += n
        if names[-1] in ("w_gate", "w_up", "w_down"):
            expert += n
    # tied embeddings serve as the lm_head -> their matmul IS model compute
    tied = bool(getattr(arch.config, "tie_embeddings", False)) or arch.is_encdec
    active = total - (0 if tied else emb)
    cfg = arch.config
    moe = getattr(cfg, "moe", None)
    if moe is not None and expert:
        active -= expert * (1.0 - moe.top_k / moe.n_experts)
    return float(total), float(active)


def model_flops(arch, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    total, active = _param_counts(arch)
    d_tokens = sh.global_batch * sh.seq_len
    if arch.is_encdec and sh.kind != "decode":
        d_tokens = sh.global_batch * (sh.seq_len + arch.config.enc_seq)
    if sh.kind == "train":
        return 6.0 * active * d_tokens
    if sh.kind == "prefill":
        return 2.0 * active * d_tokens
    return 2.0 * active * sh.global_batch  # decode: per emitted token


def run_lower(arch, shape_name, mesh, step_cfg, serve_dtype):
    """Lower one cell (train | prefill | decode) with explicit shardings."""
    from repro.runtime.train import (
        init_train_state,
        make_decode_step,
        make_prefill_step,
        make_train_step,
    )
    from repro.runtime.tree_sharding import batch_shardings, tree_shardings

    sh = SHAPES[shape_name]
    mode_quant = step_cfg.spring.is_quantized
    if sh.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), arch, step_cfg)
        )
        batch_shapes = {
            k: v for k, v in arch.input_specs(shape_name, arch.config).items()
        }
        step = make_train_step(arch, step_cfg, mesh=mesh)
        state_sh = tree_shardings(state_shapes, mesh)
        batch_sh = batch_shardings(batch_shapes, mesh)
        return jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_shapes, batch_shapes)

    from repro.models import encdec as ed_mod
    from repro.models import lm as lm_mod

    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    param_shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), arch.config))
    param_shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype)
        if s.dtype == jnp.float32 else s, param_shapes)
    param_sh = tree_shardings(param_shapes, mesh)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if sh.kind == "prefill":
        batch_shapes = dict(arch.input_specs(shape_name, arch.config))
        batch_sh = batch_shardings(batch_shapes, mesh)
        fn = make_prefill_step(arch, step_cfg, mesh=mesh)
        out_shapes = jax.eval_shape(fn, param_shapes, batch_shapes, key_spec)
        out_sh = (None, tree_shardings(out_shapes[1], mesh))
        return jax.jit(
            fn, in_shardings=(param_sh, batch_sh, None), out_shardings=out_sh
        ).lower(param_shapes, batch_shapes, key_spec)

    # decode
    cache_shapes = arch.cache_specs(
        shape_name, arch.config,
        cache_dtype="int8" if step_cfg.int8_cache else None)
    cache_shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, serve_dtype)
        if s.dtype == jnp.bfloat16 and mode_quant else s, cache_shapes)
    cache_sh = tree_shardings(cache_shapes, mesh)
    tok_shapes = dict(arch.input_specs(shape_name, arch.config))
    tok_sh = batch_shardings(tok_shapes, mesh)
    fn = make_decode_step(arch, step_cfg, mesh=mesh)
    return jax.jit(
        fn,
        in_shardings=(param_sh, tok_sh["tokens"], cache_sh, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    ).lower(param_shapes, tok_shapes["tokens"], cache_shapes, key_spec)


def _unrolled(arch):
    """Cost-shadow variant: fully unrolled layer scan so cost_analysis and
    the collective parse see every layer (XLA counts while bodies once)."""
    return dataclasses.replace(
        arch, config=dataclasses.replace(arch.config, scan_unroll=True)
    )


class DryrunSession(Session):
    """Multi-pod dry-run of one (arch x shape x mesh) cell: lower +
    compile + memory/cost/collective analyses, no allocation.

    NB: production meshes need host placeholder devices — run through
    ``repro.launch.dryrun`` (which sets ``XLA_FLAGS`` before jax loads)
    or export ``--xla_force_host_platform_device_count`` yourself.
    """

    run_mode = "dryrun"

    def _arch_for_lower(self):
        """ArchDef with the resolved concrete config swapped in —
        ``run_lower`` and the shape/cache spec helpers read
        ``arch.config``."""
        r = self.resolved
        cfg = r.config
        return dataclasses.replace(r.arch, config=cfg, reduced=lambda: cfg)

    def lower(self, mesh=None):
        """Resolve + build mesh + lower the cell (no compile): the cheap
        every-arch CI path ('dryrun-from-spec')."""
        spec = self.spec
        arch = self._arch_for_lower()
        if spec.shape.cell in arch.skipped_shapes():
            return None
        mesh = mesh or self.mesh or build_mesh(spec.shape.mesh)
        serve_dtype = (jnp.bfloat16 if spec.numerics.mode == "dense"
                       else jnp.float32)
        return run_lower(arch, spec.shape.cell, mesh, self.resolved.step,
                         serve_dtype)

    def run(self, verbose: bool = True) -> dict:
        with self.telemetry_scope():
            return self._run_body(verbose)

    def _run_body(self, verbose: bool = True) -> dict:
        from repro.kernels import registry as kernel_registry
        from repro.launch.hlo_analysis import (
            collective_bytes,
            fusion_adjusted_bytes,
            memory_summary,
            roofline_terms,
        )
        from repro.runtime.compat import cost_analysis_dict

        spec, r = self.spec, self.resolved
        arch = self._arch_for_lower()
        shape_name, mesh_spec, mode = (spec.shape.cell, spec.shape.mesh,
                                       spec.numerics.mode)
        sh = SHAPES[shape_name]
        step_cfg = r.step
        kpolicy = r.kernel_policy
        base = {
            "arch": spec.arch.id, "shape": shape_name,
            "mesh": mesh_spec.label(),
            "mode": mode, "variant": spec.dryrun.variant,
        }
        if shape_name in arch.skipped_shapes():
            return self._with_payload(dict(
                base, status="skipped",
                reason=arch.skipped_shapes()[shape_name]))
        mesh = self.mesh or build_mesh(mesh_spec)
        n_chips = mesh.devices.size
        serve_dtype = jnp.bfloat16 if mode == "dense" else jnp.float32

        kernel_registry.reset_dispatch_counts()
        from repro.runtime.sharding import mesh_fallback_counts

        fallbacks_before = mesh_fallback_counts()
        t0 = time.time()
        lowered = run_lower(arch, shape_name, mesh, step_cfg, serve_dtype)
        t_lower = time.time() - t0
        # what the program actually dispatched at trace time, plus what the
        # policy resolves for every registered op on this host
        kernel_dispatch = kernel_registry.dispatch_counts()
        kernel_impls = kernel_registry.resolution_table(kpolicy)

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        bf16c = (mode == "dense")  # TPU-native bf16; CPU legalized to f32
        cost = cost_analysis_dict(compiled)
        mem = memory_summary(compiled.memory_analysis())
        hlo_text = compiled.as_text()
        coll = collective_bytes(hlo_text, bf16_correct=bf16c)
        adj = fusion_adjusted_bytes(hlo_text, bf16_correct=bf16c)["fusion_adjusted_bytes"]

        # Cost-shadow: recompile with the layer scan unrolled AND the
        # microbatch scan disabled so per-layer FLOPs/bytes/collectives
        # are all visible; memory comes from the real compile above.
        t_cost_compile = None
        if spec.dryrun.cost_unrolled:
            t0 = time.time()
            shadow_cfg = dataclasses.replace(step_cfg, microbatch=None)
            shadow = run_lower(_unrolled(arch), shape_name, mesh, shadow_cfg,
                               serve_dtype)
            shadow_c = shadow.compile()
            t_cost_compile = time.time() - t0
            cost = cost_analysis_dict(shadow_c)
            shadow_text = shadow_c.as_text()
            coll = collective_bytes(shadow_text, bf16_correct=bf16c)
            adj = fusion_adjusted_bytes(
                shadow_text, bf16_correct=bf16c)["fusion_adjusted_bytes"]
            del shadow_c, shadow_text

        mf = model_flops(arch, shape_name)
        terms = roofline_terms(cost, coll["total"], n_chips, model_flops=mf,
                               adjusted_bytes=adj)

        result = dict(
            base,
            status="ok", n_chips=int(n_chips), microbatch=step_cfg.microbatch,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            cost_compile_s=round(t_cost_compile, 1) if t_cost_compile else None,
            kernel_policy=kpolicy.describe(),
            kernel_impls=kernel_impls,
            kernel_dispatch=kernel_dispatch,
            backward_sparsity=spec.sparsity.backward,
            memory=mem, collectives=coll, roofline=terms,
            mesh_fallbacks={
                logical: count - fallbacks_before.get(logical, 0)
                for logical, count in mesh_fallback_counts().items()
                if count - fallbacks_before.get(logical, 0)},
        )
        if n_chips > 1:
            # Measured packed-collective wire accounting at the probe
            # density (the lowered program never executes in a dry run;
            # this eager probe attributes inter-device traffic per cell).
            from repro.dist.collectives import collective_probe

            result["collective_probe"] = collective_probe(
                spec.sparsity.probe_density,
                world=max(2, min(4, int(n_chips))))
        if mode == "quant_sparse" and spec.sparsity.backward != "none" \
                and sh.kind == "train":
            # Measured fwd/bwd tile-skip at the probe density: the lowered
            # program never executes in a dry run, so this small eager
            # probe attributes backward sparsity savings per cell.
            from repro.kernels.masked_matmul.backward import sparsity_probe

            result["sparsity_probe"] = sparsity_probe(
                spec.sparsity.probe_density, size=256)
        if mode == "quant_sparse" and sh.kind == "decode":
            # Serving twin of the sparsity probe: measured KV wire bytes
            # of one packed block at the probe density.
            from repro.kernels.kv_cache.ops import kv_probe

            result["kv_probe"] = kv_probe(spec.sparsity.probe_density)
        result = self._with_payload(result)
        if verbose:
            print(json.dumps(result, indent=2))
            print(f"peak bytes/chip (arg+out+temp-alias): "
                  f"{mem['peak_bytes_per_chip_est']/1e9:.3f} GB",
                  file=sys.stderr)
        return result


SESSION_TYPES = {
    "train": TrainSession,
    "serve": ServeSession,
    "dryrun": DryrunSession,
}


def session_for(spec: RunSpec, **kw) -> Session:
    """The one dispatch point: a spec's ``run`` field picks its session."""
    return SESSION_TYPES[spec.run](spec, **kw)


# -- legacy kwargs -> spec bridges ------------------------------------------
# The pre-RunSpec launcher functions (train_loop / serve_session /
# run_cell) keep their exact signatures as wrappers over these.


def _call_overrides(pairs) -> list:
    return [(path, value, f"call:{path}") for path, value in pairs
            if value is not None]


def train_spec(arch_id: str = "llama3.2-1b", *, reduced: bool = True,
               steps: int = 100, batch: int = 8, seq: int = 128,
               mode: str = "dense", lr: float = 3e-3,
               fixed_point_weights: bool = False,
               kernel_impl: Optional[str] = None,
               backward_sparsity: str = "auto", stash: str = "none",
               ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
               log_every: int = 10, seed: int = 0) -> RunSpec:
    """RunSpec equivalent of the legacy ``train_loop`` keyword surface."""
    return build_spec("train", overrides=_call_overrides([
        ("arch.id", arch_id), ("arch.reduced", reduced),
        ("train.steps", steps), ("shape.batch", batch), ("shape.seq", seq),
        ("numerics.mode", mode), ("optimizer.lr", lr),
        ("numerics.fixed_point_weights", fixed_point_weights),
        ("kernels.policy", kernel_impl),
        ("sparsity.backward", backward_sparsity),
        ("memstash.policy", stash),
        ("train.ckpt_dir", ckpt_dir or ""), ("train.ckpt_every", ckpt_every),
        ("train.log_every", log_every), ("seeds.seed", seed),
    ]))


def serve_spec(arch_id: str = "llama3.2-1b", *, reduced: bool = True,
               batch: int = 4, prompt_len: int = 32, gen: int = 16,
               mode: str = "dense", kernel_impl: Optional[str] = None,
               greedy: bool = True, seed: int = 0,
               slots: Optional[int] = None, queue: Optional[int] = None,
               static: bool = False, pages: bool = False,
               page_tokens: Optional[int] = None,
               num_pages: Optional[int] = None,
               overcommit: Optional[float] = None,
               prefix_cache: Optional[bool] = None,
               snapshot_every: Optional[int] = None,
               snapshot_path: Optional[str] = None,
               restore_path: Optional[str] = None,
               max_queue_depth: Optional[int] = None,
               deadline_ticks: Optional[int] = None,
               deadline_aware: Optional[bool] = None,
               priority_aware: Optional[bool] = None) -> RunSpec:
    """RunSpec equivalent of the legacy ``serve_session`` surface."""
    over = _call_overrides([
        ("arch.id", arch_id), ("arch.reduced", reduced),
        ("shape.batch", batch), ("shape.prompt_len", prompt_len),
        ("shape.gen", gen), ("numerics.mode", mode),
        ("kernels.policy", kernel_impl), ("serving.greedy", greedy),
        ("seeds.seed", seed), ("serving.static", static),
        ("serving.pages", pages),
    ])
    # slots/queue: None means "default to batch" and must stay None in the
    # spec (an explicit 0 must reach the engine's own validation)
    if slots is not None:
        over.append(("serving.slots", slots, "call:serving.slots"))
    if queue is not None:
        over.append(("serving.queue", queue, "call:serving.queue"))
    # paged-pool + spring-survive knobs: None keeps the spec default
    for key, value in (("page_tokens", page_tokens), ("num_pages", num_pages),
                       ("overcommit", overcommit),
                       ("prefix_cache", prefix_cache),
                       ("snapshot_every", snapshot_every),
                       ("snapshot_path", snapshot_path),
                       ("restore_path", restore_path),
                       ("max_queue_depth", max_queue_depth),
                       ("deadline_ticks", deadline_ticks),
                       ("deadline_aware", deadline_aware),
                       ("priority_aware", priority_aware)):
        if value is not None:
            over.append((f"serving.{key}", value, f"call:serving.{key}"))
    return build_spec("serve", overrides=over)


def dryrun_spec(arch_id: str, shape_name: str, mesh_kind: str = "single",
                mode: str = "dense", *, microbatch: Optional[int] = None,
                cost_unrolled: bool = True, seq_parallel: bool = False,
                bf16_logits: bool = False, layout: str = "tp",
                remat_policy: str = "full", cache_int8: bool = False,
                quant_opt: bool = False, variant: str = "baseline",
                kernel_impl: Optional[str] = None,
                backward_sparsity: str = "auto",
                probe_density: float = 0.5) -> RunSpec:
    """RunSpec equivalent of the legacy ``run_cell`` keyword surface
    (``arch.reduced`` stays null: dryrun resolves it to the full config)."""
    over = _call_overrides([
        ("arch.id", arch_id),
        ("shape.cell", shape_name), ("shape.mesh", mesh_kind),
        ("numerics.mode", mode),
        ("dryrun.cost_unrolled", cost_unrolled),
        ("shape.seq_parallel", seq_parallel),
        ("arch.bf16_logits", bf16_logits), ("shape.layout", layout),
        ("serving.int8_cache", cache_int8), ("dryrun.quant_opt", quant_opt),
        ("dryrun.variant", variant), ("kernels.policy", kernel_impl),
        ("sparsity.backward", backward_sparsity),
        ("sparsity.probe_density", probe_density),
    ])
    if microbatch is not None:
        over.append(("shape.microbatch", microbatch, "call:shape.microbatch"))
    # legacy quirk preserved: --remat-policy full was a no-op (the arch
    # keeps whatever remat_policy its config declares)
    if remat_policy != "full":
        over.append(("arch.remat_policy", remat_policy,
                     "call:arch.remat_policy"))
    return build_spec("dryrun", overrides=over)
