"""RunSpec: one declarative, serializable configuration API for every
execution mode (train / serve / dryrun).

SPRING's contribution is a *single* accelerator serving training and
inference from the same sparsity/precision machinery; the repo mirrors
that with a single spec.  A :class:`RunSpec` is a frozen tree of small
frozen sections (arch + shape + numerics/SR + sparsity fwd/bwd +
memstash + kernel policy + serving/scheduler + seeds), built by layered
resolution

    defaults -> ArchDef -> spec file (JSON/TOML) -> SPRING_* env -> CLI

with per-field provenance, and resolved by :meth:`RunSpec.resolve` into
the concrete objects the step builders consume today
(``configs.base.ResolvedArch``, ``SpringConfig``, ``StepConfig``,
``KernelPolicy``, ``MemstashConfig``).  The ArchDef layer is
value-conditional: fields left at ``"auto"`` (today: ``memstash.policy``)
are resolved against the architecture's family at ``resolve()`` time, so
a spec file round-trips bit-identically no matter which arch it names.

Canonical form: ``to_json()`` (sorted keys) is the reproducibility
artifact every launcher embeds in its output — dryrun JSON, benchmark
``--json``, ``results/serving/*.json`` — and ``spec_hash()`` ties a
result row to the exact configuration that produced it.

Unknown fields are rejected with did-you-mean suggestions; every choice
field validates against the same constant the subsystem itself uses
(``STASH_POLICIES``, ``BACKWARD_SPARSITY_CHOICES``, ``SHAPES``, ...), so
the spec cannot drift from the machinery it configures.
"""

import dataclasses
import difflib
import hashlib
import json
import logging
import os
from typing import Mapping, Optional, Sequence

from repro.core.fixedpoint import SPRING_FORMAT
from repro.core.spring_ops import BACKWARD_SPARSITY_CHOICES, MODES
from repro.kernels.registry import KernelPolicy
from repro.memstash.config import STASH_POLICIES, MemstashConfig
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import StepConfig

RUN_MODES = ("train", "serve", "dryrun")
MESH_KINDS = ("single", "multi", "debug", "debug_multi")
LAYOUTS = ("tp", "fsdp")

#: Compat spellings: the flat pre-mesh path ``shape.mesh=<kind>`` (old
#: JSON artifacts, ``--set shape.mesh=multi``, the dryrun ``--mesh``
#: shim) lands on the nested ``shape.mesh.kind`` leaf.  Aliases are
#: resolved in ``_Builder.set`` so every layer (file/env/CLI/kwargs)
#: gets them for free.
_ALIASES = {"shape.mesh": "shape.mesh.kind"}

#: Environment layer: SPRING_<NAME> -> dotted RunSpec field.  Applied
#: between the spec file and CLI overrides.  ``SPRING_SET`` additionally
#: accepts ';'-separated ``key=value`` dotted overrides.
ENV_FIELDS = {
    "SPRING_ARCH": "arch.id",
    "SPRING_MODE": "numerics.mode",
    "SPRING_KERNEL_IMPL": "kernels.policy",
    "SPRING_BACKWARD_SPARSITY": "sparsity.backward",
    "SPRING_STASH": "memstash.policy",
    "SPRING_SEED": "seeds.seed",
}
#: ``SPRING_SET="k=v;k=v"`` dotted overrides.  Entries are separated by
#: ";" (not ","), so comma-bearing values — the KernelPolicy grammar
#: ``kernels.policy=ref,ssd_scan=jnp`` — stay representable.
ENV_SET = "SPRING_SET"

# Dry-run gradient-accumulation defaults (moved here from launch/dryrun:
# the resolver is the one source of truth for spec -> StepConfig).
DEFAULT_TRAIN_MICROBATCH = 8  # grad accumulation: activation memory / 8
# MoE dispatch buffers replicate tokens x top_k; VLM carries 26B params:
# these archs need deeper accumulation to fit 16 GB/chip.
TRAIN_MICROBATCH_OVERRIDES = {
    "olmoe-1b-7b": 16, "deepseek-v2-lite-16b": 16, "internvl2-26b": 16,
}

# FSDP logical-rule overrides (pure DP x FSDP: batch over all mesh axes).
FSDP_RULES = (
    ("batch", (("pod", "data", "model"), ("data", "model"))),
    ("heads", (None,)), ("kv_heads", (None,)),
    ("mlp_act", (None,)), ("vocab_act", (None,)),
    ("w_qkv", (None,)), ("w_mlp", (None,)), ("w_vocab", (None,)),
    ("w_embed", (("data", "model"), ("data",))),
    ("cache_batch", (("pod", "data", "model"), ("data", "model"), ("data",))),
    ("cache_seq", (None,)),
)
SEQ_PARALLEL_RULES = (("seq", (("model",), None)),)


class SpecError(ValueError):
    """A RunSpec could not be built or validated."""


# ---------------------------------------------------------------------------
# Sections.  Every field is JSON-primitive so the spec serializes without
# custom encoders; "auto" marks arch/mode-conditional resolution.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchSection:
    """Which architecture, at which size, with arch-config overrides."""

    id: str = "llama3.2-1b"
    # None = run-conditional default, resolved like memstash "auto":
    # train/serve use the reduced smoke config, dryrun analyzes the
    # published full config (its whole point).
    reduced: Optional[bool] = None
    remat_policy: str = ""  # "" = arch default; full | block_io | stash
    bf16_logits: bool = False


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device-mesh topology (spring-mesh, DESIGN.md §14).

    Explicit axis extents take precedence: when ``pod*data*model > 1``,
    sessions build a ``("pod", "data", "model")`` mesh of exactly that
    shape from the available devices.  Otherwise ``kind`` picks one of
    the named launch meshes (``single`` = no mesh).  ``data`` must be a
    power of two when > 1: the packed-collective bit-exactness guarantee
    (tree-reduce of replicated gradients, then exact /2^k rescale) only
    holds for power-of-two world sizes.
    """

    kind: str = "single"  # named mesh when no explicit axes are set
    pod: int = 1
    data: int = 1
    model: int = 1

    @property
    def explicit(self) -> bool:
        return self.pod * self.data * self.model > 1

    def label(self) -> str:
        """Flat string for run artifacts (roofline rows key on it)."""
        if self.explicit:
            return f"pod{self.pod}.data{self.data}.model{self.model}"
        return self.kind


@dataclasses.dataclass(frozen=True)
class ShapeSection:
    """Problem shape: train batch/seq, serve prompt/gen, dryrun cell/mesh."""

    batch: int = 8
    seq: int = 128
    prompt_len: int = 32
    gen: int = 16
    cell: str = "train_4k"  # dryrun shape-cell name (configs.SHAPES)
    mesh: MeshSpec = MeshSpec()  # device topology (kind or explicit axes)
    microbatch: Optional[int] = None  # None = per-arch dryrun default
    layout: str = "tp"
    seq_parallel: bool = False


@dataclasses.dataclass(frozen=True)
class NumericsSection:
    """SPRING numerics: mode, rounding, fixed-point master weights."""

    mode: str = "dense"  # dense | quant | quant_sparse
    stochastic: str = "auto"  # auto (train: SR, serve: nearest) | on | off
    operand_rounding: str = "stochastic"  # stochastic | nearest
    weights_pre_quantized: bool = False
    fixed_point_weights: bool = False  # SR Q4.16 master weights


@dataclasses.dataclass(frozen=True)
class SparsitySection:
    """Backward-direction sparsity (the forward mask is numerics.mode)."""

    backward: str = "auto"  # none | auto | ref | jnp | interpret | pallas
    probe_density: float = 0.5  # dryrun sparsity/kv probe density


@dataclasses.dataclass(frozen=True)
class MemstashSection:
    """Compressed activation stash policy (DESIGN.md §4.3)."""

    policy: str = "auto"  # auto (family default) | none | remat | stash
    value_bits: int = 20
    capacity: float = 1.0
    min_elems: int = 1024


@dataclasses.dataclass(frozen=True)
class KernelsSection:
    """Kernel-dispatch policy string (KernelPolicy.parse grammar)."""

    policy: str = "auto"  # e.g. "ref" | "ssd_scan=jnp" | "ref,ssd_scan=jnp"


@dataclasses.dataclass(frozen=True)
class OptimizerSection:
    """Train/dryrun optimizer (serving uses no optimizer)."""

    kind: str = "adamw"  # adamw | sgdm
    lr: float = 3e-3
    warmup_steps: int = 10


@dataclasses.dataclass(frozen=True)
class TrainSection:
    """Training-session driver knobs."""

    steps: int = 100
    ckpt_dir: str = ""  # "" = no checkpointing
    ckpt_every: int = 100
    log_every: int = 10


@dataclasses.dataclass(frozen=True)
class ServingSection:
    """Continuous-batching engine shape + scheduler."""

    slots: Optional[int] = None  # None = shape.batch
    queue: Optional[int] = None  # None = shape.batch
    greedy: bool = True
    static: bool = False  # force the pre-engine static reference path
    int8_cache: bool = False
    # spring-pages (DESIGN.md §12): paged COW KV pool
    pages: bool = False  # serve on the paged pool instead of slot-monolithic
    page_tokens: int = 8  # cache rows per page frame
    num_pages: Optional[int] = None  # physical page budget; None = dense-equiv
    overcommit: float = 1.5  # logical frames / physical pages
    prefix_cache: bool = True  # chain-hash prefix sharing (COW)
    # spring-survive (DESIGN.md §13): elastic serving under failure/overload
    snapshot_every: int = 0  # save an engine snapshot every N ticks (0 = off)
    snapshot_path: str = ""  # "" = spring_snapshot.npz when snapshots are on
    restore_path: str = ""  # restore + drain a saved snapshot, skip new work
    max_queue_depth: Optional[int] = None  # shed "queue_full" past this depth
    deadline_ticks: Optional[int] = None  # shed "deadline" if queued longer
    deadline_aware: bool = False  # EDF admission instead of strict FCFS
    priority_aware: bool = False  # admit higher Request.priority first


@dataclasses.dataclass(frozen=True)
class DryrunSection:
    """Dry-run analysis options."""

    cost_unrolled: bool = True
    quant_opt: bool = False  # pre-quantized weights + nearest operands
    variant: str = "baseline"


@dataclasses.dataclass(frozen=True)
class SeedsSection:
    """One master seed: params, data stream, request keys derive from it."""

    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TelemetrySection:
    """spring-trace: span tracing + metrics export (DESIGN.md §11).

    Off by default — the disabled path is a no-op and runs carry no
    telemetry payload.  Enabling must never change computed values, only
    add measurement (sealed by the parity test in test_telemetry.py).
    """

    enabled: bool = False
    #: Chrome trace-event JSON output ("" = derive from train.out_dir /
    #: the --json artifact stem; load in Perfetto / chrome://tracing)
    trace_path: str = ""
    #: fraction of root spans recorded, deterministic accumulator (no
    #: PRNG); nested spans inherit the root's decision
    sample_rate: float = 1.0


_SECTIONS = {
    "arch": ArchSection,
    "shape": ShapeSection,
    "numerics": NumericsSection,
    "sparsity": SparsitySection,
    "memstash": MemstashSection,
    "kernels": KernelsSection,
    "optimizer": OptimizerSection,
    "train": TrainSection,
    "serving": ServingSection,
    "dryrun": DryrunSection,
    "seeds": SeedsSection,
    "telemetry": TelemetrySection,
}

_CHOICES = {
    "run": RUN_MODES,
    "numerics.mode": tuple(MODES),
    "numerics.stochastic": ("auto", "on", "off"),
    "numerics.operand_rounding": ("stochastic", "nearest"),
    "sparsity.backward": BACKWARD_SPARSITY_CHOICES,
    "memstash.policy": ("auto",) + STASH_POLICIES,
    "arch.remat_policy": ("", "full", "block_io", "stash"),
    "shape.mesh.kind": MESH_KINDS,
    "shape.layout": LAYOUTS,
    "optimizer.kind": ("adamw", "sgdm"),
}


def field_paths() -> dict:
    """{dotted path: python type} for every RunSpec field.  Nested
    dataclass fields (``shape.mesh``) contribute their leaves plus the
    compat alias path (typed ``str``) so legacy flat spellings keep
    validating."""
    idx = {"run": str}
    for sec, cls in _SECTIONS.items():
        for f in dataclasses.fields(cls):
            if dataclasses.is_dataclass(f.type):
                for sf in dataclasses.fields(f.type):
                    idx[f"{sec}.{f.name}.{sf.name}"] = sf.type
            else:
                idx[f"{sec}.{f.name}"] = f.type
    for alias in _ALIASES:
        idx[alias] = str
    return idx


_FIELDS = None


def _fields() -> dict:
    global _FIELDS
    if _FIELDS is None:
        _FIELDS = field_paths()
    return _FIELDS


def _suggest(key: str, candidates) -> str:
    close = difflib.get_close_matches(str(key), [str(c) for c in candidates],
                                      n=3, cutoff=0.4)
    return f" — did you mean {', '.join(repr(m) for m in close)}?" if close else ""


def _coerce_str(path: str, raw: str):
    """Coerce a CLI/env string to the field's declared type."""
    typ = _fields()[path]
    s = raw.strip()
    low = s.lower()
    if typ in (Optional[int], Optional[bool]):
        if low in ("none", "null", ""):
            return None
        typ = int if typ == Optional[int] else bool
    if typ is bool:
        if low in ("true", "1", "yes", "on"):
            return True
        if low in ("false", "0", "no", "off"):
            return False
        raise SpecError(f"{path}: expected a boolean, got {raw!r}")
    try:
        if typ is int:
            return int(s)
        if typ is float:
            return float(s)
    except ValueError as e:
        raise SpecError(f"{path}: {e}") from None
    return s


def _check_typed(path: str, value):
    """Validate/normalize an already-typed value (JSON layer, kwargs)."""
    typ = _fields()[path]
    if typ in (Optional[int], Optional[bool]):
        if value is None:
            return None
        typ = int if typ == Optional[int] else bool
    if typ is bool:
        if not isinstance(value, bool):
            raise SpecError(f"{path}: expected a boolean, got {value!r}")
        return value
    if typ is int:
        if isinstance(value, bool) or not isinstance(value, int):
            raise SpecError(f"{path}: expected an integer, got {value!r}")
        return value
    if typ is float:
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise SpecError(f"{path}: expected a number, got {value!r}")
        return float(value)
    if not isinstance(value, str):
        raise SpecError(f"{path}: expected a string, got {value!r}")
    return value


# ---------------------------------------------------------------------------
# RunSpec.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """The one declarative run configuration.  Frozen; equality ignores
    ``provenance`` (metadata about *where* each field came from, recorded
    by the layered builder and rendered into run artifacts)."""

    run: str = "train"
    arch: ArchSection = ArchSection()
    shape: ShapeSection = ShapeSection()
    numerics: NumericsSection = NumericsSection()
    sparsity: SparsitySection = SparsitySection()
    memstash: MemstashSection = MemstashSection()
    kernels: KernelsSection = KernelsSection()
    optimizer: OptimizerSection = OptimizerSection()
    train: TrainSection = TrainSection()
    serving: ServingSection = ServingSection()
    dryrun: DryrunSection = DryrunSection()
    seeds: SeedsSection = SeedsSection()
    telemetry: TelemetrySection = TelemetrySection()
    provenance: Mapping[str, str] = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        d = {"run": self.run}
        for name in _SECTIONS:
            d[name] = dataclasses.asdict(getattr(self, name))
        return d

    def to_json(self, indent: Optional[int] = 2) -> str:
        """Canonical JSON: sorted keys, stable across dict ordering."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent) + "\n"

    def spec_hash(self) -> str:
        """Hash of the canonical compact JSON (ties artifacts to configs)."""
        compact = json.dumps(self.to_dict(), sort_keys=True,
                             separators=(",", ":"))
        return hashlib.sha256(compact.encode()).hexdigest()[:16]

    def state_hash(self) -> str:
        """``spec_hash`` with the restart-operational serving fields
        (snapshot cadence/paths) *and* the mesh topology neutralized —
        the stamp embedded in serving snapshots (DESIGN.md §13).  A run
        that merely *restores* an artifact necessarily differs from the
        run that wrote it in exactly these fields — and a snapshot taken
        on one device count must restore onto another (elastic rescale
        across topologies, DESIGN.md §14) — so they must not poison the
        compatibility check; anything numerics/shape/arch-shaped still
        rejects."""
        d = self.to_dict()
        for field in ("snapshot_every", "snapshot_path", "restore_path"):
            d["serving"][field] = ServingSection.__dataclass_fields__[
                field].default
        d["shape"]["mesh"] = dataclasses.asdict(MeshSpec())
        compact = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(compact.encode()).hexdigest()[:16]

    def payload(self) -> dict:
        """The reproducibility block every run artifact embeds."""
        return {
            "spec": self.to_dict(),
            "spec_hash": self.spec_hash(),
            "provenance": dict(self.provenance),
        }

    @classmethod
    def from_dict(cls, data: dict, label: str = "dict") -> "RunSpec":
        return build_spec(data=data, data_label=label, use_env=False)

    @classmethod
    def from_json(cls, text: str, label: str = "json") -> "RunSpec":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"invalid spec JSON: {e}") from None
        return cls.from_dict(data, label=label)

    @classmethod
    def from_file(cls, path: str) -> "RunSpec":
        return build_spec(spec_file=path, use_env=False)

    def _get(self, path: str):
        """Walk a dotted field path of any depth."""
        obj = self
        for part in path.split("."):
            obj = getattr(obj, part)
        return obj

    def describe(self) -> str:
        """Flat field = value  [provenance] rendering (debug/--explain)."""
        prov = dict(self.provenance)
        lines = []
        for path in sorted(_fields()):
            if path in _ALIASES:  # alias leaves are rendered, not the alias
                continue
            value = self._get(path)
            lines.append(f"{path} = {value!r}  [{prov.get(path, 'default')}]")
        return "\n".join(lines)

    # -- validation ---------------------------------------------------------

    def validate(self) -> "RunSpec":
        for path, choices in _CHOICES.items():
            value = self._get(path)
            if value not in choices:
                raise SpecError(
                    f"{path}: unknown value {value!r}; choose from "
                    f"{choices}{_suggest(str(value), choices)}")
        from repro.configs import SHAPES
        if self.shape.cell not in SHAPES:
            raise SpecError(
                f"shape.cell: unknown shape {self.shape.cell!r}; choose "
                f"from {tuple(SHAPES)}"
                f"{_suggest(self.shape.cell, SHAPES)}")
        if not 0.0 <= self.sparsity.probe_density <= 1.0:
            raise SpecError("sparsity.probe_density must be in [0, 1]")
        for ax in ("pod", "data", "model"):
            if getattr(self.shape.mesh, ax) < 1:
                raise SpecError(f"shape.mesh.{ax} must be >= 1")
        if self.shape.mesh.data > 1 and \
                self.shape.mesh.data & (self.shape.mesh.data - 1):
            raise SpecError(
                "shape.mesh.data must be a power of two: the packed "
                "collective bit-exactness seal (pairwise tree-reduce + "
                "exact /2^k rescale) only holds for power-of-two worlds")
        if not 0.0 < self.telemetry.sample_rate <= 1.0:
            raise SpecError("telemetry.sample_rate must be in (0, 1]")
        if self.serving.page_tokens < 1:
            raise SpecError("serving.page_tokens must be >= 1")
        if self.serving.overcommit < 1.0:
            raise SpecError("serving.overcommit must be >= 1.0")
        if self.serving.num_pages is not None and self.serving.num_pages < 1:
            raise SpecError("serving.num_pages must be >= 1 (or null)")
        if self.serving.snapshot_every < 0:
            raise SpecError("serving.snapshot_every must be >= 0")
        if (self.serving.max_queue_depth is not None
                and self.serving.max_queue_depth < 1):
            raise SpecError("serving.max_queue_depth must be >= 1 (or null)")
        if (self.serving.deadline_ticks is not None
                and self.serving.deadline_ticks < 0):
            raise SpecError("serving.deadline_ticks must be >= 0 (or null)")
        if self.serving.restore_path and self.serving.snapshot_every:
            # one engine either resumes an artifact or produces them; both
            # at once would overwrite the artifact being drained
            if (self.serving.snapshot_path or "spring_snapshot.npz") == \
                    self.serving.restore_path:
                raise SpecError(
                    "serving.restore_path equals the snapshot output path; "
                    "set serving.snapshot_path to a different file")
        try:
            KernelPolicy.parse(self._kernel_spec())
        except ValueError as e:
            raise SpecError(f"kernels.policy: {e}") from None
        try:
            MemstashConfig(
                policy="none" if self.memstash.policy == "auto"
                else self.memstash.policy,
                value_bits=self.memstash.value_bits,
                capacity=self.memstash.capacity,
                min_elems=self.memstash.min_elems)
        except ValueError as e:
            raise SpecError(f"memstash: {e}") from None
        return self

    # -- resolution ---------------------------------------------------------

    def _kernel_spec(self) -> str:
        return "" if self.kernels.policy in ("", "auto") else self.kernels.policy

    def resolved_memstash_policy(self, family: str) -> str:
        """The ArchDef layer: ``"auto"`` dispatches on the workload family
        through :func:`repro.configs.base.default_memstash` — the single
        source of truth for the per-family recommendation."""
        if self.memstash.policy != "auto":
            return self.memstash.policy
        from repro.configs.base import default_memstash

        return default_memstash(family).policy

    def resolve(self) -> "ResolvedRun":
        """Produce the concrete config objects today's step builders eat."""
        self.validate()
        from repro.configs import SHAPES, get_arch

        try:
            arch = get_arch(self.arch.id)
        except KeyError as e:
            raise SpecError(str(e)) from None
        # reduced=None: run-conditional (train/serve smoke-size, dryrun
        # analyzes the published config) — same for CLI and API callers
        use_reduced = (self.run != "dryrun" if self.arch.reduced is None
                       else self.arch.reduced)
        cfg = arch.reduced() if use_reduced else arch.config
        cfg = dataclasses.replace(cfg)  # defensive copy
        if self.arch.remat_policy and hasattr(cfg, "remat_policy"):
            cfg = dataclasses.replace(cfg, remat_policy=self.arch.remat_policy)
        if self.arch.bf16_logits and hasattr(cfg, "bf16_logits"):
            cfg = dataclasses.replace(cfg, bf16_logits=True)

        ms_policy = self.resolved_memstash_policy(arch.family)
        memstash = MemstashConfig(
            policy=ms_policy, value_bits=self.memstash.value_bits,
            capacity=self.memstash.capacity, min_elems=self.memstash.min_elems)
        # An *explicitly requested* stash/remat policy re-routes the LM
        # residual checkpoints (train_loop's --stash semantics); the
        # family-dispatched "auto" recommendation only configures the
        # stash points the model already has.
        if (self.run == "train" and self.memstash.policy != "auto"
                and ms_policy != "none"):
            if not hasattr(cfg, "remat_policy"):
                logging.getLogger("repro.api").warning(
                    "memstash.policy=%s has no residual-checkpoint effect "
                    "for %s (config has no remat_policy)",
                    ms_policy, self.arch.id)
            elif ms_policy == "stash":
                cfg = dataclasses.replace(cfg, remat_policy="stash")
            else:  # "remat": force plain recompute even if the reduced
                # variant disabled remat
                cfg = dataclasses.replace(cfg, remat=True, remat_policy="full")

        kernel_policy = KernelPolicy.parse(self._kernel_spec())
        stochastic = {"on": True, "off": False}.get(
            self.numerics.stochastic, self.run != "serve")
        spring = dataclasses.replace(
            MODES[self.numerics.mode], stochastic=stochastic,
            kernels=kernel_policy)
        if spring.is_quantized:
            spring = dataclasses.replace(
                spring,
                weights_pre_quantized=self.numerics.weights_pre_quantized
                or (self.run == "dryrun" and self.dryrun.quant_opt),
                operand_rounding="nearest"
                if (self.run == "dryrun" and self.dryrun.quant_opt)
                else self.numerics.operand_rounding)

        if self.run == "serve":
            # serving: no optimizer in the program; nearest rounding keeps
            # a request's tokens a function of the request alone
            step = StepConfig(spring=spring, optimizer=OptimizerConfig(),
                              int8_cache=self.serving.int8_cache)
        else:
            # Dryrun lowers the optimizer *kind* only: lr/warmup are
            # training-session knobs with no bearing on the analyses, and
            # keeping them out preserves bit-parity with every pre-RunSpec
            # dryrun artifact (legacy run_cell: OptimizerConfig(kind=...)).
            opt = (OptimizerConfig(kind=self.optimizer.kind)
                   if self.run == "dryrun" else OptimizerConfig(
                       kind=self.optimizer.kind, lr=self.optimizer.lr,
                       warmup_steps=self.optimizer.warmup_steps,
                       weight_format=SPRING_FORMAT
                       if self.numerics.fixed_point_weights else None))
            if self.run == "train":
                step = StepConfig(
                    spring=spring, backward_sparsity=self.sparsity.backward,
                    memstash=memstash, optimizer=opt,
                    microbatch=self.shape.microbatch)
            else:  # dryrun
                microbatch = self.shape.microbatch
                if microbatch is None and SHAPES[self.shape.cell].kind == "train":
                    microbatch = TRAIN_MICROBATCH_OVERRIDES.get(
                        self.arch.id, DEFAULT_TRAIN_MICROBATCH)
                rules = ()
                if self.shape.seq_parallel:
                    rules += SEQ_PARALLEL_RULES
                if self.shape.layout == "fsdp":
                    rules += FSDP_RULES
                step = StepConfig(
                    spring=spring, backward_sparsity=self.sparsity.backward,
                    optimizer=opt, microbatch=microbatch,
                    rules_override=rules, int8_cache=self.serving.int8_cache)

        return ResolvedRun(
            spec=self, arch=arch, view=arch.view(config=cfg), config=cfg,
            spring=spring, step=step, kernel_policy=kernel_policy,
            memstash=memstash, memstash_policy=ms_policy)


@dataclasses.dataclass(frozen=True)
class ResolvedRun:
    """What ``RunSpec.resolve()`` hands the sessions: the exact objects
    the pre-RunSpec launchers used to assemble by hand."""

    spec: RunSpec
    arch: object  # configs.base.ArchDef
    view: object  # configs.base.ResolvedArch (concrete config picked)
    config: object  # the model config (LMConfig | EncDecConfig)
    spring: object  # SpringConfig
    step: StepConfig
    kernel_policy: KernelPolicy
    memstash: MemstashConfig
    memstash_policy: str  # family-dispatched policy actually in force


# ---------------------------------------------------------------------------
# Layered builder.
# ---------------------------------------------------------------------------


class _Builder:
    def __init__(self):
        self._values: dict = {}
        self._prov: dict = {}

    def set(self, path: str, value, label: str, from_str: bool = False):
        path = _ALIASES.get(path, path)
        if path not in _fields():
            raise SpecError(
                f"unknown RunSpec field {path!r} (from {label})"
                f"{_suggest(path, _fields())}")
        self._values[path] = (_coerce_str(path, value) if from_str
                              else _check_typed(path, value))
        self._prov[path] = label

    def overlay_nested(self, data: dict, label: str):
        if not isinstance(data, dict):
            raise SpecError(f"spec root must be an object (from {label})")
        for key, value in data.items():
            if key == "run":
                self.set("run", value, label)
                continue
            if key not in _SECTIONS:
                raise SpecError(
                    f"unknown RunSpec section {key!r} (from {label})"
                    f"{_suggest(key, list(_SECTIONS) + ['run'])}")
            if not isinstance(value, dict):
                raise SpecError(
                    f"section {key!r} must be an object (from {label})")
            for leaf, v in value.items():
                if isinstance(v, dict):  # nested subsection (shape.mesh)
                    for subleaf, sv in v.items():
                        self.set(f"{key}.{leaf}.{subleaf}", sv, label)
                else:
                    self.set(f"{key}.{leaf}", v, label)

    def overlay_env(self, environ: Mapping[str, str]):
        for var, path in ENV_FIELDS.items():
            if var in environ and environ[var] != "":
                self.set(path, environ[var], f"env:{var}", from_str=True)
        for token in (t for t in environ.get(ENV_SET, "").split(";") if t.strip()):
            path, eq, value = token.partition("=")
            if not eq:
                raise SpecError(
                    f"{ENV_SET} entries must be ';'-separated key=value "
                    f"pairs, got {token!r}")
            self.set(path.strip(), value, f"env:{ENV_SET}", from_str=True)

    def overlay_sets(self, sets: Sequence[str], label: str = "set"):
        for item in sets:
            path, eq, value = item.partition("=")
            if not eq:
                raise SpecError(f"--set expects key=value, got {item!r}")
            self.set(path.strip(), value, f"{label}:{path.strip()}",
                     from_str=True)

    def build(self) -> RunSpec:
        sections = {}
        for name, cls in _SECTIONS.items():
            kw = {}
            for f in dataclasses.fields(cls):
                path = f"{name}.{f.name}"
                if dataclasses.is_dataclass(f.type):
                    sub = {sf.name: self._values[f"{path}.{sf.name}"]
                           for sf in dataclasses.fields(f.type)
                           if f"{path}.{sf.name}" in self._values}
                    if sub:
                        kw[f.name] = f.type(**sub)
                elif path in self._values:
                    kw[f.name] = self._values[path]
            try:
                sections[name] = cls(**kw)
            except ValueError as e:
                raise SpecError(f"{name}: {e}") from None
        prov = {p: "default" for p in _fields()}
        prov.update(self._prov)
        spec = RunSpec(run=self._values.get("run", "train"),
                       provenance=prov, **sections)
        return spec.validate()


def load_spec_data(path: str) -> dict:
    """Read a spec file; format from extension (.json, .toml)."""
    if path.endswith(".toml"):
        try:
            import tomllib  # py3.11+
        except ModuleNotFoundError:
            try:
                import tomli as tomllib  # type: ignore[no-redef]
            except ModuleNotFoundError:
                raise SpecError(
                    f"cannot read {path}: TOML support needs python >= 3.11 "
                    "(tomllib) or the 'tomli' package; use JSON instead"
                ) from None
        with open(path, "rb") as f:
            try:
                return tomllib.load(f)
            except tomllib.TOMLDecodeError as e:
                raise SpecError(f"invalid TOML in {path}: {e}") from None
    with open(path) as f:
        try:
            return json.load(f)
        except json.JSONDecodeError as e:
            raise SpecError(f"invalid JSON in {path}: {e}") from None


def build_spec(
    run: Optional[str] = None,
    *,
    spec_file: Optional[str] = None,
    data: Optional[dict] = None,
    data_label: str = "data",
    overrides: Sequence[tuple] = (),  # (path, typed value, label)
    sets: Sequence[str] = (),  # "key=value" strings (CLI --set)
    use_env: bool = True,
    environ: Optional[Mapping[str, str]] = None,
) -> RunSpec:
    """Assemble a RunSpec through the documented layer order:

      defaults -> data (caller base layer, e.g. an example preset)
               -> [ArchDef at resolve()] -> spec file -> SPRING_* env
               -> overrides (legacy flags / call kwargs) -> launcher run
               -> --set

    ``overrides`` carry their own labels (``legacy:--stash``,
    ``call:stash``) so provenance distinguishes shimmed spellings from
    native ones.
    """
    b = _Builder()
    if data is not None:
        b.overlay_nested(data, data_label)
    if spec_file is not None:
        b.overlay_nested(load_spec_data(spec_file), f"file:{spec_file}")
    if use_env:
        b.overlay_env(os.environ if environ is None else environ)
    for path, value, label in overrides:
        b.set(path, value, label)
    if run is not None:
        b.set("run", run, "launcher")
    b.overlay_sets(sets)
    return b.build()
