"""Shared launcher CLI: ``--spec`` + ``--set`` plus legacy-flag shims.

Every launcher builds its parser from :func:`make_parser`: the native
surface is ``--spec run.json`` and repeatable ``--set key=value`` dotted
overrides; each pre-redesign flag is declared as a :class:`LegacyFlag`
shim that resolves to the same RunSpec field with a
``DeprecationWarning`` naming the ``--set`` spelling.  The CLI-coverage
test (tests/test_cli_parity.py) fails if a launcher grows an argparse
option that is neither operational nor a declared shim — new knobs must
be RunSpec fields first.
"""

import argparse
import dataclasses
import warnings
from typing import Optional, Sequence

from repro.api.spec import RunSpec, SpecError, build_spec, field_paths

#: Options every launcher may carry that do not configure the run
#: (output routing, help).  Everything else must be --spec/--set or a
#: declared LegacyFlag.
OPERATIONAL_OPTIONS = {"--spec", "--set", "--explain", "--json", "--out",
                       "--help"}

_SKIP = object()  # a LegacyFlag.transform may veto the override


@dataclasses.dataclass(frozen=True)
class LegacyFlag:
    """One deprecated flag spelling and the RunSpec field it shims to."""

    option: str  # e.g. "--stash"
    path: str  # e.g. "memstash.policy"
    kwargs: tuple = ()  # argparse add_argument kwargs (sorted items)
    #: for boolean flags: store this constant when the flag is present
    const: object = None
    #: optional value -> spec-value hook (return _SKIP to drop)
    transform: Optional[callable] = None
    #: argparse dest override: paired flags (--greedy/--sample) share one
    #: dest so "last flag on the command line wins", like the old parsers
    dest_override: Optional[str] = None

    def __post_init__(self):
        if self.path not in field_paths():
            raise ValueError(
                f"LegacyFlag {self.option}: {self.path!r} is not a RunSpec "
                "field — add the field to repro.api.spec first")

    @property
    def dest(self) -> str:
        return self.dest_override or (
            "legacy_" + self.option.lstrip("-").replace("-", "_"))

    def add_to(self, ap: argparse.ArgumentParser) -> None:
        kw = dict(self.kwargs)
        kw.setdefault("help", argparse.SUPPRESS)
        # default=None detects "flag present" for value flags and
        # store_const booleans alike, so absence never overlays the spec
        if self.const is not None:
            ap.add_argument(self.option, dest=self.dest, action="store_const",
                            const=self.const, default=None, **kw)
        else:
            ap.add_argument(self.option, dest=self.dest, default=None, **kw)


def flag(option: str, path: str, *, const: object = None,
         transform: Optional[callable] = None, dest: Optional[str] = None,
         **kwargs) -> LegacyFlag:
    return LegacyFlag(option=option, path=path,
                      kwargs=tuple(sorted(kwargs.items())), const=const,
                      transform=transform, dest_override=dest)


def make_parser(description: str, legacy: Sequence[LegacyFlag],
                json_out: bool = False, out: bool = False,
                ) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=description,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--spec", default=None, metavar="PATH",
                    help="RunSpec file (JSON or TOML); layered as "
                         "defaults -> ArchDef -> file -> SPRING_* env -> CLI")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="dotted RunSpec override, repeatable "
                         "(e.g. --set numerics.mode=quant_sparse)")
    ap.add_argument("--explain", action="store_true",
                    help="print every resolved field with its provenance "
                         "layer, then exit")
    if json_out:
        ap.add_argument("--json", default=None, metavar="PATH",
                        help="write the run result + canonical resolved "
                             "spec as JSON")
    if out:
        ap.add_argument("--out", default=None, metavar="PATH",
                        help="write the result JSON here")
    for lf in legacy:
        lf.add_to(ap)
    return ap


def legacy_overrides(args: argparse.Namespace,
                     legacy: Sequence[LegacyFlag],
                     warn: bool = True) -> list:
    """Collect (path, value, label) overrides from legacy flags that were
    actually passed, warning with the ``--set`` spelling for each."""
    overrides = []
    seen_dests = set()
    for lf in legacy:
        if lf.dest in seen_dests:  # paired flags sharing one dest
            continue
        value = getattr(args, lf.dest)
        if value is None:
            continue
        seen_dests.add(lf.dest)
        if lf.const is not None and value != lf.const:
            # shared dest: attribute the value to the flag that sets it
            lf = next((g for g in legacy
                       if g.dest == lf.dest and g.const == value), lf)
        if warn:
            shown = str(value).lower() if isinstance(value, bool) else value
            warnings.warn(
                f"{lf.option} is deprecated; use --set {lf.path}={shown}",
                DeprecationWarning, stacklevel=3)
        if lf.transform is not None:
            value = lf.transform(value)
            if value is _SKIP:
                continue
        overrides.append((lf.path, value, f"legacy:{lf.option}"))
    return overrides


def spec_from_args(run: str, args: argparse.Namespace,
                   legacy: Sequence[LegacyFlag] = (),
                   warn: bool = True, base: Optional[dict] = None,
                   base_label: str = "launcher-default") -> RunSpec:
    """base (adapter's historical defaults) -> file -> env -> legacy
    shims -> launcher run mode -> --set."""
    return build_spec(
        run,
        data=base, data_label=base_label,
        spec_file=args.spec,
        overrides=legacy_overrides(args, legacy, warn=warn),
        sets=args.sets,
    )


def run_main(run: str, args: argparse.Namespace,
             legacy: Sequence[LegacyFlag],
             base: Optional[dict] = None) -> RunSpec:
    """Shared main() prologue: build the spec (argparse-style errors on
    bad input) and honor ``--explain``."""
    try:
        spec = spec_from_args(run, args, legacy, base=base)
    except SpecError as e:
        raise SystemExit(f"error: {e}") from None
    if getattr(args, "explain", False):
        print(spec.describe())
        raise SystemExit(0)
    return spec
