"""Spec validation CLI — the CI ``spec`` job's workhorse.

  # validate + resolve checked-in spec files
  PYTHONPATH=src python -m repro.api.validate examples/specs/*.json

  # round-trip seal: every registered arch x {train, serve, dryrun}
  PYTHONPATH=src python -m repro.api.validate --roundtrip-all

  # dryrun-from-spec: build the debug mesh and *lower* the decode cell of
  # every registered arch from a pure spec (compile is the per-arch deep
  # smoke in tests; lowering proves spec -> program for the whole registry)
  PYTHONPATH=src python -m repro.api.validate --lower-all

Exit code 0 only if everything passes.
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=8"
)

import argparse  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

from repro.api.sessions import DryrunSession, build_mesh  # noqa: E402
from repro.api.spec import RunSpec, SpecError, build_spec  # noqa: E402


def validate_files(paths) -> int:
    failures = 0
    for path in paths:
        try:
            spec = RunSpec.from_file(path)
            resolved = spec.resolve()
            roundtrip = RunSpec.from_json(spec.to_json())
            assert roundtrip == spec, "round trip changed the spec"
            assert roundtrip.spec_hash() == spec.spec_hash()
            print(f"ok {path}: run={spec.run} arch={spec.arch.id} "
                  f"hash={spec.spec_hash()} "
                  f"(memstash->{resolved.memstash_policy})")
        except (SpecError, OSError, AssertionError) as e:
            failures += 1
            print(f"FAIL {path}: {e}", file=sys.stderr)
    return failures


def roundtrip_all() -> int:
    from repro.configs import ARCHS

    failures = 0
    for arch_id in sorted(ARCHS):
        for run in ("train", "serve", "dryrun"):
            try:
                spec = build_spec(run, use_env=False, overrides=[
                    ("arch.id", arch_id, "sweep")])
                again = RunSpec.from_json(spec.to_json())
                assert again == spec
                r1, r2 = spec.resolve(), again.resolve()
                assert (r1.step, r1.spring, r1.config, r1.memstash) == \
                       (r2.step, r2.spring, r2.config, r2.memstash), \
                    "resolve() diverged after round trip"
                print(f"ok {arch_id} x {run}: {spec.spec_hash()}")
            except (SpecError, AssertionError) as e:
                failures += 1
                print(f"FAIL {arch_id} x {run}: {e}", file=sys.stderr)
    return failures


def lower_all() -> int:
    from repro.configs import ARCHS

    mesh = build_mesh("debug")
    failures = 0
    for arch_id in sorted(ARCHS):
        spec = build_spec("dryrun", use_env=False, overrides=[
            ("arch.id", arch_id, "sweep"),
            ("arch.reduced", False, "sweep"),
            ("shape.cell", "decode_32k", "sweep"),
            ("shape.mesh", "debug", "sweep"),
            ("dryrun.cost_unrolled", False, "sweep"),
        ])
        t0 = time.time()
        try:
            lowered = DryrunSession(spec).lower(mesh=mesh)
            status = "skipped" if lowered is None else "lowered"
            print(f"ok {arch_id}: {status} decode_32k from spec "
                  f"{spec.spec_hash()} in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001 — report every arch
            failures += 1
            print(f"FAIL {arch_id}: {type(e).__name__}: {e}", file=sys.stderr)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("specs", nargs="*", help="spec files to validate")
    ap.add_argument("--roundtrip-all", action="store_true",
                    help="round-trip + resolve every arch x run mode")
    ap.add_argument("--lower-all", action="store_true",
                    help="lower the decode cell of every arch from a spec")
    args = ap.parse_args(argv)
    failures = 0
    if args.specs:
        failures += validate_files(args.specs)
    if args.roundtrip_all:
        failures += roundtrip_all()
    if args.lower_all:
        failures += lower_all()
    if not (args.specs or args.roundtrip_all or args.lower_all):
        ap.error("nothing to do: pass spec files, --roundtrip-all, "
                 "or --lower-all")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
