"""repro.api — the declarative RunSpec configuration API.

One frozen, serializable :class:`RunSpec` describes any run (train /
serve / dryrun); :class:`TrainSession` / :class:`ServeSession` /
:class:`DryrunSession` execute it; ``build_spec`` implements the layered
resolution (defaults -> ArchDef -> spec file -> SPRING_* env -> CLI)
with per-field provenance.  See DESIGN.md §10.
"""

from repro.api.spec import (
    ENV_FIELDS,
    MESH_KINDS,
    RUN_MODES,
    ResolvedRun,
    RunSpec,
    SpecError,
    build_spec,
    field_paths,
    load_spec_data,
)
from repro.api.sessions import (
    DryrunSession,
    ServeSession,
    Session,
    TrainSession,
    dryrun_spec,
    serve_spec,
    session_for,
    train_spec,
)

__all__ = [
    "ENV_FIELDS", "MESH_KINDS", "RUN_MODES", "ResolvedRun", "RunSpec",
    "SpecError", "build_spec", "field_paths", "load_spec_data",
    "DryrunSession", "ServeSession", "Session", "TrainSession",
    "dryrun_spec", "serve_spec", "session_for", "train_spec",
]
