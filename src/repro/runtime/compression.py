"""Gradient compression for the inter-pod data-parallel reduction.

SPRING's own pillars applied to the *collective* roofline term: gradients
crossing the (slowest) pod-to-pod links are sent as stochastically-rounded
int8 with per-tensor scales and an error-feedback memory (Seide et al.'15
/ 1-bit Adam lineage; the SR quantizer is the paper's Eq. 4 on a dynamic
grid).  Wire bytes drop 2x vs bf16 / 4x vs fp32; EF makes the compression
error O(1/steps) instead of accumulating.

Mechanics: a ring all-reduce cannot sum int8 without overflow, so the
compressed exchange is all_gather(int8) + local dequant-sum — int8 is
what moves on the wire.  Used under ``jax.shard_map`` manual over the
``pod`` axis with data/model axes left to GSPMD (runtime/train.py).

The binary-mask (P1) compression is storage-side only: collectives need
static shapes, so value-dropping masks cannot shrink an all-reduce on
TPU — recorded in DESIGN.md §4.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def sr_quantize_int8(x: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Stochastic-rounding int8 quantization with per-tensor scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    scaled = xf / scale
    lo = jnp.floor(scaled)
    frac = scaled - lo
    u = jax.random.uniform(key, x.shape, jnp.float32)
    q = lo + (u < frac).astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce_mean(
    x: jax.Array, axis_name: str, key: jax.Array, ef: Optional[jax.Array] = None
) -> tuple[jax.Array, jax.Array]:
    """Mean over ``axis_name`` with int8-on-the-wire + error feedback.

    Returns (mean, new_error_feedback).  Call under shard_map manual over
    ``axis_name``.
    """
    local = x.astype(jnp.float32) + (0.0 if ef is None else ef)
    q, scale = sr_quantize_int8(local, key)
    new_ef = local - dequantize_int8(q, scale)
    # int8 payload crosses the link; scales are negligible (1 f32 each)
    all_q = jax.lax.all_gather(q, axis_name)  # (P, ...)
    all_s = jax.lax.all_gather(scale, axis_name)  # (P,)
    total = jnp.tensordot(all_s, all_q.astype(jnp.float32), axes=(0, 0))
    n = jax.lax.psum(1, axis_name)
    return total / n, new_ef


def compressed_allreduce_tree(
    grads: Any, axis_name: str, key: jax.Array, ef_tree: Optional[Any] = None
) -> tuple[Any, Any]:
    """Tree version with independent keys / EF buffers per leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    efs = (
        jax.tree_util.tree_leaves(ef_tree)
        if ef_tree is not None
        else [None] * len(leaves)
    )
    keys = jax.random.split(key, len(leaves))
    outs, new_efs = [], []
    for leaf, e, k in zip(leaves, efs, keys):
        o, ne = compressed_allreduce_mean(leaf, axis_name, k, e)
        outs.append(o.astype(leaf.dtype))
        new_efs.append(ne)
    return (
        jax.tree_util.tree_unflatten(treedef, outs),
        jax.tree_util.tree_unflatten(treedef, new_efs),
    )


def compression_wire_bytes(grads: Any, n_pods: int) -> dict[str, float]:
    """Accounting helper for EXPERIMENTS.md: bytes/chip crossing pod links."""
    n = sum(x.size for x in jax.tree_util.tree_leaves(grads))
    return {
        "fp32_ring": 2 * (n_pods - 1) / n_pods * n * 4,
        "bf16_ring": 2 * (n_pods - 1) / n_pods * n * 2,
        "int8_gather": (n_pods - 1) * n * 1,
    }
