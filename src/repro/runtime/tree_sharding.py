"""Infer logical sharding axes for whole pytrees (params / opt state /
caches) from leaf path names — the in/out_shardings source for jit.

The model code annotates *internal* tensors via ``constrain``; this module
gives the *boundary* (input/output) tensors matching NamedShardings so
memory analysis reflects the real resident layout instead of relying on
GSPMD propagation from the inside out.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.runtime.sharding import logical_to_spec

# (parent, leaf) -> logical axes for the trailing dims.  Leading unit-stack
# dims (scanned layers) are padded with None automatically.
_KERNEL_RULES: dict[str, tuple] = {
    "wq": ("w_embed", "w_qkv"),
    "wk": ("w_embed", "w_qkv"),
    "wv": ("w_embed", "w_qkv"),
    "wo": ("w_qkv", "w_embed"),
    "wdkv": ("w_embed", None),
    "wkr": ("w_embed", None),
    "wuk": (None, "w_qkv"),
    "wuv": (None, "w_qkv"),
    "gate": ("w_embed", "w_mlp"),
    "up": ("w_embed", "w_mlp"),
    "down": ("w_mlp", "w_embed"),
    "fc1": ("w_embed", "w_mlp"),
    "fc2": ("w_mlp", "w_embed"),
    "in_proj": ("w_embed", "w_mlp"),
    "out_proj": ("w_mlp", "w_embed"),
    "wx": ("w_embed", "w_mlp"),
    "wy": ("w_embed", "w_mlp"),
    "w_r": (None, "w_mlp"),
    "w_i": (None, "w_mlp"),
    "router": ("w_embed", None),
    "lm_head": ("w_embed", "w_vocab"),
    "enc_in": ("w_embed", None),
}

_LEAF_RULES: dict[str, tuple] = {
    "embedding": ("w_vocab", "w_embed"),
    # experts: EP over model on dim0 + FSDP over data on the d_model dim
    "w_gate": ("w_experts", "w_embed", None),
    "w_up": ("w_experts", "w_embed", None),
    "w_down": ("w_experts", None, "w_embed"),
    # caches
    "k": ("cache_batch", "cache_seq", "cache_heads", "head_dim"),
    "v": ("cache_batch", "cache_seq", "cache_heads", "head_dim"),
    "k_ring": ("cache_batch", "cache_seq", "cache_heads", "head_dim"),
    "v_ring": ("cache_batch", "cache_seq", "cache_heads", "head_dim"),
    "k_q8": ("cache_batch", "cache_seq", "cache_heads", "head_dim"),
    "v_q8": ("cache_batch", "cache_seq", "cache_heads", "head_dim"),
    "k_sc": ("cache_batch", "cache_seq", "cache_heads"),
    "v_sc": ("cache_batch", "cache_seq", "cache_heads"),
    "ckv": ("cache_batch", "cache_seq", None),
    "krope": ("cache_batch", "cache_seq", None),
    "conv": ("cache_batch", None, "mlp_act"),
    "ssm": ("cache_batch", "heads", None, None),
    "h": ("cache_batch", "mlp_act"),
}


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def logical_axes_for_path(path, shape) -> tuple:
    names = _path_names(path)
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    axes: Optional[tuple] = None
    if leaf == "kernel" and parent in _KERNEL_RULES:
        axes = _KERNEL_RULES[parent]
    elif leaf in _LEAF_RULES:
        axes = _LEAF_RULES[leaf]
    elif leaf == "bias":
        axes = (None,)
    if axes is None:
        axes = (None,) * len(shape)
    # pad for unit-stacked (scanned) leading dims
    if len(axes) < len(shape):
        axes = (None,) * (len(shape) - len(axes)) + tuple(axes)
    elif len(axes) > len(shape):
        axes = tuple(axes[-len(shape):])
    return tuple(axes)


def tree_shardings(tree, mesh: Mesh):
    """NamedSharding pytree for a ShapeDtypeStruct/array pytree."""

    def one(path, leaf):
        axes = logical_axes_for_path(path, leaf.shape)
        return NamedSharding(mesh, logical_to_spec(axes, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_shardings(batch, mesh: Mesh):
    """Input batches shard over ('pod','data') on axis 0."""

    def one(path, leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, logical_to_spec(axes, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, batch)
