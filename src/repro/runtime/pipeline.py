"""GPipe-style pipeline parallelism over a mesh axis (the `pod` axis of
the multi-pod mesh, or a dedicated `stage` axis at larger scales).

DESIGN.md §4 documents why PP is *off by default* at 512 chips (FSDP x TP
fits); this module is the scale-out path past the point where DP axes
saturate (1000+ nodes): layers split into S stages, microbatches stream
through stages via ``jax.lax.ppermute`` inside ``shard_map``, bubbles
amortized by M >> S microbatching.

The implementation is deliberately framework-shaped: it wraps any
per-stage apply function (a stack of blocks) and composes with the data/
model axes left to GSPMD (auto axes), exactly like
``runtime/compression.py`` does for the pod axis.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.compat import shard_map


def pipeline_apply(
    stage_fn: Callable[[jax.Array, any], jax.Array],
    stage_params: any,  # pytree with leading [n_stages] dim, sharded over axis
    x_microbatches: jax.Array,  # (M, mb, ...) microbatched inputs
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run M microbatches through S pipeline stages on mesh axis ``axis``.

    Schedule: standard GPipe fill-drain over T = M + S - 1 ticks.  At tick
    t, stage s processes microbatch (t - s); inter-stage transfer is a
    ring ppermute.  Returns the stage-(S-1) outputs re-assembled as
    (M, mb, ...).

    Correctness contract (tested): equals sequentially applying the S
    stages to each microbatch.
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    assert m >= 1

    def body(params_local, xs_local):
        # params_local: this stage's params (leading dim 1); xs_local: (M, mb, ...)
        sidx = jax.lax.axis_index(axis)
        params_one = jax.tree_util.tree_map(lambda a: a[0], params_local)
        mb_shape = xs_local.shape[1:]
        total = m + n_stages - 1

        def tick(carry, t):
            acc_out, live = carry  # live: the activation entering this stage
            # stage 0 ingests microbatch t (if in range); others use `live`
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = xs_local[mb_idx]
            inp = jnp.where(sidx == 0, inject, live)
            out = stage_fn(inp, params_one)
            # mask ticks where this stage has no valid microbatch yet/anymore
            my_mb = t - sidx
            valid = (my_mb >= 0) & (my_mb < m)
            out = jnp.where(valid, out, jnp.zeros_like(out))
            # last stage records its finished microbatch
            is_last = sidx == n_stages - 1
            write_idx = jnp.clip(my_mb, 0, m - 1)
            acc_out = jax.lax.cond(
                valid & is_last,
                lambda a: a.at[write_idx].set(out),
                lambda a: a,
                acc_out,
            )
            # ring transfer to the next stage
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (acc_out, nxt), None

        acc0 = jnp.zeros((m,) + mb_shape, xs_local.dtype)
        live0 = jnp.zeros(mb_shape, xs_local.dtype)
        (acc_out, _), _ = jax.lax.scan(tick, (acc0, live0), jnp.arange(total))
        # every stage holds garbage except the last; gather and select it
        gathered = jax.lax.all_gather(acc_out, axis)  # (S, M, mb, ...)
        return gathered[n_stages - 1]

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis},
        check_vma=False,
    )
    return fn(stage_params, x_microbatches)


def stack_stage_params(per_stage_params: list) -> any:
    """[S] list of per-stage param pytrees -> stacked tree (leading S)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_stage_params)
