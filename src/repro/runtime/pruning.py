"""Eager-Pruning-style progressive weight sparsification (paper §6).

The paper's closing discussion proposes combining SPRING's sparsity-aware
dataflow with Eager Pruning [Zhang et al., ISCA'19]: weight-magnitude
*rankings stabilize early in training*, so insignificant weights can be
pruned DURING training and the binary-mask machinery converts the zeros
into skipped work immediately (tile-skips in ``kernels/masked_matmul``,
compressed traffic via ``core/masking``).

This module implements that schedule on top of the SR fixed-point
trainer: a target sparsity ramp (0 -> final over the ramp steps), applied
as hard magnitude pruning of the master weights at each boundary, with
masks re-derived (not stored) — pruned coordinates stay prunable, which
matches Eager Pruning's "rank-stability" assumption rather than
irreversible pruning.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PruneSchedule:
    final_sparsity: float = 0.5
    start_step: int = 20
    ramp_steps: int = 100
    min_dim: int = 64  # leave small tensors (norms, biases) dense

    def sparsity_at(self, step: jax.Array) -> jax.Array:
        frac = jnp.clip((step - self.start_step) / max(1, self.ramp_steps), 0.0, 1.0)
        # cubic ramp (Zhu & Gupta '17): gentle early, aggressive late
        return self.final_sparsity * (1.0 - (1.0 - frac) ** 3)


def _prune_leaf(w: jax.Array, sparsity: jax.Array, min_dim: int) -> jax.Array:
    # judge size on the matmul dims — scanned layer stacks carry small
    # leading [n_units] dims that must not exempt the weights
    if w.ndim < 2 or min(w.shape[-2:]) < min_dim:
        return w
    mag = jnp.abs(w.astype(jnp.float32)).reshape(-1)
    k = w.size  # threshold at the s-quantile of |w|
    thresh = jnp.quantile(mag, sparsity)
    return jnp.where(jnp.abs(w) > thresh, w, 0.0).astype(w.dtype)


def apply_pruning(params, step: jax.Array, schedule: PruneSchedule):
    """Magnitude-prune every large weight to the scheduled sparsity."""
    s = schedule.sparsity_at(step)

    def one(w):
        return _prune_leaf(w, s, schedule.min_dim)

    return jax.tree_util.tree_map(one, params)


def measured_sparsity(params) -> jax.Array:
    """Fraction of exactly-zero weight entries (the masked-matmul input)."""
    zeros = total = 0.0
    for w in jax.tree_util.tree_leaves(params):
        if w.ndim >= 2:
            zeros += jnp.sum(w == 0.0).astype(jnp.float32)
            total += w.size
    return zeros / jnp.maximum(total, 1.0)
