"""Host-side resilience: straggler watchdog + elastic mesh policy.

On a real pod these hooks wire into the cluster controller; they are
plain-Python and fully unit-tested here.

* ``StragglerWatchdog`` — per-step wall-time EWMA with a multiplicative
  threshold; slow steps are logged and counted, and a configurable
  escalation (abort-and-restart from checkpoint) triggers after K
  consecutive slow steps.  TPU SPMD has no per-step device reassignment,
  so restart-from-checkpoint *is* the mitigation (plus data-pipeline
  prefetch so input stalls never look like stragglers).
* ``ElasticMeshPolicy`` — given the devices that survive a failure,
  choose the largest supported (data, model) mesh and signal a re-mesh
  restore (checkpoints are logical, so any mesh works —
  checkpoint/manager.py).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.resilience")


@dataclasses.dataclass
class WatchdogEvent:
    step: int
    duration: float
    ewma: float
    slow: bool


class StragglerWatchdog:
    def __init__(
        self,
        threshold: float = 2.0,
        alpha: float = 0.1,
        escalate_after: int = 5,
        on_escalate: Optional[Callable[[], None]] = None,
        warmup_steps: int = 3,
    ):
        self.threshold = threshold
        self.alpha = alpha
        self.escalate_after = escalate_after
        self.on_escalate = on_escalate
        self.warmup_steps = warmup_steps
        self.ewma: Optional[float] = None
        self.consecutive_slow = 0
        self.events: list[WatchdogEvent] = []
        self._t0: Optional[float] = None
        self._seen = 0

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> WatchdogEvent:
        if self._t0 is None:
            # used to be a bare TypeError from the float arithmetic below
            raise RuntimeError(
                "StragglerWatchdog.step_end() called without a matching "
                "step_start()")
        dt = time.monotonic() - self._t0
        self._t0 = None  # consume: a double step_end is the same bug
        self._seen += 1
        slow = False
        if self.ewma is None:
            self.ewma = dt
        else:
            if self._seen > self.warmup_steps and dt > self.threshold * self.ewma:
                slow = True
                self.consecutive_slow += 1
                log.warning("straggler: step %d took %.3fs (ewma %.3fs)", step, dt, self.ewma)
                if self.consecutive_slow >= self.escalate_after and self.on_escalate:
                    log.error("straggler escalation after %d slow steps", self.consecutive_slow)
                    self.on_escalate()
            else:
                self.consecutive_slow = 0
            # slow steps don't poison the baseline
            if not slow:
                self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        ev = WatchdogEvent(step, dt, self.ewma, slow)
        self.events.append(ev)
        return ev


@dataclasses.dataclass(frozen=True)
class MeshChoice:
    shape: tuple
    axes: tuple


class ElasticMeshPolicy:
    """Pick the best (pod, data, model) mesh for the devices available.

    Keeps the model axis fixed (TP degree is a property of the model
    layout) and scales the data axis down to the largest divisor — a
    restart after losing a slice continues with a smaller global batch
    rather than dying (grad accumulation can restore the batch size).
    """

    def __init__(self, model_parallel: int = 16, prefer_pods: int = 2):
        self.model_parallel = model_parallel
        self.prefer_pods = prefer_pods

    def choose(self, n_devices: int) -> MeshChoice:
        m = self.model_parallel
        if n_devices % m != 0:
            # degrade TP if the devices cannot host it
            while m > 1 and n_devices % m != 0:
                m //= 2
        rest = n_devices // m
        for pods in range(min(self.prefer_pods, rest), 0, -1):
            if rest % pods == 0:
                data = rest // pods
                if pods > 1:
                    return MeshChoice((pods, data, m), ("pod", "data", "model"))
                return MeshChoice((data, m), ("data", "model"))
        return MeshChoice((rest, m), ("data", "model"))
