"""Logical-axis sharding rules (DP + FSDP + TP + EP) with divisibility fallback.

Tensors throughout the framework are annotated with *logical* axis names;
a rules table maps logical axes to mesh axes.  ``logical_to_spec`` drops a
mesh axis whenever the corresponding dimension is not divisible by the
mesh-axis extent — the tensor is replicated along that axis instead of
mis-sharded.  This keeps every (arch x shape x mesh) cell compileable
(e.g. minitron's 24 q-heads on a model=16 axis) while the roofline table
surfaces the cost of the fallback, which is exactly what the §Perf
hillclimb then optimizes.

Mesh axes:
  pod    — inter-pod data parallelism (multi-pod mesh only)
  data   — intra-pod data parallelism for activations, FSDP for weights
  model  — tensor parallelism (heads / ffn / vocab / experts)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, tuple]

# logical axis -> mesh axes (order matters: first existing wins; tuples
# shard over multiple mesh axes jointly).
DEFAULT_RULES: dict[str, tuple] = {
    # activations
    "batch": (("pod", "data"), ("data",)),
    "seq": (None,),
    "embed": (None,),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (None,),
    "mlp_act": (("model",),),
    "experts_act": (("model",),),
    "capacity": (None,),
    "vocab_act": (("model",),),
    # weights
    "w_embed": (("data",),),          # FSDP axis
    "w_qkv": (("model",),),           # TP axis (flattened heads*head_dim)
    "w_mlp": (("model",),),
    "w_vocab": (("model",),),
    "w_experts": (("model",),),       # expert parallelism
    "w_state": (None,),
    # kv-cache
    "cache_batch": (("pod", "data"), ("data",)),
    "cache_heads": (("model",),),
    # decode caches: the seq dim shards over model (flash-decoding-style
    # split-K) — kv_heads rarely divide the 16-way model axis, seq always
    # does for the assigned shapes; first-listed rule that divides wins.
    "cache_seq": (("model",), None),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict[str, tuple] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Install a mesh + logical rules for ``constrain`` calls in scope."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES if rules is None else rules)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def override_rules(**updates):
    """Update rules inside the active context (hillclimb lever)."""
    _CTX.rules.update(updates)


def note_mesh_fallback(logical: str):
    """Count one replicate-instead-of-shard fallback.  The divisibility
    fallback used to be silent; the ``spring_mesh_fallback_total`` counter
    (labeled by logical axis) surfaces it in dryrun JSON and the roofline
    report (DESIGN.md §14)."""
    from repro.telemetry.metrics import default_registry

    default_registry().inc(
        "spring_mesh_fallback_total", 1.0, logical=logical,
        help="tensors replicated because no rule candidate divided")


def mesh_fallback_counts() -> dict:
    """{logical axis: fallback count} from the process metrics registry."""
    from repro.telemetry.metrics import default_registry

    snap = default_registry().snapshot()
    fam = snap.get("spring_mesh_fallback_total", {})
    return {cell["labels"].get("logical", "?"): int(cell["value"])
            for cell in fam.get("cells", [])}


def _mesh_axes_for(logical: Optional[str], dim: int, mesh: Mesh) -> Optional[tuple]:
    """Resolve one logical axis to mesh axes, honoring divisibility."""
    if logical is None:
        return None
    candidates = _CTX.rules.get(logical, (None,))
    had_candidate = False
    for cand in candidates:
        if cand is None:
            return None
        axes = tuple(a for a in cand if a in mesh.shape)
        if not axes:
            continue
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        if extent > 1:
            had_candidate = True
        if dim % extent == 0:
            return axes
    if had_candidate:
        # a rule wanted to shard this tensor but no candidate divided:
        # replicate, and make the fallback visible (satellite of §14)
        note_mesh_fallback(logical)
    return None


def logical_to_spec(logical_axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names."""
    used: set[str] = set()
    parts = []
    for name, dim in zip(logical_axes, shape):
        axes = _mesh_axes_for(name, dim, mesh)
        if axes is None or any(a in used for a in axes):
            parts.append(None)
        else:
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh))


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh
