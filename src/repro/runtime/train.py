"""Train/serve step builders: the jit-compiled SPMD programs the launcher
and the multi-pod dry-run lower.

``make_train_step`` returns a donated-state jit function implementing:
  grad(loss) -> [optional int8+EF compressed inter-pod all-reduce]
             -> clip -> AdamW/SGDm -> [optional SR fixed-point weights]

Numerics mode (dense | quant | quant_sparse) comes from the SpringConfig
in ``StepConfig`` — the paper's technique is a first-class switch, not a
fork of the trainer.

Since the RunSpec API landed (DESIGN.md §10), ``StepConfig`` is normally
*produced*, not hand-assembled: ``RunSpec.resolve().step`` (or the
``StepConfig.from_runspec`` convenience below) is the one place the five
config surfaces — SpringConfig, StepConfig, KernelPolicy,
MemstashConfig, serving arguments — are threaded together.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.spring_ops import DENSE, KeyGen, SpringConfig
from repro.memstash.config import MemstashConfig
from repro.runtime.compat import shard_map
from repro.models import encdec as ed_mod
from repro.models import lm as lm_mod
from repro.models.layers import SpringContext
from repro.optim.optimizers import OptimizerConfig, make_optimizer
from repro.runtime.sharding import DEFAULT_RULES, sharding_context


@dataclasses.dataclass(frozen=True)
class StepConfig:
    spring: SpringConfig = DENSE
    # Sparsity-aware backward pass override: None inherits the
    # SpringConfig.backward_sparsity field (default "auto" — dx/dW through
    # the registry-resolved masked_matmul_dx/dw kernels in quant_sparse
    # mode); launch CLIs set it explicitly ("none" | "auto" | impl name)
    # so --backward-sparsity switches it without rebuilding SpringConfig.
    backward_sparsity: Optional[str] = None
    prune_ratio: float = 0.0
    optimizer: OptimizerConfig = OptimizerConfig()
    # int8+error-feedback gradient reduction across the 'pod' mesh axis
    compress_pod_grads: bool = False
    microbatch: Optional[int] = None  # gradient accumulation splits
    # logical-sharding rule overrides, e.g. (("seq", (("model",), None)),)
    # = sequence-parallel residual (reduce-scatter TP boundaries)
    rules_override: tuple = ()
    # compressed-activation-stash policy (memstash subsystem); pairs with
    # LMConfig.remat_policy="stash" for the residual stream and drives the
    # per-layer conv/fc stash points in the CNN models
    memstash: MemstashConfig = MemstashConfig()
    # int8 KV cache for serving (SPRING P2 on the cache)
    int8_cache: bool = False

    @classmethod
    def from_runspec(cls, spec) -> "StepConfig":
        """Resolve a :class:`repro.api.RunSpec` (or a spec dict / JSON
        artifact embedding one under a ``"spec"`` key, as every session
        result does) to the StepConfig its run mode implies — the single
        resolution path the launchers use."""
        import json as _json

        from repro.api.spec import RunSpec, SpecError

        if isinstance(spec, str):
            try:
                spec = _json.loads(spec)
            except _json.JSONDecodeError as e:
                raise SpecError(f"invalid spec JSON: {e}") from None
        if isinstance(spec, dict):
            if "run" not in spec and isinstance(spec.get("spec"), dict):
                spec = spec["spec"]  # a run artifact embedding its spec
            spec = RunSpec.from_dict(spec)
        return spec.resolve().step


class TrainState:
    """Pytree train state: params + opt + step + rng (+ EF buffers)."""

    def __init__(self, params, opt_state, step, rng, ef=None):
        self.params, self.opt_state, self.step, self.rng, self.ef = (
            params, opt_state, step, rng, ef,
        )

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step, self.rng, self.ef), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, lambda s: s.tree_flatten(), TrainState.tree_unflatten
)


def init_train_state(key, arch, step_cfg: StepConfig, reduced: bool = False):
    cfg = arch.reduced() if reduced else arch.config
    init = ed_mod.encdec_init if arch.is_encdec else lm_mod.lm_init
    params = init(key, cfg)
    opt_init, _ = make_optimizer(step_cfg.optimizer)
    ef = None
    if step_cfg.compress_pod_grads:
        ef = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return TrainState(params, opt_init(params), jnp.zeros((), jnp.int32), key, ef)


def _loss_for(arch, cfg, params, batch, ctx):
    if arch.is_encdec:
        return ed_mod.encdec_loss(params, cfg, batch["frames"], batch["tokens"], ctx)
    return lm_mod.lm_loss(params, cfg, batch["tokens"], ctx, batch.get("img_embeds"))


def _spring_for(step_cfg: StepConfig) -> SpringConfig:
    """SpringConfig with the step-level backward_sparsity override applied
    (None = inherit whatever the SpringConfig itself says)."""
    if step_cfg.backward_sparsity is None \
            or step_cfg.spring.backward_sparsity == step_cfg.backward_sparsity:
        return step_cfg.spring
    return dataclasses.replace(step_cfg.spring,
                               backward_sparsity=step_cfg.backward_sparsity)


def _rules_for(step_cfg: StepConfig):
    if not step_cfg.rules_override:
        return None
    rules = dict(DEFAULT_RULES)
    rules.update(dict(step_cfg.rules_override))
    return rules


def make_train_step(arch, step_cfg: StepConfig, mesh=None, reduced: bool = False,
                    grad_sync=None):
    """Build the SPMD train step.  With ``mesh`` set, logical sharding
    constraints activate and the function is ready to jit with shardings.

    ``grad_sync`` (grads-tree -> grads-tree) runs between the backward
    pass and the optimizer — the seam where spring-mesh splices its
    packed reduce-scatter/all-gather gradient exchange (DESIGN.md §14).
    It composes with the ``compress_pod_grads`` int8+EF pod link, which
    stays where it was (per-pod grads differ; the data-axis exchange
    ``grad_sync`` carries is a different link)."""
    cfg = arch.reduced() if reduced else arch.config
    _, opt_update = make_optimizer(step_cfg.optimizer)
    spring_cfg = _spring_for(step_cfg)

    def ctx_for(key) -> SpringContext:
        keys = KeyGen(key) if spring_cfg.is_quantized else None
        return SpringContext(cfg=spring_cfg, keys=keys,
                             prune_ratio=step_cfg.prune_ratio,
                             memstash=step_cfg.memstash)

    def grads_and_loss(params, batch, key):
        def loss_fn(p):
            loss, metrics = _loss_for(arch, cfg, p, batch, ctx_for(key))
            return loss, metrics

        if step_cfg.microbatch is None:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            return loss, metrics, grads
        # gradient accumulation over microbatches (memory-bound shapes)
        nm = step_cfg.microbatch

        def one(i):
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(nm, x.shape[0] // nm, *x.shape[1:])[i], batch
            )
            def lf(p):
                loss, metrics = _loss_for(arch, cfg, p, mb, ctx_for(jax.random.fold_in(key, i)))
                return loss, metrics
            return jax.value_and_grad(lf, has_aux=True)(p)

        def body(carry, i):
            acc_loss, acc_grads, p = carry
            (loss, metrics), grads = one(i)
            return (acc_loss + loss / nm,
                    jax.tree_util.tree_map(lambda a, g: a + g / nm, acc_grads, grads),
                    p), metrics

        p = params
        zero_g = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        (loss, grads, _), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_g, p), jnp.arange(nm)
        )
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def plain_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        key = jax.random.fold_in(state.rng, state.step)
        with sharding_context(mesh, _rules_for(step_cfg)):
            loss, metrics, grads = grads_and_loss(state.params, batch, key)
            if grad_sync is not None:
                grads = grad_sync(grads)
            new_p, new_opt, om = opt_update(grads, state.opt_state, state.params,
                                            jax.random.fold_in(key, 0x5eed))
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(new_p, new_opt, state.step + 1, state.rng, state.ef), metrics

    if not step_cfg.compress_pod_grads:
        return plain_step

    assert mesh is not None and "pod" in mesh.shape, "pod axis required for compressed grads"
    from repro.runtime.compression import compressed_allreduce_tree

    def compressed_body(state: TrainState, batch):
        key = jax.random.fold_in(state.rng, state.step)
        with sharding_context(mesh, _rules_for(step_cfg)):
            loss, metrics, grads = grads_and_loss(state.params, batch, key)
            # int8 + error feedback across pods (per-pod grads differ since
            # each pod saw different data)
            grads, new_ef = compressed_allreduce_tree(
                grads, "pod", jax.random.fold_in(key, 0xc0de), state.ef
            )
            new_p, new_opt, om = opt_update(grads, state.opt_state, state.params,
                                            jax.random.fold_in(key, 0x5eed))
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(new_p, new_opt, state.step + 1, state.rng, new_ef), metrics

    def compressed_step(state: TrainState, batch):
        # manual over 'pod' (the compressed link), GSPMD-auto over data/model
        in_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            jax.tree_util.tree_map(lambda _: P("pod"), batch),
        )
        out_specs = (
            jax.tree_util.tree_map(lambda _: P(), state),
            P(),
        )
        fn = shard_map(
            compressed_body, mesh=mesh,
            in_specs=in_specs, out_specs=out_specs,
            axis_names={"pod"}, check_vma=False,
        )
        return fn(state, batch)

    return compressed_step


# -- serving ----------------------------------------------------------------
# The serving step builders moved to ``repro.serving.steps`` when the
# continuous-batching engine landed; re-exported here for the dry-run and
# existing callers (lazy to keep runtime <-> serving import-cycle-free).


def make_prefill_step(arch, step_cfg: StepConfig, mesh=None, reduced: bool = False):
    from repro.serving.steps import make_prefill_step as _mk

    return _mk(arch, step_cfg, mesh=mesh, reduced=reduced)


def make_decode_step(arch, step_cfg: StepConfig, mesh=None, reduced: bool = False):
    from repro.serving.steps import make_decode_step as _mk

    return _mk(arch, step_cfg, mesh=mesh, reduced=reduced)
