"""Version shims over the pinned jax (0.4.37 in the container) vs newer.

Three surfaces moved between jax versions; all callers in this repo go
through here so each call site stays version-agnostic:

  * ``shard_map`` — ``jax.shard_map(..., axis_names=..., check_vma=...)``
    in new jax; ``jax.experimental.shard_map.shard_map(..., auto=...,
    check_rep=...)`` in 0.4.x.  ``axis_names`` (the manual axes) maps to
    the old ``auto`` complement; ``check_vma`` maps to ``check_rep``.
  * treedef (de)serialization — the proto helpers live under
    ``jaxlib._jax`` in new jax and ``jaxlib.xla_extension`` in 0.4.x.
  * ``Compiled.cost_analysis()`` — a dict in new jax, a one-element list
    of dicts in 0.4.x.
"""

from __future__ import annotations

from typing import Any, Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[set] = None, check_vma: bool = False):
    """Backend-portable ``shard_map`` with the new-jax call convention.

    ``axis_names``: mesh axes the body is manual over (None = all).
    ``check_vma``: replication checking (named ``check_rep`` in 0.4.x).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def deserialize_treedef(data: bytes):
    """Proto-serialized PyTreeDef -> PyTreeDef on either jaxlib layout."""
    try:
        from jaxlib._jax import pytree as _pytree
    except ImportError:  # jax 0.4.x
        from jaxlib.xla_extension import pytree as _pytree
    return _pytree.PyTreeDef.deserialize_using_proto(
        jax.tree_util.default_registry, data
    )


def cost_analysis_dict(compiled) -> dict[str, Any]:
    """``Compiled.cost_analysis()`` normalized to a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
