"""Analytical performance/energy model of SPRING vs GTX 1080 Ti."""

from repro.perfmodel.spring_model import (
    GPU_1080TI,
    SPRING_DESIGN,
    AcceleratorResult,
    evaluate_cnn,
    gpu_eval,
    spring_eval,
)

__all__ = [
    "GPU_1080TI",
    "SPRING_DESIGN",
    "AcceleratorResult",
    "evaluate_cnn",
    "gpu_eval",
    "spring_eval",
]
