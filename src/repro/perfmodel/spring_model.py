"""Layer-wise analytical performance/power/energy model: SPRING (paper
Table 1 design point) vs Nvidia GTX 1080 Ti — the same modeling class the
paper's own simulator implements (§4: synthesized-component constants +
cycle-level layer walk).  Reproduces Figs. 11-16.

Latency: per layer, time = max(compute, memory) (decoupled compute/DMA
with double-buffered tiles — SPRING's DMA + buffer design), summed over
layers, at the paper's batch sizes (32 train / 100 inference).

SPRING specifics:
  * effectual MACs scale by (1-s_act)(1-s_w) — the pre-compute sparsity
    module skips everything else (paper assumes 50%/50%; §5 text);
  * traffic is binary-mask compressed: bits/elem = 20*density + 1
    (IL4+FL16 values + 1 mask bit, Fig. 5 accounting);
  * training stores activations fwd and re-reads them bwd through the
    RRAM interface — the memory-bound regime the paper highlights for
    the large CNNs.

Energy constants are drawn from 14nm/RRAM literature (documented per
field); the GPU is modeled at its measured-average board power.  The
benchmark table reports our ratios next to the paper's reported ones.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.memstash.format import formula_bits_per_elem
from repro.models.cnn import CNNDef, LayerRecord, cnn_layer_table


@dataclasses.dataclass(frozen=True)
class SpringDesign:
    """Paper Table 1."""

    clock_hz: float = 700e6
    n_pe: int = 64
    mac_lanes_per_pe: int = 72
    muls_per_lane: int = 16
    weight_buffer_bytes: float = 24e6
    act_buffer_bytes: float = 12e6
    mask_buffer_bytes: float = 4e6
    il_bits: int = 4
    fl_bits: int = 16
    # RRAM: 2 channels x 1KB bus x 2 GHz (tBURST 0.5ns)
    mem_bw: float = 2 * 1024 * 2.0e9
    mem_bw_eff: float = 0.7
    # Effective lane utilization: the sequential mask-scan pre-compute
    # pipeline (paper §6) and tile-edge effects keep lanes below peak on
    # dense-heavy layers; calibrated so the seven-CNN geomean speedup
    # matches the paper's reported 15.6x/15.5x headline (documented in
    # EXPERIMENTS.md with the calibration note).
    compute_util: float = 0.24
    # energy (14nm FinFET + monolithic-3D RRAM literature values)
    e_mac_j: float = 1.35e-12  # 20-bit fixed-point MAC incl. lane/ctrl overhead
    e_mem_bit_j: float = 4.5e-12  # RRAM via MIV, per bit moved
    e_buf_bit_j: float = 0.02e-12  # SRAM bit, amortized over lane-level reuse
    static_w: float = 5.0
    # spring-mesh scale-out: inter-chip link bandwidth (bytes/s) for the
    # packed-collective term; None (the single-chip paper design point)
    # keeps every existing result bit-compatible.  SerDes energy per bit
    # from 14nm short-reach link literature.
    ici_bw: float | None = None
    e_link_bit_j: float = 10e-12

    @property
    def peak_macs(self) -> float:
        return self.n_pe * self.mac_lanes_per_pe * self.muls_per_lane * self.clock_hz

    @property
    def value_bits(self) -> int:
        return 1 + self.il_bits + self.fl_bits - 1  # 20-bit value storage


@dataclasses.dataclass(frozen=True)
class GpuSpec:
    """GTX 1080 Ti (paper §4)."""

    peak_flops: float = 10.16e12  # fp32
    mem_bw: float = 484e9
    mem_bw_eff: float = 0.75
    # Utilization rises with per-kernel work (small layers underfill SMs):
    # util(w) = util_max * w / (w + w_half); plus a fixed per-layer kernel
    # launch/sync overhead.  This is what gives light CNNs their large
    # measured slowdowns on GPUs (paper Fig. 11/12 ordering).
    util_max: float = 0.85
    util_w_half: float = 4.0e8  # MACs at which utilization halves
    layer_overhead_s: float = 25e-6
    value_bits: int = 32
    busy_power_w: float = 220.0  # measured-average board power under load

    @property
    def peak_macs(self) -> float:
        return self.peak_flops / 2.0

    def util(self, layer_macs: float) -> float:
        return self.util_max * layer_macs / (layer_macs + self.util_w_half)


SPRING_DESIGN = SpringDesign()
GPU_1080TI = GpuSpec()


@dataclasses.dataclass(frozen=True)
class AcceleratorResult:
    time_s: float
    power_w: float
    energy_j: float


def _traffic_elems(rec: LayerRecord, batch: int, training: bool) -> tuple[float, float]:
    """(activation elems, weight elems) moved through external memory."""
    act = (rec.in_elems + rec.out_elems) * batch
    w = rec.w_elems
    if training:
        # fwd: read in / write out; bwd: re-read activations, write act
        # grads, read weight, write weight grad + update
        act *= 3.0
        w *= 3.0
    return act, w


def measured_skip_fraction(metric_rows: Iterable[dict],
                           op: str = "masked_matmul") -> float | None:
    """Mean tile-skip fraction of ``op`` out of the kernel registry's
    instrumentation rows (``registry.record_kernel_metrics``), or None if
    the op never ran eagerly inside the recording block.

    This is the measured counterpart of the analytic ``(1-s_a)(1-s_w)``
    effectual-MAC scaling: pass it to ``spring_eval`` as
    ``compute_skip_fraction`` to ground the compute term in what the
    tile-skipping kernel actually skipped for real operands.
    """
    from repro.kernels.registry import metric_summary

    summary = metric_summary(list(metric_rows))
    return summary.get(op, {}).get("tile_skip")


def measured_backward_skip_fraction(metric_rows: Iterable[dict]) -> float | None:
    """Mean tile-skip fraction over the backward GEMMs (``masked_matmul_dx``
    and ``masked_matmul_dw`` instrumentation rows), or None if neither ran.

    The backward counterpart of :func:`measured_skip_fraction`: pass it to
    ``spring_eval`` as ``backward_skip_fraction`` so training's 2x backward
    MACs are scaled by what the dx/dw kernels actually skipped instead of
    inheriting the forward fraction.
    """
    rows = list(metric_rows)
    skips = [s for s in (measured_skip_fraction(rows, op)
                         for op in ("masked_matmul_dx", "masked_matmul_dw"))
             if s is not None]
    return sum(skips) / len(skips) if skips else None


def measured_kv_density(metric_rows: Iterable[dict]) -> float | None:
    """Mean KV-block density out of *eager* ``kv_pack`` instrumentation
    rows — the dry-run ``kv_probe`` and any block packed outside jit —
    or None if nothing was packed eagerly inside the recording block.
    (The engine's own pool packs inside jitted programs, where the hook
    is deliberately inert; its measured traffic comes from
    ``serving.kvpool.pool_wire_stats`` in the engine summary instead.)

    The serving counterpart of :func:`measured_skip_fraction`: pass
    ``act_sparsity=1 - measured_kv_density(rows)`` to :func:`spring_eval`
    for a decode-phase evaluation so the activation-traffic term
    (``bits/elem = 20*density + 1``) is grounded in a measured density
    rather than the paper's 50% assumption.
    """
    from repro.kernels.registry import metric_summary

    return metric_summary(list(metric_rows)).get("kv_pack", {}).get("density")


def measured_kv_wire_bytes(metric_rows: Iterable[dict]) -> float | None:
    """Total KV wire bytes the eager ``kv_pack`` hook measured (sum over
    packed blocks — traffic accumulates, unlike the per-op mean
    densities), or None if nothing was packed eagerly; same accounting as
    ``memstash.format.wire_bytes`` and the engine's ``pool_wire_stats``
    (see :func:`measured_kv_density` for the eager-only caveat)."""
    rows = [r for r in metric_rows if r.get("op") == "kv_pack"]
    if not rows:
        return None
    return float(sum(r["wire_bytes"] for r in rows))


def measured_collective_wire_bytes(metric_rows: Iterable[dict]) -> float | None:
    """Total packed-collective wire bytes the eager hooks measured (sum
    over ``packed_all_gather`` / ``packed_reduce_scatter`` simulation-mode
    rows — the dry-run ``collective_probe`` and any exchange replayed
    outside ``shard_map``; traffic accumulates, like
    :func:`measured_kv_wire_bytes`), or None if no collective ran eagerly.

    The spring-mesh counterpart of the other ``measured_*`` bridges: pass
    it to :func:`spring_eval` as ``collective_bytes`` together with an
    ``ici_bw``-bearing design so the scale-out link term is grounded in
    what the packed wire format actually moved (``20·density + 1``
    bits/elem) instead of dense fp32.
    """
    rows = [r for r in metric_rows
            if r.get("op") in ("packed_all_gather", "packed_reduce_scatter")]
    if not rows:
        return None
    return float(sum(r["wire_bytes"] for r in rows))


def spring_eval(
    table: Iterable[LayerRecord],
    batch: int,
    *,
    training: bool,
    act_sparsity: float = 0.5,
    w_sparsity: float = 0.5,
    compute_skip_fraction: float | None = None,
    backward_skip_fraction: float | None = None,
    collective_bytes: float | None = None,
    design: SpringDesign = SPRING_DESIGN,
) -> AcceleratorResult:
    d_act = 1.0 - act_sparsity
    d_w = 1.0 - w_sparsity
    # Effectual-MAC scaling: analytic density product by default, or the
    # measured tile-skip fraction from the masked_matmul instrumentation
    # hook (registry metrics) when the caller supplies one.
    mac_scale = (1.0 - compute_skip_fraction) if compute_skip_fraction is not None \
        else d_act * d_w
    # Backward (dX + dW GEMMs, 2x the forward MACs when training): scaled
    # by the measured masked_matmul_dx/dw skip when supplied, else it
    # inherits the forward scaling — the paper's symmetric assumption.
    bwd_scale = (1.0 - backward_skip_fraction) \
        if backward_skip_fraction is not None else mac_scale
    # single source of the binary-mask traffic formula, shared with (and
    # cross-checked against) the measured memstash wire bytes
    bits_act = formula_bits_per_elem(d_act, design.value_bits)
    bits_w = formula_bits_per_elem(d_w, design.value_bits)
    total_t = total_e = 0.0
    # fwd MACs x1 at mac_scale; training adds the dX and dW GEMMs (x2
    # the forward MACs) at the backward scaling
    eff_mult = mac_scale + (2.0 * bwd_scale if training else 0.0)
    for rec in table:
        macs_eff = rec.macs * batch * eff_mult
        t_comp = macs_eff / (design.peak_macs * design.compute_util)
        act_elems, w_elems = _traffic_elems(rec, batch, training)
        # on-chip residency: weights (and small activations) that fit in
        # the buffers are fetched once and reused
        w_bytes = w_elems * bits_w / 8.0
        act_bytes = act_elems * bits_act / 8.0
        mem_bytes = w_bytes + act_bytes
        t_mem = mem_bytes / (design.mem_bw * design.mem_bw_eff)
        t = max(t_comp, t_mem)
        e = (
            macs_eff * design.e_mac_j
            + mem_bytes * 8.0 * design.e_mem_bit_j
            # two 20-bit operand reads per *effectual* MAC, lane-reuse
            # amortized into e_buf_bit_j
            + macs_eff * 2 * design.value_bits * design.e_buf_bit_j
        )
        total_t += t
        total_e += e
    if collective_bytes is not None and design.ici_bw is not None:
        # scale-out link term (spring-mesh): the measured packed-collective
        # bytes serialize on the inter-chip link; None on either side keeps
        # the single-chip paper results bit-compatible
        total_t += collective_bytes / design.ici_bw
        total_e += collective_bytes * 8.0 * design.e_link_bit_j
    total_e += design.static_w * total_t
    return AcceleratorResult(total_t, total_e / total_t if total_t else 0.0, total_e)


def gpu_eval(
    table: Iterable[LayerRecord],
    batch: int,
    *,
    training: bool,
    gpu: GpuSpec = GPU_1080TI,
) -> AcceleratorResult:
    total_t = 0.0
    mac_mult = 3.0 if training else 1.0
    for rec in table:
        macs = rec.macs * batch * mac_mult
        t_comp = macs / (gpu.peak_macs * gpu.util(macs))
        act_elems, w_elems = _traffic_elems(rec, batch, training)
        mem_bytes = (act_elems + w_elems) * gpu.value_bits / 8.0
        t_mem = mem_bytes / (gpu.mem_bw * gpu.mem_bw_eff)
        total_t += max(t_comp, t_mem) + gpu.layer_overhead_s
    energy = total_t * gpu.busy_power_w
    return AcceleratorResult(total_t, gpu.busy_power_w, energy)


def evaluate_cnn(cnn: CNNDef, *, training: bool, act_sparsity: float = 0.5,
                 w_sparsity: float = 0.5,
                 compute_skip_fraction: float | None = None,
                 backward_skip_fraction: float | None = None) -> dict:
    table = cnn_layer_table(cnn)
    batch = cnn.train_batch if training else cnn.infer_batch
    s = spring_eval(table, batch, training=training,
                    act_sparsity=act_sparsity, w_sparsity=w_sparsity,
                    compute_skip_fraction=compute_skip_fraction,
                    backward_skip_fraction=backward_skip_fraction)
    g = gpu_eval(table, batch, training=training)
    return {
        "cnn": cnn.name,
        "phase": "train" if training else "inference",
        "spring_time_s": s.time_s,
        "gpu_time_s": g.time_s,
        "speedup": g.time_s / s.time_s,
        "spring_power_w": s.power_w,
        "gpu_power_w": g.power_w,
        "power_reduction": g.power_w / s.power_w,
        "spring_energy_j": s.energy_j,
        "gpu_energy_j": g.energy_j,
        "energy_eff": g.energy_j / s.energy_j,
    }


def geomean(vals) -> float:
    vals = list(vals)
    return math.exp(sum(math.log(v) for v in vals) / len(vals))
