"""CPU round-trip tests for the ``kernels/mask_compress`` ref paths
(``mask_pack`` / ``mask_unpack`` / ``dangling_filter``) against the
element-serial oracles, plus the memstash-vs-Algorithm-1 consistency
check.  No hypothesis dependency: fixed seeds, parametrized shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masking import unpack_mask_bits
from repro.kernels.mask_compress.ops import dangling_filter, mask_pack, mask_unpack
from repro.kernels.mask_compress.ref import (
    dangling_filter_reference,
    mask_pack_reference,
    mask_unpack_reference,
    stash_roundtrip_reference,
)
from repro.memstash import compress, decompress


def sparse(seed, shape, sparsity):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, shape)
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) > sparsity
    return x * keep


@pytest.mark.parametrize("shape", [(7,), (64,), (31, 33), (8, 1024), (3, 5, 9)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
def test_mask_pack_ref_path_matches_oracle(shape, sparsity):
    x = sparse(0, shape, sparsity)
    words = np.asarray(mask_pack(x, impl="ref"))
    flat = np.asarray(x).reshape(-1)
    n = flat.size
    padded = np.zeros(((n + 31) // 32) * 32, np.float32)
    padded[:n] = flat
    expect = mask_pack_reference(padded.reshape(1, -1)).reshape(-1)
    np.testing.assert_array_equal(words[: expect.size], expect)


@pytest.mark.parametrize("n", [1, 31, 32, 33, 1000, 4096])
def test_mask_pack_unpack_roundtrip(n):
    x = sparse(1, (n,), 0.5)
    words = mask_pack(x, impl="ref")
    bits = np.asarray(mask_unpack(words, n))
    np.testing.assert_array_equal(bits.astype(np.int32),
                                  (np.asarray(x) != 0).astype(np.int32))
    # oracle agreement on the same words
    np.testing.assert_array_equal(
        mask_unpack_reference(np.asarray(words), n),
        np.asarray(unpack_mask_bits(jnp.asarray(words), n)).astype(np.int32))


@pytest.mark.parametrize("shape", [(64,), (100,), (16, 300)])
@pytest.mark.parametrize("sa,sw", [(0.3, 0.6), (0.5, 0.5), (0.9, 0.1)])
def test_dangling_filter_ref_path_matches_oracle(shape, sa, sw):
    a = sparse(2, shape, sa)
    w = sparse(3, shape, sw)
    af, wf = dangling_filter(a, w, impl="ref")
    ea, ew = dangling_filter_reference(np.asarray(a), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(af), ea.reshape(shape))
    np.testing.assert_array_equal(np.asarray(wf), ew.reshape(shape))
    # survivors of one operand are exactly the joint-mask positions
    np.testing.assert_array_equal(np.asarray(af) != 0,
                                  (np.asarray(a) != 0) & (np.asarray(w) != 0))


@pytest.mark.parametrize("shape", [(17,), (8, 33), (2, 3, 11)])
@pytest.mark.parametrize("sparsity", [0.0, 0.4, 1.0])
def test_memstash_matches_element_serial_oracle(shape, sparsity):
    """memstash compress->decompress == the element-serial collapse/expand
    oracle (the vectorized cumsum-scatter is the same machine as Fig. 7c)."""
    x = sparse(4, shape, sparsity)
    y = np.asarray(decompress(compress(x)))
    np.testing.assert_array_equal(y, stash_roundtrip_reference(np.asarray(x)))
