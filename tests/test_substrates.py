"""Optimizers, data pipeline, checkpointing, resilience, sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.core.fixedpoint import SPRING_FORMAT
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.optim.optimizers import OptimizerConfig, adamw_init, adamw_update, clip_by_global_norm, sgdm_init, sgdm_update
from repro.runtime.resilience import ElasticMeshPolicy, StragglerWatchdog


# -- optimizers ---------------------------------------------------------------


def test_adamw_matches_reference_step():
    cfg = OptimizerConfig(kind="adamw", lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8, grad_clip=1e9)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    state = adamw_init(p)
    new_p, state, _ = adamw_update(cfg, g, state, p)
    # step 1 with bias correction: update = g/|g| elementwise-ish
    m = 0.1 * np.asarray([0.5, 0.25])
    v = 0.01 * np.asarray([0.25, 0.0625])
    expect = np.asarray([1.0, -2.0]) - 0.1 * (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)


def test_sgdm_momentum_accumulates():
    cfg = OptimizerConfig(kind="sgdm", lr=1.0, momentum=0.5, grad_clip=1e9)
    p = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    state = sgdm_init(p)
    for expect in [-1.0, -2.5, -4.25]:
        p, state, _ = sgdm_update(cfg, g, state, p)
        np.testing.assert_allclose(float(p["w"][0]), expect, rtol=1e-6)


def test_grad_clipping():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 6.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_fixed_point_master_weights_stay_on_grid():
    cfg = OptimizerConfig(kind="sgdm", lr=0.01, weight_format=SPRING_FORMAT, grad_clip=1e9)
    p = {"w": jnp.asarray([0.5, -0.25])}
    g = {"w": jnp.asarray([0.111, -0.222])}
    state = sgdm_init(p)
    p, state, _ = sgdm_update(cfg, g, state, p, key=jax.random.PRNGKey(0))
    scaled = np.asarray(p["w"], np.float64) * 2.0**SPRING_FORMAT.fl
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)


def test_optimizer_converges_on_quadratic():
    cfg = OptimizerConfig(kind="adamw", lr=0.1, grad_clip=1e9)
    target = jnp.asarray([3.0, -1.5])
    p = {"w": jnp.zeros(2)}
    state = adamw_init(p)
    loss = lambda p_: jnp.sum((p_["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(p)
        p, state, _ = adamw_update(cfg, g, state, p)
    assert float(loss(p)) < 1e-2


# -- data ---------------------------------------------------------------------


def test_data_step_addressable_determinism():
    s1 = SyntheticLMStream(DataConfig(seed=5, vocab=64, seq_len=16, global_batch=4))
    s2 = SyntheticLMStream(DataConfig(seed=5, vocab=64, seq_len=16, global_batch=4))
    np.testing.assert_array_equal(np.asarray(s1.batch(17)), np.asarray(s2.batch(17)))
    assert not np.array_equal(np.asarray(s1.batch(17)), np.asarray(s1.batch(18)))


def test_data_is_learnable_markov():
    cfg = DataConfig(seed=0, vocab=32, seq_len=64, global_batch=8)
    s = SyntheticLMStream(cfg)
    b = np.asarray(s.batch(0))
    perm = np.asarray(s.perm)
    follows = (b[:, 1:] == perm[b[:, :-1]]).mean()
    assert follows > 0.8  # 0.9 nominal - noise


# -- checkpointing ------------------------------------------------------------


def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": (jnp.ones(3), jnp.zeros(())),
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip_structure_and_values(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, {"note": "x"})
    step, t2 = load_checkpoint(str(tmp_path))
    assert step == 7
    assert jax.tree_util.tree_structure(t) == jax.tree_util.tree_structure(t2)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, every_steps=1)
    for s in range(1, 6):
        m.maybe_save(s, _tree())
    assert m.latest_step() == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_corruption_detected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    path = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
    with open(path, "r+b") as f:
        f.seek(150)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), 1)


def test_checkpoint_remesh_sharding_fn(tmp_path):
    """Elastic restore: a sharding_fn places arrays on the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    save_checkpoint(str(tmp_path), 2, _tree())
    mesh = jax.make_mesh((1,), ("data",))
    fn = lambda name, shape: NamedSharding(mesh, P()) if shape else None
    _, t2 = load_checkpoint(str(tmp_path), sharding_fn=fn)
    assert bool(jnp.all(t2["params"]["w"] == _tree()["params"]["w"]))


def test_checkpoint_torn_write_skipped(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    # a torn (tmp) dir from a preempted writer must be ignored
    os.makedirs(os.path.join(str(tmp_path), "tmp.step_00000009"))
    step, _ = load_checkpoint(str(tmp_path))
    assert step == 1


# -- resilience ---------------------------------------------------------------


def test_watchdog_flags_stragglers():
    import time

    events = []
    w = StragglerWatchdog(threshold=3.0, escalate_after=2,
                          on_escalate=lambda: events.append("boom"), warmup_steps=0)
    for i in range(5):
        w.step_start()
        time.sleep(0.002)
        w.step_end(i)
    w.step_start(); time.sleep(0.05); w.step_end(5)
    assert w.events[-1].slow
    w.step_start(); time.sleep(0.05); w.step_end(6)
    assert events == ["boom"]


def test_watchdog_step_end_without_start_raises():
    """Regression: step_end() before step_start() used to die with a
    bare TypeError from ``time.monotonic() - None``."""
    w = StragglerWatchdog()
    with pytest.raises(RuntimeError, match="without a matching step_start"):
        w.step_end(0)
    # a completed pair consumes the start: doubling step_end is the same bug
    w.step_start()
    w.step_end(0)
    with pytest.raises(RuntimeError, match="without a matching step_start"):
        w.step_end(1)


def _timed_steps(w, durations):
    """Drive the watchdog with exact synthetic durations (rewind _t0 so
    wall-clock jitter cannot flake the assertions)."""
    import time

    for i, dt in enumerate(durations):
        w.step_start()
        w._t0 = time.monotonic() - dt
        w.step_end(i)


def test_watchdog_warmup_suppresses_early_flags():
    w = StragglerWatchdog(threshold=2.0, warmup_steps=3)
    # a huge spike inside warmup is absorbed, not flagged
    _timed_steps(w, [0.01, 0.5, 0.01])
    assert not any(ev.slow for ev in w.events)
    # past warmup the same spike flags
    _timed_steps(w, [0.01, 0.5])
    assert w.events[-1].slow


def test_watchdog_escalates_after_consecutive_slow_then_resets():
    fired = []
    w = StragglerWatchdog(threshold=2.0, escalate_after=3, warmup_steps=0,
                          on_escalate=lambda: fired.append(True))
    _timed_steps(w, [0.01, 0.01])  # baseline
    _timed_steps(w, [0.2, 0.2])  # two slow: below the escalation bar
    assert not fired and w.consecutive_slow == 2
    _timed_steps(w, [0.01])  # a fast step resets the streak
    assert w.consecutive_slow == 0
    _timed_steps(w, [0.2, 0.2, 0.2])  # three consecutive -> escalate
    assert fired and w.consecutive_slow == 3
    # slow steps never poison the EWMA baseline
    assert w.ewma < 0.05


@given(st.integers(1, 4096))
def test_elastic_mesh_policy_covers_any_device_count(n):
    choice = ElasticMeshPolicy(model_parallel=16, prefer_pods=2).choose(n)
    total = 1
    for d in choice.shape:
        total *= d
    assert total <= n and total >= max(1, n // 2)  # uses most of the fleet
    assert len(choice.shape) == len(choice.axes)


def test_elastic_mesh_policy_degrades_tp_for_awkward_counts():
    """Non-power-of-two survivor counts: TP halves until it divides."""
    pol = ElasticMeshPolicy(model_parallel=16, prefer_pods=2)
    # 24 devices cannot host TP=16 -> degrade to 8, data=3 (3 odd: 1 pod)
    assert pol.choose(24).shape == (3, 8)
    assert pol.choose(24).axes == ("data", "model")
    # prime count: TP degrades all the way to 1
    assert pol.choose(7).shape == (7, 1)
    # clean power of two keeps full TP and splits pods
    assert pol.choose(64).shape == (2, 2, 16)
    assert pol.choose(64).axes == ("pod", "data", "model")
    # single device: the degenerate 1x1 mesh
    assert pol.choose(1).shape == (1, 1)


# -- sharding rules -----------------------------------------------------------


def test_logical_to_spec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P

    from repro.runtime.sharding import logical_to_spec

    mesh = jax.make_mesh((1,), ("model",))
    # trivially divisible
    assert logical_to_spec(("heads",), (16,), mesh) == P("model")
    mesh2 = jax.make_mesh((1,), ("data",))
    # axis not in mesh -> replicated
    assert logical_to_spec(("heads",), (16,), mesh2) == P(None)


def test_tree_sharding_rules_match_names():
    from repro.runtime.tree_sharding import logical_axes_for_path

    class K:  # fake DictKey
        def __init__(self, key):
            self.key = key

    axes = logical_axes_for_path((K("mixer"), K("wq"), K("kernel")), (256, 512))
    assert axes == ("w_embed", "w_qkv")
    # unit-stacked leading dim gets padded with None
    axes = logical_axes_for_path((K("unit_0"), K("mixer"), K("wq"), K("kernel")), (4, 256, 512))
    assert axes == (None, "w_embed", "w_qkv")
    axes = logical_axes_for_path((K("embed"), K("embedding")), (1000, 64))
    assert axes == ("w_vocab", "w_embed")
