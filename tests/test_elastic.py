"""spring-survive seal (ISSUE 9): elastic serving under failure/overload.

Three layers:

  * pure-python scheduler properties (no jax): load shedding, admission
    deadlines, priority/EDF ordering, preempt/resume, and the
    no-silent-loss conservation law — every submitted request ends
    either completed or typed-rejected;
  * engine snapshot/restore: versioned, spec-hash-stamped artifacts that
    round-trip the packed KV pool bits byte-exactly across all numerics
    modes x both pool backends, and restore to emit the exact remaining
    tokens of every in-flight request;
  * chaos: hypothesis drives kill/rewind/roundtrip/rescale schedules at
    arbitrary tick boundaries against the uninterrupted oracle.
"""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.serving.elastic import (ChaosEvent, ChaosHarness, SnapshotError,
                                   load_snapshot, save_snapshot)
from repro.serving.request import Request
from repro.serving.scheduler import (REJECT_DEADLINE, REJECT_QUEUE_FULL,
                                     ShedPolicy, SlotScheduler)

pytestmark = pytest.mark.elastic

ARCH = "llama3.2-1b"
PROMPT, GEN, MAX_LEN = 8, 4, 64
N_PROMPTS = 3


# -- pure-python scheduler properties (no jax) -------------------------------


def _req(rid, *, prio=0, deadline=None, max_tokens=3, prompt=(1, 2, 3)):
    return Request(rid=rid, prompt=tuple(prompt), max_tokens=max_tokens,
                   priority=prio, deadline_ticks=deadline)


def _drain(sched, tick0=0, max_ticks=500):
    """Run the scheduler alone (one token per active slot per tick) until
    dry; returns (completed rids, ticks used)."""
    done, tick = [], tick0
    while sched.has_work():
        for req, _ in sched.shed_expired(tick):
            pass
        sched.admit_gated(lambda s: True, lambda r: True)
        toks = {slot: 7 for slot in sched.active}
        done += [t.req.rid for t in sched.record_tokens(toks)]
        sched.check_invariants()
        tick += 1
        assert tick - tick0 < max_ticks, "scheduler did not drain"
    return done, tick - tick0


@given(st.data())
def test_shed_scheduler_conserves_every_request(data):
    """No silent loss under any policy: every submitted rid is either
    completed or typed-rejected, and invariants hold every tick."""
    policy = ShedPolicy(
        max_queue_depth=data.draw(st.one_of(st.just(None),
                                            st.integers(1, 3))),
        deadline_ticks=data.draw(st.one_of(st.just(None),
                                           st.integers(0, 4))),
        deadline_aware=data.draw(st.booleans()),
        priority_aware=data.draw(st.booleans()))
    sched = SlotScheduler(data.draw(st.integers(1, 3)), policy=policy)
    n_req = data.draw(st.integers(1, 10))
    arrivals = sorted(data.draw(st.integers(0, 6)) for _ in range(n_req))
    completed, rejected, tick, rid = [], [], 0, 0
    while sched.has_work() or rid < n_req:
        for req, reason in sched.shed_expired(tick):
            rejected.append((req.rid, reason))
        while rid < n_req and arrivals[rid] <= tick:
            req = _req(rid, prio=data.draw(st.integers(0, 2)),
                       deadline=data.draw(st.one_of(st.just(None),
                                                    st.integers(0, 3))),
                       max_tokens=data.draw(st.integers(1, 4)))
            reason = sched.submit(req, tick=tick)
            if reason is not None:
                rejected.append((rid, reason))
            rid += 1
        sched.admit_gated(lambda s: True, lambda r: True)
        completed += [t.req.rid
                      for t in sched.record_tokens(
                          {slot: 7 for slot in sched.active})]
        sched.check_invariants()
        tick += 1
        assert tick < 500
    assert sorted(completed + [r for r, _ in rejected]) == list(range(n_req))
    assert sched.shed_log == rejected
    for _, reason in rejected:
        assert reason in (REJECT_QUEUE_FULL, REJECT_DEADLINE)


def test_queue_depth_shed_is_typed_and_fcfs_kept():
    sched = SlotScheduler(1, policy=ShedPolicy(max_queue_depth=2))
    assert sched.submit(_req(0)) is None
    assert sched.submit(_req(1)) is None
    assert sched.submit(_req(2)) == REJECT_QUEUE_FULL  # depth 2 reached
    done, _ = _drain(sched)
    assert done == [0, 1]
    assert sched.shed_log == [(2, REJECT_QUEUE_FULL)]


def test_deadline_shed_uses_request_override():
    sched = SlotScheduler(1, policy=ShedPolicy(deadline_ticks=10))
    sched.submit(_req(0, max_tokens=4), tick=0)  # occupies the slot
    sched.submit(_req(1, deadline=1), tick=0)  # per-request: expires first
    sched.submit(_req(2), tick=0)  # policy default 10: survives
    done, _ = _drain(sched)
    assert done == [0, 2]
    assert sched.shed_log == [(1, REJECT_DEADLINE)]


def test_priority_aware_admission_order():
    sched = SlotScheduler(1, policy=ShedPolicy(priority_aware=True))
    for rid, prio in [(0, 0), (1, 2), (2, 1), (3, 2)]:
        sched.submit(_req(rid, prio=prio, max_tokens=1))
    done, _ = _drain(sched)
    # priority desc, FCFS within a class
    assert done == [1, 3, 2, 0]


def test_deadline_aware_admission_is_edf():
    sched = SlotScheduler(1, policy=ShedPolicy(deadline_aware=True))
    for rid, dl in [(0, None), (1, 9), (2, 5)]:
        sched.submit(_req(rid, deadline=dl, max_tokens=1), tick=0)
    done, _ = _drain(sched)
    assert done == [2, 1, 0]  # earliest deadline first, None last


def test_preempt_resume_order_and_counters():
    sched = SlotScheduler(2)
    for rid, prio in [(0, 0), (1, 5), (2, 0)]:
        sched.submit(_req(rid, prio=prio, max_tokens=2))
    sched.admit_gated(lambda s: True, lambda r: True)  # 0, 1 in slots
    sched.record_tokens({s: 7 for s in sched.active})
    sched.preempt(0, payload="p0")  # rid 0
    sched.preempt(1, payload="p1")  # rid 1 (higher priority)
    assert sched.n_spills == 2 and sched.spilled == 2
    got = sched.admit_gated(lambda s: True, lambda r: True)
    # resumes fill the pool first (priority order: rid 1 before rid 0);
    # rid 2 waits — resumed trackers keep their emitted tokens
    assert [(t.req.rid, s is not None) for t, s in got] == [
        (1, True), (0, True)]
    assert got[0][0].tokens == [7] and got[0][1].payload == "p1"
    assert sched.n_resumes == 2
    sched.check_invariants()
    # a completion frees a slot, then the queued rid admits fresh
    sched.record_tokens({s: 7 for s in sched.active})  # rid 0/1 finish
    got = sched.admit_gated(lambda s: True, lambda r: True)
    assert [(t.req.rid, s) for t, s in got] == [(2, None)]
    sched.check_invariants()


def test_blocked_spill_head_stalls_new_admissions():
    sched = SlotScheduler(2)
    sched.submit(_req(0, max_tokens=2))
    sched.submit(_req(1, max_tokens=1))
    sched.admit_gated(lambda s: True, lambda r: True)
    sched.preempt(0, payload="x")
    # spilled head infeasible -> strict head-of-line: queue must not jump it
    got = sched.admit_gated(lambda s: False, lambda r: True)
    assert got == [] and sched.pending == 0  # rid 1 already active
    sched.submit(_req(2, max_tokens=1))
    assert sched.admit_gated(lambda s: False, lambda r: True) == []
    sched.check_invariants()


def test_rescale_requires_drained_pool():
    sched = SlotScheduler(2)
    sched.submit(_req(0))
    sched.admit_gated(lambda s: True, lambda r: True)
    with pytest.raises(AssertionError):
        sched.rescale(4)
    sched.preempt(0, payload=None)
    sched.rescale(4)
    assert sched.n_slots == 4 and sched.free_slots == 4
    done, _ = _drain(sched)
    assert done == [0]


# -- engine fixtures: one cached engine per (mode, backend) ------------------


_ENGINES: dict = {}


def _build_engine(mode, backend, *, n_slots=2, greedy=True, shed=None,
                  spec_hash="feedbeefcafe0123"):
    import jax

    from repro.configs import get_arch
    from repro.launch.serve import serving_config
    from repro.models.lm import lm_init
    from repro.optim.optimizers import OptimizerConfig
    from repro.runtime.train import StepConfig
    from repro.serving.engine import ServingEngine
    from repro.serving.paging.engine import PagedServingEngine

    view = get_arch(ARCH).view(reduced=True)
    step_cfg = StepConfig(spring=serving_config(mode),
                          optimizer=OptimizerConfig())
    params = lm_init(jax.random.PRNGKey(0), view.config)
    kw = dict(params=params, n_slots=n_slots, max_len=MAX_LEN,
              greedy=greedy, spec_hash=spec_hash, shed=shed)
    if backend == "paged":
        return PagedServingEngine(view, step_cfg, page_tokens=8, **kw)
    return ServingEngine(view, step_cfg, **kw)


def _prompts(vocab):
    import jax

    key = jax.random.PRNGKey(3)
    return [[int(t) for t in jax.random.randint(
        jax.random.fold_in(key, i), (PROMPT + i,), 0, vocab)]
        for i in range(N_PROMPTS)]


def get_engine(mode, backend, greedy=True):
    """Cached (engine, post-submit snapshot, oracle tokens): restoring
    the snapshot rewinds the engine to the pristine just-submitted state,
    so every test/example replays the same workload without recompiling.
    The oracle is the uninterrupted run's per-request token lists."""
    key = (mode, backend, greedy)
    if key not in _ENGINES:
        eng = _build_engine(mode, backend, greedy=greedy)
        for i, p in enumerate(_prompts(eng.cfg.vocab)):
            eng.submit_prompt(p, GEN, seed=100 + i)
        snap0 = eng.snapshot()
        out = eng.run()
        oracle = [r["tokens"] for r in out["per_request"]]
        assert all(len(t) == GEN for t in oracle)
        _ENGINES[key] = (eng, snap0, oracle)
    return _ENGINES[key]


def _tokens(out):
    return [r["tokens"] for r in sorted(out["per_request"],
                                        key=lambda r: r["rid"])]


# -- snapshot round-trip: all modes x both backends --------------------------


@pytest.mark.parametrize("backend", ["monolithic", "paged"])
@pytest.mark.parametrize("mode", ["dense", "quant", "quant_sparse"])
def test_snapshot_roundtrip_bit_exact(mode, backend, tmp_path):
    """Mid-run snapshot -> .npz -> load: every packed pool array is
    byte-identical, and the restored engine finishes with the oracle's
    exact tokens."""
    eng, snap0, oracle = get_engine(mode, backend)
    eng.restore(snap0)
    for _ in range(3):
        eng.step()
    snap = eng.snapshot()
    path = str(tmp_path / "snap.npz")
    save_snapshot(snap, path)
    loaded = load_snapshot(path)
    bits_key = "pool" if backend == "monolithic" else "store"
    assert len(snap["backend"][bits_key]) == len(loaded["backend"][bits_key])
    for a, b in zip(snap["backend"][bits_key], loaded["backend"][bits_key]):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()
    assert loaded["spec_hash"] == eng.spec_hash
    assert loaded["kind"] == eng.backend_kind
    eng.restore(loaded)
    assert _tokens(eng.run()) == oracle


def test_restore_into_fresh_engine_exact_remaining_tokens():
    """True process death: a cold engine restores a mid-run snapshot and
    emits the exact remaining tokens of every in-flight request."""
    eng, snap0, oracle = get_engine("dense", "monolithic")
    eng.restore(snap0)
    for _ in range(4):
        eng.step()
    snap = eng.snapshot()
    fresh = _build_engine("dense", "monolithic")
    fresh.restore(snap)
    assert fresh.tick == eng.tick and fresh.decode_steps == eng.decode_steps
    assert _tokens(fresh.run()) == oracle


def test_sampled_decode_keys_survive_restore():
    """Per-request sampling keys (seed + draw index) are part of the
    snapshot: a sampled (non-greedy) run restored mid-flight emits the
    same tokens as the uninterrupted sampled run."""
    eng, snap0, oracle = get_engine("dense", "monolithic", greedy=False)
    eng.restore(snap0)
    for _ in range(3):
        eng.step()
    eng.restore(eng.snapshot())
    assert _tokens(eng.run()) == oracle


# -- restore rejection: wrong hash / kind / version --------------------------


def test_restore_under_wrong_spec_hash_rejected():
    eng, snap0, _ = get_engine("dense", "monolithic")
    bad = dict(snap0)
    bad["spec_hash"] = "0" * 16
    with pytest.raises(SnapshotError, match="spec_hash"):
        eng.restore(bad)
    # None on either side means "unstamped": restore is allowed
    unstamped = dict(snap0)
    unstamped["spec_hash"] = None
    eng.restore(unstamped)
    assert _tokens(eng.run()) == get_engine("dense", "monolithic")[2]


def test_restore_wrong_backend_kind_and_version_rejected():
    eng, snap0, _ = get_engine("dense", "monolithic")
    wrong_kind = dict(snap0)
    wrong_kind["kind"] = "paged"
    with pytest.raises(SnapshotError, match="pool"):
        eng.restore(wrong_kind)
    wrong_ver = dict(snap0)
    wrong_ver["version"] = 999
    with pytest.raises(SnapshotError, match="version"):
        eng.restore(wrong_ver)
    with pytest.raises(SnapshotError, match="version"):
        eng.restore({"not": "a snapshot"})


def test_restore_structural_mismatch_rejected():
    eng, snap0, _ = get_engine("dense", "monolithic")
    bad = dict(snap0)
    bad["signature"] = dict(snap0["signature"], max_len=MAX_LEN * 2)
    with pytest.raises(SnapshotError, match="max_len"):
        eng.restore(bad)


def test_state_hash_invariant_across_mesh_topology():
    """Snapshots are stamped with state_hash (engine restore gates on it):
    spring-mesh topology must not poison it, or a snapshot taken on one
    device count could never restore onto another — while anything that
    changes the numerical state must still flip it."""
    from repro.api.spec import build_spec

    base = build_spec("serve", use_env=False)
    resized = build_spec("serve", use_env=False,
                         sets=["shape.mesh.data=4", "shape.mesh.pod=2"])
    assert base.spec_hash() != resized.spec_hash()
    assert base.state_hash() == resized.state_hash()
    numerics = build_spec("serve", use_env=False, sets=["numerics.mode=quant"])
    assert numerics.state_hash() != base.state_hash()


# -- live rescaling ----------------------------------------------------------


@pytest.mark.parametrize("backend", ["monolithic", "paged"])
def test_rescale_grow_and_shrink_keeps_every_request(backend):
    """Shrink below occupancy (spill path), then grow back: nothing is
    dropped and every token matches the oracle."""
    eng, snap0, oracle = get_engine("quant_sparse", backend)
    eng.restore(snap0)
    for _ in range(2):
        eng.step()
    eng.rescale(1)  # below occupancy: actives spill
    assert eng.sched.n_spills >= 1
    for _ in range(2):
        eng.step()
    eng.rescale(3)
    out = eng.run()
    assert _tokens(out) == oracle
    assert out["elastic"]["n_rescales"] == 2
    assert out["elastic"]["n_resumes"] == out["elastic"]["n_spills"]


def test_paged_rescale_infeasible_page_budget_rejected():
    eng, snap0, oracle = get_engine("quant_sparse", "paged")
    eng.restore(snap0)
    eng.step()
    with pytest.raises(ValueError, match="pages"):
        eng.rescale(num_pages=1)
    # rejected before any mutation: the run still completes exactly
    assert _tokens(eng.run()) == oracle


# -- chaos: arbitrary failure schedules vs the static oracle ------------------


def _draw_events(data, *, paged):
    events = []
    for _ in range(data.draw(st.integers(0, 4), label="n_events")):
        at = data.draw(st.integers(0, 12), label="at")
        kind = data.draw(st.sampled_from(ChaosEvent.KINDS), label="kind")
        if kind == "rescale":
            slots = data.draw(st.integers(1, 4), label="slots")
            pages = (data.draw(st.sampled_from([None, 8, 12, 16]),
                               label="pages") if paged else None)
            events.append(ChaosEvent(at, kind, slots=slots, num_pages=pages))
        else:
            events.append(ChaosEvent(at, kind))
    return events


@pytest.mark.parametrize("backend", ["monolithic", "paged"])
@pytest.mark.parametrize("mode", ["dense", "quant", "quant_sparse"])
def test_chaos_fixed_schedule_every_mode(mode, backend):
    """The acceptance matrix: one kill/rewind/roundtrip/rescale schedule
    on every (numerics mode x pool backend), bit-identical to the
    uninterrupted oracle."""
    eng, snap0, oracle = get_engine(mode, backend)
    eng.restore(snap0)
    events = [ChaosEvent(1, "snapshot"), ChaosEvent(2, "kill"),
              ChaosEvent(3, "rewind"), ChaosEvent(4, "roundtrip"),
              ChaosEvent(5, "rescale", slots=3)]
    out = ChaosHarness(eng, events, max_steps=500).run()
    assert _tokens(out) == oracle
    assert out["finite"]


@given(st.data())
def test_chaos_monolithic_matches_oracle(data):
    eng, snap0, oracle = get_engine("quant_sparse", "monolithic")
    eng.restore(snap0)
    harness = ChaosHarness(eng, _draw_events(data, paged=False),
                           max_steps=500)
    out = harness.run()
    assert _tokens(out) == oracle
    assert out["finite"]


@given(st.data())
def test_chaos_paged_matches_oracle(data):
    eng, snap0, oracle = get_engine("quant_sparse", "paged")
    eng.restore(snap0)
    harness = ChaosHarness(eng, _draw_events(data, paged=True),
                           max_steps=500)
    out = harness.run()
    assert _tokens(out) == oracle
    assert out["finite"]


# -- engine-level shedding + periodic snapshots ------------------------------


def test_engine_typed_rejections_no_silent_loss():
    """An overloaded engine completes or typed-rejects every request —
    and the rejection reason lands in the per-request results."""
    eng = _build_engine("dense", "monolithic", n_slots=1,
                        shed=ShedPolicy(max_queue_depth=1))
    for i, p in enumerate(_prompts(eng.cfg.vocab)):
        eng.submit_prompt(p, GEN, seed=100 + i)
    out = eng.run()
    rows = {r["rid"]: r for r in out["per_request"]}
    assert len(rows) == N_PROMPTS
    completed = [r for r in rows.values() if r["status"] == "completed"]
    rejected = [r for r in rows.values() if r["status"] == "rejected"]
    assert len(completed) + len(rejected) == N_PROMPTS
    assert rejected and all(r["rejected"] == REJECT_QUEUE_FULL
                            and r["finished_by"] == "rejected"
                            and r["tokens"] == [] for r in rejected)
    assert out["elastic"]["rejected"] == {
        REJECT_QUEUE_FULL: len(rejected)}
    # completed requests are unaffected by the shedding around them
    oracle = get_engine("dense", "monolithic")[2]
    for r in completed:
        assert r["tokens"] == oracle[r["rid"]]


def test_periodic_snapshots_and_restore_file(tmp_path):
    eng, snap0, oracle = get_engine("dense", "monolithic")
    eng.restore(snap0)
    path = str(tmp_path / "auto.npz")
    eng.snapshot_every, eng.snapshot_path = 2, path
    ticks_before = len(eng.watchdog.events)
    try:
        out = eng.run()
    finally:
        eng.snapshot_every, eng.snapshot_path = 0, ""
    assert _tokens(out) == oracle
    assert out["elastic"]["n_snapshots"] >= 1
    # the watchdog observed every tick of the run
    assert len(eng.watchdog.events) - ticks_before == out["latency"]["ticks"]
    # the on-disk artifact restores (here: some suffix of the run)
    eng.restore_file(path)
    assert _tokens(eng.run()) == oracle
