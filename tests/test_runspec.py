"""RunSpec seal (ISSUE 5): round-trip bit-exactness across every
registered arch x run mode, layered resolution with provenance,
unknown-field rejection with did-you-mean, spec emission in session
artifacts, and resolver parity with the legacy launcher surfaces."""

import dataclasses
import json

import pytest

from repro.api.spec import (
    ENV_FIELDS,
    RunSpec,
    SpecError,
    build_spec,
    field_paths,
)
from repro.configs import ARCHS

pytestmark = pytest.mark.spec

ALL_ARCHS = sorted(ARCHS)
RUNS = ("train", "serve", "dryrun")


# -- round-trip seal ----------------------------------------------------------


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
@pytest.mark.parametrize("run", RUNS)
def test_roundtrip_bit_identical_per_arch_and_mode(arch_id, run):
    """RunSpec -> to_json -> from_json -> resolve() is bit-identical for
    every registered arch x {train, serve, dryrun}."""
    spec = build_spec(run, use_env=False,
                      overrides=[("arch.id", arch_id, "test")])
    text = spec.to_json()
    again = RunSpec.from_json(text)
    assert again == spec
    assert again.to_json() == text
    assert again.spec_hash() == spec.spec_hash()
    r1, r2 = spec.resolve(), again.resolve()
    # the resolved objects the step builders consume must be identical
    assert r1.step == r2.step
    assert r1.spring == r2.spring
    assert r1.config == r2.config
    assert r1.view == r2.view
    assert r1.memstash == r2.memstash
    assert r1.memstash_policy == r2.memstash_policy


def test_roundtrip_preserves_non_defaults():
    spec = build_spec("serve", use_env=False, sets=[
        "serving.slots=2", "serving.queue=7", "numerics.mode=quant_sparse",
        "kernels.policy=ref,ssd_scan=jnp", "shape.microbatch=none",
        "sparsity.probe_density=0.25", "seeds.seed=11",
    ])
    again = RunSpec.from_json(spec.to_json())
    assert again.serving.slots == 2 and again.serving.queue == 7
    assert again.kernels.policy == "ref,ssd_scan=jnp"
    assert again.shape.microbatch is None
    assert again == spec


def test_canonical_json_is_sorted_and_stable():
    spec = build_spec("train", use_env=False)
    d = json.loads(spec.to_json())
    assert list(d) == sorted(d)
    # hash is a pure function of the canonical form
    assert spec.spec_hash() == RunSpec.from_dict(d).spec_hash()


# -- unknown fields / invalid values -----------------------------------------


def test_unknown_field_rejected_with_suggestion():
    with pytest.raises(SpecError, match="numerics.mode"):
        RunSpec.from_dict({"numerics": {"mod": "quant"}})
    with pytest.raises(SpecError, match="did you mean"):
        RunSpec.from_dict({"numeric": {"mode": "quant"}})
    with pytest.raises(SpecError, match="did you mean"):
        build_spec(sets=["serving.slotss=2"], use_env=False)


def test_invalid_choice_rejected_with_suggestion():
    with pytest.raises(SpecError, match="quant_sparse"):
        build_spec(sets=["numerics.mode=quant_spars"], use_env=False)
    with pytest.raises(SpecError, match="memstash"):
        build_spec(sets=["memstash.policy=stashh"], use_env=False)
    with pytest.raises(SpecError, match="kernels.policy"):
        build_spec(sets=["kernels.policy=ssd_scanx=jnp"], use_env=False)
    with pytest.raises(SpecError, match="block_io"):
        build_spec(sets=["arch.remat_policy=blockio"], use_env=False)


def test_type_errors_are_spec_errors():
    with pytest.raises(SpecError, match="integer"):
        RunSpec.from_dict({"shape": {"batch": "eight"}})
    with pytest.raises(SpecError, match="boolean"):
        build_spec(sets=["arch.reduced=maybe"], use_env=False)


# -- layered resolution + provenance -----------------------------------------


def test_layer_precedence_file_env_cli(tmp_path):
    p = tmp_path / "s.json"
    p.write_text(json.dumps({
        "numerics": {"mode": "quant"}, "shape": {"batch": 4},
        "train": {"steps": 9}}))
    env = {"SPRING_MODE": "quant_sparse", "SPRING_SEED": "5"}
    spec = build_spec("train", spec_file=str(p), environ=env,
                      sets=["numerics.mode=dense"])
    # CLI --set > env > file; untouched fields keep file/default values
    assert spec.numerics.mode == "dense"
    assert spec.seeds.seed == 5
    assert spec.shape.batch == 4 and spec.train.steps == 9
    prov = spec.provenance
    assert prov["numerics.mode"].startswith("set:")
    assert prov["seeds.seed"] == "env:SPRING_SEED"
    assert prov["shape.batch"].startswith("file:")
    assert prov["train.ckpt_dir"] == "default"


def test_env_fields_cover_documented_vars():
    for var, path in ENV_FIELDS.items():
        assert var.startswith("SPRING_")
        assert path in field_paths()
    spec = build_spec(environ={"SPRING_SET": "shape.seq=999"})
    assert spec.shape.seq == 999


def test_provenance_excluded_from_equality_and_serialization():
    a = build_spec("train", use_env=False)
    b = build_spec("train", use_env=False, environ={})
    object.__setattr__(b, "provenance", {"run": "somewhere-else"})
    assert a == b
    assert "provenance" not in a.to_dict()


# -- resolver parity with the legacy surfaces --------------------------------


def test_resolver_matches_legacy_train_stepconfig():
    """train_spec(...) resolves to the StepConfig train_loop used to
    build by hand (ISSUE 5 tentpole: one resolution path)."""
    from repro.api.sessions import train_spec
    from repro.core.fixedpoint import SPRING_FORMAT
    from repro.core.spring_ops import MODES
    from repro.kernels.registry import KernelPolicy
    from repro.memstash.config import MemstashConfig
    from repro.optim.optimizers import OptimizerConfig

    spec = train_spec("llama3.2-1b", mode="quant", lr=1e-2,
                      fixed_point_weights=True, kernel_impl="ref",
                      backward_sparsity="jnp", stash="stash")
    r = spec.resolve()
    assert r.spring == dataclasses.replace(
        MODES["quant"], kernels=KernelPolicy.parse("ref"))
    assert r.step.backward_sparsity == "jnp"
    assert r.step.memstash == MemstashConfig(policy="stash")
    assert r.step.optimizer == OptimizerConfig(
        kind="adamw", lr=1e-2, warmup_steps=10, weight_format=SPRING_FORMAT)
    # explicit stash re-routes the LM residual checkpoints
    assert r.config.remat_policy == "stash"


def test_resolver_matches_legacy_serving_config():
    from repro.api.sessions import serve_spec
    from repro.core.spring_ops import MODES

    for mode in ("dense", "quant", "quant_sparse"):
        r = serve_spec("llama3.2-1b", mode=mode).resolve()
        assert r.spring == dataclasses.replace(
            MODES[mode], stochastic=False)
        assert r.step.optimizer.warmup_steps == 0  # serving OptimizerConfig()


def test_resolver_dryrun_microbatch_defaults():
    from repro.api.sessions import dryrun_spec
    from repro.api.spec import DEFAULT_TRAIN_MICROBATCH, TRAIN_MICROBATCH_OVERRIDES

    assert dryrun_spec("qwen2-7b", "train_4k").resolve().step.microbatch \
        == DEFAULT_TRAIN_MICROBATCH
    assert dryrun_spec("olmoe-1b-7b", "train_4k").resolve().step.microbatch \
        == TRAIN_MICROBATCH_OVERRIDES["olmoe-1b-7b"]
    assert dryrun_spec("qwen2-7b", "decode_32k").resolve().step.microbatch is None
    assert dryrun_spec("qwen2-7b", "train_4k",
                       microbatch=4).resolve().step.microbatch == 4


def test_resolver_dryrun_optimizer_parity_with_legacy_run_cell():
    """Dryrun lowers the optimizer *kind* only (legacy run_cell built
    OptimizerConfig(kind="adamw")): lr/warmup must not leak into the
    lowered program, preserving bit-parity with pre-RunSpec artifacts."""
    from repro.api.sessions import dryrun_spec
    from repro.optim.optimizers import OptimizerConfig

    r = dryrun_spec("qwen2-7b", "train_4k").resolve()
    assert r.step.optimizer == OptimizerConfig(kind="adamw")
    assert r.step.optimizer.warmup_steps == 0


def test_spring_set_env_supports_comma_bearing_values():
    """SPRING_SET entries are ';'-separated so the documented multi-op
    KernelPolicy grammar survives the env layer."""
    spec = build_spec(environ={
        "SPRING_SET": "kernels.policy=ref,ssd_scan=jnp;shape.batch=16"})
    assert spec.kernels.policy == "ref,ssd_scan=jnp"
    assert spec.shape.batch == 16
    assert spec.resolve().kernel_policy.describe() == "ref,ssd_scan=jnp"


def test_resolver_dryrun_layout_rules():
    from repro.api.sessions import dryrun_spec

    base = dryrun_spec("qwen2-7b", "train_4k")
    assert base.resolve().step.rules_override == ()
    fsdp = dryrun_spec("qwen2-7b", "train_4k", layout="fsdp",
                       seq_parallel=True)
    rules = dict(fsdp.resolve().step.rules_override)
    assert "seq" in rules and "batch" in rules and "w_qkv" in rules


def test_arch_reduced_null_is_run_conditional_in_resolver():
    """arch.reduced=null: train/serve resolve the reduced smoke config,
    dryrun the published full config — identically for CLI and API
    callers (no launcher-only correction)."""
    from repro.configs import get_arch

    arch = get_arch("llama3.2-1b")
    train = build_spec("train", use_env=False)
    assert train.arch.reduced is None
    assert train.resolve().config == arch.reduced()
    dry = build_spec("dryrun", use_env=False)
    assert dry.resolve().config == arch.config
    # explicit values still win in both directions
    assert build_spec("dryrun", use_env=False,
                      sets=["arch.reduced=true"]).resolve().config \
        == arch.reduced()
    assert build_spec("train", use_env=False,
                      sets=["arch.reduced=false"]).resolve().config \
        == arch.config


def test_stochastic_auto_rule():
    """auto: SR on for train/dryrun (the paper's convergence device),
    nearest for serve (batch invariance); on/off force it."""
    from repro.api.sessions import serve_spec, train_spec

    assert train_spec("llama3.2-1b", mode="quant").resolve().spring.stochastic
    assert not serve_spec("llama3.2-1b", mode="quant").resolve().spring.stochastic
    off = build_spec("train", use_env=False,
                     sets=["numerics.mode=quant", "numerics.stochastic=off"])
    assert off.resolve().spring.stochastic is False
    on = build_spec("serve", use_env=False,
                    sets=["numerics.mode=quant", "numerics.stochastic=on"])
    assert on.resolve().spring.stochastic is True


# -- session artifacts embed the spec ----------------------------------------


def test_sessions_embed_canonical_spec():
    from repro.api.sessions import ServeSession, TrainSession, serve_spec, train_spec

    tspec = train_spec("llama3.2-1b", steps=1, batch=2, seq=16)
    tout = TrainSession(tspec).run()
    assert tout["spec_hash"] == tspec.spec_hash()
    assert tout["spec"] == tspec.to_dict()
    assert tout["provenance"]["train.steps"] == "call:train.steps"

    sspec = serve_spec("llama3.2-1b", batch=2, prompt_len=4, gen=2)
    sout = ServeSession(sspec).run()
    assert sout["spec_hash"] == sspec.spec_hash()
    assert sout["spec"]["run"] == "serve"
    # the artifact alone reproduces the run: rebuild the spec from it
    again = RunSpec.from_dict(sout["spec"])
    assert again == sspec


def test_stepconfig_from_runspec_accepts_spec_dict_and_json():
    from repro.runtime.train import StepConfig

    spec = build_spec("train", use_env=False,
                      sets=["numerics.mode=quant_sparse",
                            "sparsity.backward=jnp"])
    want = spec.resolve().step
    assert StepConfig.from_runspec(spec) == want
    assert StepConfig.from_runspec(spec.to_dict()) == want
    assert StepConfig.from_runspec(spec.to_json()) == want
    # a run artifact embedding its spec (what every session/launcher
    # emits) reproduces the same StepConfig
    artifact = dict(spec.payload(), result={"loss": 1.0})
    assert StepConfig.from_runspec(artifact) == want
    assert StepConfig.from_runspec(json.dumps(artifact)) == want


def test_session_rejects_wrong_run_mode():
    from repro.api.sessions import TrainSession, serve_spec

    with pytest.raises(SpecError, match="run='train'"):
        TrainSession(serve_spec("llama3.2-1b"))


def test_example_specs_validate_and_resolve():
    """Every checked-in example spec must stay loadable + resolvable
    (the CI spec job also runs repro.api.validate over them)."""
    import pathlib

    spec_dir = pathlib.Path(__file__).parent.parent / "examples" / "specs"
    paths = sorted(spec_dir.glob("*.json"))
    assert paths, "examples/specs/ must contain at least one worked example"
    for p in paths:
        spec = RunSpec.from_file(str(p))
        spec.resolve()
        assert RunSpec.from_json(spec.to_json()) == spec
