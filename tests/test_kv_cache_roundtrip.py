"""KV-cache compression roundtrips (ISSUE 4, satellite 3).

``kv_pack``/``kv_unpack`` are bit-exact against the element-serial numpy
oracle, their wire accounting matches the paper's ``20*density + 1``
bits/elem formula exactly at word alignment, and the serving slot pool
(kvpool) round-trips a real model cache bit-exactly — including install /
merge / release slot surgery.

The registry parity harness (tests/test_kernel_registry.py) additionally
cross-checks every registered (op, impl) pair on the registered examples;
completeness enforcement covers the kv_cache package like any other.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry
from repro.kernels.kv_cache.ops import (
    KV_VALUE_BITS,
    kv_pack,
    kv_unpack,
    kv_wire_bits,
)
from repro.kernels.kv_cache.ref import (
    kv_pack_reference,
    kv_unpack_reference,
    kv_wire_bits_reference,
)
from repro.memstash.format import formula_bits_per_elem

pytestmark = pytest.mark.serving


def _block(seed, n, density, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,))
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < density
    return (x * keep).astype(dtype)


@pytest.mark.parametrize("n,density,dtype", [
    (1024, 0.0, jnp.float32),
    (1024, 0.5, jnp.float32),
    (4096, 0.37, jnp.bfloat16),
    (1000, 0.8, jnp.bfloat16),   # unaligned length
    (33, 1.0, jnp.float32),
])
def test_pack_matches_serial_oracle_and_roundtrips(n, density, dtype):
    x = _block(n, n, density, dtype)
    packed = kv_pack(x)
    vr, wr, nr = kv_pack_reference(np.asarray(x))
    np.testing.assert_array_equal(np.asarray(packed["values"]).view(np.uint16)
                                  if dtype == jnp.bfloat16 else np.asarray(packed["values"]),
                                  vr.view(np.uint16) if dtype == jnp.bfloat16 else vr)
    np.testing.assert_array_equal(np.asarray(packed["mask"]), wr)
    assert int(packed["nnz"]) == nr
    dec = kv_unpack(packed["values"], packed["mask"], n)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(x))
    ser = kv_unpack_reference(vr, wr, n)
    np.testing.assert_array_equal(np.asarray(dec), ser)


def test_every_cpu_impl_roundtrips_bit_exactly():
    x = _block(7, 2048, 0.45, jnp.bfloat16)
    want = np.asarray(x)
    for pack_impl in ("ref", "jnp", "interpret"):
        packed = kv_pack(x, impl=pack_impl)
        for unpack_impl in ("ref", "jnp", "interpret"):
            dec = kv_unpack(packed["values"], packed["mask"], x.size,
                            impl=unpack_impl)
            np.testing.assert_array_equal(np.asarray(dec), want)


def test_negative_zero_canonicalizes_without_changing_math():
    x = jnp.asarray([1.0, -0.0, 0.0, -2.5], jnp.float32)
    packed = kv_pack(x)
    assert int(packed["nnz"]) == 2  # -0.0 is not occupancy
    dec = np.asarray(kv_unpack(packed["values"], packed["mask"], 4))
    np.testing.assert_array_equal(dec, [1.0, 0.0, 0.0, -2.5])


def test_wire_bits_match_formula_at_word_alignment():
    """kv_wire_bits == n * (20*density + 1) exactly when 32 | n — the
    single-sourced perfmodel/memstash traffic formula."""
    for n, density in [(32, 0.5), (1024, 0.25), (4096, 1.0), (2048, 0.0)]:
        x = _block(n, n, density)
        packed = kv_pack(x)
        nnz = int(packed["nnz"])
        measured = float(kv_wire_bits(nnz, n))
        formula = n * formula_bits_per_elem(nnz / n, KV_VALUE_BITS)
        assert measured == formula, (n, density, measured, formula)
        assert measured == kv_wire_bits_reference(nnz, n)
    # off alignment the measured mask words are whole uint32s (>= formula)
    x = _block(5, 1000, 0.5)
    packed = kv_pack(x)
    nnz = int(packed["nnz"])
    assert float(kv_wire_bits(nnz, 1000)) == nnz * KV_VALUE_BITS + 32 * 32


def test_perfmodel_helpers_consume_eager_kv_metrics():
    """measured_kv_density / measured_kv_wire_bytes ground spring_eval's
    decode-phase traffic term from eager kv_pack rows (kv_probe-style)."""
    from repro.kernels.kv_cache.ops import kv_probe
    from repro.perfmodel.spring_model import (
        measured_kv_density,
        measured_kv_wire_bytes,
    )

    with registry.record_kernel_metrics() as rows:
        probe = kv_probe(0.4, size=1 << 12)
        kv_probe(0.4, size=1 << 12)
    d = measured_kv_density(rows)
    w = measured_kv_wire_bytes(rows)
    assert d is not None and abs(d - probe["density"]) < 1e-9
    assert w == 2 * probe["wire_bytes"]  # traffic sums, density averages
    assert measured_kv_density([]) is None
    assert measured_kv_wire_bytes([]) is None


def test_wire_metrics_hook_records_density_and_bytes():
    x = _block(11, 4096, 0.5)
    with registry.record_kernel_metrics() as rows:
        packed = kv_pack(x)
    summary = registry.metric_summary(rows)["kv_pack"]
    nnz = int(packed["nnz"])
    assert summary["wire_bytes"] == float(kv_wire_bits(nnz, 4096)) / 8.0
    assert summary["density"] == nnz / 4096
    # inert under jit tracing (no host sync in compiled programs)
    with registry.record_kernel_metrics() as rows2:
        jax.jit(kv_pack)(x)
    assert not [r for r in rows2 if r["op"] == "kv_pack"]


# -- the serving slot pool on a real model cache ------------------------------


def _pool_fixture():
    from repro.configs import get_arch
    from repro.models.lm import lm_init, lm_init_cache

    cfg = get_arch("llama3.2-1b").reduced()
    cache = lm_init_cache(cfg, 2, 24)
    # fill with recognizable non-trivial values: first 9 positions live
    def fill(path, leaf):
        if leaf.ndim < 2:
            return leaf
        live = jnp.arange(leaf.shape[-3 if leaf.ndim >= 4 else -2]) < 9
        shape = [1] * leaf.ndim
        shape[-3 if leaf.ndim >= 4 else -2] = live.shape[0]
        vals = jax.random.normal(jax.random.PRNGKey(hash(str(path)) % 2**31),
                                 leaf.shape).astype(leaf.dtype)
        return jnp.where(live.reshape(shape), vals, jnp.zeros((), leaf.dtype))

    cache = jax.tree_util.tree_map_with_path(fill, cache)
    cache["pos"] = jnp.asarray([9, 9], jnp.int32)
    return cfg, cache


def test_kvpool_roundtrip_is_bit_exact_on_model_cache():
    from repro.serving import kvpool

    _, cache = _pool_fixture()
    pool = kvpool.pack_cache(cache)
    back = kvpool.unpack_cache(pool)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        assert str(pa) == str(pb)
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32), err_msg=str(pa))


def test_kvpool_wire_stats_track_occupancy():
    from repro.serving import kvpool

    _, cache = _pool_fixture()
    stats = kvpool.pool_wire_stats(kvpool.pack_cache(cache))
    assert 0.0 < stats["kv_density"] < 0.6  # 9 of 24 positions live
    assert stats["kv_compression_vs_fp32"] > 2.0
    assert stats["kv_wire_bytes"] < stats["kv_dense_fp32_bytes"]


def test_kvpool_release_clears_one_slot_only():
    from repro.serving import kvpool

    _, cache = _pool_fixture()
    pool = kvpool.pack_cache(cache)
    cleared = kvpool.unpack_cache(
        kvpool.pack_cache(
            kvpool.release_slot(kvpool.unpack_cache(pool), jnp.int32(0))))
    for path, leaf in jax.tree_util.tree_flatten_with_path(cleared)[0]:
        name = str(path)
        ax = kvpool.slot_axis(path) if "pos" not in name else 0
        sl = np.asarray(jnp.take(leaf, 0, axis=ax), np.float32)
        keep = np.asarray(jnp.take(leaf, 1, axis=ax), np.float32)
        np.testing.assert_array_equal(sl, np.zeros_like(sl), err_msg=name)
        orig = np.asarray(jnp.take(_lookup_like(cache, path), 1, axis=ax), np.float32)
        np.testing.assert_array_equal(keep, orig, err_msg=name)


def _lookup_like(tree, path):
    node = tree
    for p in path:
        node = node[getattr(p, "key", getattr(p, "idx", None))]
    return node


def test_packed_splice_surgery_matches_dense_path():
    """install_packed / release_packed (the engine's O(slot) splices) are
    bit-identical to packing the dense-path install/release of the whole
    pool — the equivalence that lets the engine skip full-pool repacks."""
    import jax.numpy as jnp

    from repro.serving import kvpool

    cfg, cache = _pool_fixture()
    pool = kvpool.pack_cache(cache)

    # a batch-1 "prefill" cache of length 7 (pool max_len is 24)
    from repro.models.lm import lm_init_cache

    pcache = lm_init_cache(cfg, 1, 7)
    pcache = jax.tree_util.tree_map(
        lambda leaf: jax.random.normal(jax.random.PRNGKey(leaf.size % 97),
                                       leaf.shape).astype(leaf.dtype)
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 else leaf, pcache)

    slot = jnp.int32(1)
    spliced = kvpool.install_packed(pool, pcache, slot, 7)
    via_dense = kvpool.pack_cache(
        kvpool.install_prefill(kvpool.unpack_cache(pool), pcache, slot, 7))
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(spliced)[0],
            jax.tree_util.tree_flatten_with_path(via_dense)[0]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32), err_msg=str(pa))

    rel = kvpool.release_packed(spliced, jnp.int32(0))
    via_dense_rel = kvpool.pack_cache(
        kvpool.release_slot(kvpool.unpack_cache(spliced), jnp.int32(0)))
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(rel)[0],
            jax.tree_util.tree_flatten_with_path(via_dense_rel)[0]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32), err_msg=str(pa))
