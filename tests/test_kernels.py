"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.masked_matmul.ops import masked_matmul, tile_skip_fraction
from repro.kernels.mask_compress.ops import dangling_filter, mask_pack
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.stochastic_round.ops import stochastic_round


# -- stochastic rounding -----------------------------------------------------


@pytest.mark.parametrize("shape", [(7,), (128,), (333, 17), (8, 1024), (3, 5, 9)])
@pytest.mark.parametrize("il,fl", [(4, 16), (2, 6)])
def test_sr_interpret_exact_vs_ref(shape, il, fl):
    x = jax.random.normal(jax.random.PRNGKey(42), shape) * 3
    a = stochastic_round(x, jnp.uint32(9), il=il, fl=fl, impl="interpret")
    b = stochastic_round(x, jnp.uint32(9), il=il, fl=fl, impl="ref")
    assert bool(jnp.all(a == b)), "kernel must be bit-identical to oracle"


def test_sr_seed_changes_stream():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    a = stochastic_round(x, jnp.uint32(1), impl="ref")
    b = stochastic_round(x, jnp.uint32(2), impl="ref")
    assert not bool(jnp.all(a == b))


# -- masked fixed-point matmul ----------------------------------------------


def qgrid(seed, shape, sparsity, fl=8):
    key = jax.random.PRNGKey(seed)
    v = jnp.round(jax.random.normal(key, shape) * 2**6) / 2**fl
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) > sparsity
    return v * keep


@pytest.mark.parametrize("mkn", [(128, 128, 128), (100, 70, 50), (256, 384, 128), (64, 512, 200)])
@pytest.mark.parametrize("apply_sr", [True, False])
def test_masked_matmul_sweep(mkn, apply_sr):
    m, k, n = mkn
    x = qgrid(m * 7 + k, (m, k), 0.5)
    w = qgrid(n * 13 + k, (k, n), 0.5)
    a = masked_matmul(x, w, jnp.uint32(5), apply_sr=apply_sr, impl="interpret")
    b = masked_matmul(x, w, jnp.uint32(5), apply_sr=apply_sr, impl="ref")
    if apply_sr:
        assert bool(jnp.all(a == b))
    else:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_masked_matmul_tile_skip_preserves_results():
    """Block-pruned operands: >0 tiles skipped, results still exact."""
    x = qgrid(0, (256, 512), 0.3).at[:128, :256].set(0.0)
    w = qgrid(1, (512, 256), 0.3).at[256:, 128:].set(0.0)
    skip = float(tile_skip_fraction(x, w))
    assert skip >= 0.45
    a = masked_matmul(x, w, jnp.uint32(3), impl="interpret")
    b = masked_matmul(x, w, jnp.uint32(3), impl="ref")
    assert bool(jnp.all(a == b))


def test_masked_matmul_grad_path():
    """The quant training path wraps this op via STE at a higher level;
    the op itself must be usable inside jit."""
    x = qgrid(3, (64, 64), 0.5)
    w = qgrid(4, (64, 64), 0.5)
    y = jax.jit(lambda a, b: masked_matmul(a, b, impl="ref"))(x, w)
    assert y.shape == (64, 64) and bool(jnp.all(jnp.isfinite(y)))


# -- flash attention ----------------------------------------------------------


@pytest.mark.parametrize("spec", [
    dict(B=2, H=4, HKV=2, S=256, D=64, causal=True, window=None),
    dict(B=1, H=4, HKV=1, S=300, D=64, causal=True, window=None),
    dict(B=2, H=2, HKV=2, S=256, D=64, causal=True, window=128),
    dict(B=1, H=8, HKV=4, S=384, D=128, causal=False, window=None),
])
def test_flash_attention_sweep(spec):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(key, 1), (spec["B"], spec["H"], spec["S"], spec["D"]))
    k = jax.random.normal(jax.random.fold_in(key, 2), (spec["B"], spec["HKV"], spec["S"], spec["D"]))
    v = jax.random.normal(jax.random.fold_in(key, 3), (spec["B"], spec["HKV"], spec["S"], spec["D"]))
    a = flash_attention(q, k, v, causal=spec["causal"], window=spec["window"], impl="interpret")
    b = flash_attention(q, k, v, causal=spec["causal"], window=spec["window"], impl="ref")
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 2, 128, 64), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 64), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 64), dtype)
    a = flash_attention(q, k, v, impl="interpret")
    b = flash_attention(q, k, v, impl="ref")
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) < tol


# -- SSD scan -----------------------------------------------------------------


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
@pytest.mark.parametrize("B,S,H,P,G,N", [(2, 320, 4, 64, 2, 32), (1, 128, 2, 32, 1, 16), (1, 96, 2, 32, 1, 16)])
def test_ssd_scan_sweep(impl, B, S, H, P, G, N):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (H,)) * 0.5)
    b = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) / N**0.5
    c = jax.random.normal(jax.random.fold_in(key, 5), (B, S, G, N)) / N**0.5
    ref = ssd_scan(x, dt, a, b, c, impl="ref")
    got = ssd_scan(x, dt, a, b, c, impl=impl)
    rel = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-4


def test_ssd_return_state_matches_sequential():
    """Prefill -> decode handoff: the returned state must equal the state
    the sequential recurrence reaches after S tokens."""
    B, S, H, P, G, N = 1, 256, 2, 32, 1, 16
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (H,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) / 4
    c = jax.random.normal(jax.random.fold_in(key, 5), (B, S, G, N)) / 4
    _, state = ssd_scan(x, dt, a, b, c, impl="jnp", return_state=True)

    # sequential state
    import numpy as np
    bf = np.repeat(np.asarray(b), H // G, 2)
    st = np.zeros((B, H, N, P), np.float32)
    for t in range(S):
        alpha = np.exp(np.asarray(dt)[:, t] * np.asarray(a))
        st = st * alpha[..., None, None] + np.einsum(
            "bhn,bhp->bhnp", bf[:, t] * np.asarray(dt)[:, t][..., None], np.asarray(x)[:, t])
    np.testing.assert_allclose(np.asarray(state), st, rtol=2e-4, atol=1e-5)


# -- mask compress ------------------------------------------------------------


def test_dangling_filter_kernel():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (5000,)) * (jax.random.uniform(jax.random.fold_in(key, 1), (5000,)) > 0.5)
    w = jax.random.normal(jax.random.fold_in(key, 2), (5000,)) * (jax.random.uniform(jax.random.fold_in(key, 3), (5000,)) > 0.6)
    af1, wf1 = dangling_filter(a, w, impl="interpret")
    af2, wf2 = dangling_filter(a, w, impl="ref")
    assert bool(jnp.all(af1 == af2)) and bool(jnp.all(wf1 == wf2))


def test_mask_pack_roundtrip_any_shape():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (777,)) * (jax.random.uniform(jax.random.fold_in(key, 1), (777,)) > 0.4)
    w1 = mask_pack(x, impl="interpret")
    w2 = mask_pack(x, impl="ref")
    assert bool(jnp.all(w1 == w2))
