"""Kernel behavioral tests (SR stream properties, tile-skip invariance,
grad-path usability, SSD state handoff).

Oracle parity for every registered (op, impl) pair is NOT enumerated here
any more: ``tests/test_kernel_registry.py::test_registry_parity`` generates
it from the kernel registry's per-op example inputs and comparison specs.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.masked_matmul.ops import masked_matmul, tile_skip_fraction
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.stochastic_round.ops import stochastic_round


def test_sr_seed_changes_stream():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    a = stochastic_round(x, jnp.uint32(1), impl="ref")
    b = stochastic_round(x, jnp.uint32(2), impl="ref")
    assert not bool(jnp.all(a == b))


def qgrid(seed, shape, sparsity, fl=8):
    key = jax.random.PRNGKey(seed)
    v = jnp.round(jax.random.normal(key, shape) * 2**6) / 2**fl
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) > sparsity
    return v * keep


def test_masked_matmul_tile_skip_preserves_results():
    """Block-pruned operands: >0 tiles skipped, results still exact."""
    x = qgrid(0, (256, 512), 0.3).at[:128, :256].set(0.0)
    w = qgrid(1, (512, 256), 0.3).at[256:, 128:].set(0.0)
    skip = float(tile_skip_fraction(x, w))
    assert skip >= 0.45
    a = masked_matmul(x, w, jnp.uint32(3), impl="interpret")
    b = masked_matmul(x, w, jnp.uint32(3), impl="ref")
    assert bool(jnp.all(a == b))


def test_masked_matmul_grad_path():
    """The quant training path wraps this op via STE at a higher level;
    the op itself must be usable inside jit."""
    x = qgrid(3, (64, 64), 0.5)
    w = qgrid(4, (64, 64), 0.5)
    y = jax.jit(lambda a, b: masked_matmul(a, b, impl="ref"))(x, w)
    assert y.shape == (64, 64) and bool(jnp.all(jnp.isfinite(y)))


def test_ssd_return_state_matches_sequential():
    """Prefill -> decode handoff: the returned state must equal the state
    the sequential recurrence reaches after S tokens."""
    B, S, H, P, G, N = 1, 256, 2, 32, 1, 16
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (H,)) * 0.3)
    b = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) / 4
    c = jax.random.normal(jax.random.fold_in(key, 5), (B, S, G, N)) / 4
    _, state = ssd_scan(x, dt, a, b, c, impl="jnp", return_state=True)

    # sequential state
    bf = np.repeat(np.asarray(b), H // G, 2)
    st = np.zeros((B, H, N, P), np.float32)
    for t in range(S):
        alpha = np.exp(np.asarray(dt)[:, t] * np.asarray(a))
        st = st * alpha[..., None, None] + np.einsum(
            "bhn,bhp->bhnp", bf[:, t] * np.asarray(dt)[:, t][..., None], np.asarray(x)[:, t])
    np.testing.assert_allclose(np.asarray(state), st, rtol=2e-4, atol=1e-5)
