"""Deterministic stand-in for ``hypothesis``, used when the real package is
not installed (the CPU test container ships without it).

Only the surface this suite uses is provided: ``given`` (positional and
keyword forms), ``settings`` (profile registration + decorator no-op),
``HealthCheck``, and the strategies ``integers`` / ``floats`` /
``lists`` / ``tuples`` / ``sampled_from`` / ``data`` / ``booleans`` /
``just`` / ``one_of``.
``@given`` tests run a fixed number of pseudo-random examples drawn from a
per-test seeded RNG, so failures reproduce exactly across runs.  With the
real hypothesis installed this module is never imported (see conftest.py).
"""

from __future__ import annotations

import math
import random
import sys
import types

_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


#: Smallest magnitude the log-uniform float draw reaches down to.
_TINY = 1e-12


def floats(min_value: float, max_value: float, allow_nan: bool = False,
           allow_infinity: bool = False) -> _Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite

    def draw(rng: random.Random) -> float:
        if min_value == max_value:
            return min_value
        # Mostly log-uniform in magnitude: a plain uniform draw over a
        # wide range essentially never yields small magnitudes (over
        # [1e-3, 1e6] the sub-1.0 regime — real latencies in seconds —
        # has probability ~1e-6 per draw), so log spacing covers every
        # decade.  A uniform slice is kept for boundary/large coverage.
        if rng.random() < 0.25:
            return rng.uniform(min_value, max_value)
        hi = max(abs(min_value), abs(max_value))
        if hi <= 0.0:
            return 0.0
        lo = (min(abs(min_value), abs(max_value))
              if (min_value > 0.0 or max_value < 0.0) else _TINY)
        lo = max(lo, _TINY)
        mag = math.exp(rng.uniform(math.log(lo), math.log(hi)))
        if min_value >= 0.0:
            x = mag
        elif max_value <= 0.0:
            x = -mag
        else:
            x = mag if rng.random() < 0.5 else -mag
        return min(max(x, min_value), max_value)

    return _Strategy(draw)


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(lambda rng: rng.choice(opts))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def one_of(*strategies) -> _Strategy:
    opts = list(strategies[0]) if (len(strategies) == 1
                                   and isinstance(strategies[0],
                                                  (list, tuple))) else list(
        strategies)
    return _Strategy(lambda rng: rng.choice(opts).example(rng))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]

    return _Strategy(draw)


def tuples(*strategies) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


class _DataObject:
    """Interactive draw handle, the ``st.data()`` protocol."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str | None = None):
        del label  # reporting sugar only
        return strategy.example(self._rng)


def data() -> _Strategy:
    return _Strategy(lambda rng: _DataObject(rng))


def given(*strategies, **kw_strategies):
    def decorate(fn):
        # Zero-argument wrapper: pytest must not mistake the strategy
        # parameters for fixtures, so the original signature is hidden
        # (and no __wrapped__ is set, which pytest would follow).
        def runner():
            rng = random.Random(f"spring:{fn.__module__}.{fn.__name__}")
            for _ in range(_MAX_EXAMPLES):
                fn(*(s.example(rng) for s in strategies),
                   **{k: s.example(rng) for k, s in
                      sorted(kw_strategies.items())})

        runner.__name__ = fn.__name__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        runner.hypothesis_fallback = True
        return runner

    return decorate


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


class settings:
    _profiles: dict = {}

    def __init__(self, **kwargs):
        self._kwargs = kwargs

    def __call__(self, fn):  # @settings(...) decorator form
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs) -> None:
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str) -> None:
        global _MAX_EXAMPLES
        _MAX_EXAMPLES = int(cls._profiles.get(name, {}).get(
            "max_examples", _MAX_EXAMPLES))


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "sampled_from",
                 "data", "booleans", "just", "one_of"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
