"""Property tests for the Q(IL,FL) fixed-point + stochastic rounding core."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.fixedpoint import (
    SPRING_FORMAT,
    FixedPointFormat,
    from_int,
    quantize_nearest,
    quantize_stochastic,
    ste_quantize_nearest,
    ste_quantize_stochastic,
    to_int,
)

FMT_STRAT = st.sampled_from([FixedPointFormat(4, 16), FixedPointFormat(2, 6), FixedPointFormat(4, 8)])


@given(FMT_STRAT, st.lists(st.floats(-20, 20, allow_nan=False), min_size=1, max_size=64))
def test_nearest_on_grid_and_within_half_eps(fmt, vals):
    x = jnp.asarray(vals, jnp.float32)
    q = quantize_nearest(x, fmt)
    # on grid: q * 2^fl is integral
    scaled = np.asarray(q, np.float64) * 2.0**fmt.fl
    assert np.allclose(scaled, np.round(scaled), atol=1e-3)
    # within eps/2 of the clipped input
    clipped = np.clip(np.asarray(x), fmt.min_value, fmt.max_value)
    assert np.all(np.abs(np.asarray(q) - clipped) <= fmt.eps / 2 + 1e-7)


@given(FMT_STRAT, st.integers(0, 2**31 - 1))
def test_stochastic_on_grid_and_within_eps(fmt, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.uniform(key, (128,), minval=-3.0, maxval=3.0)
    q = quantize_stochastic(jax.random.fold_in(key, 1), x, fmt)
    scaled = np.asarray(q, np.float64) * 2.0**fmt.fl
    assert np.allclose(scaled, np.round(scaled), atol=1e-3)
    assert np.all(np.abs(np.asarray(q) - np.asarray(x)) < fmt.eps + 1e-7)


def test_stochastic_rounding_is_unbiased():
    """E[SR(x)] = x — the property that makes fixed-point training converge."""
    fmt = SPRING_FORMAT
    x = jnp.full((200_000,), 0.5 + 0.37 * fmt.eps)
    q = quantize_stochastic(jax.random.PRNGKey(3), x, fmt)
    bias_in_eps = float((q.mean() - x[0]) / fmt.eps)
    assert abs(bias_in_eps) < 0.01
    # probability of rounding up ~= fractional part
    frac_up = float((q > x[0]).mean())
    assert abs(frac_up - 0.37) < 0.01


def test_nearest_rounding_is_biased_where_sr_is_not():
    fmt = FixedPointFormat(4, 8)
    x = jnp.full((1000,), 0.5 + 0.3 * fmt.eps)
    qn = quantize_nearest(x, fmt)
    assert float(jnp.abs(qn.mean() - x[0])) > 0.25 * fmt.eps  # systematic error


def test_ste_gradients_pass_through_in_range():
    f = lambda x: ste_quantize_nearest(x, SPRING_FORMAT).sum()
    g = jax.grad(f)(jnp.asarray([0.5, -1.25, 100.0, -100.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])

    f2 = lambda x: ste_quantize_stochastic(jax.random.PRNGKey(0), x, SPRING_FORMAT).sum()
    g2 = jax.grad(f2)(jnp.asarray([0.5, 200.0]))
    np.testing.assert_allclose(np.asarray(g2), [1.0, 0.0])


@given(st.integers(0, 1000))
def test_int_roundtrip(seed):
    x = quantize_nearest(jax.random.normal(jax.random.PRNGKey(seed), (32,)) * 3)
    np.testing.assert_allclose(np.asarray(from_int(to_int(x))), np.asarray(x), atol=1e-7)


def test_saturation():
    fmt = SPRING_FORMAT
    q = quantize_nearest(jnp.asarray([1e9, -1e9]), fmt)
    np.testing.assert_allclose(np.asarray(q), [fmt.max_value, fmt.min_value])
