"""End-to-end behaviour tests: training converges, resumes exactly from
checkpoints, serving generates, SR fixed-point training tracks fp32 (the
paper's central training claim), and the dry-run machinery works on a
small in-process mesh."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train_loop


def test_training_loss_decreases(tmp_path):
    res = train_loop("llama3.2-1b", reduced=True, steps=40, batch=8, seq=64,
                     ckpt_dir=str(tmp_path), ckpt_every=20)
    assert res["last_loss"] < res["first_loss"] - 0.5
    assert res["slow_steps"] <= 2


def test_checkpoint_resume_is_exact(tmp_path):
    """Train 20 steps with checkpointing, kill, resume to 30; compare with
    an uninterrupted 30-step run — losses must match exactly (determinism
    across restart is the fault-tolerance contract)."""
    a = train_loop("llama3.2-1b", reduced=True, steps=30, batch=4, seq=32)
    train_loop("llama3.2-1b", reduced=True, steps=20, batch=4, seq=32,
               ckpt_dir=str(tmp_path), ckpt_every=10)
    b = train_loop("llama3.2-1b", reduced=True, steps=30, batch=4, seq=32,
                   ckpt_dir=str(tmp_path), ckpt_every=10)
    np.testing.assert_allclose(a["losses"][-1], b["losses"][-1], rtol=1e-4)


def test_sr_fixed_point_training_tracks_fp32():
    """Gupta'15 / paper §6: Q4.16 + stochastic rounding trains ~like fp32."""
    fp32 = train_loop("llama3.2-1b", reduced=True, steps=60, batch=8, seq=64, mode="dense")
    srq = train_loop("llama3.2-1b", reduced=True, steps=60, batch=8, seq=64,
                     mode="quant", fixed_point_weights=True)
    assert srq["last_loss"] < srq["first_loss"] - 0.3, "SR training must learn"
    assert srq["last_loss"] < fp32["last_loss"] + 0.6, (
        f"SR-fixed-point diverged from fp32: {srq['last_loss']} vs {fp32['last_loss']}")


def test_serving_generates_finite_tokens():
    from repro.launch.serve import serve_session

    out = serve_session("llama3.2-1b", reduced=True, batch=2, prompt_len=12, gen=6)
    assert out["finite"]
    assert out["generated"].shape == (2, 6)


def test_compressed_allreduce_int8_error_feedback():
    """int8+EF gradient reduction: single-shard semantics (mean==identity)
    and error feedback captures exactly the quantization residual."""
    from repro.runtime.compression import (
        compressed_allreduce_tree,
        dequantize_int8,
        sr_quantize_int8,
    )

    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}

    from jax.sharding import PartitionSpec as P

    def run(grads):
        return compressed_allreduce_tree(grads, "pod", jax.random.PRNGKey(1))

    from repro.runtime.compat import shard_map

    fn = shard_map(run, mesh=mesh,
                   in_specs=(jax.tree_util.tree_map(lambda _: P(), g),),
                   out_specs=(jax.tree_util.tree_map(lambda _: P(), g),) * 2,
                   check_vma=False)
    out, ef = fn(g)
    # mean over 1 shard == dequantized value; residual = original - dequant
    np.testing.assert_allclose(np.asarray(out["w"] + ef["w"]), np.asarray(g["w"]),
                               rtol=1e-5, atol=1e-6)
    # quantization error bounded by one int8 step
    q, scale = sr_quantize_int8(g["w"], jax.random.PRNGKey(2))
    err = np.abs(np.asarray(g["w"] - dequantize_int8(q, scale)))
    assert err.max() <= float(scale) + 1e-7


@pytest.mark.slow
def test_dryrun_debug_mesh_subprocess():
    """The actual dry-run entrypoint on an 8-device debug mesh (full-size
    llama decode cell): lower + compile + analyses must succeed."""
    env = dict(os.environ,
               REPRO_DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "decode_32k", "--mesh", "debug", "--mode", "dense",
         "--no-unrolled-cost"],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    payload = out.stdout[out.stdout.find("{"):]
    result = json.loads(payload[: payload.rfind("}") + 1])
    assert result["status"] == "ok"
    assert result["memory"]["peak_bytes_per_chip_est"] > 0
