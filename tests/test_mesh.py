"""spring-mesh suite: packed-collective bit-identity, wire accounting,
MeshSpec threading, divisibility-fallback telemetry, and — on an 8-device
host (CI mesh job) — the single-device-oracle parity seals for sharded
training and serving (DESIGN.md §14).

Simulation-mode tests run everywhere (tier-1); tests taking the
``debug_mesh`` fixture self-skip unless
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` was exported
before jax initialized.
"""

from __future__ import annotations

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.api.spec import RunSpec, SpecError, build_spec
from repro.dist import collectives as C
from repro.kernels import registry
from repro.memstash.format import formula_bits_per_elem

pytestmark = pytest.mark.mesh

registry.ensure_registered()

# stacked (D, n) payloads shaped like the three numerics modes' wires
PAYLOADS = {
    "dense": C._shard_block(0, 4, 1024, 1.0),
    "quant": C._shard_block(1, 4, 512, 0.5, jnp.bfloat16),
    "quant_sparse": C._shard_block(2, 4, 500, 0.1),
}


# -- packed collectives, simulation mode (tier-1) ----------------------------


@pytest.mark.parametrize("mode", sorted(PAYLOADS))
@pytest.mark.parametrize("impl", ["ref", "jnp", "interpret"])
def test_packed_matches_dense_per_shard(mode, impl):
    """The packed wire format is bit-invisible: every impl's all-gather /
    reduce-scatter equals the dense reference exactly, per shard."""
    x = PAYLOADS[mode]
    ag = registry.resolve("packed_all_gather", impl).fn(x)
    assert jnp.array_equal(ag, C.dense_all_gather(x))
    rs = registry.resolve("packed_reduce_scatter", impl).fn(x)
    assert jnp.array_equal(rs, C.dense_reduce_scatter(x))


def test_tree_sum_identical_addends_exact():
    """The bit-exactness seal: a power-of-two pairwise tree over D
    identical addends is exactly D*g, and /D recovers g bit-for-bit."""
    g = jax.random.normal(jax.random.PRNGKey(3), (4096,))
    rows = jnp.stack([g, g, g, g])
    total = C._tree_sum(rows)
    assert jnp.array_equal(total, g * 4.0)
    assert jnp.array_equal(total / 4, g)


def test_tree_sum_rejects_non_power_of_two():
    with pytest.raises(ValueError, match="power of two"):
        C._tree_sum(jnp.ones((3, 8)))


def test_wire_bits_obey_formula():
    """collective_wire_bits == length*(20*density + 1) per device at word
    alignment — the paper's interface formula, single-sourced with
    memstash."""
    world, length = 4, 1 << 12  # word-aligned
    x = C._shard_block(5, world, length, 0.37)
    nnz = int(jnp.count_nonzero(x))
    measured = C.collective_wire_bits(nnz, length, world)
    formula = world * length * formula_bits_per_elem(
        nnz / (world * length), C.COLLECTIVE_VALUE_BITS)
    assert measured == pytest.approx(formula)
    probe = C.collective_probe(0.5, world=2, length=1 << 12)
    assert probe["wire_vs_formula"] == pytest.approx(1.0)
    assert probe["exact"]
    assert probe["compression_vs_fp32"] > 2.0


def test_collective_probe_emits_telemetry():
    from repro.telemetry.metrics import default_registry

    default_registry().reset()
    C.collective_probe(0.5, world=2)
    snap = default_registry().snapshot()
    fam = snap["spring_mesh_collective_bytes_total"]
    kinds = {c["labels"]["kind"] for c in fam["cells"]}
    assert "packed_all_gather" in kinds
    assert all(c["value"] > 0 for c in fam["cells"])
    assert "spring_mesh_collective_density" in snap


# -- MeshSpec threading through RunSpec (tier-1) -----------------------------


def test_meshspec_fields_and_alias():
    spec = build_spec("train", use_env=False, sets=["shape.mesh.data=4"])
    assert spec.shape.mesh.data == 4
    assert spec.shape.mesh.explicit
    assert spec.shape.mesh.label() == "pod1.data4.model1"
    assert spec.provenance["shape.mesh.data"].startswith("set")
    # legacy string spelling routes through the alias to the kind field
    old = build_spec("train", use_env=False, sets=["shape.mesh=debug"])
    assert old.shape.mesh.kind == "debug"
    assert not old.shape.mesh.explicit
    assert old.shape.mesh.label() == "debug"


def test_meshspec_roundtrip_and_legacy_dict():
    spec = build_spec("train", use_env=False, sets=["shape.mesh.data=2"])
    again = RunSpec.from_dict(spec.to_dict())
    assert again == spec
    # pre-mesh artifacts carried a plain string: the alias absorbs it
    d = spec.to_dict()
    d["shape"]["mesh"] = "single"
    legacy = RunSpec.from_dict(d)
    assert legacy.shape.mesh.kind == "single"
    assert not legacy.shape.mesh.explicit


def test_meshspec_validation():
    with pytest.raises(SpecError, match="power of two"):
        build_spec("train", use_env=False, sets=["shape.mesh.data=3"])
    with pytest.raises(SpecError, match=">= 1"):
        build_spec("train", use_env=False, sets=["shape.mesh.model=0"])
    with pytest.raises(SpecError, match="shape.mesh.kind"):
        build_spec("train", use_env=False, sets=["shape.mesh=bogus"])


# -- divisibility fallback telemetry (satellite) -----------------------------


def test_fallback_counter_on_indivisible_axis():
    """A rule that wants to shard but cannot divide replicates AND
    counts — the previously-silent tree_sharding fallback."""
    from repro.runtime.sharding import logical_to_spec, mesh_fallback_counts
    from repro.telemetry.metrics import default_registry

    default_registry().reset()
    stub = types.SimpleNamespace(shape={"data": 3})
    spec = logical_to_spec(("batch",), (4,), stub)  # 4 % 3 != 0
    assert spec == P(None)
    assert mesh_fallback_counts() == {"batch": 1}
    # divisible dims shard without counting
    assert logical_to_spec(("batch",), (6,), stub) == P("data")
    assert mesh_fallback_counts() == {"batch": 1}


# -- sharded-vs-oracle parity seals (CI mesh job, 8 host devices) ------------


TRAIN_SETS = ["arch.id=llama3.2-1b", "train.steps=2", "shape.batch=4",
              "shape.seq=16"]
SERVE_SETS = ["arch.id=llama3.2-1b", "shape.batch=4", "shape.prompt_len=8",
              "shape.gen=3", "serving.static=true"]


def test_axis_mode_matches_simulation(debug_mesh):
    """The real wire hop: shard_map'd collectives over the data axis
    reproduce simulation mode bit-for-bit."""
    from repro.runtime.compat import shard_map

    x = C._shard_block(6, 4, 512, 0.4)
    flat = x.reshape(-1)  # P("data") slices back to the stacked rows

    ag = shard_map(lambda v: C.packed_all_gather(v, axis_name="data"),
                   mesh=debug_mesh, in_specs=P("data"), out_specs=P(),
                   axis_names={"data"}, check_vma=False)
    assert jnp.array_equal(jax.jit(ag)(flat), C.packed_all_gather(x))

    rs = shard_map(lambda v: C.packed_reduce_scatter(v, axis_name="data"),
                   mesh=debug_mesh, in_specs=P("data"), out_specs=P("data"),
                   axis_names={"data"}, check_vma=False)
    assert jnp.array_equal(jax.jit(rs)(flat),
                           C.packed_reduce_scatter(x).reshape(-1))


def test_sharded_train_losses_match_oracle(debug_mesh):
    from repro.api.sessions import TrainSession

    oracle = TrainSession(
        build_spec("train", use_env=False, sets=TRAIN_SETS)).run()
    sharded = TrainSession(
        build_spec("train", use_env=False,
                   sets=TRAIN_SETS + ["shape.mesh.data=4"])).run()
    assert sharded["mesh"] == "pod1.data4.model1"
    assert sharded["losses"] == oracle["losses"]
    probe = sharded["collective_probe"]
    assert probe["world"] == 4 and probe["exact"]


@pytest.mark.parametrize("mode", ["dense", "quant"])
def test_sharded_serve_tokens_match_oracle(debug_mesh, mode):
    from repro.api.sessions import ServeSession

    sets = SERVE_SETS + [f"numerics.mode={mode}"]
    oracle = ServeSession(
        build_spec("serve", use_env=False, sets=sets)).run()
    sharded = ServeSession(
        build_spec("serve", use_env=False,
                   sets=sets + ["shape.mesh.data=4"])).run()
    assert np.array_equal(np.asarray(oracle["generated"]),
                          np.asarray(sharded["generated"]))
    assert sharded["collective_probe"]["exact"]


def test_sharded_serve_indivisible_batch_falls_back(debug_mesh):
    from repro.api.sessions import ServeSession
    from repro.runtime.sharding import mesh_fallback_counts
    from repro.telemetry.metrics import default_registry

    default_registry().reset()
    sets = ["arch.id=llama3.2-1b", "shape.batch=3", "shape.prompt_len=8",
            "shape.gen=2", "serving.static=true", "shape.mesh.data=4"]
    out = ServeSession(build_spec("serve", use_env=False, sets=sets)).run()
    assert out["finite"]
    assert "collective_probe" not in out  # replicated: nothing crossed wire
    assert mesh_fallback_counts().get("serve_batch") == 1
