"""Kernel-dispatch registry: policy semantics, instrumentation, and the
registry-GENERATED parity harness (replaces the hand-enumerated per-op
interpret-vs-ref sweeps — every registered (op, impl) pair runnable on
this backend is cross-checked against its oracle automatically, so a new
kernel cannot land without registering)."""

import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry
from repro.kernels.registry import KernelPolicy, compare_outputs, kernel_policy
from repro.kernels.ssd_scan.ops import ssd_scan


# -- policy object ------------------------------------------------------------


def test_policy_parse_global_and_per_op():
    p = KernelPolicy.parse("ref,ssd_scan=jnp")
    assert p.default == "ref"
    assert p.impl_for("ssd_scan") == "jnp"
    assert p.impl_for("masked_matmul") == "ref"
    assert KernelPolicy.parse("").is_auto
    assert KernelPolicy.parse("auto").is_auto


def test_policy_rejects_unknown_impl_names():
    with pytest.raises(ValueError, match="unknown kernel impl"):
        KernelPolicy.parse("cuda")
    with pytest.raises(ValueError, match="unknown kernel impl"):
        KernelPolicy.parse("ssd_scan=fast")
    with pytest.raises(ValueError, match="unknown kernel op"):
        KernelPolicy.parse("not_an_op=ref")
    with pytest.raises(ValueError, match="unknown kernel impl"):
        KernelPolicy(default="bogus")


def test_policy_rejects_unknown_op_names_everywhere():
    """A typo'd op must raise, not silently pin nothing (constructor and
    context-manager paths, not just parse)."""
    with pytest.raises(ValueError, match="unknown kernel op"):
        KernelPolicy(overrides=(("ssd_scn", "jnp"),))
    with pytest.raises(ValueError, match="unknown kernel op"):
        with kernel_policy(ssd_scn="jnp"):
            pass


def test_policy_describe_roundtrips():
    for spec in ("auto", "ref", "interpret,ssd_scan=jnp"):
        assert KernelPolicy.parse(spec).describe() == spec.replace("auto", "auto")
    assert KernelPolicy().describe() == "auto"


# -- context manager + env var ------------------------------------------------


def test_kernel_policy_context_wins_over_auto_and_restores():
    before = registry.current_policy()
    with kernel_policy("ref"):
        assert registry.resolve("ssd_scan").name == "ref"
        # nesting: innermost wins
        with kernel_policy(ssd_scan="jnp"):
            assert registry.resolve("ssd_scan").name == "jnp"
        assert registry.resolve("ssd_scan").name == "ref"
    assert registry.current_policy() == before
    # auto on CPU: ssd -> jnp (vectorized), others -> ref
    assert registry.resolve("ssd_scan").name == "jnp"
    assert registry.resolve("masked_matmul").name == "ref"


def test_kernel_policy_context_restores_on_exception():
    with pytest.raises(RuntimeError):
        with kernel_policy("interpret"):
            raise RuntimeError("boom")
    assert registry.current_policy().is_auto


def test_env_var_policy(monkeypatch):
    monkeypatch.setenv(registry.ENV_VAR, "ssd_scan=ref")
    assert registry.resolve("ssd_scan").name == "ref"
    # the context manager outranks the env var
    with kernel_policy(ssd_scan="jnp"):
        assert registry.resolve("ssd_scan").name == "jnp"
    monkeypatch.setenv(registry.ENV_VAR, "bogus")
    with pytest.raises(ValueError, match="unknown kernel impl"):
        registry.resolve("ssd_scan")


def test_explicit_impl_beats_policy():
    with kernel_policy("ref"):
        assert registry.resolve("ssd_scan", "jnp").name == "jnp"


def test_unknown_names_rejected_at_resolve():
    with pytest.raises(ValueError, match="unknown kernel impl"):
        registry.resolve("ssd_scan", "fast")
    with pytest.raises(KeyError, match="unknown kernel op"):
        registry.resolve("conv9000")


def test_global_default_is_soft_but_per_op_is_strict():
    # masked_matmul registers no "jnp": a global jnp default falls back
    # to auto, a per-op pin raises
    with kernel_policy("jnp"):
        assert registry.resolve("masked_matmul").name == "ref"
    with kernel_policy(masked_matmul="jnp"):
        with pytest.raises(ValueError, match="no 'jnp' implementation"):
            registry.resolve("masked_matmul")


def test_pallas_unavailable_on_cpu_is_an_error():
    assert jax.default_backend() != "tpu"
    with pytest.raises(ValueError, match="not available"):
        registry.resolve("masked_matmul", "pallas")


# -- capability gating (ssd_scan return_state) --------------------------------


def _ssd_inputs(b=1, s=96, h=2, p=32, g=1, n=16):
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 2), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3), (h,)) * 0.3)
    bb = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n)) / 4
    c = jax.random.normal(jax.random.fold_in(key, 5), (b, s, g, n)) / 4
    return x, dt, a, bb, c


def test_ssd_return_state_rejects_non_jnp_impls_with_clear_error():
    args = _ssd_inputs()
    for impl in ("ref", "interpret"):
        with pytest.raises(ValueError) as ei:
            ssd_scan(*args, impl=impl, return_state=True)
        assert impl in str(ei.value) and "jnp" in str(ei.value)


def test_ssd_return_state_auto_routes_to_jnp():
    args = _ssd_inputs()
    y, state = ssd_scan(*args, return_state=True)  # auto
    assert state.shape == (1, 2, 16, 32)
    assert bool(jnp.all(jnp.isfinite(y)))
    # a soft global default that can't serve the call also routes to jnp
    with kernel_policy("ref"):
        y2, state2 = ssd_scan(*args, return_state=True)
    np.testing.assert_array_equal(np.asarray(state), np.asarray(state2))


# -- dispatch counters + instrumentation metrics ------------------------------


def test_dispatch_counters_accumulate_and_reset():
    from repro.kernels.stochastic_round.ops import stochastic_round

    registry.reset_dispatch_counts()
    x = jnp.ones((64,))
    stochastic_round(x, jnp.uint32(1))
    stochastic_round(x, jnp.uint32(2), impl="interpret")
    counts = registry.dispatch_counts()["stochastic_round"]
    assert counts["ref"] == 1 and counts["interpret"] == 1
    registry.reset_dispatch_counts()
    assert registry.dispatch_counts() == {}


def test_metrics_hooks_record_tile_skip_and_wire_bytes():
    from repro.kernels.mask_compress.ops import mask_pack
    from repro.kernels.masked_matmul.ops import masked_matmul

    x = jnp.zeros((256, 256)).at[:128, :128].set(1.0)
    w = jnp.ones((256, 256))
    with registry.record_kernel_metrics() as rows:
        masked_matmul(x, w, jnp.uint32(0))
        mask_pack(x)
    summary = registry.metric_summary(rows)
    assert 0.0 < summary["masked_matmul"]["tile_skip"] < 1.0
    assert summary["mask_pack"]["wire_bytes"] == 256 * 256 / 32 * 4
    # unaligned length: ceil(n/32) words of wire, NOT the kernel's lane pad
    with registry.record_kernel_metrics() as rows2:
        mask_pack(jnp.ones((1000,)))
    assert registry.metric_summary(rows2)["mask_pack"]["wire_bytes"] == 32 * 4
    # hooks are inert outside the recording block and under tracing
    jax.jit(lambda a, b: masked_matmul(a, b, jnp.uint32(0)))(x, w)


def test_measured_skip_feeds_perfmodel():
    from repro.kernels.masked_matmul.ops import masked_matmul
    from repro.models.cnn import LayerRecord
    from repro.perfmodel.spring_model import measured_skip_fraction, spring_eval

    x = jnp.zeros((256, 256)).at[:128, :128].set(1.0)
    with registry.record_kernel_metrics() as rows:
        masked_matmul(x, jnp.ones((256, 256)), jnp.uint32(0))
    skip = measured_skip_fraction(rows)
    assert skip is not None and 0.0 < skip < 1.0
    assert measured_skip_fraction([]) is None
    # compute-bound synthetic layer: the measured skip must scale the
    # compute term exactly like (1 - skip)
    rec = LayerRecord(kind="fc", name="l", macs=10**12,
                      in_elems=10, w_elems=10, out_elems=10)
    dense = spring_eval([rec], 1, training=False,
                        act_sparsity=0.0, w_sparsity=0.0)
    meas = spring_eval([rec], 1, training=False, act_sparsity=0.0,
                       w_sparsity=0.0, compute_skip_fraction=skip)
    np.testing.assert_allclose(meas.time_s, dense.time_s * (1.0 - skip), rtol=1e-6)


def test_resolution_table_never_raises():
    table = registry.resolution_table(KernelPolicy.parse("pallas"))
    assert set(table) == set(registry.ops())
    assert all(str(v).startswith("error") for v in table.values())
    auto = registry.resolution_table()
    assert auto["ssd_scan"] == "jnp" and auto["masked_matmul"] == "ref"


def test_resolution_table_with_auto_policy_reflects_ambient(monkeypatch):
    """An auto policy argument must not shadow the ambient env policy —
    the dry-run's kernel_impls field reports what the trace actually saw."""
    monkeypatch.setenv(registry.ENV_VAR, "ssd_scan=ref")
    table = registry.resolution_table(KernelPolicy())
    assert table["ssd_scan"] == "ref"


# -- config threading ---------------------------------------------------------


def test_spring_config_policy_reaches_matmul_dispatch():
    from repro.core.spring_ops import QUANT_SPARSE, KeyGen, spring_matmul
    import dataclasses

    registry.reset_dispatch_counts()
    cfg = dataclasses.replace(QUANT_SPARSE,
                              kernels=KernelPolicy.parse("masked_matmul=interpret"))
    x = jnp.round(jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 16) / 64
    y = spring_matmul(x, x, cfg, KeyGen(jax.random.PRNGKey(1)))
    assert y.shape == (64, 64)
    # exactly one dispatch: the config-threading planning resolution must
    # not double-count on top of the wrapper's dispatching resolution
    assert registry.dispatch_counts()["masked_matmul"] == {"interpret": 1}


def test_planning_resolutions_do_not_count_as_dispatches():
    registry.reset_dispatch_counts()
    registry.resolution_table()
    registry.resolve_with(KernelPolicy.parse("ref"), "ssd_scan")
    assert registry.dispatch_counts() == {}


def test_spring_config_use_pallas_is_gone():
    from repro.core.spring_ops import SpringConfig

    assert not hasattr(SpringConfig(), "use_pallas")
    assert isinstance(SpringConfig().kernels, KernelPolicy)


# -- registration completeness ------------------------------------------------


def test_every_kernel_package_registers_an_op():
    """A kernels/<name>/ops.py that registers nothing is a bug: the parity
    harness and the policy machinery would silently skip it."""
    kernels_dir = pathlib.Path(registry.__file__).parent
    packages = sorted(
        d.name for d in kernels_dir.iterdir()
        if d.is_dir() and (d / "ops.py").exists()
    )
    assert packages, "kernel packages not found"
    registered_modules = set()
    for op in registry.ops():
        for kimpl in registry.impls(op).values():
            mod = getattr(kimpl.fn, "__module__", "") or ""
            # partial() wrappers keep the underlying function's module
            fn = getattr(kimpl.fn, "func", kimpl.fn)
            registered_modules.add(getattr(fn, "__module__", mod))
    for pkg in packages:
        assert any(f"repro.kernels.{pkg}." in m for m in registered_modules), (
            f"kernels/{pkg}/ops.py registers no implementation with "
            f"repro.kernels.registry")


def test_capability_table_shape():
    table = registry.capability_table()
    assert set(table) == set(registry.ops())
    for op, impls in table.items():
        oracle = [n for n, row in impls.items() if row["oracle"]]
        assert len(oracle) == 1, f"{op} must declare exactly one oracle"
        assert all(not row["selectable"] for n, row in impls.items()
                   if n == "interpret"), "interpret is explicit-only"


# -- the generated parity harness --------------------------------------------


PAIRS = [(op, impl) for op, impl in registry.parity_pairs()
         if registry.op_spec(op).examples is not None]


@pytest.mark.kernel_parity
@pytest.mark.parametrize("op,impl", PAIRS, ids=[f"{o}-{i}" for o, i in PAIRS])
def test_registry_parity(op, impl):
    """Every registered (op, impl) runnable on this backend matches the
    op's oracle on the op's registered example inputs, under the op's
    registered comparison spec."""
    spec = registry.op_spec(op)
    oracle_fn = registry.impls(op)[spec.oracle].fn
    impl_fn = registry.impls(op)[impl].fn
    for case in spec.examples():
        args, kwargs = case[0], case[1]
        case_cmp = case[2] if len(case) > 2 else None
        want = oracle_fn(*args, **kwargs)
        got = impl_fn(*args, **kwargs)
        compare_outputs(op, got, want, case_cmp)


@pytest.mark.kernel_parity
def test_parity_pairs_cover_all_cpu_impls():
    """The generated suite exercises every non-oracle registered impl that
    is runnable on CPU (pallas is TPU-only and correctly excluded)."""
    covered = set(PAIRS)
    for op in registry.ops():
        spec = registry.op_spec(op)
        for name, kimpl in registry.impls(op).items():
            if name == spec.oracle or not kimpl.parity or not kimpl.available():
                continue
            assert (op, name) in covered, f"({op}, {name}) missing from parity sweep"
