"""Beyond-paper extensions: pipeline parallelism, Eager-Pruning schedule,
activation-sparsity probe."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.activation_stats import relu_sparsity_probe, tensor_sparsity
from repro.runtime.pruning import PruneSchedule, apply_pruning, measured_sparsity


def test_prune_schedule_ramps_cubically():
    s = PruneSchedule(final_sparsity=0.5, start_step=10, ramp_steps=100)
    assert float(s.sparsity_at(jnp.asarray(0))) == 0.0
    assert float(s.sparsity_at(jnp.asarray(10))) == 0.0
    mid = float(s.sparsity_at(jnp.asarray(60)))
    assert 0.2 < mid < 0.5
    assert abs(float(s.sparsity_at(jnp.asarray(1000))) - 0.5) < 1e-6


def test_apply_pruning_hits_target_and_spares_small_tensors():
    key = jax.random.PRNGKey(0)
    params = {
        "big": jax.random.normal(key, (128, 256)),
        "norm": jnp.ones((128,)),  # must stay dense
    }
    sched = PruneSchedule(final_sparsity=0.6, start_step=0, ramp_steps=1)
    pruned = apply_pruning(params, jnp.asarray(100), sched)
    sp = float(jnp.mean((pruned["big"] == 0.0).astype(jnp.float32)))
    assert abs(sp - 0.6) < 0.02
    assert bool(jnp.all(pruned["norm"] == 1.0))
    assert 0.5 < float(measured_sparsity(pruned)) < 0.7


def test_eager_pruning_training_keeps_learning():
    """Sparsify to 50% during training (paper §6 direction): loss still
    drops and the weights really are half zeros at the end."""
    from repro.configs import ARCHS
    from repro.data.pipeline import DataConfig, SyntheticLMStream
    from repro.models import lm as lm_mod
    from repro.models.layers import SpringContext
    from repro.optim.optimizers import OptimizerConfig, make_optimizer

    cfg = ARCHS["llama3.2-1b"].reduced()
    data = SyntheticLMStream(DataConfig(seed=0, vocab=cfg.vocab, seq_len=64, global_batch=8))
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = make_optimizer(OptimizerConfig(lr=3e-3, warmup_steps=5))
    opt_state = opt_init(params)
    sched = PruneSchedule(final_sparsity=0.5, start_step=10, ramp_steps=30, min_dim=32)

    @jax.jit
    def step(params, opt_state, tokens, i):
        loss, grads = jax.value_and_grad(
            lambda p: lm_mod.lm_loss(p, cfg, tokens, SpringContext())[0])(params)
        params, opt_state, _ = opt_update(grads, opt_state, params)
        params = apply_pruning(params, i, sched)
        return params, opt_state, loss

    losses = []
    for i in range(60):
        params, opt_state, loss = step(params, opt_state, data.batch(i), jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, "pruned training must still learn"
    sp = float(measured_sparsity(params))
    assert 0.4 < sp < 0.6, f"expected ~50% weight sparsity, got {sp}"


def test_activation_sparsity_probe_on_cnn():
    """ReLU CNNs show the high activation sparsity the paper relies on."""
    key = jax.random.PRNGKey(0)

    def apply_fn(relu, x, w1, w2):
        h = relu(x @ w1)
        return relu(h @ w2)

    x = jax.random.normal(key, (32, 64))
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (64, 128))
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (128, 128))
    stats = relu_sparsity_probe(apply_fn, x, w1, w2)
    assert stats["layers"] == 2
    assert 0.3 < stats["mean_sparsity"] < 0.7  # ~50% for zero-mean inputs
    # SiLU (LM archs) has ~no exact zeros — the DESIGN.md §5 contrast
    assert tensor_sparsity(jax.nn.silu(x)) < 0.01


@pytest.mark.slow
def test_pipeline_parallelism_matches_sequential():
    """GPipe schedule over 4 stages == sequential stage application."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.runtime.pipeline import pipeline_apply, stack_stage_params
mesh = jax.make_mesh((4,), ("pod",))
key = jax.random.PRNGKey(0)
S, M, mb, d = 4, 6, 8, 32
stage_params = [{"w": jax.random.normal(jax.random.fold_in(key, s), (d, d)) / d**0.5}
                for s in range(S)]
stage_fn = lambda x, p: jnp.tanh(x @ p["w"])
xs = jax.random.normal(jax.random.fold_in(key, 99), (M, mb, d))
got = pipeline_apply(stage_fn, stack_stage_params(stage_params), xs, mesh=mesh, axis="pod")
want = xs
for p in stage_params:
    want = jax.vmap(lambda x: stage_fn(x, p))(want)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         timeout=600, env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "PIPELINE_OK" in out.stdout, out.stderr[-2000:]
