"""Test config: single CPU device (the dry-run sets its own device count
in a subprocess), moderate hypothesis budgets for the 1-core container.

The container may not ship ``hypothesis``; in that case a deterministic
fallback shim (tests/_hypothesis_fallback.py) is installed so the property
tests still run instead of aborting collection."""

import jax
import pytest

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    from _hypothesis_fallback import install

    install()
    from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")

jax.config.update("jax_platform_name", "cpu")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")
    config.addinivalue_line(
        "markers",
        "kernel_parity: registry-generated kernel oracle cross-checks "
        "(CI kernel-parity job runs `pytest -m kernel_parity`)",
    )
    config.addinivalue_line(
        "markers",
        "grad_parity: sparsity-aware backward (custom_vjp) gradient "
        "cross-checks vs the dense ref gradient "
        "(CI grad-parity job runs `pytest -m grad_parity`)",
    )
    config.addinivalue_line(
        "markers",
        "serving: continuous-batching engine parity/property/KV-roundtrip "
        "suite (CI serving job runs `pytest -m serving`)",
    )
    config.addinivalue_line(
        "markers",
        "spec: RunSpec round-trip/parity/coverage suite "
        "(CI spec job runs `pytest -m spec`)",
    )
    config.addinivalue_line(
        "markers",
        "telemetry: spring-trace metrics/span/latency-attribution suite "
        "(CI telemetry job runs `pytest -m telemetry`)",
    )
    config.addinivalue_line(
        "markers",
        "paging: spring-pages paged/COW KV pool parity + property suite "
        "(CI paging job runs `pytest -m paging`)",
    )
    config.addinivalue_line(
        "markers",
        "elastic: spring-survive chaos/snapshot/shed suite "
        "(CI elastic job runs `pytest -m elastic`)",
    )
    config.addinivalue_line(
        "markers",
        "mesh: spring-mesh packed-collective + sharded-oracle parity suite "
        "(CI mesh job runs `pytest -m mesh` under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8; "
        "device-gated tests self-skip on a 1-device host)",
    )


@pytest.fixture
def debug_mesh():
    """An explicit pod1.data4.model1 mesh over 8 host devices; skips when
    the pool is too small (tier-1 runs single-device — the CI mesh job
    sets the XLA flag before jax initializes)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.dist.mesh import make_explicit_mesh

    return make_explicit_mesh(1, 4, 1)


@pytest.fixture(autouse=True)
def _isolate_metrics():
    """Snapshot/restore the default MetricsRegistry around every test.

    The registry now backs the kernel dispatch counters (global mutable
    state by design — it outlives any one run), so without isolation a
    test's asserts would see whatever counts earlier tests dispatched.
    """
    from repro.telemetry import default_registry

    reg = default_registry()
    saved = reg.snapshot()
    try:
        yield reg
    finally:
        reg.reset()
        reg.restore(saved)
