"""Test config: single CPU device (the dry-run sets its own device count
in a subprocess), moderate hypothesis budgets for the 1-core container."""

import jax
from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")

jax.config.update("jax_platform_name", "cpu")
