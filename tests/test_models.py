"""Per-architecture smoke tests (reduced configs) + decode-vs-teacher-forced
consistency — one forward/train step on CPU asserting shapes and no NaNs,
as required per assigned arch."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.core.spring_ops import QUANT, KeyGen
from repro.models import encdec as ed_mod
from repro.models import lm as lm_mod
from repro.models.layers import SpringContext

ALL_ARCHS = sorted(ARCHS)


def _finite_tree(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree_util.tree_leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.reduced()
    key = jax.random.PRNGKey(0)
    ctx = SpringContext()
    B, S = 2, 32
    if arch.is_encdec:
        params = ed_mod.encdec_init(key, cfg)
        frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        loss, metrics = ed_mod.encdec_loss(params, cfg, frames, tokens, ctx)
        grads = jax.grad(lambda p: ed_mod.encdec_loss(p, cfg, frames, tokens, ctx)[0])(params)
    else:
        params = lm_mod.lm_init(key, cfg)
        tokens = jax.random.randint(key, (B, S - cfg.vlm_prefix_len), 0, cfg.vocab)
        img = (jax.random.normal(key, (B, cfg.vlm_prefix_len, cfg.d_model), jnp.bfloat16)
               if cfg.vlm_prefix_len else None)
        h, _ = lm_mod.lm_hidden(params, cfg, tokens, ctx, img)
        assert h.shape == (B, S, cfg.d_model)
        loss, metrics = lm_mod.lm_loss(params, cfg, tokens, ctx, img)
        grads = jax.grad(lambda p: lm_mod.lm_loss(p, cfg, tokens, ctx, img)[0])(params)
    assert loss.shape == () and bool(jnp.isfinite(loss))
    assert _finite_tree(grads), f"{arch_id}: non-finite grads"


@pytest.mark.parametrize("arch_id", [a for a in ALL_ARCHS if not ARCHS[a].is_encdec])
def test_decode_matches_teacher_forced(arch_id):
    """Prefill(s-1 tokens) + decode(1) must reproduce the full-sequence
    last-token logits — the KV-cache/state machinery is exact."""
    arch = ARCHS[arch_id]
    cfg = arch.reduced()
    if cfg.vlm_prefix_len:
        pytest.skip("vlm decode covered via llama-family; prefix handling differs")
    key = jax.random.PRNGKey(1)
    ctx = SpringContext()
    B, S = 2, 24
    params = lm_mod.lm_init(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    h, _ = lm_mod.lm_hidden(params, cfg, tokens, ctx)
    full_logits = jnp.einsum(
        "bd,dv->bv", h[:, -1].astype(jnp.float32),
        (params["embed"]["embedding"].T if cfg.tie_embeddings
         else params["lm_head"]["kernel"]).astype(jnp.float32))

    _, cache = lm_mod.lm_prefill(params, cfg, tokens[:, :-1], ctx)
    cache = lm_mod.pad_cache(cache, 1)  # headroom for the decoded token
    step_logits, _ = lm_mod.lm_decode_step(params, cfg, tokens[:, -1], cache, ctx)

    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-6
    err = float(jnp.max(jnp.abs(step_logits - full_logits))) / scale
    assert err < 0.05, f"{arch_id}: decode/teacher-forced mismatch rel={err}"


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "olmoe-1b-7b", "mamba2-780m"])
def test_quantized_mode_runs(arch_id):
    """The paper's numerics as a config switch: quant mode trains finitely."""
    arch = ARCHS[arch_id]
    cfg = arch.reduced()
    key = jax.random.PRNGKey(2)
    ctx = SpringContext(cfg=QUANT, keys=KeyGen(key))
    params = lm_mod.lm_init(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    loss, _ = lm_mod.lm_loss(params, cfg, tokens, ctx)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm_mod.lm_loss(
        p, cfg, tokens, SpringContext(cfg=QUANT, keys=KeyGen(key)))[0])(params)
    assert _finite_tree(grads)


def test_whisper_decode_step():
    arch = ARCHS["whisper-medium"]
    cfg = arch.reduced()
    key = jax.random.PRNGKey(3)
    ctx = SpringContext()
    B = 2
    params = ed_mod.encdec_init(key, cfg)
    frames = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    cache = ed_mod.encdec_init_cache(params, cfg, frames, ctx, max_len=8)
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        logits, cache = ed_mod.encdec_decode_step(params, cfg, tok, cache, ctx)
        tok = jnp.argmax(logits, -1)
    assert logits.shape == (B, cfg.vocab) and bool(jnp.all(jnp.isfinite(logits)))


def test_moe_capacity_and_balance_loss():
    from repro.models.moe import MoESpec, moe_apply, moe_init

    spec = MoESpec(n_experts=8, top_k=2, d_ff=32)
    key = jax.random.PRNGKey(0)
    params = moe_init(key, 16, spec)
    x = jax.random.normal(key, (2, 24, 16))
    y, aux = moe_apply(params, x, SpringContext(), spec)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz at any routing
