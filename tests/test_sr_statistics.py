"""Statistical seal on the stochastic-rounding unit (ISSUE 3, satellite 3):
the paper's "no accuracy loss" claim rests on SR being unbiased
(E[Round(x)] = x, Eq. 4) — verified here within CLT bounds over >=10k
draws for both the PRNG-key quantizer and the counter-hash kernel op, plus
determinism under a fixed key/seed."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import SPRING_FORMAT, quantize_stochastic
from repro.kernels.stochastic_round.ops import stochastic_round

N_DRAWS = 20_000
SIGMAS = 5.0  # false-failure odds ~ 1 in 1.7M per check


def _clt_bound(frac: float, eps: float, n: int) -> float:
    """SIGMAS-sigma bound on |mean - x|: one draw deviates by eps with
    variance eps^2 * frac * (1 - frac)."""
    return SIGMAS * eps * np.sqrt(max(frac * (1.0 - frac), 1e-12) / n)


def test_quantize_stochastic_mean_is_unbiased_within_clt():
    eps = SPRING_FORMAT.eps
    for frac, seed in [(0.3, 0), (0.5, 1), (0.77, 2), (0.05, 3)]:
        x = jnp.full((N_DRAWS,), 0.5 + frac * eps, jnp.float32)
        q = quantize_stochastic(jax.random.PRNGKey(seed), x)
        bias = float(q.mean() - x[0])
        assert abs(bias) <= _clt_bound(frac, eps, N_DRAWS), (frac, bias)
        # every draw lands on one of the two neighboring grid points
        lo = np.floor(0.5 / eps + frac) * eps
        assert set(np.unique(np.asarray(q))) <= {np.float32(lo),
                                                 np.float32(lo + eps)}


def test_stochastic_round_kernel_mean_is_unbiased_within_clt():
    """The counter-hash (LFSR stand-in) kernel op is unbiased too: its
    per-element streams are independent across the >=10k lanes."""
    eps = 2.0 ** -16
    for frac, seed in [(0.25, 9), (0.5, 10), (0.9, 11)]:
        x = jnp.full((N_DRAWS,), 1.0 + frac * eps, jnp.float32)
        q = stochastic_round(x, jnp.uint32(seed))
        bias = float(q.mean() - x[0])
        assert abs(bias) <= _clt_bound(frac, eps, N_DRAWS), (frac, bias)


def test_stochastic_round_probability_matches_fraction():
    """P(round up) tracks the fractional part (Eq. 4), not just the mean."""
    eps = 2.0 ** -16
    for frac in (0.2, 0.5, 0.8):
        x = jnp.full((N_DRAWS,), 2.0 + frac * eps, jnp.float32)
        q = stochastic_round(x, jnp.uint32(42))
        up = float((q > x[0]).mean())
        assert abs(up - frac) <= SIGMAS * np.sqrt(frac * (1 - frac) / N_DRAWS)


def test_stochastic_rounding_is_deterministic_under_fixed_key():
    x = jax.random.normal(jax.random.PRNGKey(7), (4096,)) * 2
    a = quantize_stochastic(jax.random.PRNGKey(3), x)
    b = quantize_stochastic(jax.random.PRNGKey(3), x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different keys produce different draws on in-between values
    c = quantize_stochastic(jax.random.PRNGKey(4), x)
    assert np.any(np.asarray(a) != np.asarray(c))

    ka = stochastic_round(x, jnp.uint32(5))
    kb = stochastic_round(x, jnp.uint32(5))
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb))
    kc = stochastic_round(x, jnp.uint32(6))
    assert np.any(np.asarray(ka) != np.asarray(kc))
