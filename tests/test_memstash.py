"""Memstash subsystem: bit-exact compressed round trips, wire-byte
accounting vs the perfmodel traffic formula, gradient exactness of the
stash/restore custom_vjp, and the CNN/LM/trainer integration points."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.memstash import (
    MemstashConfig,
    compress,
    decompress,
    dense_fp32_bytes,
    formula_bits_per_elem,
    record_stash_traffic,
    stash_apply,
    summarize,
    wire_bytes,
)
from repro.memstash.stash import checkpoint_apply


def sparse_tensor(seed: int, shape, sparsity: float, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, shape) * 3.0
    keep = jax.random.uniform(jax.random.fold_in(key, 1), shape) > sparsity
    return (x * keep).astype(dtype)


# -- format: round trips ------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16, jnp.int32])
@pytest.mark.parametrize("shape", [(7,), (33,), (8, 128), (3, 5, 9), (1, 1)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.95])
def test_roundtrip_bit_exact(dtype, shape, sparsity):
    x = sparse_tensor(0, shape, sparsity, dtype)
    y = decompress(compress(x))
    assert y.shape == x.shape and y.dtype == x.dtype
    assert bool(jnp.all(y == x)), "stash round trip must be bit-exact"


def test_roundtrip_edge_densities():
    zeros = jnp.zeros((257,))
    sv = compress(zeros)
    assert int(sv.nnz) == 0
    np.testing.assert_array_equal(np.asarray(decompress(sv)), np.zeros(257))
    full = jnp.arange(1, 130, dtype=jnp.float32)
    sv = compress(full)
    assert int(sv.nnz) == 129
    np.testing.assert_array_equal(np.asarray(decompress(sv)), np.asarray(full))


def test_roundtrip_preserves_nan_inf():
    x = jnp.asarray([0.0, jnp.nan, -jnp.inf, 2.5, 0.0, jnp.inf])
    y = np.asarray(decompress(compress(x)))
    np.testing.assert_array_equal(y, np.asarray(x))


def test_roundtrip_under_jit_and_values_front_collapsed():
    x = sparse_tensor(3, (1024,), 0.6)
    sv = jax.jit(compress)(x)
    y = jax.jit(decompress)(sv)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    nnz = int(sv.nnz)
    np.testing.assert_array_equal(
        np.asarray(sv.values[:nnz]), np.asarray(x[x != 0.0]))
    assert not np.any(np.asarray(sv.values[nnz:]))


def test_capacity_truncates_and_counts_overflow():
    x = sparse_tensor(4, (4096,), 0.5)  # density ~0.5
    sv = compress(x, capacity=0.25)
    assert sv.capacity_len == 1024
    assert int(sv.overflow) > 0
    y = decompress(sv)
    # the first capacity_len non-zeros survive, the rest decode as zero
    np.testing.assert_array_equal(
        np.asarray(y[y != 0.0]), np.asarray(x[x != 0.0])[:sv.capacity_len])
    # plenty of headroom -> exact
    lo = sparse_tensor(5, (4096,), 0.9)
    sv = compress(lo, capacity=0.25)
    assert int(sv.overflow) == 0
    np.testing.assert_array_equal(np.asarray(decompress(sv)), np.asarray(lo))


# -- accounting vs the perfmodel traffic formula ------------------------------


@pytest.mark.parametrize("sparsity", [0.5, 0.7])
def test_wire_bytes_match_traffic_formula_and_beat_fp32(sparsity):
    """Acceptance: at >=50% sparsity, measured stashed bytes are within 10%
    of ``bits/elem = 20*density + 1`` and strictly below dense fp32."""
    n = 1 << 16
    x = sparse_tensor(6, (n,), sparsity)
    sv = compress(x)
    measured = float(wire_bytes(sv, value_bits=20))
    density = float(sv.nnz) / n
    formula = n * formula_bits_per_elem(density, 20) / 8.0
    assert abs(measured - formula) / formula < 0.10
    assert measured < dense_fp32_bytes(sv)


def test_perfmodel_uses_same_formula():
    from repro.perfmodel.spring_model import SPRING_DESIGN

    assert SPRING_DESIGN.value_bits == 20
    assert formula_bits_per_elem(0.5, SPRING_DESIGN.value_bits) == 11.0


# -- stash/restore autodiff ---------------------------------------------------


def _mlp_loss(x, aux):
    w1, w2 = aux
    h = jax.nn.relu(x @ w1)
    return jnp.sum((h @ w2) ** 2)


def test_stash_gradients_exact():
    key = jax.random.PRNGKey(7)
    x = jax.nn.relu(jax.random.normal(key, (16, 64)))  # ~50% sparse
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (32, 8))
    scfg = MemstashConfig(policy="stash")

    g_ref = jax.grad(_mlp_loss, argnums=(0, 1))(x, (w1, w2))
    g_st = jax.grad(lambda x_, aux: stash_apply(_mlp_loss, scfg, "mlp", x_, aux),
                    argnums=(0, 1))(x, (w1, w2))
    for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("policy", ["none", "remat", "stash"])
def test_checkpoint_apply_policies_agree(policy):
    key = jax.random.PRNGKey(8)
    x = jax.nn.relu(jax.random.normal(key, (8, 64)))
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (32, 8))
    scfg = MemstashConfig(policy=policy)
    y = checkpoint_apply(_mlp_loss, policy, scfg, "mlp", x, (w1, w2))
    y_ref = _mlp_loss(x, (w1, w2))
    np.testing.assert_allclose(float(y), float(y_ref), rtol=1e-6)
    g = jax.jit(jax.grad(
        lambda x_: checkpoint_apply(_mlp_loss, policy, scfg, "mlp", x_, (w1, w2))))(x)
    g_ref = jax.grad(_mlp_loss)(x, (w1, w2))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-6)


# -- policy resolution --------------------------------------------------------


def test_default_memstash_family_dispatch_via_spec_resolver():
    """ISSUE 5 satellite: ``default_memstash`` family dispatch is driven
    by the spec resolver — ``memstash.policy="auto"`` resolves per
    workload family through the one source of truth, for every family the
    registry actually carries plus the CNN workloads."""
    from repro.api.spec import build_spec
    from repro.configs import ARCHS
    from repro.configs.base import default_memstash

    families = {a.family for a in ARCHS.values()}
    assert families == {"dense", "hybrid", "vlm", "moe", "ssm", "audio"}
    # the paper CNNs are genuinely sparse post-ReLU: compressed stash wins
    assert default_memstash("cnn").policy == "stash"
    # every LM-side family: dense residual streams -> remat
    for family in families:
        assert default_memstash(family).policy == "remat", family

    for arch_id, arch in sorted(ARCHS.items()):
        spec = build_spec("train", use_env=False,
                          overrides=[("arch.id", arch_id, "test")])
        resolved = spec.resolve()
        want = default_memstash(arch.family).policy
        assert resolved.memstash_policy == want, (arch_id, arch.family)
        assert resolved.step.memstash.policy == want
        # the family *recommendation* must not re-route the arch config —
        # only an explicitly requested policy does (provenance-aware)
        assert getattr(resolved.config, "remat_policy", None) != "stash"
        explicit = build_spec(
            "train", use_env=False,
            overrides=[("arch.id", arch_id, "test"),
                       ("memstash.policy", "stash", "test")]).resolve()
        assert explicit.memstash_policy == "stash"
        if hasattr(explicit.config, "remat_policy"):
            assert explicit.config.remat_policy == "stash"


def test_policy_per_layer_overrides_and_min_elems():
    cfg = MemstashConfig(policy="stash",
                         per_layer=(("head*", "none"), ("s0b*", "remat")),
                         min_elems=1000)
    assert cfg.policy_for("c3_1", elems=4096) == "stash"
    assert cfg.policy_for("c3_1", elems=10) == "none"  # below min_elems
    assert cfg.policy_for("head", elems=10**6) == "none"
    assert cfg.policy_for("s0b2/1", elems=10**6) == "remat"
    with pytest.raises(ValueError):
        MemstashConfig(policy="bogus")
    with pytest.raises(ValueError):
        MemstashConfig(capacity=0.0)


# -- model integration --------------------------------------------------------


def test_cnn_conv_grads_exact_under_stash():
    from repro.models.cnn import PAPER_CNNS, cnn_apply, cnn_init
    from repro.models.layers import SpringContext

    cnn = PAPER_CNNS["mobilenet_v2"]
    params = cnn_init(jax.random.PRNGKey(0), cnn, input_hw=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))

    def loss(p, ctx):
        return jnp.sum(cnn_apply(p, cnn, x, ctx) ** 2)

    g_ref = jax.grad(loss)(params, SpringContext())
    g_st = jax.grad(loss)(params, SpringContext(memstash=MemstashConfig(policy="stash")))
    for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cnn_stash_instrumentation_records_sparsity():
    from repro.models.cnn import PAPER_CNNS, cnn_apply, cnn_init
    from repro.models.layers import SpringContext

    cnn = PAPER_CNNS["mobilenet_v2"]
    params = cnn_init(jax.random.PRNGKey(0), cnn, input_hw=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
    ctx = SpringContext(memstash=MemstashConfig(policy="stash"))
    with record_stash_traffic() as rows:
        cnn_apply(params, cnn, x, ctx)
    assert len(rows) > 10
    s = summarize(rows)
    # post-ReLU maps: genuinely sparse, compressed strictly below fp32,
    # and the measured wire bytes track the analytical formula
    assert 0.2 < s["mean_density"] < 0.9
    assert s["wire_bytes"] < s["dense_fp32_bytes"]
    assert abs(s["wire_vs_formula"] - 1.0) < 0.10


def test_lm_remat_policy_stash_matches_full():
    from repro.configs import get_arch
    from repro.models import lm as lm_mod
    from repro.models.layers import SpringContext

    cfg = get_arch("llama3.2-1b").reduced()
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    out = {}
    for pol, ms in [("full", None), ("stash", MemstashConfig(policy="stash"))]:
        c = dataclasses.replace(cfg, remat_policy=pol)
        ctx = SpringContext(memstash=ms)
        with record_stash_traffic() as rows:
            loss, _ = jax.jit(lambda p, c=c, ctx=ctx: lm_mod.lm_loss(p, c, tokens, ctx))(params)
        out[pol] = float(loss)
        # the stash point must actually be wired into the compiled program
        # (trace-time markers), not silently fall back to plain remat
        stash_rows = [r for r in rows if r["layer"] == "lm/residual"]
        assert bool(stash_rows) == (pol == "stash"), (pol, rows)
    np.testing.assert_allclose(out["stash"], out["full"], rtol=1e-5)


def test_lm_memstash_config_vetoes_stash_nomination():
    """remat_policy="stash" nominates the residual stream, but the
    MemstashConfig (policy "none" or a per_layer override) has the last
    word — mirroring the CNN path's ctx.stash_policy resolution."""
    from repro.configs import get_arch
    from repro.models import lm as lm_mod
    from repro.models.layers import SpringContext

    cfg = dataclasses.replace(get_arch("llama3.2-1b").reduced(), remat_policy="stash")
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    cases = [
        (MemstashConfig(policy="none"), False),
        (MemstashConfig(policy="stash", per_layer=(("lm/*", "remat"),)), False),
        (MemstashConfig(policy="stash"), True),
        (None, True),  # no step-level config: the nomination stands
    ]
    for ms, want in cases:
        ctx = SpringContext(memstash=ms)
        with record_stash_traffic() as rows:
            jax.jit(lambda p, ctx=ctx: lm_mod.lm_loss(p, cfg, tokens, ctx)[0])(params)
        got = any(r["layer"] == "lm/residual" for r in rows)
        assert got == want, (ms, rows)


def test_train_loop_with_stash_matches_baseline():
    from repro.launch.train import train_loop

    a = train_loop("llama3.2-1b", reduced=True, steps=4, batch=4, seq=32)
    b = train_loop("llama3.2-1b", reduced=True, steps=4, batch=4, seq=32, stash="stash")
    np.testing.assert_allclose(a["losses"], b["losses"], rtol=1e-4)
