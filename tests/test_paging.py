"""spring-pages seal (ISSUE 7).

The paged, copy-on-write KV pool must be *bit-identical*, per request,
to the slot-monolithic pool (and through it to the static reference
path) across all three numerics modes — including runs where requests
share prompt prefixes copy-on-write and runs that exercise the
density-aware spill/resume path.  The pure-python allocator / block
table / admission layers are property-tested with hypothesis: no page
leaks, refcounts hit zero exactly at release, COW never aliases a
written page, and admission never leaves the pool over its physical
budget once the spill path has run.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_arch
from repro.launch.serve import serve_session, serving_config, static_reference_session
from repro.optim.optimizers import OptimizerConfig
from repro.runtime.train import StepConfig
from repro.serving.engine import ServingEngine
from repro.serving.kvpool import SlotLedger
from repro.serving.paging import (
    AdmissionController,
    BlockTable,
    PageAllocator,
    PageError,
    PagedServingEngine,
    chain_keys,
)

pytestmark = pytest.mark.paging

ARCH = "llama3.2-1b"
BATCH, PROMPT, GEN = 3, 8, 5


# =========================================================================
# allocator properties (S3) — pure python, no jax
# =========================================================================

ops_strategy = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 10 ** 6)),
    min_size=1, max_size=80)


@given(capacity=st.integers(1, 12), ops=ops_strategy)
def test_allocator_stream_no_leaks(capacity, ops):
    """Random alloc/incref/decref streams: conservation holds after every
    op, and draining every reference returns the pool to fully free."""
    alloc = PageAllocator(capacity)
    live = {}  # frame -> model refcount
    for op, pick in ops:
        if op == 0:  # alloc
            if alloc.n_free:
                f = alloc.alloc()
                assert f not in live and f >= PageAllocator.RESERVED
                live[f] = 1
            else:
                with pytest.raises(PageError, match="out of pages"):
                    alloc.alloc()
        elif live:
            f = sorted(live)[pick % len(live)]
            if op == 1:
                alloc.incref(f)
                live[f] += 1
            else:
                left = alloc.decref(f)
                live[f] -= 1
                assert left == live[f]
                if live[f] == 0:
                    del live[f]
        alloc.check_invariants()
        assert alloc.n_allocated == len(live)
        for f, n in live.items():
            assert alloc.refcount(f) == n
    for f in list(live):
        for _ in range(live[f]):
            alloc.decref(f)
    assert alloc.n_free == capacity and alloc.n_allocated == 0


def test_allocator_errors():
    alloc = PageAllocator(2)
    f = alloc.alloc()
    alloc.decref(f)
    with pytest.raises(PageError, match="double free"):
        alloc.decref(f)
    with pytest.raises(PageError, match="unallocated"):
        alloc.incref(f)
    assert isinstance(PageError("x"), ValueError)  # callers catch ValueError
    with pytest.raises(PageError):
        PageAllocator(0)


def test_allocator_reuses_lowest_frame_deterministically():
    alloc = PageAllocator(4)
    frames = [alloc.alloc() for _ in range(4)]
    alloc.decref(frames[1])
    alloc.decref(frames[0])
    assert alloc.alloc() == frames[0]  # lowest free first, always
    assert alloc.alloc() == frames[1]


# =========================================================================
# chain keys / block table properties (S3)
# =========================================================================

tokens_strategy = st.lists(st.integers(0, 7), min_size=1, max_size=24)


@given(a=tokens_strategy, b=tokens_strategy,
       pt=st.integers(1, 5), m=st.integers(0, 24))
def test_chain_keys_share_exactly_the_common_prefix(a, b, pt, m):
    """Two prompts agreeing on their first m tokens share exactly their
    common full-block keys — the prefix-cache hit condition."""
    m = min(m, len(a), len(b))
    b = a[:m] + b[m:]
    ka = chain_keys(a, pt, len(a))
    kb = chain_keys(b, pt, len(b))
    shared_full = m // pt
    for i in range(min(shared_full, len(ka), len(kb))):
        if ka[i][0] == "full" and kb[i][0] == "full":
            assert ka[i] == kb[i]
    if a == b:
        assert ka == kb
    # a full and a partial block never collide, whatever the hashes do
    assert all(k[0] in ("full", "partial") for k in ka)


@given(data=st.data())
@settings(max_examples=25)
def test_blocktable_cow_never_aliases_a_written_page(data):
    """After ensure_writable, the returned frame has refcount 1 and is
    referenced by no other request — writes can never leak into a page a
    second request still reads."""
    pt = data.draw(st.integers(1, 4), label="page_tokens")
    alloc = PageAllocator(64)
    table = BlockTable(alloc, pt, prefix_cache=True)
    n_req = data.draw(st.integers(2, 4), label="n_req")
    base = data.draw(st.lists(st.integers(0, 3), min_size=pt,
                              max_size=4 * pt), label="base")
    for rid in range(n_req):
        # half the requests reuse the base prompt (forcing shared frames)
        toks = base if rid % 2 == 0 else data.draw(
            st.lists(st.integers(0, 3), min_size=1, max_size=4 * pt),
            label=f"toks{rid}")
        keys = chain_keys(toks, pt, len(toks))
        plan = table.plan_prompt(toks, len(toks))
        table.open(rid)
        for b, hit in enumerate(plan):
            if hit is not None:
                table.adopt_block(rid, hit)
            else:
                table.append_block(rid, key=keys[b])
        table.check_invariants()
    writes = data.draw(st.lists(st.integers(0, 10 ** 6), max_size=12),
                       label="writes")
    for pick in writes:
        rid = pick % n_req
        if not table.n_blocks(rid):
            continue
        frame, cow = table.ensure_writable(
            rid, (pick // n_req) % table.n_blocks(rid))
        assert alloc.refcount(frame) == 1
        for other in range(n_req):
            if other != rid:
                assert frame not in table.frames_of(other)
        table.check_invariants()
    for rid in range(n_req):
        table.release(rid)
    assert alloc.n_allocated == 0 and alloc.n_free == alloc.capacity


def test_blocktable_release_raises_on_double_free():
    alloc = PageAllocator(8)
    table = BlockTable(alloc, 2)
    table.open(7)
    table.append_block(7)
    table.release(7)
    assert alloc.n_allocated == 0
    with pytest.raises(PageError, match="double free"):
        table.release(7)


def test_blocktable_shared_partial_tail_forks_on_write():
    """Identical prompts share even the partial tail block; the first
    decode write forks it (cow) leaving the sharer's page untouched."""
    alloc = PageAllocator(8)
    table = BlockTable(alloc, 4, prefix_cache=True)
    toks = [1, 2, 3, 4, 5, 6]  # full(4) + partial(2)
    keys = chain_keys(toks, 4, len(toks))
    table.open(0)
    for b in range(len(keys)):
        table.append_block(0, key=keys[b])
    plan = table.plan_prompt(toks, len(toks))
    assert plan == table.frames_of(0)  # both blocks hit, partial included
    table.open(1)
    for hit in plan:
        table.adopt_block(1, hit)
    assert table.prefix_hits == 2
    shared_tail = table.frames_of(1)[-1]
    frame, cow = table.ensure_writable(1, 1)
    assert cow and frame != shared_tail
    assert table.frames_of(0)[-1] == shared_tail  # request 0 unaffected
    assert table.cow_copies == 1


# =========================================================================
# admission arithmetic (S3)
# =========================================================================

def test_admission_budget_is_dense_pages_at_20d_plus_1():
    """With one mask bit per element the wire cost is exactly the paper's
    (20*density + 1) bits/elem; the physical budget is num_pages dense
    pages of that storage."""
    elems = 320
    adm = AdmissionController(elems, page_mask_bits=elems, num_pages=4)
    assert adm.budget_bits == 4 * elems * 21
    for d in (0.0, 0.25, 0.5, 1.0):
        assert adm.page_bits(d) == pytest.approx(elems * (20 * d + 1))
    assert adm.admits(0.0, 4, 1.0)
    assert not adm.admits(0.0, 5, 1.0)
    # at half density the same budget admits ~2x the dense page count
    assert adm.admits(0.0, 7, 0.5)
    assert adm.admits_exact(0.0, adm.budget_bits)
    assert not adm.admits_exact(1.0, adm.budget_bits)
    assert adm.over_budget(adm.budget_bits + 1)
    assert adm.utilization(adm.budget_bits) == pytest.approx(1.0)


@given(live=st.floats(0, 1e9), n=st.integers(0, 64),
       d=st.floats(0.05, 1.0))
def test_admission_admit_implies_within_budget(live, n, d):
    adm = AdmissionController(256, page_mask_bits=256, num_pages=8)
    if adm.admits(live, n, d):
        assert adm.projected_bits(live, n, d) <= adm.budget_bits
        if n:  # admitting more pages at the same density must cost more
            assert (adm.projected_bits(live, n + 1, d)
                    > adm.projected_bits(live, n, d))


# =========================================================================
# slot ledger (S1 regression)
# =========================================================================

def test_slot_ledger_double_release_raises():
    led = SlotLedger(2)
    led.install(0)
    assert list(led.occupied) == [0]
    led.release(0)
    with pytest.raises(ValueError, match="double release"):
        led.release(0)
    with pytest.raises(ValueError, match="not installed"):
        led.release(1)
    led.install(0)
    with pytest.raises(ValueError, match="already installed"):
        led.install(0)
    with pytest.raises(ValueError, match="out of range"):
        led.install(2)
    with pytest.raises(ValueError):
        SlotLedger(0)


# =========================================================================
# engine parity — paged vs monolithic vs static reference
# =========================================================================

def _tokens(out) -> np.ndarray:
    return np.asarray(out["generated"])


@pytest.mark.parametrize("mode", ["dense", "quant", "quant_sparse"])
def test_paged_engine_matches_static_reference(mode):
    """serving.pages=true serves bit-identically to the static oracle in
    every numerics mode, even when 2 slots force mid-flight joins."""
    static = static_reference_session(
        ARCH, reduced=True, batch=BATCH, prompt_len=PROMPT, gen=GEN, mode=mode)
    paged = serve_session(
        ARCH, reduced=True, batch=BATCH, prompt_len=PROMPT, gen=GEN, mode=mode,
        slots=2, pages=True)
    np.testing.assert_array_equal(_tokens(paged), _tokens(static))
    assert paged["finite"]
    assert paged["paging"]["num_pages"] >= 1  # summary surfaced


@pytest.fixture(scope="module")
def small_model():
    arch = get_arch(ARCH)
    view = arch.view(reduced=True)
    step_cfg = StepConfig(spring=serving_config("quant_sparse"),
                          optimizer=OptimizerConfig())
    from repro.models.lm import lm_init

    params = lm_init(jax.random.PRNGKey(0), view.config)
    key = jax.random.PRNGKey(3)
    # ragged lengths (8..11): partial tail blocks exercise the COW fork
    prompts = [
        [int(t) for t in jax.random.randint(jax.random.fold_in(key, i),
                                            (PROMPT + i,), 0, view.config.vocab)]
        for i in range(4)
    ]
    return view, step_cfg, params, prompts


def _run_mono(small_model, prompts, gen, n_slots, **kw):
    view, step_cfg, params, _ = small_model
    eng = ServingEngine(view, step_cfg, params=params, n_slots=n_slots,
                        max_len=64, **kw)
    for i, p in enumerate(prompts):
        eng.submit_prompt(p, gen, seed=100 + i)
    out = eng.run()
    return [r["tokens"] for r in out["per_request"]], out, eng


def _run_paged(small_model, prompts, gen, n_slots, **kw):
    view, step_cfg, params, _ = small_model
    eng = PagedServingEngine(view, step_cfg, params=params, n_slots=n_slots,
                             max_len=64, **kw)
    for i, p in enumerate(prompts):
        eng.submit_prompt(p, gen, seed=100 + i)
    out = eng.run()
    return [r["tokens"] for r in out["per_request"]], out, eng


def test_cow_prefix_sharing_is_bit_identical(small_model):
    """>= 2 in-flight requests sharing a prompt prefix through COW pages
    emit exactly the monolithic pool's tokens, prefix cache on or off."""
    _, _, _, prompts = small_model
    p = prompts[3]  # 11 tokens: full pages + a partial tail at pt=4
    batch = [p, list(p), p[:8] + [5, 9], prompts[0]]
    mono, _, _ = _run_mono(small_model, batch, GEN, n_slots=4)
    on, out_on, eng_on = _run_paged(small_model, batch, GEN, n_slots=4,
                                    page_tokens=4, prefix_cache=True)
    off, _, _ = _run_paged(small_model, batch, GEN, n_slots=4,
                           page_tokens=4, prefix_cache=False)
    assert on == mono
    assert off == mono
    pg = out_on["paging"]
    assert pg["prefix_hits"] >= 3  # identical twin + shared 8-token prefix
    assert pg["cow_copies"] >= 1   # the twin forked its shared tail
    assert pg["prefix_cache"] is True
    assert eng_on.alloc.n_allocated == 0  # every page came back


def test_spill_resume_is_bit_identical(small_model):
    """Overcommitted admission spills the most recent resident to host
    and resumes it with its exact packed bits: tokens unchanged."""
    _, _, _, prompts = small_model
    mono, _, _ = _run_mono(small_model, prompts, GEN, n_slots=4)
    paged, out, eng = _run_paged(small_model, prompts, GEN, n_slots=4,
                                 page_tokens=4, num_pages=8, overcommit=2.0)
    assert paged == mono
    pg = out["paging"]
    assert pg["spills"] >= 1, "config did not exercise the spill path"
    assert pg["resumes"] == pg["spills"]  # everyone came back and finished
    assert eng.alloc.n_allocated == 0


def test_chunked_prefill_parity_greedy_and_sampled(small_model):
    """prefill_chunk=1 staggers prompt page installs across ticks while
    earlier residents keep decoding; tokens stay bit-identical, greedy
    and sampled."""
    _, _, _, prompts = small_model
    for greedy in (True, False):
        mono, _, _ = _run_mono(small_model, prompts, GEN, n_slots=2,
                               greedy=greedy)
        paged, out, _ = _run_paged(small_model, prompts, GEN, n_slots=2,
                                   greedy=greedy, page_tokens=4,
                                   prefill_chunk=1)
        assert paged == mono, f"greedy={greedy}"
        assert out["finite"]


def test_admission_never_exceeds_budget_after_spill(small_model):
    """Stepping manually: after every tick either live packed bits fit
    the physical budget or a single request remains (which the submit
    guard guarantees fits on its own)."""
    view, step_cfg, params, prompts = small_model
    eng = PagedServingEngine(view, step_cfg, params=params, n_slots=4,
                             max_len=64, page_tokens=4, num_pages=8,
                             overcommit=2.0)
    for i, p in enumerate(prompts):
        eng.submit_prompt(p, GEN, seed=100 + i)
    while eng.sched.has_work():
        eng.step()  # runs alloc/table check_invariants internally
        assert (not eng.admission.over_budget(eng._live_bits)
                or len(eng._resident_order) <= 1)
        assert eng.alloc.n_allocated <= eng.alloc.capacity
    out = eng.summary()
    assert out["paging"]["spills"] >= 1
    assert eng.alloc.n_allocated == 0


def test_engine_release_slot_double_release_raises(small_model):
    """S1 end-to-end: once the run drained, releasing any slot again is
    a loud ValueError on both pool backends."""
    prompts = small_model[3]
    _, mono_out, mono_eng = _run_mono(small_model, prompts[:1], 2, n_slots=2)
    with pytest.raises(ValueError, match="double release|not installed"):
        mono_eng.release_slot(0)
    _, out, eng = _run_paged(small_model, prompts[:1], 2, n_slots=2)
    with pytest.raises(ValueError, match="double release|not installed"):
        eng.release_slot(0)
    assert mono_out["finite"] and out["finite"]


def test_paged_gauges_and_summary(small_model):
    """The paging telemetry surface: spring_pages_* gauges inside an
    enabled scope plus the summary()['paging'] block."""
    from repro import telemetry
    from repro.telemetry import TelemetryConfig

    _, _, _, prompts = small_model
    with telemetry.scope(TelemetryConfig(enabled=True)):
        _, out, eng = _run_paged(small_model, prompts[:2], GEN, n_slots=2,
                                 page_tokens=4)
        m = telemetry.metrics()
        for g in ("spring_pages_allocated", "spring_pages_free",
                  "spring_pages_utilization", "spring_pages_shared",
                  "spring_pages_prefix_hits_total",
                  "spring_pages_cow_copies_total",
                  "spring_pages_spills_total"):
            assert m.get(g) is not None, g
    pg = out["paging"]
    for k in ("page_tokens", "num_pages", "logical_frames", "overcommit",
              "max_blocks", "peak_active", "prefix_hits", "cow_copies",
              "spills", "resumes", "budget_bits", "peak_page_utilization",
              "page_utilization"):
        assert k in pg, k
    assert pg["logical_frames"] >= pg["num_pages"]
    assert 0.0 <= pg["peak_page_utilization"] <= 1.0 + 1e-9
