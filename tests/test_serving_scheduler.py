"""Scheduler property tests (ISSUE 4, satellite 2): random arrival /
length streams driven through the pure-python SlotScheduler (no jax —
the same object the engine drives with real jitted steps).

Invariants: no slot leaks, FCFS admission order preserved (no
starvation), every request completes with exactly min(steps-to-eos,
max_tokens) tokens, total decode ticks >= the longest request.

Runs under real hypothesis when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""

import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.serving.request import Request
from repro.serving.scheduler import SlotScheduler

pytestmark = pytest.mark.serving


def _simulate(n_slots: int, specs: list) -> tuple[SlotScheduler, dict, int]:
    """Drive a full drain.  specs: per request (arrival_tick, max_tokens,
    eos_step | None).  The scripted model emits token ``eos_id`` when a
    request has already emitted ``eos_step`` tokens, else a counter."""
    eos_id = 10**9
    sched = SlotScheduler(n_slots)
    pending = sorted(range(len(specs)), key=lambda i: (specs[i][0], i))
    finished = {}
    tick = 0
    decode_ticks = 0
    submitted = 0
    while submitted < len(specs) or sched.has_work():
        for i in list(pending):
            if specs[i][0] <= tick:
                arrival, max_tokens, eos_step = specs[i]
                sched.submit(Request(rid=i, prompt=(1,), max_tokens=max_tokens,
                                     eos_id=eos_id))
                pending.remove(i)
                submitted += 1
        sched.admit()
        sched.check_invariants()
        if sched.active:
            token_by_slot = {}
            for slot, tracker in sched.active.items():
                eos_step = specs[tracker.req.rid][2]
                emit_eos = eos_step is not None and len(tracker.tokens) == eos_step
                token_by_slot[slot] = eos_id if emit_eos else len(tracker.tokens)
            for tracker in sched.record_tokens(token_by_slot):
                finished[tracker.req.rid] = tracker
            decode_ticks += 1
        sched.check_invariants()
        tick += 1
        assert tick < 10_000, "scheduler failed to drain"
    return sched, finished, decode_ticks


@given(
    st.integers(min_value=1, max_value=5),
    st.lists(st.integers(min_value=0, max_value=6), min_size=1, max_size=14),
    st.lists(st.integers(min_value=1, max_value=9), min_size=1, max_size=14),
    st.lists(st.integers(min_value=0, max_value=12), min_size=1, max_size=14),
)
@settings(deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.filter_too_much])
def test_random_streams_preserve_all_invariants(n_slots, arrivals, lengths, eos_raw):
    n = min(len(arrivals), len(lengths), len(eos_raw))
    specs = []
    for i in range(n):
        # eos beyond max_tokens (or the sentinel > 9) means "never"
        eos = eos_raw[i] if eos_raw[i] < lengths[i] else None
        specs.append((arrivals[i], lengths[i], eos))

    sched, finished, decode_ticks = _simulate(n_slots, specs)

    # no slot leaks: the drained pool is whole again
    assert sched.free_slots == n_slots and not sched.active and sched.pending == 0
    # no starvation: admissions happened in exact submission order
    assert sched.admission_log == sched._submit_log
    assert sorted(finished) == list(range(n))
    expected_tokens = []
    for i, (_, max_tokens, eos) in enumerate(specs):
        expect = max_tokens if eos is None else min(eos + 1, max_tokens)
        expected_tokens.append(expect)
        assert len(finished[i].tokens) == expect, (
            f"request {i}: {len(finished[i].tokens)} tokens != {expect}")
        assert finished[i].finished_by == (
            "eos" if eos is not None and eos + 1 <= max_tokens else "max_tokens")
    # the pool can't finish faster than its longest request decodes
    assert decode_ticks >= max(expected_tokens)
    # nor faster than the total work divided over the slots
    assert decode_ticks >= -(-sum(expected_tokens) // n_slots)


def test_admission_is_fcfs_across_retirements():
    """A freed slot must go to the *oldest* queued request, not the newest."""
    sched = SlotScheduler(1)
    for i in range(4):
        sched.submit(Request(rid=i, prompt=(1,), max_tokens=1))
    order = []
    while sched.has_work():
        for t in sched.admit():
            order.append(t.req.rid)
        for t in sched.record_tokens({s: 0 for s in sched.active}):
            pass
    assert order == [0, 1, 2, 3]


def test_slots_reused_lowest_first():
    sched = SlotScheduler(3)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=(1,), max_tokens=2))
    admitted = {t.req.rid: t.slot for t in sched.admit()}
    assert admitted == {0: 0, 1: 1, 2: 2}
    sched.retire(1)
    sched.submit(Request(rid=9, prompt=(1,), max_tokens=1))
    assert [t.slot for t in sched.admit()] == [1]


def test_tracker_rejects_tokens_after_finish():
    sched = SlotScheduler(1)
    sched.submit(Request(rid=0, prompt=(1,), max_tokens=1))
    (tracker,) = sched.admit()
    assert tracker.append(7) is True
    with pytest.raises(AssertionError):
        tracker.append(8)


def test_invalid_construction_rejected():
    with pytest.raises(ValueError):
        SlotScheduler(0)
    with pytest.raises(ValueError):
        Request(rid=0, prompt=(), max_tokens=1)
    with pytest.raises(ValueError):
        Request(rid=0, prompt=(1,), max_tokens=0)
