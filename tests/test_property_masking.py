"""Property-based tests (hypothesis; the deterministic fallback shim fills
in when the real package is absent) for the binary-mask machinery:
``core/masking.py`` collapse/expand and the ``mask_compress`` pack/unpack
ops — random shapes and densities, bit-exact roundtrips, and packed wire
bytes matching the perfmodel traffic formula ``bits/elem = 20*density + 1``
(ISSUE 3, satellite 2)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.masking import (
    MASK_WORD_BITS,
    collapse_to_front,
    expand_from_mask,
    mask_decode,
    mask_encode,
    pack_mask_bits,
    unpack_mask_bits,
)
from repro.kernels.mask_compress.ops import mask_pack, mask_unpack
from repro.memstash.format import (
    compress,
    decompress,
    formula_bits_per_elem,
    wire_bits,
)


# A fixed palette of lengths (aligned, unaligned, word-edge, large):
# hypothesis draws freely among them while keeping the jit-compilation
# count bounded on the 1-core CI container.
LENGTHS = [1, 3, 31, 32, 33, 64, 100, 257, 512, 1000, 1024, 1337, 2000]
WORD_COUNTS = [1, 2, 3, 7, 16, 31, 64]


def _vec(seed: int, n: int, density: float) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (n,))
    keep = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) < density
    return v * keep


@given(st.integers(0, 2**31 - 1), st.sampled_from(LENGTHS),
       st.floats(0.0, 1.0))
@settings(deadline=None)
def test_collapse_expand_roundtrip_bit_exact(seed, n, density):
    """collapse_to_front/expand_from_mask at full capacity is the identity
    for any length and density (Fig. 7(c) shifter, both directions)."""
    x = _vec(seed, n, density)
    bits = x != 0.0
    collapsed = collapse_to_front(x, bits, n)
    restored = expand_from_mask(collapsed, bits)
    np.testing.assert_array_equal(np.asarray(restored), np.asarray(x))
    # live values sit contiguously at the front, tail is zero
    nnz = int(bits.sum())
    assert not np.any(np.asarray(collapsed[nnz:]))


@given(st.integers(0, 2**31 - 1), st.sampled_from(LENGTHS),
       st.floats(0.0, 1.0))
@settings(deadline=None)
def test_mask_encode_decode_roundtrip(seed, n, density):
    x = _vec(seed, n, density)
    mv = mask_encode(x)
    np.testing.assert_array_equal(np.asarray(mask_decode(mv)), np.asarray(x))
    assert int(mv.nnz) == int(np.count_nonzero(np.asarray(x)))


@given(st.integers(0, 2**31 - 1), st.sampled_from(LENGTHS))
@settings(deadline=None)
def test_pack_unpack_mask_bits_roundtrip(seed, n):
    """pack_mask_bits/unpack_mask_bits roundtrip bit-exactly for any
    length, aligned or not."""
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, n, dtype=np.uint32).astype(bool))
    words = pack_mask_bits(bits)
    assert words.shape[0] == -(-n // MASK_WORD_BITS)
    np.testing.assert_array_equal(
        np.asarray(unpack_mask_bits(words, n)), np.asarray(bits))


@given(st.integers(0, 2**31 - 1), st.sampled_from(LENGTHS),
       st.floats(0.0, 1.0))
@settings(deadline=None)
def test_mask_compress_op_pack_unpack_roundtrip(seed, n, density):
    """The registry-dispatched mask_pack/mask_unpack ops roundtrip the
    occupancy pattern of any-shaped input (the packed words cover the
    kernel's lane padding; the first ceil(n/32) words carry the data)."""
    x = _vec(seed, n, density)
    words = mask_pack(x)
    got = mask_unpack(words, n)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(x) != 0.0)


@given(st.integers(0, 2**31 - 1), st.sampled_from(WORD_COUNTS),
       st.floats(0.0, 1.0))
@settings(deadline=None)
def test_packed_wire_bits_match_perfmodel_formula(seed, words, density):
    """For word-aligned lengths the measured stash wire bits are EXACTLY
    the perfmodel formula ``n * (20*density + 1)`` at the measured
    density — the single-sourced traffic accounting (paper Fig. 5)."""
    n = words * MASK_WORD_BITS
    x = _vec(seed, n, density)
    sv = compress(x)
    measured_density = int(sv.nnz) / n
    want_bits = n * formula_bits_per_elem(measured_density, 20)
    np.testing.assert_allclose(float(wire_bits(sv, 20)), want_bits, rtol=1e-12)
    np.testing.assert_array_equal(np.asarray(decompress(sv)), np.asarray(x))


@given(st.integers(0, 2**31 - 1), st.sampled_from(LENGTHS),
       st.floats(0.0, 1.0))
@settings(deadline=None)
def test_wire_bits_unaligned_within_one_word_of_formula(seed, n, density):
    """Unaligned lengths pay only the final word's padding: measured wire
    bits exceed the formula by the mask tail, strictly < 32 bits."""
    x = _vec(seed, n, density)
    sv = compress(x)
    formula = int(sv.nnz) * 20 + n  # value bits + 1 mask bit/elem
    pad = float(wire_bits(sv, 20)) - formula
    assert 0 <= pad < MASK_WORD_BITS
